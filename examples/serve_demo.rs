//! Serving demo for the v2 API: a long-lived engine that owns its backend,
//! a priority scheduler under contention, mixed per-request sampling
//! (greedy next to seeded temperature/top-k/top-p), and the step-driven
//! streaming event loop — tokens are printed as the engine emits them,
//! one request is cancelled mid-generation. Runs over a heterogeneous
//! child architecture with per-layer variable KV-head counts (paper §6).
//! Hermetic: pure-Rust reference backend with an in-memory manifest.
//!
//!   cargo run --release --example serve_demo

use anyhow::Result;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::config::TinyManifest;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{share, RefBackend};
use puzzle::serving::{EngineConfig, GenRequest, SamplingParams, SchedulerKind, StreamEvent};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;

fn main() -> Result<()> {
    let be = share(RefBackend::new(TinyManifest::synthetic()));
    let cfg = be.man().cfg.clone();

    // a child with per-layer variable KV-head counts — the exact case
    // TensorRT-LLM could not serve before the paper's §6 changes
    let mut rng = Rng::new(0);
    let mut store = init_parent(be.man(), &mut rng);
    let mut arch = Arch::parent(cfg.n_layers);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
    arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..cfg.n_layers {
        for (kind, variant) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant };
                bld::init_job_weights(be.man(), &mut store, &job, None)?;
            }
        }
    }

    // the engine owns its backend handle: it could move to a server thread
    let mut engine = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .scheduler(SchedulerKind::Priority)
        .build(be.clone(), &store, &arch)?;

    let world = World::new(3, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut rng = Rng::new(9);
    let n_requests = 24;
    let mut cancel_target = None;
    for i in 0..n_requests {
        let plen = rng.range(4, cfg.s_prefill.min(48));
        let prompt = sample_sequence(&world, &mix, plen, &mut rng);
        // mixed sampling in one batch: greedy, seeded temperature, and
        // temperature restricted by top-k + nucleus
        let sampling = match i % 3 {
            0 => SamplingParams::greedy(),
            1 => SamplingParams::temperature(0.8).with_seed(100 + i as u64),
            _ => SamplingParams::temperature(1.0).with_top_k(32).with_top_p(0.9).with_seed(i as u64),
        };
        let id = engine.submit(
            GenRequest::new(prompt, rng.range(8, 32))
                .with_priority((i % 4) as i32) // contention: priority beats arrival order
                .with_sampling(sampling),
        )?;
        if i == 5 {
            cancel_target = Some(id);
        }
    }
    println!(
        "submitted {n_requests} requests (queue {}, scheduler {})",
        engine.queue_len(),
        engine.scheduler_name()
    );

    // step-driven streaming: one batched decode step per iteration; print
    // the event stream for a few requests and cancel one mid-generation.
    let mut steps = 0usize;
    while !engine.is_idle() {
        for ev in engine.step()? {
            match ev {
                StreamEvent::Token { id, tok } if id <= 3 => println!("  step {steps:>3} | req {id}: token {tok}"),
                StreamEvent::Token { .. } => {}
                StreamEvent::Finished { id, reason } => {
                    println!("  step {steps:>3} | req {id}: finished ({})", reason.as_str())
                }
                StreamEvent::Rejected { id, cause } => {
                    println!("  step {steps:>3} | req {id}: rejected ({cause})")
                }
            }
        }
        if steps == 4 {
            if let Some(id) = cancel_target.take() {
                let hit = engine.cancel(id);
                println!("  step {steps:>3} | cancel({id}) -> {hit} (KV pages freed immediately)");
            }
        }
        steps += 1;
    }

    let responses = engine.take_finished();
    println!("completed {} (in {} steps)", responses.len(), steps);
    println!("{}", engine.metrics.summary());
    for r in responses.iter().take(3) {
        println!(
            "  req {}: {} tokens, finish {}, ttft {:.1} ms, e2e {:.1} ms",
            r.id,
            r.tokens.len(),
            r.finish.as_str(),
            r.ttft_secs * 1e3,
            r.e2e_secs * 1e3
        );
    }
    Ok(())
}
