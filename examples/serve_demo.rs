//! Serving demo: run the variable-GQA continuous-batching engine (paper
//! §6) over a heterogeneous child architecture with batched requests and
//! report latency/throughput.
//!
//!   make artifacts && cargo run --release --example serve_demo

use anyhow::Result;
use std::path::Path;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::Registry;
use puzzle::serving::Engine;
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;

fn main() -> Result<()> {
    let reg = Registry::open(Path::new("artifacts/tiny"))?;
    let cfg = &reg.man.cfg;

    // a child with per-layer variable KV-head counts — the exact case
    // TensorRT-LLM could not serve before the paper's §6 changes
    let mut rng = Rng::new(0);
    let mut store = init_parent(&reg.man, &mut rng);
    let mut arch = Arch::parent(cfg.n_layers);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
    arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..cfg.n_layers {
        for (kind, variant) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant };
                bld::init_job_weights(&reg.man, &mut store, &job, None)?;
            }
        }
    }

    let mut engine = Engine::new(&reg, &store, &arch, 32 << 20)?;
    let world = World::new(3, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut rng = Rng::new(9);
    let n_requests = 24;
    for _ in 0..n_requests {
        let plen = rng.range(4, cfg.s_prefill.min(48));
        let prompt = sample_sequence(&world, &mix, plen, &mut rng);
        engine.submit(prompt, rng.range(8, 32));
    }
    println!("submitted {n_requests} requests (queue {})", engine.queue_len());
    let responses = engine.run_to_completion()?;
    println!("completed {}", responses.len());
    println!("{}", engine.metrics.summary());
    for r in responses.iter().take(3) {
        println!(
            "  req {}: {} tokens, ttft {:.1} ms, e2e {:.1} ms",
            r.id,
            r.tokens.len(),
            r.ttft_secs * 1e3,
            r.e2e_secs * 1e3
        );
    }
    Ok(())
}
