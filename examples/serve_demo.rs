//! Serving demo: run the variable-GQA continuous-batching engine (paper
//! §6) over a heterogeneous child architecture with batched requests and
//! report latency/throughput. Hermetic: runs on the pure-Rust reference
//! backend with an in-memory manifest.
//!
//!   cargo run --release --example serve_demo

use anyhow::Result;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::config::TinyManifest;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{Backend, RefBackend};
use puzzle::serving::Engine;
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;

fn main() -> Result<()> {
    let be = RefBackend::new(TinyManifest::synthetic());
    let be: &dyn Backend = &be;
    let cfg = be.man().cfg.clone();

    // a child with per-layer variable KV-head counts — the exact case
    // TensorRT-LLM could not serve before the paper's §6 changes
    let mut rng = Rng::new(0);
    let mut store = init_parent(be.man(), &mut rng);
    let mut arch = Arch::parent(cfg.n_layers);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
    arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..cfg.n_layers {
        for (kind, variant) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant };
                bld::init_job_weights(be.man(), &mut store, &job, None)?;
            }
        }
    }

    let mut engine = Engine::new(be, &store, &arch, 32 << 20)?;
    let world = World::new(3, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut rng = Rng::new(9);
    let n_requests = 24;
    for _ in 0..n_requests {
        let plen = rng.range(4, cfg.s_prefill.min(48));
        let prompt = sample_sequence(&world, &mix, plen, &mut rng);
        engine.submit(prompt, rng.range(8, 32))?;
    }
    println!("submitted {n_requests} requests (queue {})", engine.queue_len());
    let responses = engine.run_to_completion()?;
    println!("completed {}", responses.len());
    println!("{}", engine.metrics.summary());
    for r in responses.iter().take(3) {
        println!(
            "  req {}: {} tokens, ttft {:.1} ms, e2e {:.1} ms",
            r.id,
            r.tokens.len(),
            r.ttft_secs * 1e3,
            r.e2e_secs * 1e3
        );
    }
    Ok(())
}
