//! Serving demo for the v2 API: a long-lived engine that owns its backend,
//! a priority scheduler under contention, mixed per-request sampling
//! (greedy next to seeded temperature/top-k/top-p), and the step-driven
//! streaming event loop — tokens are printed as the engine emits them,
//! one request is cancelled mid-generation. Runs over a heterogeneous
//! child architecture with per-layer variable KV-head counts (paper §6).
//! Hermetic: pure-Rust reference backend with an in-memory manifest.
//!
//!   cargo run --release --example serve_demo

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use puzzle::arch::{Arch, AttnChoice, FfnChoice};
use puzzle::bld;
use puzzle::config::TinyManifest;
use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
use puzzle::runtime::{share, RefBackend};
use puzzle::serving::{EngineConfig, GenRequest, SamplingParams, SchedulerKind, StreamEvent};
use puzzle::specdec::{expected_tokens_per_pass, SpecBatch, SpecConfig, SpecRequest, SpecSession};
use puzzle::util::{Json, Rng};
use puzzle::weights::store::init_parent;

fn main() -> Result<()> {
    let be = share(RefBackend::new(TinyManifest::synthetic()));
    let cfg = be.man().cfg.clone();

    // a child with per-layer variable KV-head counts — the exact case
    // TensorRT-LLM could not serve before the paper's §6 changes
    let mut rng = Rng::new(0);
    let mut store = init_parent(be.man(), &mut rng);
    let mut arch = Arch::parent(cfg.n_layers);
    arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
    arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    for l in 0..cfg.n_layers {
        for (kind, variant) in [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())] {
            if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                let job = bld::Job { layer: l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant };
                bld::init_job_weights(be.man(), &mut store, &job, None)?;
            }
        }
    }

    // the engine owns its backend handle: it could move to a server thread
    let mut engine = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .scheduler(SchedulerKind::Priority)
        .build(be.clone(), &store, &arch)?;

    let world = World::new(3, cfg.v as u32);
    let mix = CorpusMix::distillation_mix();
    let mut rng = Rng::new(9);
    let n_requests = 24;
    let mut cancel_target = None;
    for i in 0..n_requests {
        let plen = rng.range(4, cfg.s_prefill.min(48));
        let prompt = sample_sequence(&world, &mix, plen, &mut rng);
        // mixed sampling in one batch: greedy, seeded temperature, and
        // temperature restricted by top-k + nucleus
        let sampling = match i % 3 {
            0 => SamplingParams::greedy(),
            1 => SamplingParams::temperature(0.8).with_seed(100 + i as u64),
            _ => SamplingParams::temperature(1.0).with_top_k(32).with_top_p(0.9).with_seed(i as u64),
        };
        let id = engine.submit(
            GenRequest::new(prompt, rng.range(8, 32))
                .with_priority((i % 4) as i32) // contention: priority beats arrival order
                .with_sampling(sampling),
        )?;
        if i == 5 {
            cancel_target = Some(id);
        }
    }
    println!(
        "submitted {n_requests} requests (queue {}, scheduler {})",
        engine.queue_len(),
        engine.scheduler_name()
    );

    // step-driven streaming: one batched decode step per iteration; print
    // the event stream for a few requests and cancel one mid-generation.
    let mut steps = 0usize;
    while !engine.is_idle() {
        for ev in engine.step()? {
            match ev {
                StreamEvent::Token { id, tok } if id <= 3 => println!("  step {steps:>3} | req {id}: token {tok}"),
                StreamEvent::Token { .. } => {}
                StreamEvent::Finished { id, reason } => {
                    println!("  step {steps:>3} | req {id}: finished ({})", reason.as_str())
                }
                StreamEvent::Rejected { id, cause } => {
                    println!("  step {steps:>3} | req {id}: rejected ({cause})")
                }
            }
        }
        if steps == 4 {
            if let Some(id) = cancel_target.take() {
                let hit = engine.cancel(id);
                println!("  step {steps:>3} | cancel({id}) -> {hit} (KV pages freed immediately)");
            }
        }
        steps += 1;
    }

    let responses = engine.take_finished();
    println!("completed {} (in {} steps)", responses.len(), steps);
    println!("{}", engine.metrics.summary());
    for r in responses.iter().take(3) {
        println!(
            "  req {}: {} tokens, finish {}, ttft {:.1} ms, e2e {:.1} ms",
            r.id,
            r.tokens.len(),
            r.finish.as_str(),
            r.ttft_secs * 1e3,
            r.e2e_secs * 1e3
        );
    }

    // ---- speculative section: the Puzzle child drafts, the parent ----
    // ---- verifies (specdec subsystem; DESIGN.md §5)               ----
    let parent_arch = Arch::parent(cfg.n_layers);
    let draft_k = 4usize;
    let max_new = 16usize;
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    for i in 0..8usize {
        prompts.push(sample_sequence(&world, &mix, 4 + i, &mut rng));
    }

    // plain greedy parent decoding: the wall-clock baseline AND the
    // byte-equivalence oracle for greedy speculation
    let t_plain = Instant::now();
    let mut plain = EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &parent_arch)?;
    let mut ids = Vec::new();
    for p in &prompts {
        ids.push(plain.submit(GenRequest::new(p.clone(), max_new))?);
    }
    let plain_by_id: HashMap<u64, Vec<u32>> =
        plain.run_to_completion()?.into_iter().map(|r| (r.id, r.tokens)).collect();
    let plain_wall = t_plain.elapsed().as_secs_f64();
    let plain_tokens: usize = plain_by_id.values().map(Vec::len).sum();

    println!("\nspeculative decoding (draft_k {draft_k}, greedy):");
    let mut rows = Vec::new();
    let mut best_tpp = 0.0f64;
    let mut best_alpha = 0.0f64;
    let mut best_name = "";
    let (mut child_tpp, mut child_alpha) = (0.0f64, 0.0f64);
    // two drafters: the parent itself (structural α = 1 upper bound) and
    // the bld-initialized Puzzle child actually worth deploying
    for (name, drafter_arch) in [("parent_as_drafter", &parent_arch), ("puzzle_child", &arch)] {
        let mut sess = SpecSession::new(
            be.clone(),
            &store,
            &parent_arch,
            &store,
            drafter_arch,
            SpecConfig { draft_k, engine: EngineConfig::new().kv_budget_bytes(32 << 20), ..Default::default() },
        )?;
        let t_spec = Instant::now();
        let (mut tokens, mut passes, mut accepted, mut proposed, mut attempted) = (0, 0, 0, 0, 0);
        for (i, p) in prompts.iter().enumerate() {
            let r = sess.generate(p, max_new, SamplingParams::greedy())?;
            assert_eq!(
                r.tokens, plain_by_id[&ids[i]],
                "greedy speculative output must be byte-identical to plain parent decoding"
            );
            tokens += r.tokens.len();
            passes += r.parent_passes;
            accepted += r.accepted;
            proposed += r.proposed;
            attempted += r.attempted;
        }
        let spec_wall = t_spec.elapsed().as_secs_f64();
        let alpha = if attempted == 0 { 0.0 } else { accepted as f64 / attempted as f64 };
        let tpp = tokens as f64 / passes.max(1) as f64;
        let model_tpp = expected_tokens_per_pass(alpha, draft_k);
        println!(
            "  {name:<18} {tokens} tokens / {passes} parent passes = {tpp:.2} tok/pass | accepted/proposed {accepted}/{proposed} (α̂ {:.0}%) | model {model_tpp:.2} tok/verify-pass | wall {:.1} ms (plain batched {:.1} ms)",
            alpha * 100.0,
            spec_wall * 1e3,
            plain_wall * 1e3
        );
        if tpp > best_tpp {
            best_tpp = tpp;
            best_alpha = alpha;
            best_name = name;
        }
        if name == "puzzle_child" {
            child_tpp = tpp;
            child_alpha = alpha;
        }
        rows.push(Json::from_pairs(vec![
            ("drafter", Json::str(name)),
            ("tokens", Json::num(tokens as f64)),
            ("parent_passes", Json::num(passes as f64)),
            ("tokens_per_pass", Json::num(tpp)),
            ("acceptance_rate", Json::num(alpha)),
            ("accepted", Json::num(accepted as f64)),
            ("proposed", Json::num(proposed as f64)),
            ("model_tokens_per_pass", Json::num(model_tpp)),
            ("spec_wall_s", Json::num(spec_wall)),
        ]));
    }
    println!("  all speculative outputs byte-identical to plain greedy decoding ✓");

    // ---- batched speculation: N=4 sequences sharing the engines' ----
    // ---- decode lanes, fused multi-token verify (DESIGN.md §6)   ----
    let batch_n = 4usize;
    let batch_prompts: Vec<Vec<u32>> = prompts.iter().take(batch_n).cloned().collect();
    let batch_oracle: Vec<Vec<u32>> =
        (0..batch_n).map(|i| plain_by_id[&ids[i]].clone()).collect();
    let spec_cfg = || SpecConfig {
        draft_k,
        engine: EngineConfig::new().kv_budget_bytes(32 << 20),
        ..Default::default()
    };

    // baseline: the same 4 requests one after another through the
    // single-sequence session (one lane busy, the rest parked)
    let mut seq_sess =
        SpecSession::new(be.clone(), &store, &parent_arch, &store, &arch, spec_cfg())?;
    let t_seq = Instant::now();
    let mut seq_tokens = 0usize;
    for (p, want) in batch_prompts.iter().zip(&batch_oracle) {
        let r = seq_sess.generate(p, max_new, SamplingParams::greedy())?;
        assert_eq!(&r.tokens, want, "sequential speculative run must stay byte-identical");
        seq_tokens += r.tokens.len();
    }
    let seq_wall = t_seq.elapsed().as_secs_f64();

    // batched: all 4 at once, lanes backfilled as sequences finish
    let mut batch =
        SpecBatch::new(be.clone(), &store, &parent_arch, &store, &arch, spec_cfg())?;
    let reqs: Vec<SpecRequest> =
        batch_prompts.iter().map(|p| SpecRequest::new(p.clone(), max_new)).collect();
    let t_batch = Instant::now();
    let rs = batch.generate_many(&reqs)?;
    let batch_wall = t_batch.elapsed().as_secs_f64();
    let (mut b_tokens, mut b_passes) = (0usize, 0usize);
    for (r, want) in rs.iter().zip(&batch_oracle) {
        assert_eq!(&r.tokens, want, "batched speculative run must stay byte-identical");
        b_tokens += r.tokens.len();
        b_passes += r.parent_passes;
    }
    assert_eq!(batch.kv_allocated_bytes(), (0, 0), "batched run must hand every page back");
    let batched_tpp = b_tokens as f64 / b_passes.max(1) as f64;
    println!(
        "batched speculation: N={batch_n} over {} lanes | {b_tokens} tokens = {batched_tpp:.2} tok/parent-pass | wall {:.1} ms vs {:.1} ms sequential ({:.2}x) | fused verify passes {}",
        batch.lane_capacity(),
        batch_wall * 1e3,
        seq_wall * 1e3,
        seq_wall / batch_wall.max(1e-12),
        batch.parent_metrics().spec_fused_passes
    );
    assert_eq!(seq_tokens, b_tokens);

    // headline = best drafter (labeled); the deployable Puzzle child's own
    // numbers are first-class fields so a child regression is visible
    // without digging into the drafters array
    let j = Json::from_pairs(vec![
        ("draft_k", Json::num(draft_k as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("requests", Json::num(prompts.len() as f64)),
        ("tokens_per_pass", Json::num(best_tpp)),
        ("headline_drafter", Json::str(best_name)),
        ("acceptance_rate", Json::num(best_alpha)),
        ("child_tokens_per_pass", Json::num(child_tpp)),
        ("child_acceptance_rate", Json::num(child_alpha)),
        ("plain_wall_s", Json::num(plain_wall)),
        ("plain_tokens", Json::num(plain_tokens as f64)),
        ("greedy_equivalent", Json::Bool(true)),
        ("batched_n", Json::num(batch_n as f64)),
        ("batched_lanes", Json::num(batch.lane_capacity() as f64)),
        ("batched_tokens_per_pass", Json::num(batched_tpp)),
        ("batched_wall_s", Json::num(batch_wall)),
        ("sequential_wall_s", Json::num(seq_wall)),
        ("batched_speedup_vs_sequential", Json::num(seq_wall / batch_wall.max(1e-12))),
        ("batched_fused_passes", Json::num(batch.parent_metrics().spec_fused_passes as f64)),
        ("batched_greedy_equivalent", Json::Bool(true)),
        ("drafters", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_specdec.json", j.to_pretty())?;
    println!(
        "speculative perf -> BENCH_specdec.json (best {best_tpp:.2} tok/parent-pass [{best_name}], puzzle child {child_tpp:.2} at α̂ {:.0}%, batched N={batch_n} {batched_tpp:.2} tok/pass)",
        child_alpha * 100.0
    );

    // ---- prefix cache: a fleet of requests sharing one system ----
    // ---- prompt prefills it once (serving/prefixcache.rs)       ----
    let sys = sample_sequence(&world, &mix, 23, &mut rng); // 24-token system prompt
    let n_shared = 8usize;
    let shared_prompts: Vec<Vec<u32>> = (0..n_shared)
        .map(|_| {
            let mut p = sys.clone();
            p.extend(sample_sequence(&world, &mix, 3, &mut rng));
            p
        })
        .collect();
    let shared_max_new = 4usize;
    // requests run one at a time so each TTFT isolates its own prefill
    let serve_all = |eng: &mut puzzle::serving::Engine| -> Result<(Vec<Vec<u32>>, Vec<f64>)> {
        let mut tokens = Vec::new();
        let mut ttfts = Vec::new();
        for p in &shared_prompts {
            eng.submit(GenRequest::new(p.clone(), shared_max_new))?;
            let r = eng.run_to_completion()?.pop().expect("one response per request");
            tokens.push(r.tokens);
            ttfts.push(r.ttft_secs);
        }
        Ok((tokens, ttfts))
    };

    let mut cold_eng =
        EngineConfig::new().kv_budget_bytes(32 << 20).page_len(8).build(be.clone(), &store, &arch)?;
    let (cold_tokens, cold_ttfts) = serve_all(&mut cold_eng)?;
    let mut warm_eng = EngineConfig::new()
        .kv_budget_bytes(32 << 20)
        .page_len(8)
        .prefix_cache(true, 8 << 20)
        .build(be.clone(), &store, &arch)?;
    let (warm_tokens, warm_ttfts) = serve_all(&mut warm_eng)?;
    assert_eq!(
        warm_tokens, cold_tokens,
        "cache-hit generations must be byte-identical to cold-miss generations"
    );
    let m = &warm_eng.metrics;
    assert!(m.prefix_hits > 0, "the shared system prompt must produce hits");
    // request 0 is the cold miss that retains; every later TTFT rides it
    let ttft_miss = warm_ttfts[0];
    let ttft_hit = warm_ttfts[1..].iter().sum::<f64>() / (warm_ttfts.len() - 1) as f64;
    let ttft_cold_mean = cold_ttfts.iter().sum::<f64>() / cold_ttfts.len() as f64;
    println!(
        "\nprefix cache: {n_shared} requests sharing a {}-token system prompt | hit rate {:.0}% | {} prefill tokens saved | ttft hit {:.2} ms vs miss {:.2} ms (uncached mean {:.2} ms) | {} segments holding {} KiB | outputs byte-identical ✓",
        sys.len(),
        m.prefix_hit_rate() * 100.0,
        m.prefix_tokens_saved,
        ttft_hit * 1e3,
        ttft_miss * 1e3,
        ttft_cold_mean * 1e3,
        warm_eng.prefix_segments(),
        warm_eng.prefix_retained_bytes() / 1024
    );
    let j = Json::from_pairs(vec![
        ("requests", Json::num(n_shared as f64)),
        ("system_prompt_tokens", Json::num(sys.len() as f64)),
        ("hits", Json::num(m.prefix_hits as f64)),
        ("misses", Json::num(m.prefix_misses as f64)),
        ("hit_rate", Json::num(m.prefix_hit_rate())),
        ("prefill_tokens_saved", Json::num(m.prefix_tokens_saved as f64)),
        ("ttft_hit_ms", Json::num(ttft_hit * 1e3)),
        ("ttft_miss_ms", Json::num(ttft_miss * 1e3)),
        ("ttft_uncached_mean_ms", Json::num(ttft_cold_mean * 1e3)),
        ("retained_segments", Json::num(warm_eng.prefix_segments() as f64)),
        ("retained_bytes", Json::num(warm_eng.prefix_retained_bytes() as f64)),
        ("byte_identical", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_prefixcache.json", j.to_pretty())?;
    println!("prefix-cache perf -> BENCH_prefixcache.json");
    Ok(())
}
