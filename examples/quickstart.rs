//! Quickstart: open a runtime backend (hermetic pure-Rust reference by
//! default — no artifacts needed), assemble a heterogeneous Puzzle child
//! out of "puzzle pieces", run a forward pass, and compare its cost
//! profile against the parent.
//!
//!   cargo run --release --example quickstart

use anyhow::Result;

use puzzle::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use puzzle::bld;
use puzzle::config::TinyManifest;
use puzzle::data::{Batcher, CorpusMix, World};
use puzzle::model::CompiledModel;
use puzzle::perf::{HwProfile, Scenario};
use puzzle::runtime::{share, RefBackend};
use puzzle::serving::{EngineConfig, GenRequest};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;

fn main() -> Result<()> {
    // 1. open the execution backend (in-memory manifest + rust interpreter);
    // the shared handle is what long-lived components (engines) hold
    let be = share(RefBackend::new(TinyManifest::synthetic()));
    let cfg = be.man().cfg.clone();
    println!("model: d={} layers={} heads={} vocab={}", cfg.d, cfg.n_layers, cfg.n_heads, cfg.v);

    // 2. the search space (paper §2): 54^L candidate architectures
    let space = SearchSpace::full(cfg.n_heads as u32);
    println!(
        "search space: {} combos/layer, 10^{:.1} architectures",
        space.per_layer_combinations(),
        space.log10_size(cfg.n_layers)
    );

    // 3. initialize a parent and derive child blocks via §3.2 inits
    let mut rng = Rng::new(0);
    let mut store = init_parent(be.man(), &mut rng);
    for (kind, variant) in [("attn", "gqa_r2"), ("ffn", "r50")] {
        let job = bld::Job { layer: 1, kind: if kind == "attn" { "attn" } else { "ffn" }, variant: variant.into() };
        bld::init_job_weights(be.man(), &mut store, &job, None)?;
    }

    // 4. assemble a heterogeneous child: layer 1 slimmed, last layer skipped
    let mut arch = Arch::parent(cfg.n_layers);
    arch.layers[1] = (AttnChoice::Gqa { divisor: 2 }, FfnChoice::Ratio(3));
    arch.layers[cfg.n_layers - 1] = (AttnChoice::NoOp, FfnChoice::NoOp);
    let child = CompiledModel::assemble(be.man(), &store, &arch)?;
    println!("child arch: {}", arch.signature());

    // 5. run a forward pass through the chained block executables
    let world = World::new(7, cfg.v as u32);
    let mut batcher = Batcher::new(world, CorpusMix::distillation_mix(), cfg.b_train, cfg.s_train, 1);
    let batch = batcher.next_batch();
    let trace = child.forward(&*be, "train", &batch.inputs, batch.b, batch.s)?;
    println!("logits shape: {:?} (finite: {})",
        trace.logits.shape,
        trace.logits.data.iter().all(|x| x.is_finite())
    );

    // 6. modeled H100 cost comparison
    let hw = HwProfile::h100_fp8();
    let sc = Scenario { prefill: 128, decode: 128, batch: 64 };
    let parent = Arch::parent(cfg.n_layers);
    let tp_parent = puzzle::perf::scenario_throughput(be.man(), &parent, &hw, &sc);
    let tp_child = puzzle::perf::scenario_throughput(be.man(), &arch, &hw, &sc);
    println!(
        "modeled H100 throughput: parent {:.0} tok/s, child {:.0} tok/s ({:.2}x)",
        tp_parent,
        tp_child,
        tp_child / tp_parent
    );

    // 7. serve one prompt through the v2 engine (owned backend, greedy)
    let mut eng = EngineConfig::new().build(be.clone(), &store, &arch)?;
    eng.submit(GenRequest::new(vec![1, 5, 9, 7], 8))?;
    let resp = eng.run_to_completion()?.remove(0);
    println!(
        "served 1 request: {} tokens generated, finish {}, ttft {:.2} ms",
        resp.tokens.len(),
        resp.finish.as_str(),
        resp.ttft_secs * 1e3
    );
    Ok(())
}
