//! "Train once, adapt on demand" (paper §4.3): reuse one block library and
//! one score table to generate architectures for *different hardware
//! targets* — H100 FP8, H100 FP16, A100, RTX 4090 — and show how the MIP
//! adapts the chosen blocks to each platform's roofline. Hermetic: runs on
//! the pure-Rust reference backend.
//!
//!   cargo run --release --example hardware_sweep

use anyhow::Result;
use std::path::PathBuf;

use puzzle::arch::{Arch, AttnChoice, SearchSpace};
use puzzle::config::TinyManifest;
use puzzle::mip::{self, Constraints};
use puzzle::perf::{CostTable, HwProfile, Scenario};
use puzzle::pipeline::{Pipeline, StageCfg};
use puzzle::runtime::{share, RefBackend};
use puzzle::scoring::Metric;

fn main() -> Result<()> {
    puzzle::util::log::init();
    let be = share(RefBackend::new(TinyManifest::synthetic()));
    let cfg = be.man().cfg.clone();
    let pipe = Pipeline::new(be.clone(), &PathBuf::from("runs/ref-tiny"), StageCfg::fast())?;
    let space = SearchSpace::full(cfg.n_heads as u32);
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let n_layers = cfg.n_layers;
    let sc = Scenario { prefill: cfg.s_prefill, decode: cfg.s_prefill, batch: 64 };

    println!("{:<14} {:>9} {:>10} {:>9}  arch sketch (kv heads per layer)", "hardware", "tok/s", "params", "KL cost");
    for hw in [
        HwProfile::h100_fp8(),
        HwProfile::h100_fp16(),
        HwProfile::a100_fp16(),
        HwProfile::rtx4090_fp16(),
    ] {
        let ct = CostTable::modeled(be.man(), &hw, &sc);
        let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
        let cons = Constraints {
            throughput_min: Some(parent_tp * 1.8),
            // consumer GPU: memory-constrained too
            memory_max_bytes: if hw.name.contains("4090") { Some(hw.vram * 0.5) } else { None },
            ..Default::default()
        };
        let sol = mip::search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0)?;
        let sketch: String = sol
            .arch
            .layers
            .iter()
            .map(|(a, _)| match a {
                AttnChoice::Gqa { divisor } => format!("{}", cfg.n_heads / *divisor as usize),
                AttnChoice::Linear => "L".into(),
                AttnChoice::NoOp => "-".into(),
            })
            .collect::<Vec<_>>()
            .join(",");
        println!(
            "{:<14} {:>9.0} {:>9.2}M {:>9.4}  [{}]",
            hw.name,
            sol.throughput,
            sol.params / 1e6,
            sol.cost,
            sketch
        );
    }
    println!("(differences across rows = hardware-aware adaptation with zero retraining)");
    Ok(())
}
