//! Workload-harness demo (DESIGN.md §9): generate a seeded mixed trace
//! (chat, shared-system-prompt, multi-turn, speculative, long-context
//! conversations over Poisson arrivals), replay it closed-loop against
//! three serving configurations — plain engine, prefix-cache engine,
//! speculative drafter/verifier — and score goodput under the lenient
//! and strict (TTFT, ITL) SLO profiles. Every latency is a virtual tick
//! count, so the whole table is deterministic; only the tok/s column is
//! wall clock. Hermetic: pure-Rust reference backend.
//!
//!   cargo run --release --example workload_replay

use anyhow::Result;

use puzzle::arch::Arch;
use puzzle::config::TinyManifest;
use puzzle::runtime::{share, RefBackend};
use puzzle::serving::EngineConfig;
use puzzle::specdec::{SpecBatch, SpecConfig};
use puzzle::util::Rng;
use puzzle::weights::store::init_parent;
use puzzle::workload::{default_profiles, goodput, replay, MixKind, Server, TraceSpec};

fn main() -> Result<()> {
    let be = share(RefBackend::new(TinyManifest::synthetic()));
    let cfg = be.man().cfg.clone();
    let mut rng = Rng::new(0);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);

    let trace = TraceSpec::small(MixKind::Mixed, 7).generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    println!(
        "trace '{}': {} conversations, {} requests, Poisson arrivals\n",
        trace.name,
        trace.convs.len(),
        trace.requests()
    );

    let engine_cfg = |prefix: bool| {
        EngineConfig::new().kv_budget_bytes(16 << 20).page_len(4).prefix_cache(prefix, 8 << 20)
    };
    let mut runs = Vec::new();
    {
        let mut eng = engine_cfg(false).build(be.clone(), &store, &arch)?;
        runs.push(replay(&trace, &mut Server::Engine(&mut eng), "plain")?);
    }
    {
        let mut eng = engine_cfg(true).build(be.clone(), &store, &arch)?;
        runs.push(replay(&trace, &mut Server::Engine(&mut eng), "prefix_cache")?);
    }
    {
        let scfg = SpecConfig { draft_k: 3, adapt_k_max: None, engine: engine_cfg(true) };
        let mut batch = SpecBatch::new(be.clone(), &store, &arch, &store, &arch, scfg)?;
        runs.push(replay(&trace, &mut Server::Spec(&mut batch), "speculative")?);
    }

    let slos = default_profiles();
    println!(
        "{:<14} {:>6} {:>9} {:>12} {:>10} {:>10} {:>10}",
        "config", "ticks", "completed", "tok/forward", "gen-hits", "lenient", "strict"
    );
    for run in &runs {
        let m = &run.metrics;
        let g: Vec<f64> = slos.iter().map(|s| goodput(run, s).1).collect();
        println!(
            "{:<14} {:>6} {:>9} {:>12.2} {:>10} {:>9.0}% {:>9.0}%",
            run.config,
            run.ticks,
            run.completed(),
            run.tok_per_forward(),
            m.prefix_gen_hits,
            g[0] * 100.0,
            g[1] * 100.0
        );
        assert!(g[1] <= g[0] + 1e-12, "strict goodput can never beat lenient");
    }
    let warm = &runs[1];
    assert!(
        warm.metrics.prefix_hits > 0,
        "shared-prefix and multi-turn conversations must hit the cache"
    );
    println!("\nper-config summaries:");
    for run in &runs {
        println!("[{}] {}", run.config, run.metrics.summary());
    }
    println!("\n(one `bench-workload` CLI run writes this table to BENCH_workloads.json)");
    Ok(())
}
