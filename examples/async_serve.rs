//! Async serving front-end demo: one worker thread owns the engine,
//! eight concurrent client threads stream completions through cloned
//! `ServerHandle`s, and one extra client cancels its request
//! mid-generation. The engine runs SLO-aware chunked prefill
//! (`prefill_budget`), and every streamed completion is asserted
//! byte-identical to a synchronous engine WITHOUT chunking — greedy and
//! seeded-stochastic sampling alike — over a heterogeneous child
//! architecture with per-layer variable KV-head counts (paper §6).
//! Hermetic: pure-Rust reference backend, in-memory manifest.
//!
//!   cargo run --release --example async_serve

#[cfg(not(feature = "pjrt"))]
fn main() -> anyhow::Result<()> {
    demo::run()
}

#[cfg(feature = "pjrt")]
fn main() {
    println!("async_serve needs the threaded front-end of the default backend build (the PJRT engine is not Send); rebuild without --features pjrt");
}

#[cfg(not(feature = "pjrt"))]
mod demo {
    use anyhow::Result;

    use puzzle::arch::{Arch, AttnChoice, FfnChoice};
    use puzzle::bld;
    use puzzle::config::TinyManifest;
    use puzzle::data::{corpus::sample_sequence, CorpusMix, World};
    use puzzle::runtime::{share, RefBackend};
    use puzzle::server::{AsyncServer, StreamItem};
    use puzzle::serving::{EngineConfig, FinishReason, GenRequest, SamplingParams};
    use puzzle::util::Rng;
    use puzzle::weights::store::init_parent;

    pub fn run() -> Result<()> {
        let be = share(RefBackend::new(TinyManifest::synthetic()));
        let cfg = be.man().cfg.clone();

        // a child with per-layer variable KV-head counts — the serving
        // case the paper's §6 contributes
        let mut rng = Rng::new(0);
        let mut store = init_parent(be.man(), &mut rng);
        let mut arch = Arch::parent(cfg.n_layers);
        arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
        arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
        arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
        for l in 0..cfg.n_layers {
            for (kind, variant) in
                [("attn", arch.layers[l].0.name()), ("ffn", arch.layers[l].1.name())]
            {
                if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                    let job = bld::Job { layer: l, kind, variant };
                    bld::init_job_weights(be.man(), &mut store, &job, None)?;
                }
            }
        }

        // one deterministic request set, replayed through both engines;
        // mixed sampling so byte identity covers greedy AND seeded
        // stochastic streams
        let world = World::new(3, cfg.v as u32);
        let mix = CorpusMix::distillation_mix();
        let mut rng = Rng::new(9);
        let n_requests = 16usize;
        let clients = 8usize;
        let reqs: Vec<GenRequest> = (0..n_requests)
            .map(|i| {
                let plen = rng.range(4, cfg.s_prefill.min(32));
                let prompt = sample_sequence(&world, &mix, plen, &mut rng);
                let sampling = if i % 2 == 0 {
                    SamplingParams::greedy()
                } else {
                    SamplingParams::temperature(0.8).with_seed(100 + i as u64)
                };
                GenRequest::new(prompt, 8 + (i % 3) * 8).with_sampling(sampling)
            })
            .collect();

        // oracle: the same requests through a synchronous engine with NO
        // prefill budget (whole-prompt inline prefills)
        let mut sync_eng =
            EngineConfig::new().kv_budget_bytes(32 << 20).build(be.clone(), &store, &arch)?;
        let mut ids = Vec::new();
        for r in &reqs {
            ids.push(sync_eng.submit(r.clone())?);
        }
        let by_id: std::collections::HashMap<u64, Vec<u32>> =
            sync_eng.run_to_completion()?.into_iter().map(|r| (r.id, r.tokens)).collect();
        let oracle: Vec<Vec<u32>> = ids.iter().map(|id| by_id[id].clone()).collect();

        // async: chunked prefill (12 tokens/step), eight client threads
        let eng = EngineConfig::new()
            .kv_budget_bytes(32 << 20)
            .prefill_budget(12)
            .build(be.clone(), &store, &arch)?;
        let server = AsyncServer::spawn(eng);
        let mut got: Vec<(usize, Vec<u32>)> = Vec::new();
        let mut cancelled_tokens = 0usize;
        std::thread::scope(|s| -> Result<()> {
            let mut joins = Vec::new();
            for ci in 0..clients {
                let h = server.handle();
                let lot: Vec<(usize, GenRequest)> = reqs
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % clients == ci)
                    .map(|(i, r)| (i, r.clone()))
                    .collect();
                joins.push(s.spawn(move || -> Result<Vec<(usize, Vec<u32>)>> {
                    let mut out = Vec::new();
                    for (i, req) in lot {
                        let stream = h.submit(req)?;
                        let (tokens, finish) = stream.collect();
                        anyhow::ensure!(finish.is_some(), "server died mid-request {i}");
                        out.push((i, tokens));
                    }
                    Ok(out)
                }));
            }
            // a ninth client: cancel mid-generation, concurrently with the
            // byte-identity fleet — per-lane isolation means the other
            // streams must not change
            let hc = server.handle();
            let canceller = s.spawn(move || -> Result<usize> {
                let prompt = vec![3u32; 12];
                let stream = hc.submit(GenRequest::new(prompt, 64))?;
                let first = stream.recv();
                anyhow::ensure!(
                    matches!(first, Some(StreamItem::Token(_))),
                    "expected a first token before cancelling, got {first:?}"
                );
                stream.cancel();
                let (tokens, finish) = stream.collect();
                anyhow::ensure!(
                    finish == Some(FinishReason::Cancelled),
                    "cancelled stream must finish with Cancelled, got {finish:?}"
                );
                Ok(1 + tokens.len())
            });
            for j in joins {
                got.extend(j.join().expect("client thread panicked")?);
            }
            cancelled_tokens = canceller.join().expect("cancel thread panicked")?;
            Ok(())
        })?;
        got.sort_by_key(|(i, _)| *i);
        for (i, tokens) in &got {
            assert_eq!(
                tokens, &oracle[*i],
                "async chunked-prefill stream {i} must be byte-identical to the sync engine"
            );
        }
        println!(
            "served {n_requests} requests from {clients} concurrent clients — all byte-identical to the unchunked sync engine ✓"
        );
        println!("cancelled client got {cancelled_tokens} tokens, then Finished(Cancelled) ✓");

        // the worker is idle now: no live sequences, no queued work, and
        // every KV page handed back (the cancel freed its pages too)
        let stats = server.handle().stats()?;
        assert_eq!((stats.active, stats.queued), (0, 0), "server must drain to idle");
        assert_eq!(stats.kv_allocated_bytes, 0, "all KV pages must be back in the pool");
        let eng = server.shutdown();
        assert!(
            eng.metrics.prefill_chunk_passes > 0,
            "budgeted prefill must have run chunk passes"
        );
        println!("server stats at idle: {stats:?}");
        println!("{}", eng.metrics.summary());
        Ok(())
    }
}
