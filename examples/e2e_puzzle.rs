//! END-TO-END DRIVER (the repo's required full-system validation):
//! pretrains a real parent transformer on the synthetic corpus (logging
//! the loss curve), then runs the complete Puzzle pipeline — BLD block
//! library, replace-1-block KL scoring, MIP architecture search under a
//! throughput constraint, GKD uptraining — and finally serves batched
//! requests through both parent and child, reporting accuracy retention
//! and the measured + modeled speedups. Results are recorded in
//! EXPERIMENTS.md. Hermetic: runs on the pure-Rust reference backend with
//! an in-memory manifest (no artifacts, no python).
//!
//!   cargo run --release --example e2e_puzzle [-- --config tiny --scale 1.0]

use anyhow::{anyhow, Result};
use std::path::PathBuf;

use puzzle::arch::{Arch, SearchSpace};
use puzzle::config::TinyManifest;
use puzzle::data::corpus::sample_sequence;
use puzzle::eval::Evaluator;
use puzzle::perf::{self, HwProfile, Scenario};
use puzzle::pipeline::{Pipeline, StageCfg};
use puzzle::runtime::{share, RefBackend};
use puzzle::scoring::Metric;
use puzzle::serving::{EngineConfig, GenRequest};
use puzzle::train::LossSpec;
use puzzle::util::{Args, Rng, Timer};

fn main() -> Result<()> {
    puzzle::util::log::init();
    let args = Args::from_env();
    let config = args.str("config", "tiny");
    let man = match config.as_str() {
        "tiny" => TinyManifest::synthetic(),
        "small" => TinyManifest::synthetic_small(),
        other => return Err(anyhow!("unknown synthetic config '{other}' (tiny|small)")),
    };
    let be = share(RefBackend::new(man));
    let cfg = be.man().cfg.clone();
    let mut stage = StageCfg::scaled(args.f64("scale", 1.0));
    stage.seed = args.u64("seed", 42);
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/e2e_{config}")));
    let pipe = Pipeline::new(be.clone(), &run_dir, stage)?;
    let t_total = Timer::start();

    println!("=== Puzzle end-to-end ({config}: {} layers, d={}, v={}) ===", cfg.n_layers, cfg.d, cfg.v);
    let space = SearchSpace::full(cfg.n_heads as u32);
    println!(
        "search space: {} combos/layer -> 10^{:.1} architectures",
        space.per_layer_combinations(),
        space.log10_size(cfg.n_layers)
    );

    // Stage 0+1: parent pretraining + BLD library (loss curve -> run dir)
    let library = pipe.ensure_library(&space)?;
    // Stage 2: scoring + MIP
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let ct = pipe.default_cost_table();
    let sol = pipe.search_speedup(&space, &scores, &ct, args.f64("speedup", 1.8))?;
    println!("child architecture: {}", sol.arch.signature());
    // Stage 3: GKD
    let mut child = library.clone();
    let gkd = pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), pipe.cfg.gkd_steps)?;
    child.save(&run_dir.join("child_e2e.pzw"))?;

    // Accuracy retention
    let parent_arch = Arch::parent(cfg.n_layers);
    let pe = Evaluator::new(&*be, &library, &parent_arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    let ce = Evaluator::new(&*be, &child, &sol.arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    println!("parent: {}", pe.row());
    println!("child : {}", ce.row());
    let preserved = 100.0 * ce.accuracy() / pe.accuracy().max(1e-9);

    // Serving comparison (real engine, measured on this machine)
    let mut tps = Vec::new();
    for arch in [&sol.arch, &parent_arch] {
        let weights = if arch == &sol.arch { &child } else { &library };
        // warmup pass outside the timed region
        {
            let mut warm = EngineConfig::new().build(be.clone(), weights, arch)?;
            warm.submit(GenRequest::new(vec![1, 5, 9], 2))?;
            warm.run_to_completion()?;
        }
        let mut eng = EngineConfig::new().build(be.clone(), weights, arch)?;
        let mut rng = Rng::new(5);
        for _ in 0..cfg.b_decode * 3 {
            let plen = rng.range(4, cfg.s_prefill / 2);
            let prompt = sample_sequence(&pipe.world, &pipe.mix, plen, &mut rng);
            eng.submit(GenRequest::new(prompt, cfg.s_prefill / 4))?;
        }

        eng.run_to_completion()?;
        println!(
            "{}: {}",
            if arch == &sol.arch { "child  engine" } else { "parent engine" },
            eng.metrics.summary()
        );
        tps.push(eng.metrics.gen_throughput());
    }

    let hw = HwProfile::h100_fp8();
    let sc = Scenario { prefill: cfg.s_prefill, decode: cfg.s_prefill, batch: 64 };
    let modeled = perf::scenario_throughput(be.man(), &sol.arch, &hw, &sc)
        / perf::scenario_throughput(be.man(), &parent_arch, &hw, &sc);

    println!("=== e2e summary ===");
    println!("accuracy preserved : {preserved:.1}% (paper: 98.4%)");
    println!("measured speedup   : {:.2}x (ref backend)", tps[0] / tps[1]);
    println!("modeled H100 FP8   : {modeled:.2}x (paper: 2.17x)");
    println!("final val KLD      : {:.4}", gkd.val_kld);
    println!("total wall time    : {:.1}s", t_total.secs());
    Ok(())
}
