"""Model configurations and the Puzzle search space.

These definitions are the single source of truth shared (via
artifacts/<cfg>/manifest.json) with the rust coordinator: weight names,
shapes and executable signatures are all derived from here.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# FFN intermediate-dimension ratios from the paper (Section 2): full,
# ~87%, 75%, 50%, 25%, 20% and 10% of the parent intermediate size.
FFN_RATIOS: Dict[str, float] = {
    "r100": 1.00,
    "r87": 0.87,
    "r75": 0.75,
    "r50": 0.50,
    "r25": 0.25,
    "r20": 0.20,
    "r10": 0.10,
}

# GQA key-value head reduction factors (paper: kv heads 8, 4, 2, 1 from an
# 8-kv-head parent — we express them as divisors of the parent head count).
GQA_DIVISORS: List[int] = [1, 2, 4, 8]


def round_dim(x: float, multiple: int = 16, minimum: int = 16) -> int:
    """Round a pruned dimension to a hardware-friendly multiple."""
    return max(minimum, int(round(x / multiple)) * multiple)


@dataclass
class ModelCfg:
    name: str
    d: int          # hidden size
    n_layers: int
    n_heads: int
    head_dim: int
    i: int          # FFN intermediate size (parent)
    v: int          # vocab size
    s_train: int    # training sequence length
    b_train: int    # training batch size
    s_prefill: int  # serving prefill max length
    b_decode: int   # serving decode batch (engine slot count)
    s_max: int      # serving KV-cache capacity per sequence
    s_long: int     # long-context eval length (RULER-proxy)
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def qdim(self) -> int:
        return self.n_heads * self.head_dim

    def kv_heads(self, divisor: int) -> int:
        assert self.n_heads % divisor == 0
        return self.n_heads // divisor

    def attn_variants(self) -> List[str]:
        """GQA variants that are valid for this head count, plus linear.

        no-op is handled purely in rust (skip the block)."""
        out = [f"gqa_r{g}" for g in GQA_DIVISORS if self.n_heads % g == 0 and self.n_heads // g >= 1]
        out.append("linear")
        return out

    def ffn_variants(self) -> List[str]:
        return list(FFN_RATIOS.keys()) + ["linear"]

    def ffn_dim(self, ratio_name: str) -> int:
        return round_dim(self.i * FFN_RATIOS[ratio_name])

    # ---- weight layouts (ordered name -> shape), shared with rust ----

    def attn_weights(self, variant: str) -> List[Tuple[str, Tuple[int, ...]]]:
        if variant == "linear":
            return [("norm", (self.d,)), ("wl", (self.d, self.d))]
        g = int(variant.split("_r")[1])
        kv = self.kv_heads(g)
        return [
            ("norm", (self.d,)),
            ("wq", (self.d, self.qdim)),
            ("wk", (self.d, kv * self.head_dim)),
            ("wv", (self.d, kv * self.head_dim)),
            ("wo", (self.qdim, self.d)),
        ]

    def ffn_weights(self, variant: str) -> List[Tuple[str, Tuple[int, ...]]]:
        if variant == "linear":
            return [("norm", (self.d,)), ("wl", (self.d, self.d))]
        i = self.ffn_dim(variant)
        return [
            ("norm", (self.d,)),
            ("wg", (self.d, i)),
            ("wu", (self.d, i)),
            ("wd", (i, self.d)),
        ]


CONFIGS: Dict[str, ModelCfg] = {
    "tiny": ModelCfg(
        name="tiny", d=64, n_layers=4, n_heads=4, head_dim=16, i=192,
        v=256, s_train=64, b_train=8, s_prefill=64, b_decode=4, s_max=96,
        s_long=256,
    ),
    "small": ModelCfg(
        name="small", d=128, n_layers=8, n_heads=8, head_dim=16, i=512,
        v=512, s_train=128, b_train=8, s_prefill=128, b_decode=4, s_max=192,
        s_long=512,
    ),
    "base": ModelCfg(
        name="base", d=320, n_layers=12, n_heads=8, head_dim=40, i=1280,
        v=512, s_train=128, b_train=8, s_prefill=128, b_decode=4, s_max=192,
        s_long=512,
    ),
}
