"""RMSNorm as a Pallas kernel (token-tiled).

Small but on every block's critical path; tiling over tokens keeps each
(BT, D) tile resident in VMEM for the two passes (mean-square, scale).
interpret=True for CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-5, block_t: int = 256):
    """RMSNorm over last axis. x: [T, D]; w: [D] -> [T, D]."""
    t, d = x.shape
    bt = min(block_t, t)
    while t % bt != 0:
        bt -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda ti: (ti, 0)),
            pl.BlockSpec((d,), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w)


# ---- hand-derived VJP ----

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_vjp(x, w, eps: float = 1e-5):
    return rmsnorm(x, w, eps)


def _rms_fwd(x, w, eps):
    return rmsnorm(x, w, eps), (x, w)


def _rms_bwd(eps, res, dy):
    x, w = res
    r = jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    xhat = x * r
    dw = jnp.sum(dy * xhat, axis=0)
    dxhat = dy * w
    dx = r * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    return dx, dw


rmsnorm_vjp.defvjp(_rms_fwd, _rms_bwd)
