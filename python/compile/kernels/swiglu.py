"""SwiGLU FFN as a Pallas kernel with K-dimension (intermediate) tiling.

The paper's FFN variants shrink the intermediate dimension I (100%..10%);
this kernel expresses the HBM<->VMEM schedule the paper's CUDA kernels get
from threadblock tiling: grid = (token_tile, intermediate_tile), each step
streams a (D, BI) stripe of the gate/up projections and a (BI, D) stripe of
the down projection through VMEM and accumulates the partial down-projection
into the output tile (initialize on i==0, accumulate after). The gate
(silu(x@wg) * (x@wu)) is fused so the intermediate activation never leaves
scratchpad. interpret=True for CPU PJRT; see DESIGN.md §6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    i = pl.program_id(1)
    x = x_ref[...]                       # [BT, D]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u               # [BT, BI], fused in VMEM
    contrib = jnp.dot(h, wd_ref[...], preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(i > 0)
    def _acc():
        o_ref[...] = o_ref[...] + contrib


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (tiles must divide exactly)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def swiglu(x, wg, wu, wd, block_t: int = 128, block_i: int = 128):
    """SwiGLU: (silu(x@wg) * (x@wu)) @ wd. x: [T, D] -> [T, D]."""
    t, d = x.shape
    i = wg.shape[1]
    bt = _pick_tile(t, block_t)
    bi = _pick_tile(i, block_i)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(t // bt, i // bi),
        in_specs=[
            pl.BlockSpec((bt, d), lambda ti, ii: (ti, 0)),
            pl.BlockSpec((d, bi), lambda ti, ii: (0, ii)),
            pl.BlockSpec((d, bi), lambda ti, ii: (0, ii)),
            pl.BlockSpec((bi, d), lambda ti, ii: (ii, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ti, ii: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, wg, wu, wd)


# ---- hand-derived VJP (recomputes gate/up activations from saved inputs) ----

@jax.custom_vjp
def swiglu_vjp(x, wg, wu, wd):
    return swiglu(x, wg, wu, wd)


def _swiglu_fwd(x, wg, wu, wd):
    return swiglu(x, wg, wu, wd), (x, wg, wu, wd)


def _silu_grad(g):
    sg = jax.nn.sigmoid(g)
    return sg * (1.0 + g * (1.0 - sg))


def _swiglu_bwd(res, dy):
    x, wg, wu, wd = res
    g = x @ wg
    u = x @ wu
    s = jax.nn.silu(g)
    h = s * u
    dh = dy @ wd.T
    du = dh * s
    dg = dh * u * _silu_grad(g)
    dx = dg @ wg.T + du @ wu.T
    return dx, x.T @ dg, x.T @ du, h.T @ dy


swiglu_vjp.defvjp(_swiglu_fwd, _swiglu_bwd)
