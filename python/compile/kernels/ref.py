"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact reference implementation
here; pytest asserts allclose between the two across a hypothesis sweep of
shapes. The references are also used directly by model.py on the decode
path (tiny tensors, memory-bound -- not worth a kernel)."""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, eps: float = 1e-5):
    """RMSNorm over the last axis: x / rms(x) * w."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def attention_ref(q, k, v, causal: bool = True):
    """Grouped-query attention.

    q: [B, S, H, Dh]; k, v: [B, S, KV, Dh] with H % KV == 0.
    Returns [B, S, H, Dh]. Causal mask over the sequence axis.
    """
    b, s, h, dh = q.shape
    kv = k.shape[2]
    group = h // kv
    # expand kv heads to match query heads
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        scores = jnp.where(ki <= qi, scores, jnp.asarray(-1e30, scores.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def swiglu_ref(x, wg, wu, wd):
    """SwiGLU FFN: (silu(x @ wg) * (x @ wu)) @ wd.

    x: [T, D]; wg, wu: [D, I]; wd: [I, D]."""
    g = x @ wg
    u = x @ wu
    return (jax.nn.silu(g) * u) @ wd


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Single-token cached attention used on the serving decode path.

    q: [B, 1, H, Dh]; caches: [B, Smax, KV, Dh]; pos: [B] int32 giving the
    index of the *current* token (cache already contains it at `pos`).
    Attends over cache positions <= pos. Returns [B, 1, H, Dh].
    """
    b, _, h, dh = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    group = h // kv
    k = jnp.repeat(k_cache, group, axis=2)
    v = jnp.repeat(v_cache, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale  # [B,H,1,Smax]
    mask = jnp.arange(smax)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
