"""Fused causal GQA attention as a Pallas kernel.

Hardware adaptation (paper targets H100 tensor cores / paged attention; we
re-think for the TPU model Pallas exposes): the grid is (batch, q_head,
q_tile) and the BlockSpec schedule stages one Q tile plus the matching KV
head's full K/V stripe through VMEM, so the softmax(QK^T)V pipeline never
materializes the S x S score tensor in HBM (flash-style). GQA sharing is
expressed in the K/V index_map: query head h reads KV head h // group, which
is exactly the paper's "reduced KV heads shrink both compute and KV-cache"
knob. Kernels are lowered with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); see DESIGN.md §6 for the VMEM/MXU estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, bq, s, causal):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :] * scale          # [BQ, Dh]
    k = k_ref[0, :, 0, :]                  # [S, Dh]
    v = v_ref[0, :, 0, :]                  # [S, Dh]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [BQ, S]
    if causal:
        q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, s), 0)
        k_idx = jax.lax.broadcasted_iota(jnp.int32, (bq, s), 1)
        scores = jnp.where(k_idx <= q_idx, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, :, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def attention(q, k, v, causal: bool = True, block_q: int = 128):
    """Causal GQA attention. q: [B,S,H,Dh]; k,v: [B,S,KV,Dh] -> [B,S,H,Dh]."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    assert h % kv == 0, "query heads must be a multiple of kv heads"
    group = h // kv
    bq = min(block_q, s)
    assert s % bq == 0, f"seq len {s} must be a multiple of q tile {bq}"
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale, bq=bq, s=s, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(b, h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda bi, hi, qi: (bi, qi, hi, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda bi, hi, qi: (bi, 0, hi // group, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda bi, hi, qi: (bi, 0, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda bi, hi, qi: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)


# ---- hand-derived VJP (interpret-mode pallas_call is not differentiable;
# the backward pass recomputes the softmax from saved q,k,v = remat) ----

@jax.custom_vjp
def attention_vjp(q, k, v):
    return attention(q, k, v, causal=True)


def _attn_fwd(q, k, v):
    return attention(q, k, v, causal=True), (q, k, v)


def _attn_bwd(res, do):
    q, k, v = res
    b, s, h, dh = q.shape
    kv = k.shape[2]
    group = h // kv
    scale = 1.0 / (dh ** 0.5)
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kx) * scale
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(ki <= qi, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)                      # [B,H,Q,K]
    dv = jnp.einsum("bhqk,bqhd->bkhd", p, do)                # expanded heads
    dp = jnp.einsum("bqhd,bkhd->bhqk", do, vx)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kx) * scale
    dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q) * scale
    # fold expanded query-head grads back onto shared kv heads
    dk = dk.reshape(b, s, kv, group, dh).sum(axis=3)
    dv = dv.reshape(b, s, kv, group, dh).sum(axis=3)
    return dq, dk, dv


attention_vjp.defvjp(_attn_fwd, _attn_bwd)
