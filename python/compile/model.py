"""L2: the NanoLlama compute graph per Puzzle block variant.

Every function here takes its weights as *positional arguments* so that the
AOT-lowered executable is parameterized by weights: one compiled artifact per
variant type serves every layer and every candidate child architecture — the
rust coordinator assembles heterogeneous models by chaining these.

Modes per variant:
  train_fwd  (Bt, St)  — returns block output y (used for activations + BLD)
  train_vjp  (Bt, St)  — (x, *w, dy) -> (dx, *dw); primal recomputed inside
  prefill    (1,  Sp)  — gqa variants additionally return the roped K/V for
                          the serving engine's KV cache
  decode     (Bd, 1)   — cached attention with per-sequence positions
  long       (1,  Sl)  — long-context scoring (RULER-proxy)

Hot spots (prefill attention, FFN, norms) call the Pallas kernels; the
decode path and the hand-derived backward passes use the jnp references
(tiny/memory-bound tensors). All blocks are pre-norm residual:
y = x + subblock(rmsnorm(x)).
"""

import jax
import jax.numpy as jnp

from .configs import ModelCfg
from .kernels.attention import attention, attention_vjp
from .kernels.swiglu import swiglu, swiglu_vjp
from .kernels.rmsnorm import rmsnorm, rmsnorm_vjp
from .kernels import ref


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Rotary embedding. x: [B, S, H, Dh]; positions: [B, S] int32."""
    b, s, h, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * freqs  # [B,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _positions(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


# --------------------------------------------------------------------------
# Attention blocks
# --------------------------------------------------------------------------

def attn_gqa_fwd(cfg: ModelCfg, x, norm, wq, wk, wv, wo, *, use_vjp_kernels=False):
    """Pre-norm GQA block: y = x + Wo . attn(rope(q), rope(k), v)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    kv = wk.shape[1] // dh
    rms = rmsnorm_vjp if use_vjp_kernels else rmsnorm
    att = attention_vjp if use_vjp_kernels else attention
    hnorm = rms(x.reshape(b * s, d), norm).reshape(b, s, d)
    pos = _positions(b, s)
    q = rope((hnorm @ wq).reshape(b, s, h, dh), pos, cfg.rope_theta)
    k = rope((hnorm @ wk).reshape(b, s, kv, dh), pos, cfg.rope_theta)
    v = (hnorm @ wv).reshape(b, s, kv, dh)
    o = att(q, k, v).reshape(b, s, h * dh)
    return x + o @ wo, k, v


def attn_gqa_decode(cfg: ModelCfg, x, k_cache, v_cache, pos, norm, wq, wk, wv, wo):
    """Cached decode step. x: [B,1,D]; caches [B,Smax,KV,Dh]; pos: [B] int32.

    Writes the new K/V at `pos` (functional update) and attends over <= pos.
    Returns (y, k_cache', v_cache')."""
    b, _, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    kv = wk.shape[1] // dh
    hnorm = ref.rmsnorm_ref(x, norm)
    p2 = pos[:, None]
    q = rope((hnorm @ wq).reshape(b, 1, h, dh), p2, cfg.rope_theta)
    k = rope((hnorm @ wk).reshape(b, 1, kv, dh), p2, cfg.rope_theta)
    v = (hnorm @ wv).reshape(b, 1, kv, dh)
    upd = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    k_cache = upd(k_cache, k, pos)
    v_cache = upd(v_cache, v, pos)
    o = ref.decode_attention_ref(q, k_cache, v_cache, pos).reshape(b, 1, h * dh)
    return x + o @ wo, k_cache, v_cache


def attn_linear_fwd(x, norm, wl, *, use_vjp_kernels=False):
    """Attention replaced by a single token-wise linear layer (paper §2).

    Initialized in rust as Wv @ Wo ("each token attends only to itself")."""
    b, s, d = x.shape
    rms = rmsnorm_vjp if use_vjp_kernels else rmsnorm
    hnorm = rms(x.reshape(b * s, d), norm).reshape(b, s, d)
    return x + hnorm @ wl


# --------------------------------------------------------------------------
# FFN blocks
# --------------------------------------------------------------------------

def ffn_fwd(x, norm, wg, wu, wd, *, use_vjp_kernels=False):
    b, s, d = x.shape
    rms = rmsnorm_vjp if use_vjp_kernels else rmsnorm
    swi = swiglu_vjp if use_vjp_kernels else swiglu
    hnorm = rms(x.reshape(b * s, d), norm)
    return x + swi(hnorm, wg, wu, wd).reshape(b, s, d)


def ffn_linear_fwd(x, norm, wl, *, use_vjp_kernels=False):
    """FFN replaced by a linear layer, initialized as W_up @ W_down."""
    b, s, d = x.shape
    rms = rmsnorm_vjp if use_vjp_kernels else rmsnorm
    hnorm = rms(x.reshape(b * s, d), norm).reshape(b, s, d)
    return x + hnorm @ wl


# --------------------------------------------------------------------------
# Embedding / LM head (tied)
# --------------------------------------------------------------------------

def embed_fwd(tokens, e):
    return e[tokens]


def head_fwd(x, norm, e, *, use_vjp_kernels=False):
    b, s, d = x.shape
    rms = rmsnorm_vjp if use_vjp_kernels else rmsnorm
    hnorm = rms(x.reshape(b * s, d), norm).reshape(b, s, d)
    return hnorm @ e.T


# --------------------------------------------------------------------------
# Block dispatch by variant name (shared with aot.py and tests)
# --------------------------------------------------------------------------

def block_fn(cfg: ModelCfg, kind: str, variant: str):
    """Returns fn(x, *weights) -> y (train-mode, differentiable)."""
    if kind == "attn":
        if variant == "linear":
            return lambda x, norm, wl: attn_linear_fwd(x, norm, wl, use_vjp_kernels=True)
        return lambda x, *w: attn_gqa_fwd(cfg, x, *w, use_vjp_kernels=True)[0]
    if kind == "ffn":
        if variant == "linear":
            return lambda x, norm, wl: ffn_linear_fwd(x, norm, wl, use_vjp_kernels=True)
        return lambda x, *w: ffn_fwd(x, *w, use_vjp_kernels=True)
    raise ValueError(f"unknown kind {kind}")


def block_vjp_fn(cfg: ModelCfg, kind: str, variant: str):
    """Returns fn(x, *weights, dy) -> (dx, *dweights). Primal recomputed."""
    f = block_fn(cfg, kind, variant)

    def vjp_fn(*args):
        x, w, dy = args[0], args[1:-1], args[-1]
        _, pull = jax.vjp(f, x, *w)
        return pull(dy)

    return vjp_fn


# --------------------------------------------------------------------------
# Losses — parity oracles for the rust implementations (train/losses.rs)
# --------------------------------------------------------------------------

def ce_loss(logits, targets):
    """Mean token cross-entropy. logits [B,S,V], targets [B,S] int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def ce_loss_grad(logits, targets):
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    n = logits.shape[0] * logits.shape[1]
    return (p - onehot) / n


def kld_loss(logits_p, logits_c):
    """Mean token KL(parent || child)."""
    lp = jax.nn.log_softmax(logits_p, axis=-1)
    lc = jax.nn.log_softmax(logits_c, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(lp) * (lp - lc), axis=-1))


def kld_loss_grad(logits_p, logits_c):
    """d KL(p||c) / d logits_c."""
    p = jax.nn.softmax(logits_p, axis=-1)
    c = jax.nn.softmax(logits_c, axis=-1)
    n = logits_c.shape[0] * logits_c.shape[1]
    return (c - p) / n


def cosine_loss(h_c, h_p):
    """1 - cos(h_c, h_p) averaged over tokens (per-layer hidden states)."""
    num = jnp.sum(h_c * h_p, axis=-1)
    den = jnp.linalg.norm(h_c, axis=-1) * jnp.linalg.norm(h_p, axis=-1) + 1e-8
    return jnp.mean(1.0 - num / den)


def nmse_loss(o_c, o_p):
    """BLD objective (§3): MSE(o_p, o_c) / MSE(o_p, 0)."""
    return jnp.sum((o_c - o_p) ** 2) / (jnp.sum(o_p ** 2) + 1e-8)
