"""AOT exporter: lower every Puzzle block-variant executable to HLO text.

This is the *only* python entrypoint the system needs (`make artifacts`);
after it runs, the rust coordinator is self-contained. HLO **text** (not
`.serialize()`) is the interchange format: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Everything is lowered with return_tuple=True so the rust side uniformly
unwraps a tuple literal. Weights are inputs, so one executable per variant
type serves every layer and every candidate architecture.

Usage: python -m compile.aot --config tiny [--config small] --out-dir ../artifacts
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, ModelCfg
from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


class Exporter:
    def __init__(self, cfg: ModelCfg, out_dir: str):
        self.cfg = cfg
        self.dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.execs = {}

    def export(self, name: str, fn, in_specs):
        """Lower fn at in_specs, write <name>.hlo.txt, record in manifest."""
        t0 = time.time()
        # keep_unused: some vjps don't read every input (e.g. the embedding
        # gather's grad ignores the table values) but the manifest/rust
        # contract passes them all.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        out_specs = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_specs, (tuple, list)):
            out_specs = (out_specs,)
        text = to_hlo_text(lowered)
        path = os.path.join(self.dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.execs[name] = {
            "file": f"{name}.hlo.txt",
            "in": [_fmt(s) for s in in_specs],
            "out": [_fmt(s) for s in out_specs],
        }
        print(f"  [{time.time()-t0:5.2f}s] {name}", flush=True)

    # ---- per-variant exports ----

    def attn_variant(self, variant: str):
        cfg = self.cfg
        d = cfg.d
        wspecs = [spec(s) for _, s in cfg.attn_weights(variant)]
        bt, st = cfg.b_train, cfg.s_train
        bd, sp, sl, smax = cfg.b_decode, cfg.s_prefill, cfg.s_long, cfg.s_max
        f = M.block_fn(cfg, "attn", variant)
        fv = M.block_vjp_fn(cfg, "attn", variant)
        n = f"attn_{variant}"
        x_t = spec((bt, st, d))
        self.export(f"{n}_train_fwd", lambda x, *w: (f(x, *w),), [x_t] + wspecs)
        self.export(f"{n}_train_vjp", fv, [x_t] + wspecs + [x_t])
        if variant == "linear":
            g = lambda x, *w: (M.attn_linear_fwd(x, *w),)
            self.export(f"{n}_prefill", g, [spec((1, sp, d))] + wspecs)
            self.export(f"{n}_decode", g, [spec((bd, 1, d))] + wspecs)
            self.export(f"{n}_long", g, [spec((1, sl, d))] + wspecs)
        else:
            kv = cfg.kv_heads(int(variant.split("_r")[1]))
            pre = lambda x, *w: M.attn_gqa_fwd(cfg, x, *w)  # (y, k, v)
            self.export(f"{n}_prefill", pre, [spec((1, sp, d))] + wspecs)
            self.export(
                f"{n}_decode",
                lambda x, kc, vc, pos, *w: M.attn_gqa_decode(cfg, x, kc, vc, pos, *w),
                [
                    spec((bd, 1, d)),
                    spec((bd, smax, kv, cfg.head_dim)),
                    spec((bd, smax, kv, cfg.head_dim)),
                    spec((bd,), I32),
                ]
                + wspecs,
            )
            self.export(f"{n}_long", lambda x, *w: (pre(x, *w)[0],), [spec((1, sl, d))] + wspecs)

    def ffn_variant(self, variant: str):
        cfg = self.cfg
        d = cfg.d
        wspecs = [spec(s) for _, s in cfg.ffn_weights(variant)]
        bt, st = cfg.b_train, cfg.s_train
        bd, sp, sl = cfg.b_decode, cfg.s_prefill, cfg.s_long
        f = M.block_fn(cfg, "ffn", variant)
        fv = M.block_vjp_fn(cfg, "ffn", variant)
        n = f"ffn_{variant}"
        x_t = spec((bt, st, d))
        g = lambda x, *w: (f(x, *w),)
        self.export(f"{n}_train_fwd", g, [x_t] + wspecs)
        self.export(f"{n}_train_vjp", fv, [x_t] + wspecs + [x_t])
        self.export(f"{n}_prefill", g, [spec((1, sp, d))] + wspecs)
        self.export(f"{n}_decode", g, [spec((bd, 1, d))] + wspecs)
        self.export(f"{n}_long", g, [spec((1, sl, d))] + wspecs)

    def embed_head(self):
        cfg = self.cfg
        d, v = cfg.d, cfg.v
        bt, st = cfg.b_train, cfg.s_train
        bd, sp, sl = cfg.b_decode, cfg.s_prefill, cfg.s_long
        e = spec((v, d))
        nw = spec((d,))
        shapes = {"train": (bt, st), "prefill": (1, sp), "decode": (bd, 1), "long": (1, sl)}
        for mode, (b, s) in shapes.items():
            self.export(
                f"embed_{mode}", lambda t, e: (M.embed_fwd(t, e),), [spec((b, s), I32), e]
            )
            self.export(
                f"head_{mode}",
                lambda x, n, e: (M.head_fwd(x, n, e, use_vjp_kernels=True),),
                [spec((b, s, d)), nw, e],
            )
        # training backward passes
        def embed_vjp(t, ew, dx):
            _, pull = jax.vjp(lambda ew: M.embed_fwd(t, ew), ew)
            return pull(dx)

        self.export("embed_train_vjp", embed_vjp, [spec((bt, st), I32), e, spec((bt, st, d))])

        def head_vjp(x, n, ew, dl):
            _, pull = jax.vjp(lambda x, n, ew: M.head_fwd(x, n, ew, use_vjp_kernels=True), x, n, ew)
            return pull(dl)

        self.export(
            "head_train_vjp", head_vjp, [spec((bt, st, d)), nw, e, spec((bt, st, v))]
        )

    def manifest(self):
        cfg = self.cfg
        man = {
            "config": {
                "name": cfg.name, "d": cfg.d, "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads, "head_dim": cfg.head_dim, "i": cfg.i,
                "v": cfg.v, "s_train": cfg.s_train, "b_train": cfg.b_train,
                "s_prefill": cfg.s_prefill, "b_decode": cfg.b_decode,
                "s_max": cfg.s_max, "s_long": cfg.s_long,
                "rope_theta": cfg.rope_theta, "eps": cfg.eps,
            },
            "attn_variants": {
                va: {
                    "weights": [[n, list(s)] for n, s in cfg.attn_weights(va)],
                    "kv_heads": (0 if va == "linear" else cfg.kv_heads(int(va.split("_r")[1]))),
                }
                for va in cfg.attn_variants()
            },
            "ffn_variants": {
                vf: {
                    "weights": [[n, list(s)] for n, s in cfg.ffn_weights(vf)],
                    "i_dim": (0 if vf == "linear" else cfg.ffn_dim(vf)),
                }
                for vf in cfg.ffn_variants()
            },
            "execs": self.execs,
        }
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(man, f, indent=1)

    def run(self):
        print(f"== exporting config '{self.cfg.name}' -> {self.dir}", flush=True)
        for va in self.cfg.attn_variants():
            self.attn_variant(va)
        for vf in self.cfg.ffn_variants():
            self.ffn_variant(vf)
        self.embed_head()
        self.manifest()
        print(f"== {len(self.execs)} executables exported for '{self.cfg.name}'")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", action="append", default=None, choices=list(CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    configs = args.config or ["tiny", "small"]
    for name in configs:
        Exporter(CONFIGS[name], args.out_dir).run()


if __name__ == "__main__":
    main()
