"""AOT export integration: manifest completeness + HLO-text interchange."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import Exporter, to_hlo_text
from compile.configs import CONFIGS
from compile import model as M


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    Exporter(CONFIGS["tiny"], str(out)).run()
    return os.path.join(str(out), "tiny")


def test_manifest_covers_search_space(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    cfg = CONFIGS["tiny"]
    # paper search space: gqa variants + linear (no-op lives in rust)
    assert set(man["attn_variants"]) == set(cfg.attn_variants())
    assert set(man["ffn_variants"]) == set(cfg.ffn_variants())
    for va in cfg.attn_variants():
        for mode in ["train_fwd", "train_vjp", "prefill", "decode", "long"]:
            assert f"attn_{va}_{mode}" in man["execs"], (va, mode)
    for vf in cfg.ffn_variants():
        for mode in ["train_fwd", "train_vjp", "prefill", "decode", "long"]:
            assert f"ffn_{vf}_{mode}" in man["execs"], (vf, mode)
    for n in ["embed_train", "head_train", "embed_train_vjp", "head_train_vjp",
              "embed_decode", "head_decode", "embed_long", "head_long"]:
        assert n in man["execs"]


def test_hlo_files_are_parseable_text(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    for name, meta in man["execs"].items():
        path = os.path.join(export_dir, meta["file"])
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, name
        # the 64-bit-id failure mode shows up as serialized protos; text never.
        assert not text.startswith("\x08"), name


def test_manifest_shapes_match_lowering(export_dir):
    man = json.load(open(os.path.join(export_dir, "manifest.json")))
    cfg = CONFIGS["tiny"]
    e = man["execs"]["attn_gqa_r2_decode"]
    kv = cfg.n_heads // 2
    assert e["in"][1]["shape"] == [cfg.b_decode, cfg.s_max, kv, cfg.head_dim]
    assert e["out"][0]["shape"] == [cfg.b_decode, 1, cfg.d]
    h = man["execs"]["head_train"]
    assert h["out"][0]["shape"] == [cfg.b_train, cfg.s_train, cfg.v]


def test_hlo_text_parses_back():
    """The emitted text must parse back through XLA's HLO text parser —
    the exact path the rust runtime uses (HloModuleProto::from_text_file).
    Numerics of the round trip are covered by the rust integration tests."""
    from jax._src.lib import xla_client as xc

    fn = lambda a, b: (jnp.matmul(a, b) + 1.5,)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32), jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "ROOT" in text and "tuple(" in text  # tuple-rooted for uniform unwrap
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_exports_are_deterministic():
    fn = lambda a: (a * 2.0,)
    s = jax.ShapeDtypeStruct((3, 3), jnp.float32)
    t1 = to_hlo_text(jax.jit(fn).lower(s))
    t2 = to_hlo_text(jax.jit(fn).lower(s))
    assert t1 == t2
