"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

These are the CORE correctness signal for the compiled artifacts: every
serving/training executable is composed from these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, attention_vjp
from compile.kernels.swiglu import swiglu, swiglu_vjp
from compile.kernels.rmsnorm import rmsnorm, rmsnorm_vjp
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([8, 16, 32, 64, 128]),
    h=st.sampled_from([1, 2, 4, 8]),
    kv_div=st.sampled_from([1, 2, 4, 8]),
    dh=st.sampled_from([4, 8, 16]),
)
def test_attention_matches_ref(b, s, h, kv_div, dh):
    if h % kv_div != 0:
        kv_div = 1
    kv = h // kv_div
    q = rnd(0, (b, s, h, dh))
    k = rnd(1, (b, s, kv, dh))
    v = rnd(2, (b, s, kv, dh))
    got = attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5, rtol=2e-5)


def test_attention_is_causal():
    """Perturbing a future token must not change earlier outputs."""
    b, s, h, dh = 1, 16, 2, 8
    q, k, v = rnd(0, (b, s, h, dh)), rnd(1, (b, s, h, dh)), rnd(2, (b, s, h, dh))
    base = attention(q, k, v)
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    pert = attention(q, k2, v2)
    np.testing.assert_allclose(np.array(base[:, :-1]), np.array(pert[:, :-1]), atol=1e-6)


def test_attention_q_tiling_invariance():
    """Different q tile sizes must produce identical results."""
    q, k, v = rnd(0, (2, 64, 4, 8)), rnd(1, (2, 64, 2, 8)), rnd(2, (2, 64, 2, 8))
    a = attention(q, k, v, block_q=64)
    b_ = attention(q, k, v, block_q=16)
    np.testing.assert_allclose(np.array(a), np.array(b_), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([8, 16]),
    h=st.sampled_from([2, 4]),
    kv_div=st.sampled_from([1, 2]),
)
def test_attention_vjp_matches_ref_grads(s, h, kv_div):
    kv, dh = h // kv_div, 8
    q, k, v = rnd(0, (1, s, h, dh)), rnd(1, (1, s, kv, dh)), rnd(2, (1, s, kv, dh))
    w = rnd(3, (dh,))
    f_ker = lambda q, k, v: jnp.sum(attention_vjp(q, k, v) * w)
    f_ref = lambda q, k, v: jnp.sum(ref.attention_ref(q, k, v) * w)
    g1 = jax.grad(f_ker, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------- swiglu

@settings(**SETTINGS)
@given(
    t=st.sampled_from([1, 7, 32, 128, 200]),
    d=st.sampled_from([8, 32, 64]),
    i=st.sampled_from([16, 48, 144, 256]),
)
def test_swiglu_matches_ref(t, d, i):
    x, wg, wu, wd = rnd(0, (t, d)), rnd(1, (d, i)), rnd(2, (d, i)), rnd(3, (i, d))
    got = swiglu(x, wg, wu, wd)
    want = ref.swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-3, rtol=1e-4)


def test_swiglu_i_tiling_accumulation():
    """I-dim tiling (the paper's pruned-FFN axis) accumulates exactly."""
    x, wg, wu, wd = rnd(0, (16, 8)), rnd(1, (8, 256)), rnd(2, (8, 256)), rnd(3, (256, 8))
    a = swiglu(x, wg, wu, wd, block_i=256)   # single tile
    b = swiglu(x, wg, wu, wd, block_i=32)    # 8 accumulation steps
    np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([4, 16]), d=st.sampled_from([8, 16]), i=st.sampled_from([16, 32]))
def test_swiglu_vjp_matches_ref_grads(t, d, i):
    x, wg, wu, wd = rnd(0, (t, d)), rnd(1, (d, i)), rnd(2, (d, i)), rnd(3, (i, d))
    c = rnd(4, (d,))
    g1 = jax.grad(lambda *a: jnp.sum(swiglu_vjp(*a) * c), (0, 1, 2, 3))(x, wg, wu, wd)
    g2 = jax.grad(lambda *a: jnp.sum(ref.swiglu_ref(*a) * c), (0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------- rmsnorm

@settings(**SETTINGS)
@given(t=st.sampled_from([1, 3, 64, 300]), d=st.sampled_from([8, 64, 128]))
def test_rmsnorm_matches_ref(t, d):
    x, w = rnd(0, (t, d)), rnd(1, (d,))
    np.testing.assert_allclose(
        np.array(rmsnorm(x, w)), np.array(ref.rmsnorm_ref(x, w)), atol=1e-5, rtol=1e-5
    )


def test_rmsnorm_vjp_matches_ref_grads():
    x, w, c = rnd(0, (16, 32)), rnd(1, (32,)), rnd(2, (32,))
    g1 = jax.grad(lambda x, w: jnp.sum(rmsnorm_vjp(x, w) * c), (0, 1))(x, w)
    g2 = jax.grad(lambda x, w: jnp.sum(ref.rmsnorm_ref(x, w) * c), (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-5, rtol=1e-4)


def test_rmsnorm_scale_invariance():
    """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
    x, w = rnd(0, (8, 64)), rnd(1, (64,))
    a = rmsnorm(x, w)
    b = rmsnorm(x * 1000.0, w)
    np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-3, rtol=1e-3)
