"""L2 correctness: block variants, decode/prefill cache consistency, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS
from compile import model as M
from compile.kernels import ref

CFG = CONFIGS["tiny"]


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * 0.3


def gqa_weights(kv_div, key0=0):
    kv = CFG.kv_heads(kv_div)
    d, qd, dh = CFG.d, CFG.qdim, CFG.head_dim
    return [
        jnp.abs(rnd(key0, (d,))) + 0.5,
        rnd(key0 + 1, (d, qd)),
        rnd(key0 + 2, (d, kv * dh)),
        rnd(key0 + 3, (d, kv * dh)),
        rnd(key0 + 4, (qd, d)),
    ]


# ------------------------------------------------------------ decode == prefill

@pytest.mark.parametrize("kv_div", [1, 2, 4])
def test_decode_matches_prefill(kv_div):
    """Token-by-token cached decode must reproduce the full prefill pass.

    This is the correctness contract between the serving engine's KV cache
    and the attention executables."""
    b, s, smax = 2, 12, 24
    d = CFG.d
    kv, dh = CFG.kv_heads(kv_div), CFG.head_dim
    w = gqa_weights(kv_div)
    x = rnd(9, (b, s, d))
    y_full, k_full, v_full = M.attn_gqa_fwd(CFG, x, *w)

    k_cache = jnp.zeros((b, smax, kv, dh))
    v_cache = jnp.zeros((b, smax, kv, dh))
    outs = []
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        y_t, k_cache, v_cache = M.attn_gqa_decode(
            CFG, x[:, t : t + 1], k_cache, v_cache, pos, *w
        )
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(y_dec), np.array(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.array(k_cache[:, :s]), np.array(k_full), atol=1e-5)
    np.testing.assert_allclose(np.array(v_cache[:, :s]), np.array(v_full), atol=1e-5)


def test_decode_respects_positions():
    """Sequences at different positions in the same decode batch stay isolated."""
    b, smax = 2, 16
    w = gqa_weights(1)
    kv, dh = CFG.n_heads, CFG.head_dim
    k_cache = rnd(1, (b, smax, kv, dh))
    v_cache = rnd(2, (b, smax, kv, dh))
    x = rnd(3, (b, 1, CFG.d))
    pos = jnp.array([3, 7], jnp.int32)
    y, kc, vc = M.attn_gqa_decode(CFG, x, k_cache, v_cache, pos, *w)
    # garbage beyond pos must not affect the result
    k2 = k_cache.at[0, 10:].set(99.0)
    v2 = v_cache.at[0, 10:].set(-99.0)
    y2, _, _ = M.attn_gqa_decode(CFG, x, k2, v2, pos, *w)
    np.testing.assert_allclose(np.array(y), np.array(y2), atol=1e-5)


# ------------------------------------------------------------ block variants

def test_attn_linear_identity_when_wl_zero():
    x = rnd(0, (2, 8, CFG.d))
    norm = jnp.ones((CFG.d,))
    y = M.attn_linear_fwd(x, norm, jnp.zeros((CFG.d, CFG.d)))
    np.testing.assert_allclose(np.array(y), np.array(x))


def test_ffn_matches_ref_composition():
    x = rnd(0, (2, 8, CFG.d))
    i = CFG.ffn_dim("r50")
    norm = jnp.abs(rnd(1, (CFG.d,))) + 0.5
    wg, wu, wd = rnd(2, (CFG.d, i)), rnd(3, (CFG.d, i)), rnd(4, (i, CFG.d))
    got = M.ffn_fwd(x, norm, wg, wu, wd)
    hn = ref.rmsnorm_ref(x.reshape(-1, CFG.d), norm)
    want = x + ref.swiglu_ref(hn, wg, wu, wd).reshape(x.shape)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("kind,variant", [("attn", "gqa_r2"), ("attn", "linear"),
                                          ("ffn", "r50"), ("ffn", "linear")])
def test_block_vjp_consistent_with_autodiff(kind, variant):
    """block_vjp_fn must equal jax.grad of block_fn (same custom_vjp path)."""
    shapes = (CFG.attn_weights(variant) if kind == "attn" else CFG.ffn_weights(variant))
    w = [rnd(i + 1, s) for i, (_, s) in enumerate(shapes)]
    w[0] = jnp.abs(w[0]) + 0.5  # norm weight positive
    x = rnd(0, (2, 8, CFG.d))
    dy = rnd(99, (2, 8, CFG.d))
    f = M.block_fn(CFG, kind, variant)
    got = M.block_vjp_fn(CFG, kind, variant)(x, *w, dy)
    want = jax.grad(lambda x, *w: jnp.sum(f(x, *w) * dy), argnums=tuple(range(len(w) + 1)))(x, *w)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-4, rtol=1e-3)


def test_rope_relative_shift():
    """RoPE dot products depend only on relative positions."""
    dh = 8
    q = rnd(0, (1, 1, 1, dh))
    k = rnd(1, (1, 1, 1, dh))
    def dot_at(p_q, p_k):
        qq = M.rope(q, jnp.array([[p_q]], jnp.int32), CFG.rope_theta)
        kk = M.rope(k, jnp.array([[p_k]], jnp.int32), CFG.rope_theta)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5  # sanity: not constant


# ------------------------------------------------------------ losses

def test_kld_zero_on_identical_logits():
    lg = rnd(0, (2, 4, CFG.v))
    assert abs(float(M.kld_loss(lg, lg))) < 1e-6
    g = M.kld_loss_grad(lg, lg)
    np.testing.assert_allclose(np.array(g), 0.0, atol=1e-7)


def test_ce_grad_matches_autodiff():
    lg = rnd(0, (2, 4, 16))
    tg = jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    got = M.ce_loss_grad(lg, tg)
    want = jax.grad(lambda l: M.ce_loss(l, tg))(lg)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-6)


def test_kld_grad_matches_autodiff():
    lp, lc = rnd(0, (2, 4, 16)), rnd(1, (2, 4, 16))
    got = M.kld_loss_grad(lp, lc)
    want = jax.grad(lambda c: M.kld_loss(lp, c))(lc)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-6)


def test_nmse_normalization():
    o = rnd(0, (4, 8))
    assert abs(float(M.nmse_loss(jnp.zeros_like(o), o)) - 1.0) < 1e-5
    assert float(M.nmse_loss(o, o)) < 1e-10


def test_cosine_loss_bounds():
    h = rnd(0, (2, 4, 8))
    assert abs(float(M.cosine_loss(h, h))) < 1e-6
    assert abs(float(M.cosine_loss(h, -h)) - 2.0) < 1e-5
