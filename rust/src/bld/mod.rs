//! Blockwise Local Distillation (paper §3): train every block variant in
//! the library to mimic its parent block, feeding *parent* activations so
//! all jobs are independent ("only the parent activations are transferred
//! between layers", Fig. 2).
//!
//! Decoupled BLD (§3.1) trains each attention variant against the parent
//! attention subblock's output and each FFN variant against the parent FFN
//! subblock's output — (m + n) · L jobs instead of m · n · L. Coupled BLD
//! trains an (attention, FFN) pair jointly against the parent block output,
//! used on a reduced subspace for refinement (§8.1.1).
//!
//! The objective is the normalized MSE of §3: MSE(o_p, o_c) / MSE(o_p, 0).
//! All jobs step on the same data stream each round — the scheduling
//! structure of the paper's multi-GPU pipeline with P = 1.

use anyhow::Result;
use std::collections::HashMap;

use crate::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use crate::config::Manifest;
use crate::data::Batcher;
use crate::info;
use crate::model::{CompiledModel, Trace};
use crate::runtime::{tensor_to_val, val_to_tensor, Backend, Value};
use crate::tensor::Tensor;
use crate::train::losses::nmse_loss_and_grad;
use crate::train::{Adam, AdamCfg};
use crate::weights::{init, store::block_key, Store};

/// One library-construction job: train `variant` of `kind` at `layer`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Job {
    /// Layer index the block lives at.
    pub layer: usize,
    /// Subblock kind: "attn" or "ffn".
    pub kind: &'static str, // "attn" | "ffn"
    /// Variant name from the search space (e.g. "gqa_r2", "r50").
    pub variant: String,
}

#[derive(Debug, Clone, Default)]
/// Aggregate outcome of one BLD run over the whole library.
pub struct BldReport {
    /// final normalized-MSE per job
    pub final_loss: HashMap<String, f64>,
    /// Optimizer steps each job took.
    pub steps: usize,
    /// Training tokens streamed through the jobs.
    pub tokens: u64,
    /// Number of jobs trained.
    pub jobs: usize,
}

fn job_key(j: &Job) -> String {
    format!("L{}.{}@{}", j.layer, j.kind, j.variant)
}

/// Enumerate decoupled-BLD jobs for a search space: every non-parent,
/// non-noop variant at every layer.
pub fn decoupled_jobs(space: &SearchSpace, n_layers: usize) -> Vec<Job> {
    let mut jobs = Vec::new();
    for l in 0..n_layers {
        for a in &space.attn {
            match a {
                AttnChoice::Gqa { divisor: 1 } | AttnChoice::NoOp => {}
                _ => jobs.push(Job { layer: l, kind: "attn", variant: a.name() }),
            }
        }
        for f in &space.ffn {
            match f {
                FfnChoice::Ratio(0) | FfnChoice::NoOp => {}
                _ => jobs.push(Job { layer: l, kind: "ffn", variant: f.name() }),
            }
        }
    }
    jobs
}

/// Initialize library weights for a job from the parent (paper §3.2)
/// using calibration activations when provided.
pub fn init_job_weights(
    man: &Manifest,
    store: &mut Store,
    job: &Job,
    calib_h: Option<&Tensor>,
) -> Result<()> {
    let cfg = &man.cfg;
    if job.kind == "attn" {
        let parent = store.block(job.layer, "attn", "gqa_r1", &man.attn_variants["gqa_r1"])?;
        let ws = match AttnChoice::from_name(&job.variant).unwrap() {
            AttnChoice::Gqa { divisor } => init::derive_gqa(cfg, &parent, divisor),
            AttnChoice::Linear => init::derive_attn_linear(&parent),
            AttnChoice::NoOp => return Ok(()),
        };
        let layout = man.attn_variants[&job.variant].clone();
        store.put_block(job.layer, "attn", &job.variant, &layout, ws);
    } else {
        let parent = store.block(job.layer, "ffn", "r100", &man.ffn_variants["r100"])?;
        let ws = match FfnChoice::from_name(&job.variant).unwrap() {
            FfnChoice::Ratio(_) => {
                let i_dim = man.ffn_variants[&job.variant].i_dim;
                init::derive_ffn_ratio(&parent, i_dim, calib_h)
            }
            FfnChoice::Linear => init::derive_ffn_linear(&parent),
            FfnChoice::NoOp => return Ok(()),
        };
        let layout = man.ffn_variants[&job.variant].clone();
        store.put_block(job.layer, "ffn", &job.variant, &layout, ws);
    }
    Ok(())
}

/// Post-norm calibration activations for layer `l`'s FFN: mean over a
/// parent trace batch of the FFN block inputs, flattened to [b*s, d].
/// (Channel Contribution needs the *post-norm* h; the norm is cheap to
/// apply host-side.)
fn calib_hidden(man: &Manifest, store: &Store, trace: &Trace, layer: usize) -> Result<Tensor> {
    let x = val_to_tensor(&trace.ffn_in[layer])?;
    let d = man.cfg.d;
    let t = x.numel() / d;
    let norm = store.get(&block_key(layer, "ffn", "r100", "norm"))?;
    let mut out = Tensor::zeros(&[t, d]);
    let eps = man.cfg.eps as f32;
    for row in 0..t {
        let xs = &x.data[row * d..(row + 1) * d];
        let ms = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for j in 0..d {
            out.data[row * d + j] = xs[j] * r * norm.data[j];
        }
    }
    Ok(out)
}

/// Run decoupled BLD: initialize (§3.2) and train (§3) the whole library.
/// `store` holds the parent and receives the trained library entries.
pub fn run_decoupled(
    be: &dyn Backend,
    store: &mut Store,
    space: &SearchSpace,
    batcher: &mut Batcher,
    steps: usize,
    lr: f32,
) -> Result<BldReport> {
    let man = be.man();
    let n_layers = man.cfg.n_layers;
    let parent_arch = Arch::parent(n_layers);
    let jobs = decoupled_jobs(space, n_layers);
    info!("BLD(decoupled): {} jobs x {} steps", jobs.len(), steps);

    // calibration pass for Channel-Contribution inits
    let parent = CompiledModel::assemble(man, store, &parent_arch)?;
    let calib_batch = batcher.next_batch();
    let calib_trace = parent.forward(be, "train", &calib_batch.inputs, calib_batch.b, calib_batch.s)?;
    for job in &jobs {
        let h = if job.kind == "ffn" {
            Some(calib_hidden(man, store, &calib_trace, job.layer)?)
        } else {
            None
        };
        init_job_weights(man, store, job, h.as_ref())?;
    }

    // one Adam state per job; all jobs share the data stream
    let mut adams: HashMap<String, Adam> =
        jobs.iter().map(|j| (job_key(j), Adam::new(AdamCfg { lr, ..Default::default() }))).collect();
    let mut report = BldReport { jobs: jobs.len(), steps, ..Default::default() };

    for step in 0..steps {
        let batch = batcher.next_batch();
        let parent = CompiledModel::assemble(man, store, &parent_arch)?;
        let trace = parent.forward(be, "train", &batch.inputs, batch.b, batch.s)?;
        report.tokens += (batch.b * batch.s) as u64;
        for job in &jobs {
            let (x, target) = job_io(&trace, job, n_layers);
            let loss = bld_step(be, store, job, x, target, adams.get_mut(&job_key(job)).unwrap())?;
            if step + 1 == steps {
                report.final_loss.insert(job_key(job), loss);
            }
        }
        if step % 10 == 0 {
            let mean: f64 = jobs
                .iter()
                .filter_map(|j| report.final_loss.get(&job_key(j)))
                .sum::<f64>()
                / report.final_loss.len().max(1) as f64;
            crate::debug!("bld step {step}: last mean nmse {mean:.4}");
        }
    }
    Ok(report)
}

/// (input, target) values for a decoupled job from the parent trace.
fn job_io<'a>(trace: &'a Trace, job: &Job, n_layers: usize) -> (&'a Value, &'a Value) {
    if job.kind == "attn" {
        // attn subblock: input = layer input, target = parent attn output
        (&trace.attn_in[job.layer], &trace.ffn_in[job.layer])
    } else {
        // ffn subblock: input = parent attn output, target = layer output
        let target = if job.layer + 1 < n_layers {
            &trace.attn_in[job.layer + 1]
        } else {
            &trace.hidden
        };
        (&trace.ffn_in[job.layer], target)
    }
}

/// One normalized-MSE distillation step of a single subblock.
fn bld_step(
    be: &dyn Backend,
    store: &mut Store,
    job: &Job,
    x: &Value,
    target: &Value,
    adam: &mut Adam,
) -> Result<f64> {
    let man = be.man();
    let layout = if job.kind == "attn" {
        man.attn_variants[&job.variant].clone()
    } else {
        man.ffn_variants[&job.variant].clone()
    };
    let ws = store.block(job.layer, job.kind, &job.variant, &layout)?;
    let vals: Vec<Value> = ws.iter().map(|t| tensor_to_val(t)).collect::<Result<_>>()?;
    let prefix = format!("{}_{}", job.kind, job.variant);

    // forward
    let mut inputs: Vec<&Value> = vec![x];
    inputs.extend(vals.iter());
    let y = be.run(&format!("{prefix}_train_fwd"), &inputs)?.remove(0);

    // normalized MSE + grad
    let yc = val_to_tensor(&y)?;
    let yp = val_to_tensor(target)?;
    let (loss, dy) = nmse_loss_and_grad(&yc, &yp);

    // backward
    let dy_val = tensor_to_val(&dy)?;
    let mut vjp_in: Vec<&Value> = vec![x];
    vjp_in.extend(vals.iter());
    vjp_in.push(&dy_val);
    let mut out = be.run(&format!("{prefix}_train_vjp"), &vjp_in)?;
    out.remove(0); // dx unused — inputs are parent activations

    adam.begin_step();
    for ((name, _), dval) in layout.weights.iter().zip(out) {
        let key = block_key(job.layer, job.kind, &job.variant, name);
        let g = val_to_tensor(&dval)?;
        let w = store.map.get_mut(&key).unwrap();
        adam.update(&key, w, &g, 1.0);
    }
    Ok(loss)
}

/// Coupled BLD (§8.1.1): train (attention, FFN) pairs jointly against the
/// parent *block* output, on a reduced search space.
pub fn run_coupled(
    be: &dyn Backend,
    store: &mut Store,
    space: &SearchSpace,
    batcher: &mut Batcher,
    steps: usize,
    lr: f32,
) -> Result<BldReport> {
    let man = be.man();
    let n_layers = man.cfg.n_layers;
    let parent_arch = Arch::parent(n_layers);

    // pairs of trainable variants (skip pure-parent pair; noop handled by MIP)
    let mut pairs: Vec<(usize, AttnChoice, FfnChoice)> = Vec::new();
    for l in 0..n_layers {
        for a in &space.attn {
            for f in &space.ffn {
                if matches!(a, AttnChoice::NoOp) || matches!(f, FfnChoice::NoOp) {
                    continue;
                }
                if matches!(a, AttnChoice::Gqa { divisor: 1 }) && matches!(f, FfnChoice::Ratio(0)) {
                    continue;
                }
                pairs.push((l, *a, *f));
            }
        }
    }
    info!("BLD(coupled): {} pairs x {} steps", pairs.len(), steps);

    // initialize any missing variant weights from the parent
    let parent = CompiledModel::assemble(man, store, &parent_arch)?;
    let calib_batch = batcher.next_batch();
    let calib = parent.forward(be, "train", &calib_batch.inputs, calib_batch.b, calib_batch.s)?;
    for (l, a, f) in &pairs {
        for (kind, variant) in [("attn", a.name()), ("ffn", f.name())] {
            let job = Job { layer: *l, kind: if kind == "attn" { "attn" } else { "ffn" }, variant };
            let exists = match job.kind {
                "attn" => store.has(&block_key(*l, "attn", &job.variant, "norm")),
                _ => store.has(&block_key(*l, "ffn", &job.variant, "norm")),
            };
            if !exists {
                let h = if job.kind == "ffn" { Some(calib_hidden(man, store, &calib, *l)?) } else { None };
                init_job_weights(man, store, &job, h.as_ref())?;
            }
        }
    }

    let mut adams: HashMap<String, Adam> = pairs
        .iter()
        .map(|(l, a, f)| {
            (format!("L{l}.{}+{}", a.name(), f.name()), Adam::new(AdamCfg { lr, ..Default::default() }))
        })
        .collect();
    let mut report = BldReport { jobs: pairs.len(), steps, ..Default::default() };

    for _step in 0..steps {
        let batch = batcher.next_batch();
        let parent = CompiledModel::assemble(man, store, &parent_arch)?;
        let trace = parent.forward(be, "train", &batch.inputs, batch.b, batch.s)?;
        report.tokens += (batch.b * batch.s) as u64;
        for (l, a, f) in &pairs {
            let key = format!("L{l}.{}+{}", a.name(), f.name());
            let x = &trace.attn_in[*l];
            let target =
                if *l + 1 < n_layers { &trace.attn_in[*l + 1] } else { &trace.hidden };
            let loss =
                coupled_step(be, store, *l, a, f, x, target, adams.get_mut(&key).unwrap())?;
            report.final_loss.insert(key, loss);
        }
    }
    Ok(report)
}

/// One coupled step: forward attn -> ffn, nMSE on block output, backward
/// through both subblocks.
#[allow(clippy::too_many_arguments)]
fn coupled_step(
    be: &dyn Backend,
    store: &mut Store,
    layer: usize,
    a: &AttnChoice,
    f: &FfnChoice,
    x: &Value,
    target: &Value,
    adam: &mut Adam,
) -> Result<f64> {
    let man = be.man();
    let la = man.attn_variants[&a.name()].clone();
    let lf = man.ffn_variants[&f.name()].clone();
    let wa: Vec<Value> = store
        .block(layer, "attn", &a.name(), &la)?
        .iter()
        .map(|t| tensor_to_val(t))
        .collect::<Result<_>>()?;
    let wf: Vec<Value> = store
        .block(layer, "ffn", &f.name(), &lf)?
        .iter()
        .map(|t| tensor_to_val(t))
        .collect::<Result<_>>()?;
    let pa = format!("attn_{}", a.name());
    let pf = format!("ffn_{}", f.name());

    let mut in_a: Vec<&Value> = vec![x];
    in_a.extend(wa.iter());
    let mid = be.run(&format!("{pa}_train_fwd"), &in_a)?.remove(0);
    let mut in_f: Vec<&Value> = vec![&mid];
    in_f.extend(wf.iter());
    let y = be.run(&format!("{pf}_train_fwd"), &in_f)?.remove(0);

    let (loss, dy) = nmse_loss_and_grad(&val_to_tensor(&y)?, &val_to_tensor(target)?);
    let dy_val = tensor_to_val(&dy)?;

    let mut vf: Vec<&Value> = vec![&mid];
    vf.extend(wf.iter());
    vf.push(&dy_val);
    let mut of = be.run(&format!("{pf}_train_vjp"), &vf)?;
    let dmid = of.remove(0);
    let mut va: Vec<&Value> = vec![x];
    va.extend(wa.iter());
    va.push(&dmid);
    let mut oa = be.run(&format!("{pa}_train_vjp"), &va)?;
    oa.remove(0);

    adam.begin_step();
    for ((name, _), dval) in lf.weights.iter().zip(of) {
        let key = block_key(layer, "ffn", &f.name(), name);
        let g = val_to_tensor(&dval)?;
        adam.update(&key, store.map.get_mut(&key).unwrap(), &g, 1.0);
    }
    for ((name, _), dval) in la.weights.iter().zip(oa) {
        let key = block_key(layer, "attn", &a.name(), name);
        let g = val_to_tensor(&dval)?;
        adam.update(&key, store.map.get_mut(&key).unwrap(), &g, 1.0);
    }
    Ok(loss)
}
