//! Continuous-batching inference engine over the block executables of any
//! `Backend`.
//!
//! Slots are fixed by the decode executables' compiled batch (`b_decode`);
//! admission is gated by the variable-GQA paged KV manager; prefill runs
//! at batch 1 and seeds the slot's dense cache; decode steps all active
//! slots together with per-sequence positions (the paper's §4.1 point that
//! batched decode amortizes weight reads is physical here too). Greedy
//! sampling; stop on EOS / max_new / cache horizon.
//!
//! Prompts longer than the prefill window are *chunked*: the first
//! `s_prefill` tokens go through the prefill executable, the remainder is
//! streamed through decode steps (teacher-forcing the known prompt tokens)
//! before generation starts — no silent truncation. Prompts that cannot
//! fit the cache horizon at all are rejected at submit.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::{Arch, AttnChoice};
use crate::data::world::EOS;
use crate::model::CompiledModel;
use crate::runtime::{val_f32, val_i32, val_to_tensor, Backend, Value};
use crate::weights::Store;

use super::kvcache::{PageCfg, PagedKvManager};
use super::metrics::EngineMetrics;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

impl Request {
    /// The sequence's full cache horizon: what `can_admit` checks and
    /// `prefill` reserves. Deriving both from one place is what makes the
    /// no-deadlock invariant structural.
    fn horizon(&self, s_max: usize) -> usize {
        (self.prompt.len() + self.max_new).min(s_max)
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub ttft_secs: f64,
    pub e2e_secs: f64,
}

struct Slot {
    req: Request,
    generated: Vec<u32>,
    /// next position to write (== tokens so far)
    len: usize,
    last_token: u32,
    /// prompt tokens beyond the prefill window, still to be teacher-forced
    pending: VecDeque<u32>,
    t_submit: Instant,
    t_first: Option<Instant>,
}

/// Per-layer decode cache (gqa layers only).
struct LayerCache {
    k: Value,
    v: Value,
    kv_heads: usize,
}

/// Exec names precomputed per layer (perf: the decode loop used to
/// `format!` two strings per layer per step — see EXPERIMENTS.md §Perf).
struct LayerExecs {
    attn_prefill: Option<String>,
    attn_decode: Option<String>,
    ffn_prefill: Option<String>,
    ffn_decode: Option<String>,
}

pub struct Engine<'a> {
    be: &'a dyn Backend,
    model: CompiledModel,
    caches: Vec<Option<LayerCache>>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(Request, Instant)>,
    execs: Vec<LayerExecs>,
    paged: PagedKvManager,
    pub metrics: EngineMetrics,
    finished: Vec<Response>,
    next_id: u64,
}

impl<'a> Engine<'a> {
    pub fn new(be: &'a dyn Backend, store: &Store, arch: &Arch, kv_budget_bytes: usize) -> Result<Engine<'a>> {
        let man = be.man();
        let cfg = &man.cfg;
        let model = CompiledModel::assemble(man, store, arch)?;
        let mut caches = Vec::with_capacity(arch.n_layers());
        for (a, _) in arch.layers.iter() {
            match a {
                AttnChoice::Gqa { .. } => {
                    let kv = man.attn_variants[&a.name()].kv_heads;
                    let shape = [cfg.b_decode, cfg.s_max, kv, cfg.head_dim];
                    let zeros = vec![0f32; shape.iter().product()];
                    caches.push(Some(LayerCache {
                        k: val_f32(&shape, &zeros)?,
                        v: val_f32(&shape, &zeros)?,
                        kv_heads: kv,
                    }));
                }
                _ => caches.push(None),
            }
        }
        let paged = PagedKvManager::new(
            man,
            arch,
            PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: kv_budget_bytes },
        );
        let execs = (0..arch.n_layers())
            .map(|l| LayerExecs {
                attn_prefill: model.attn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                attn_decode: model.attn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
                ffn_prefill: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                ffn_decode: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
            })
            .collect();
        Ok(Engine {
            be,
            model,
            caches,
            slots: (0..cfg.b_decode).map(|_| None).collect(),
            queue: VecDeque::new(),
            execs,
            paged,
            metrics: EngineMetrics::default(),
            finished: Vec::new(),
            next_id: 1,
        })
    }

    /// Enqueue a request. Rejects prompts the engine can never serve:
    /// empty prompts and prompts that fill the whole cache horizon leaving
    /// no room for a generated token.
    pub fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> Result<u64> {
        let s_max = self.be.man().cfg.s_max;
        if prompt.is_empty() {
            self.metrics.rejected_prompts += 1;
            return Err(anyhow!("empty prompt"));
        }
        if prompt.len() >= s_max {
            self.metrics.rejected_prompts += 1;
            return Err(anyhow!(
                "prompt of {} tokens cannot fit the cache horizon s_max={} (needs >= 1 slot for generation)",
                prompt.len(),
                s_max
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((Request { id, prompt, max_new }, Instant::now()));
        Ok(id)
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Admit queued requests into free slots (router policy: FIFO).
    fn admit(&mut self) -> Result<()> {
        while let Some(slot_idx) = self.free_slot() {
            let Some((req, _t)) = self.queue.front() else { break };
            let horizon = req.horizon(self.be.man().cfg.s_max);
            if !self.paged.can_admit(horizon) {
                break; // backpressure: wait for a release
            }
            let (req, t_submit) = self.queue.pop_front().unwrap();
            self.prefill(slot_idx, req, t_submit)?;
        }
        Ok(())
    }

    /// Prefill a prompt at batch 1 and seed the slot's caches. Prompts
    /// longer than the prefill window leave their tail in `pending`, to be
    /// teacher-forced through decode steps before generation starts.
    ///
    /// Pages for the sequence's *full horizon* are reserved here — the
    /// same amount `can_admit` checked — so concurrently admitted
    /// sequences can never jointly over-commit the pool and `grow` cannot
    /// fail mid-generation.
    fn prefill(&mut self, slot_idx: usize, req: Request, t_submit: Instant) -> Result<()> {
        let cfg = &self.be.man().cfg;
        let horizon = req.horizon(cfg.s_max);
        let sp = cfg.s_prefill;
        let plen = req.prompt.len().min(sp);
        let chunked = req.prompt.len() > sp;
        let mut tokens: Vec<i32> = req.prompt.iter().take(plen).map(|&t| t as i32).collect();
        tokens.resize(sp, 0); // right-pad; causal masking isolates the pad
        let tok = val_i32(&[1, sp], &tokens)?;
        let t_exec = Instant::now();
        let mut x = self.be.run("embed_prefill", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_prefill {
                None => {}
                Some(exec) => {
                    let mut inputs: Vec<&Value> = vec![&x];
                    inputs.extend(blk.vals.iter());
                    let mut out = self.be.run(exec, &inputs)?;
                    x = out.remove(0);
                    if let Some(cache) = &mut self.caches[l] {
                        // splice rows [0, plen) of the prefill K/V into this
                        // slot's lane, in place (Values are host-resident)
                        let row = cache.kv_heads * cfg.head_dim;
                        let smax = cfg.s_max;
                        let k_new = out[0].as_f32()?;
                        let kc = cache.k.as_f32_mut()?;
                        for p in 0..plen {
                            let dst = (slot_idx * smax + p) * row;
                            kc.data[dst..dst + row].copy_from_slice(&k_new.data[p * row..(p + 1) * row]);
                        }
                        let v_new = out[1].as_f32()?;
                        let vc = cache.v.as_f32_mut()?;
                        for p in 0..plen {
                            let dst = (slot_idx * smax + p) * row;
                            vc.data[dst..dst + row].copy_from_slice(&v_new.data[p * row..(p + 1) * row]);
                        }
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_prefill {
                let mut inputs: Vec<&Value> = vec![&x];
                inputs.extend(blk.vals.iter());
                x = self.be.run(exec, &inputs)?.remove(0);
            }
        }
        if chunked {
            // the prompt continues past the window: the true next token is
            // known, so skip the head matmul entirely and stream the tail
            // through decode steps.
            self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
            self.paged.admit(req.id, horizon);
            self.metrics.prefills += 1;
            self.metrics.prompt_tokens += req.prompt.len();
            self.metrics.chunked_prefills += 1;
            let mut pending: VecDeque<u32> = req.prompt[plen..].iter().copied().collect();
            let first_pending = pending.pop_front().unwrap();
            let slot = Slot {
                req,
                generated: vec![],
                len: plen,
                last_token: first_pending,
                pending,
                t_submit,
                t_first: None,
            };
            self.slots[slot_idx] = Some(slot);
            return Ok(());
        }

        let logits =
            self.be.run("head_prefill", &[&x, &self.model.final_norm, &self.model.embed])?.remove(0);
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        self.paged.admit(req.id, horizon);
        self.metrics.prefills += 1;
        self.metrics.prompt_tokens += req.prompt.len();

        let logits = val_to_tensor(&logits)?;
        // greedy next token from the last prompt position
        let v = cfg.v;
        let rowbase = (plen - 1) * v;
        let first = argmax(&logits.data[rowbase..rowbase + v]) as u32;

        let slot = Slot {
            req,
            generated: vec![first],
            len: plen,
            last_token: first,
            pending: VecDeque::new(),
            t_submit,
            t_first: Some(Instant::now()),
        };
        self.metrics
            .ttft
            .push(slot.t_first.unwrap().duration_since(slot.t_submit).as_secs_f64());
        self.metrics.generated_tokens += 1;
        // immediate completion checks
        if first == EOS || slot.req.max_new <= 1 {
            self.finish(Some(slot));
            return Ok(());
        }
        self.slots[slot_idx] = Some(slot);
        Ok(())
    }

    /// One batched decode step over all active slots.
    fn decode_step(&mut self) -> Result<()> {
        let cfg = &self.be.man().cfg;
        let bd = cfg.b_decode;
        let t_step = Instant::now();
        let mut tokens = vec![0i32; bd];
        let mut pos = vec![0i32; bd];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[i] = s.last_token as i32;
                pos[i] = s.len as i32;
            }
        }
        let tok = val_i32(&[bd, 1], &tokens)?;
        let pos_val = val_i32(&[bd], &pos)?;
        let t_exec = Instant::now();
        let mut x = self.be.run("embed_decode", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_decode {
                None => {}
                Some(exec) => {
                    if let Some(cache) = &mut self.caches[l] {
                        let mut inputs: Vec<&Value> = vec![&x, &cache.k, &cache.v, &pos_val];
                        inputs.extend(blk.vals.iter());
                        let mut out = self.be.run(exec, &inputs)?;
                        x = out.remove(0);
                        cache.v = out.pop().unwrap();
                        cache.k = out.pop().unwrap();
                    } else {
                        // linear attention: stateless decode
                        let mut inputs: Vec<&Value> = vec![&x];
                        inputs.extend(blk.vals.iter());
                        x = self.be.run(exec, &inputs)?.remove(0);
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_decode {
                let mut inputs: Vec<&Value> = vec![&x];
                inputs.extend(blk.vals.iter());
                x = self.be.run(exec, &inputs)?.remove(0);
            }
        }
        // the LM head is only needed if some slot will actually sample this
        // step; while every active slot is still teacher-forcing a chunked
        // prompt tail, its output would be discarded wholesale.
        let sampling = self.slots.iter().flatten().any(|s| s.pending.is_empty());
        let logits = if sampling {
            let l = self
                .be
                .run("head_decode", &[&x, &self.model.final_norm, &self.model.embed])?
                .remove(0);
            Some(val_to_tensor(&l)?)
        } else {
            None
        };
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        let v = cfg.v;

        let mut to_finish = Vec::new();
        for i in 0..bd {
            let Some(slot) = &mut self.slots[i] else { continue };
            // no per-step page growth: the full horizon was reserved at
            // admission, and the done-checks below keep `len` inside it
            slot.len += 1;
            debug_assert!(slot.len < self.be.man().cfg.s_max);
            if let Some(next_prompt_tok) = slot.pending.pop_front() {
                // still consuming the prompt tail: the model's prediction is
                // discarded, the true prompt token is teacher-forced.
                slot.last_token = next_prompt_tok;
                continue;
            }
            let logits = logits.as_ref().expect("sampling slot implies head ran");
            let next = argmax(&logits.data[i * v..(i + 1) * v]) as u32;
            if slot.t_first.is_none() {
                // first *generated* token of a chunked prompt
                slot.t_first = Some(Instant::now());
                self.metrics
                    .ttft
                    .push(slot.t_first.unwrap().duration_since(slot.t_submit).as_secs_f64());
            }
            slot.generated.push(next);
            slot.last_token = next;
            self.metrics.generated_tokens += 1;
            let done = next == EOS
                || slot.generated.len() >= slot.req.max_new
                || slot.len + 1 >= cfg.s_max;
            if done {
                to_finish.push(i);
            }
        }
        for i in to_finish {
            let slot = self.slots[i].take();
            self.finish(slot);
        }
        self.metrics.decode_steps += 1;
        self.metrics.sched_overhead_secs +=
            (t_step.elapsed().as_secs_f64() - t_exec.elapsed().as_secs_f64()).max(0.0);
        Ok(())
    }

    fn finish(&mut self, slot: Option<Slot>) {
        if let Some(slot) = slot {
            self.paged.release(slot.req.id);
            self.metrics.requests_completed += 1;
            self.metrics
                .e2e
                .push(slot.t_submit.elapsed().as_secs_f64());
            self.finished.push(Response {
                id: slot.req.id,
                tokens: slot.generated,
                ttft_secs: slot
                    .t_first
                    .map(|t| t.duration_since(slot.t_submit).as_secs_f64())
                    .unwrap_or(0.0),
                e2e_secs: slot.t_submit.elapsed().as_secs_f64(),
            });
        }
    }

    /// Drive the engine until queue and slots are empty; returns all
    /// responses. Records wall time into metrics.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        loop {
            self.admit()?;
            if self.active() == 0 {
                if self.queue.is_empty() {
                    break;
                }
                // queue non-empty but nothing admitted -> cache stuck
                if self.free_slot().is_some() {
                    return Err(anyhow!("engine stalled: request cannot be admitted"));
                }
            }
            if self.active() > 0 {
                self.decode_step()?;
            }
        }
        self.metrics.wall_secs += t0.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// NaN-safe greedy argmax: NaN logits are skipped (a NaN never wins);
/// all-NaN rows fall back to index 0.
fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if x > xs[b] => best = Some(i),
            _ => {}
        }
    }
    best.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_ignores_nans() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[2.0, f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }
}
