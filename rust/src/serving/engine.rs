//! Continuous-batching inference engine over the block executables of any
//! `Backend` — the v2 serving core.
//!
//! The engine *owns* its backend through a `SharedBackend` handle, so it
//! can outlive the stack frame that built it and move to a server thread
//! (default build; the PJRT handle is `Rc` and stays put). Construction
//! goes through the `EngineConfig` builder (KV budget, page length, queue
//! depth, scheduler policy); requests are `GenRequest`s carrying their
//! own priority and `SamplingParams`; progress is driven by the public
//! `step()`, which returns the `StreamEvent`s produced by that step
//! (tokens, finishes, rejections) — `run_to_completion` is a thin loop
//! over it. `cancel(id)` frees the slot and its KV pages mid-generation.
//!
//! Slots are fixed by the decode executables' compiled batch (`b_decode`);
//! admission is delegated to the configured `Scheduler` and gated by the
//! variable-GQA paged KV manager; prefill runs at batch 1 and seeds the
//! slot's dense cache; decode steps all active slots together with
//! per-sequence positions (the paper's §4.1 point that batched decode
//! amortizes weight reads is physical here too).
//!
//! Prompts longer than the prefill window are *chunked*: the first
//! `s_prefill` tokens go through the prefill executable, the remainder is
//! streamed through decode steps (teacher-forcing the known prompt tokens)
//! before generation starts — no silent truncation. Requests the engine
//! can never serve — empty prompt, `max_new == 0`, prompt filling the
//! whole cache horizon, or a horizon that exceeds the *total* KV budget —
//! are rejected at `submit`.
//!
//! With `EngineConfig::prefill_budget` set, admission stops running
//! prefill to completion: an admitted prompt only books its KV pages and
//! queues ALL its tokens for ingestion, and every `step()` spends at most
//! that many prompt tokens in one teacher-forced multi-token pass (the
//! same fused machinery the speculative verify uses) before the batched
//! decode step runs. One long prompt can therefore never stall a live
//! lane's next token by more than a budget's worth of work — the
//! SLO-aware chunked prefill of DESIGN.md §10 — while outputs stay
//! byte-identical to the synchronous path, because decode-lowered rows
//! are bitwise equal to prefill rows and each request's sampling rng
//! draws the same stream regardless of how its prompt was chunked.
//!
//! With `EngineConfig::prefix_cache` on, cold prefills retain their
//! prompt's page-aligned K/V prefix in a radix tree
//! (`serving::prefixcache`); later prompts sharing that prefix import the
//! rows (`Backend::import_kv`) and teacher-force only the unmatched
//! suffix — a cache-hit generation is byte-identical to the cold miss,
//! because every reference kernel is row-wise bit-identical between the
//! prefill and decode lowerings. Retention also runs when a sequence
//! *finishes*: the committed stream — prompt **and** generated tokens —
//! becomes a shared segment, so a multi-turn conversation whose next
//! prompt extends the previous completion reuses the whole turn
//! (DESIGN.md §9). Cancelled sequences retain nothing.
//!
//! Batched and speculative sequences share the decode lanes (mixed-mode
//! serving): every forward — batched decode steps and spec-path passes
//! alike — parks unfed live lanes at their own cache frontier, where the
//! garbage K/V write is dead by the attention masking rule.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::{Arch, AttnChoice};
use crate::data::world::EOS;
use crate::model::CompiledModel;
use crate::obs::{Event, Tracer};
use crate::runtime::{val_f32, val_i32, val_to_tensor, SharedBackend, Value};
use crate::tensor::Tensor;
use crate::util::Rng;
use crate::weights::Store;

use super::kvcache::{PageCfg, PagedKvManager};
use super::metrics::EngineMetrics;
use super::prefixcache::{align_down, KvSegment, MigratedPrefix, PrefixCache, PrefixHit};
use super::sampling::{sample, SamplingParams};
use super::scheduler::{QueueView, Scheduler, SchedulerKind};

/// A generation request: prompt plus per-request generation policy.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Prompt token ids (non-empty).
    pub prompt: Vec<u32>,
    /// Maximum generated tokens (>= 1).
    pub max_new: usize,
    /// Larger = more urgent; only the `Priority` scheduler looks at it.
    pub priority: i32,
    /// Per-request sampling policy.
    pub sampling: SamplingParams,
}

impl GenRequest {
    /// A default-priority greedy request.
    pub fn new(prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest { prompt, max_new, priority: 0, sampling: SamplingParams::greedy() }
    }

    /// Override the scheduling priority.
    pub fn with_priority(mut self, priority: i32) -> GenRequest {
        self.priority = priority;
        self
    }

    /// Override the sampling policy.
    pub fn with_sampling(mut self, sampling: SamplingParams) -> GenRequest {
        self.sampling = sampling;
        self
    }

    /// The sequence's full cache horizon: what admission checks and
    /// `prefill` reserves. Deriving both from one place is what makes the
    /// no-deadlock invariant structural.
    fn horizon(&self, s_max: usize) -> usize {
        (self.prompt.len() + self.max_new).min(s_max)
    }
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The model emitted the end-of-sequence token.
    Eos,
    /// The request's `max_new` budget is spent.
    MaxNew,
    /// The compiled cache horizon `s_max` is full.
    CacheHorizon,
    /// `cancel(id)` tore the request down.
    Cancelled,
}

impl FinishReason {
    /// Stable lowercase label (metrics, CLI output).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxNew => "max_new",
            FinishReason::CacheHorizon => "cache_horizon",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// What one engine step can emit, in order of occurrence. `Token` carries
/// every *generated* token (teacher-forced prompt-tail tokens are not
/// echoed); `Finished` is terminal per id; `Rejected` is emitted by
/// `submit` for requests that never enter the queue.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// One generated token of request `id`.
    Token { id: u64, tok: u32 },
    /// Request `id` reached a terminal state (exactly once per id).
    Finished { id: u64, reason: FinishReason },
    /// Submit-time validation refused the request.
    Rejected { id: u64, cause: String },
}

#[derive(Debug, Clone)]
/// A finished request's output and latency record.
pub struct Response {
    /// Request id from `submit`.
    pub id: u64,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub finish: FinishReason,
    /// Time to first generated token, seconds.
    pub ttft_secs: f64,
    /// Submit-to-finish latency, seconds.
    pub e2e_secs: f64,
}

/// Engine construction parameters (replaces the v1 positional args).
///
/// ```
/// use puzzle::serving::{EngineConfig, SchedulerKind};
/// let cfg = EngineConfig::new()
///     .kv_budget_bytes(32 << 20)
///     .page_len(8)
///     .max_queue(64)
///     .scheduler(SchedulerKind::Priority);
/// assert_eq!(cfg.page_len, 8);
/// assert_eq!(cfg.scheduler, SchedulerKind::Priority);
/// assert!(cfg.fused_verify, "fused multi-token decode is on by default");
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Total byte budget of the paged KV pool.
    pub kv_budget_bytes: usize,
    /// Positions per KV page.
    pub page_len: usize,
    /// Max waiting requests before `submit` rejects.
    pub max_queue: usize,
    /// Admission policy for the waiting queue.
    pub scheduler: SchedulerKind,
    /// Use the backend's fused multi-token decode for speculative
    /// extensions when it offers one (`Backend::run_fused`); off forces
    /// the sequential-decode lowering (the two produce identical logits —
    /// asserted in the integration tests).
    pub fused_verify: bool,
    /// Enable the radix-tree prefix cache: prompts sharing a page-aligned
    /// prefix with a retained one import its K/V rows and prefill only
    /// the unmatched suffix. Off by default; on backends without a
    /// `Backend::export_kv` implementation (pjrt) the cache disables
    /// itself at the first retention attempt. A cache-hit generation is
    /// byte-identical to the cold-miss generation.
    pub prefix_cache: bool,
    /// Host-byte budget for retained prefix rows; LRU unreferenced
    /// segments are evicted past it (and under KV-pool pressure, so
    /// retention never starves admission).
    pub prefix_retain_budget: usize,
    /// SLO-aware chunked prefill: when set, each `step()` ingests at most
    /// this many queued prompt tokens in one teacher-forced pass before
    /// the batched decode step, instead of prefilling an admitted prompt
    /// to completion inside `admit`. `None` (the default) keeps the
    /// synchronous admit-then-prefill behavior. Outputs are byte-identical
    /// either way (see the module docs).
    pub prefill_budget: Option<usize>,
    /// Request-lifecycle tracer threaded through the engine. Disabled by
    /// default — one branch per call site, no allocation — so there is no
    /// cost unless a handle built by `obs::Tracer::virtual_ticks`/`wall`
    /// is installed. The engine records the full lifecycle (submitted /
    /// admitted / prefill chunks / tokens / finished) plus the step
    /// timeline into it; keep a clone to export after the run.
    pub tracer: Tracer,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            kv_budget_bytes: 64 << 20,
            page_len: 16,
            max_queue: 1024,
            scheduler: SchedulerKind::Fifo,
            fused_verify: true,
            prefix_cache: false,
            prefix_retain_budget: 8 << 20,
            prefill_budget: None,
            tracer: Tracer::disabled(),
        }
    }
}

impl EngineConfig {
    /// Default configuration (64 MiB KV pool, 16-position pages, FIFO).
    pub fn new() -> EngineConfig {
        EngineConfig::default()
    }

    /// Set the total byte budget of the paged KV pool.
    pub fn kv_budget_bytes(mut self, bytes: usize) -> EngineConfig {
        self.kv_budget_bytes = bytes;
        self
    }

    /// Set the number of positions per KV page.
    pub fn page_len(mut self, page_len: usize) -> EngineConfig {
        self.page_len = page_len;
        self
    }

    /// Set the queue depth beyond which `submit` rejects.
    pub fn max_queue(mut self, max_queue: usize) -> EngineConfig {
        self.max_queue = max_queue;
        self
    }

    /// Choose the admission scheduler.
    pub fn scheduler(mut self, kind: SchedulerKind) -> EngineConfig {
        self.scheduler = kind;
        self
    }

    /// Enable/disable the fused multi-token decode path for speculative
    /// extensions (on by default; disabling forces the sequential
    /// lowering, which is useful for equivalence tests and benchmarks).
    pub fn fused_verify(mut self, fused: bool) -> EngineConfig {
        self.fused_verify = fused;
        self
    }

    /// Enable the prefix cache with a host retain budget of
    /// `retain_budget` bytes (see the `prefix_cache` field docs; off by
    /// default).
    pub fn prefix_cache(mut self, on: bool, retain_budget: usize) -> EngineConfig {
        self.prefix_cache = on;
        self.prefix_retain_budget = retain_budget;
        self
    }

    /// Set the per-step prompt-token budget for SLO-aware chunked prefill
    /// (see the `prefill_budget` field docs).
    pub fn prefill_budget(mut self, tokens: usize) -> EngineConfig {
        self.prefill_budget = Some(tokens);
        self
    }

    /// Install a request-lifecycle tracer (see the `tracer` field docs).
    pub fn tracer(mut self, tracer: Tracer) -> EngineConfig {
        self.tracer = tracer;
        self
    }

    /// Assemble the model and build a long-lived engine that owns `be`.
    pub fn build(self, be: SharedBackend, store: &Store, arch: &Arch) -> Result<Engine> {
        Engine::with_config(be, store, arch, self)
    }
}

struct Queued {
    id: u64,
    req: GenRequest,
    t_submit: Instant,
    /// `Engine::steps` at submit time — schedulers see the difference as
    /// the aging term that makes length/affinity policies starvation-free
    submit_step: usize,
}

struct Slot {
    id: u64,
    req: GenRequest,
    rng: Rng,
    generated: Vec<u32>,
    /// next position to write (== tokens so far)
    len: usize,
    last_token: u32,
    /// prompt tokens beyond the prefill window, still to be teacher-forced
    pending: VecDeque<u32>,
    t_submit: Instant,
    t_first: Option<Instant>,
    /// when the previous generated token was sampled (ITL gaps)
    t_last: Option<Instant>,
}

/// A speculative sequence handle: the KV lane it pins and its committed
/// write position. Speculative sequences are driven externally
/// (`specdec::SpecBatch` / `specdec::SpecSession`) through `spec_open` /
/// `spec_extend_batch` / `spec_truncate`, never by the batched `step()`
/// loop; up to `b_decode` of them share the decode lanes.
struct SpecSlot {
    id: u64,
    /// next cache position to write (== positions teacher-forced so far)
    len: usize,
}

/// One entry of a batched teacher-forced extension
/// (`Engine::spec_extend_batch`): which speculative sequence to extend,
/// the tokens to feed, and from which token index logits are wanted.
#[derive(Debug, Clone, Copy)]
pub struct SpecFeed<'a> {
    /// Speculative sequence handle returned by `spec_open`.
    pub id: u64,
    /// Tokens to teacher-force, in order (must be non-empty).
    pub tokens: &'a [u32],
    /// Collect the post-token logits row from this token index on
    /// (`tokens.len()` collects nothing, `0` collects every row).
    pub collect_from: usize,
}

/// One lane's contribution to the internal teacher-forced multi-token
/// forward (`feeds_forward`), shared by the speculative verify path and
/// the budgeted prefill-chunk phase. Unlike the public `SpecFeed` it is
/// lane-addressed and carries its own start position, so it can feed
/// batched slots mid-chunked-prefill as well as speculative sequences.
struct LaneFeed<'a> {
    lane: usize,
    /// committed cache position the first token writes to
    start: usize,
    tokens: &'a [u32],
    /// logits rows wanted from this token index on (`tokens.len()` = none)
    collect_from: usize,
}

/// Per-layer decode cache (gqa layers only).
struct LayerCache {
    k: Value,
    v: Value,
    kv_heads: usize,
}

/// Exec names precomputed per layer (perf: the decode loop used to
/// `format!` two strings per layer per step — see EXPERIMENTS.md §Perf).
struct LayerExecs {
    attn_prefill: Option<String>,
    attn_decode: Option<String>,
    ffn_prefill: Option<String>,
    ffn_decode: Option<String>,
}

/// The continuous-batching inference engine (see the module docs for
/// the lifecycle, and DESIGN.md §4-§6 for the serving API contract).
pub struct Engine {
    be: SharedBackend,
    cfg: EngineConfig,
    model: CompiledModel,
    caches: Vec<Option<LayerCache>>,
    slots: Vec<Option<Slot>>,
    /// speculative sequences, sharing the decode lanes with `slots` (a
    /// lane is free only when both are None at its index)
    spec: Vec<Option<SpecSlot>>,
    /// waiting requests in arrival order (schedulers index into this)
    queue: Vec<Queued>,
    sched: Box<dyn Scheduler>,
    execs: Vec<LayerExecs>,
    paged: PagedKvManager,
    /// Radix-tree prefix cache (`EngineConfig::prefix_cache`); dropped to
    /// `None` when off or when the backend cannot transfer KV rows.
    prefix: Option<PrefixCache>,
    /// Bumped on every retained-set mutation (retain / evict / adopt).
    /// The router caches each replica's probe answers keyed by this
    /// digest: while it is unchanged, the radix tree's retained paths are
    /// unchanged, so `prefix_probe` would return the same answer for the
    /// same prompt (DESIGN.md §13).
    prefix_generation: u64,
    events: Vec<StreamEvent>,
    /// Lifecycle tracer (cloned from the config; disabled = no-op).
    trace: Tracer,
    /// Engine-level counters and latency records.
    pub metrics: EngineMetrics,
    finished: Vec<Response>,
    next_id: u64,
    /// completed `step()` calls — the clock behind scheduler aging
    steps: usize,
}

impl Engine {
    fn with_config(be: SharedBackend, store: &Store, arch: &Arch, cfg: EngineConfig) -> Result<Engine> {
        let man = be.man();
        let mcfg = &man.cfg;
        let model = CompiledModel::assemble(man, store, arch)?;
        let mut caches = Vec::with_capacity(arch.n_layers());
        for (a, _) in arch.layers.iter() {
            match a {
                AttnChoice::Gqa { .. } => {
                    let kv = man.attn_variants[&a.name()].kv_heads;
                    let shape = [mcfg.b_decode, mcfg.s_max, kv, mcfg.head_dim];
                    let zeros = vec![0f32; shape.iter().product()];
                    caches.push(Some(LayerCache {
                        k: val_f32(&shape, &zeros)?,
                        v: val_f32(&shape, &zeros)?,
                        kv_heads: kv,
                    }));
                }
                _ => caches.push(None),
            }
        }
        let paged = PagedKvManager::new(
            man,
            arch,
            PageCfg { page_len: cfg.page_len, dtype_bytes: 4, budget_bytes: cfg.kv_budget_bytes },
        );
        let execs = (0..arch.n_layers())
            .map(|l| LayerExecs {
                attn_prefill: model.attn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                attn_decode: model.attn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
                ffn_prefill: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                ffn_decode: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
            })
            .collect();
        let slots = (0..mcfg.b_decode).map(|_| None).collect();
        let spec = (0..mcfg.b_decode).map(|_| None).collect();
        let sched = cfg.scheduler.build();
        let prefix = if cfg.prefix_cache {
            Some(PrefixCache::new(cfg.page_len, cfg.prefix_retain_budget))
        } else {
            None
        };
        let trace = cfg.tracer.clone();
        Ok(Engine {
            be,
            cfg,
            model,
            caches,
            slots,
            spec,
            queue: Vec::new(),
            sched,
            execs,
            paged,
            prefix,
            prefix_generation: 0,
            events: Vec::new(),
            trace,
            metrics: EngineMetrics::default(),
            finished: Vec::new(),
            next_id: 1,
            steps: 0,
        })
    }

    /// Enqueue a request. Requests the engine can *never* serve are
    /// rejected here rather than stalling later: empty prompts,
    /// `max_new == 0` (prefill would still sample a token), prompts that
    /// fill the whole cache horizon, horizons whose pages exceed the total
    /// KV budget, and queue overflow past `max_queue`.
    pub fn submit(&mut self, req: GenRequest) -> Result<u64> {
        let s_max = self.be.man().cfg.s_max;
        let id = self.next_id;
        self.next_id += 1;
        // batched and speculative sequences coexist (mixed-mode serving):
        // every forward — batched decode steps included — parks unfed
        // live lanes at their own frontier, where garbage K/V writes are
        // dead by the masking rule, so neither mode can corrupt the other
        if req.prompt.is_empty() {
            return Err(self.reject(id, "empty prompt".into()));
        }
        if req.max_new == 0 {
            return Err(self.reject(id, "max_new == 0: nothing to generate".into()));
        }
        if req.prompt.len() >= s_max {
            return Err(self.reject(
                id,
                format!(
                    "prompt of {} tokens cannot fit the cache horizon s_max={} (needs >= 1 slot for generation)",
                    req.prompt.len(),
                    s_max
                ),
            ));
        }
        let horizon = req.horizon(s_max);
        if !self.paged.fits_budget(horizon) {
            return Err(self.reject(
                id,
                format!(
                    "horizon of {} positions needs more KV pages than the total budget of {} bytes",
                    horizon,
                    self.paged.budget_bytes()
                ),
            ));
        }
        if self.queue.len() >= self.cfg.max_queue {
            return Err(self.reject(id, format!("queue full (max_queue = {})", self.cfg.max_queue)));
        }
        self.trace.record(Event::Submitted { id, prompt: req.prompt.len(), max_new: req.max_new });
        self.queue.push(Queued { id, req, t_submit: Instant::now(), submit_step: self.steps });
        Ok(id)
    }

    fn reject(&mut self, id: u64, cause: String) -> anyhow::Error {
        self.metrics.rejected_prompts += 1;
        if self.trace.enabled() {
            self.trace.record(Event::Rejected { id, cause: cause.clone() });
        }
        let err = anyhow!("request {id} rejected: {cause}");
        self.events.push(StreamEvent::Rejected { id, cause });
        err
    }

    /// Cancel a queued or running request: the slot and all its KV pages
    /// are freed immediately and a `Finished{Cancelled}` event plus a
    /// partial `Response` are produced. Returns false for unknown ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(qidx) = self.queue.iter().position(|q| q.id == id) {
            let q = self.queue.remove(qidx);
            // never admitted: no pages held, no tokens generated
            self.emit_terminal(id, vec![], FinishReason::Cancelled, 0.0, q.t_submit.elapsed().as_secs_f64());
            return true;
        }
        if let Some(sidx) = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.id == id))
        {
            let slot = self.slots[sidx].take().unwrap();
            self.finish(sidx, slot, FinishReason::Cancelled);
            return true;
        }
        false
    }

    fn free_slot(&self) -> Option<usize> {
        (0..self.slots.len()).find(|&i| self.slots[i].is_none() && self.spec[i].is_none())
    }

    /// Number of sequences currently holding a decode slot.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Number of waiting requests.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Nothing queued and nothing running.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Name of the configured admission scheduler.
    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    /// Paged-KV accounting, exposed for tests and ops dashboards.
    pub fn kv_allocated_bytes(&self) -> usize {
        self.paged.allocated_bytes()
    }

    /// Is the prefix cache live? (False when configured off, and after it
    /// disabled itself on a backend without KV transfer.)
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Retained prefix segments currently held by the cache.
    pub fn prefix_segments(&self) -> usize {
        self.prefix.as_ref().map(|p| p.segments()).unwrap_or(0)
    }

    /// Pool bytes charged to retained prefix segments (the share of
    /// `kv_allocated_bytes` that outlives individual sequences).
    pub fn prefix_retained_bytes(&self) -> usize {
        self.paged.shared_allocated_bytes()
    }

    /// Evict every unreferenced retained segment (tests and ops; live
    /// references are never broken). Returns the number evicted.
    pub fn clear_prefix_cache(&mut self) -> usize {
        let mut n = 0;
        while self.evict_prefix_lru(None) {
            n += 1;
        }
        n
    }

    /// Sequences currently holding KV pages.
    pub fn kv_active_seqs(&self) -> usize {
        self.paged.active_seqs()
    }

    /// Drain finished responses accumulated so far (streaming consumers;
    /// `run_to_completion` calls this at the end).
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Would a submit be shed at the door right now? (Admission queue at
    /// `max_queue`.) The router sheds only when every replica reports
    /// this.
    pub fn queue_full(&self) -> bool {
        self.queue.len() >= self.cfg.max_queue
    }

    /// Read-only prefix probe: how many leading tokens of `prompt` the
    /// cache could serve from a retained segment (page-aligned, capped at
    /// `prompt.len() - 1`; 0 with the cache off or no match). Unlike a
    /// real lookup this bumps no LRU clock — the router calls it on
    /// *every* replica per placement decision, and only the replica that
    /// actually serves the request should count as using the segment.
    pub fn prefix_probe(&self, prompt: &[u32]) -> usize {
        self.prefix.as_ref().map(|p| p.matched_len(prompt)).unwrap_or(0)
    }

    /// Retained-set digest: a counter bumped on every retain, eviction,
    /// and adoption. Two probes of the same prompt under the same
    /// generation are guaranteed equal, so the router can cache probe
    /// answers and skip the control-channel round-trip while this is
    /// unchanged.
    pub fn prefix_generation(&self) -> u64 {
        self.prefix_generation
    }

    // ---- cross-engine prefix migration (router; DESIGN.md §12) ----

    /// Package this engine's best retained match for `prompt` for
    /// migration to another engine. The rows are **cloned** — the source
    /// segment, its pool charge, and any live references are untouched,
    /// so a mid-migration cancel on either side can never unbalance
    /// refcounts. The export counts as a use (LRU bump): a segment hot
    /// enough to migrate is hot enough to keep. `None` with the cache
    /// off or no match.
    pub fn export_prefix(&mut self, prompt: &[u32]) -> Option<MigratedPrefix> {
        let hit = self.prefix.as_mut()?.lookup(prompt)?;
        let seg = self.prefix.as_ref()?.rows(hit.seg_id).ok()?.truncated(hit.len);
        Some(MigratedPrefix {
            tokens: prompt[..hit.len].to_vec(),
            prompt_tokens: hit.len - hit.gen_tokens,
            src_seg: hit.seg_id,
            seg,
        })
    }

    /// Adopt a prefix exported from another engine: insert the rows as a
    /// fresh retained segment (new local id, zero references) under the
    /// same budget-or-evict rule as local retention, charging the pool
    /// via `retain_shared` exactly like a locally exported segment.
    /// Returns false — leaving all accounting untouched — when the cache
    /// is off, the payload is misaligned or geometrically incompatible
    /// with this engine's caches, the path is already covered locally, or
    /// no room can be made; best-effort by design, like `maybe_retain`.
    pub fn adopt_prefix(&mut self, prefix: MigratedPrefix) -> bool {
        let Some(cache) = &self.prefix else { return false };
        let len = prefix.seg.len;
        if len == 0
            || len % self.cfg.page_len != 0
            || prefix.tokens.len() != len
            || prefix.seg.layers.len() != self.caches.len()
        {
            return false;
        }
        if cache.covered(&prefix.tokens, len) {
            return false; // already held here: nothing to do
        }
        // an aligned f32 segment's host bytes equal its pool bytes, so a
        // geometry mismatch (different kv-head widths) shows up as a
        // byte-count mismatch and is rejected before touching budgets
        let pool_bytes = self.paged.shared_bytes(len);
        if prefix.seg.host_bytes() != pool_bytes {
            return false;
        }
        loop {
            let cache = self.prefix.as_ref().unwrap();
            let fits = cache.fits_retain_budget(pool_bytes)
                && self.paged.allocated_bytes() + pool_bytes <= self.paged.budget_bytes();
            if fits {
                break;
            }
            if !self.evict_prefix_lru(None) {
                return false; // cannot make room: decline the migration
            }
        }
        let gen_from = prefix.prompt_tokens.min(len);
        let seg_id = self.prefix.as_mut().unwrap().insert(&prefix.tokens, prefix.seg, gen_from);
        let retained = self.paged.retain_shared(seg_id, len);
        debug_assert!(retained, "pool fit was just checked");
        if !retained {
            self.prefix.as_mut().unwrap().remove(seg_id);
            return false;
        }
        self.prefix_generation += 1;
        true
    }

    /// Raise the request-id counter to at least `base` (no-op if ids have
    /// already passed it). The router gives replica `i` the base
    /// `(i as u64) << 48` *before* serving starts, so every id is
    /// globally unique and `id >> 48` recovers the owning replica —
    /// `RouterHandle::cancel` routes on exactly that.
    pub fn set_request_id_base(&mut self, base: u64) {
        self.next_id = self.next_id.max(base.max(1));
    }

    /// Admit queued requests into free slots under the configured policy.
    /// If the picked request does not fit the KV pool *right now*, first
    /// evict unreferenced retained prefix segments (retention must never
    /// starve admission), then wait for a release (backpressure) instead
    /// of skipping past it.
    fn admit(&mut self) -> Result<()> {
        let s_max = self.be.man().cfg.s_max;
        // the per-request radix walk is only paid for the scheduler that
        // actually ranks by it; every other policy sees 0
        let rank_by_prefix = self.cfg.scheduler == SchedulerKind::PrefixAffinity;
        while self.free_slot().is_some() && !self.queue.is_empty() {
            let view: Vec<QueueView> = self
                .queue
                .iter()
                .map(|q| QueueView {
                    id: q.id,
                    priority: q.req.priority,
                    prompt_len: q.req.prompt.len(),
                    max_new: q.req.max_new,
                    cached_prefix: if rank_by_prefix {
                        self.prefix
                            .as_ref()
                            .map(|p| p.matched_len(&q.req.prompt))
                            .unwrap_or(0)
                    } else {
                        0
                    },
                    waited: self.steps.saturating_sub(q.submit_step),
                })
                .collect();
            let Some(qidx) = self.sched.pick(&view) else { break };
            debug_assert!(qidx < self.queue.len(), "scheduler returned an out-of-range index");
            let horizon = self.queue[qidx].req.horizon(s_max);
            let mut hit = match &mut self.prefix {
                Some(p) => p.lookup(&self.queue[qidx].req.prompt),
                None => None,
            };
            while !self.admissible(horizon, hit) {
                // evict LRU unreferenced retained segments (never the one
                // this request is about to ride) before giving up
                if !self.evict_prefix_lru(hit.map(|h| h.seg_id)) {
                    break;
                }
            }
            if !self.admissible(horizon, hit) && hit.is_some() {
                // the protected segment itself may be what blocks the pool
                // (a partial hit into a segment longer than its discount):
                // fall back to a cold admission, which may evict it too —
                // this is what keeps "admitted work always fits an idle
                // pool" true with retention in play
                hit = None;
                while !self.admissible(horizon, None) {
                    if !self.evict_prefix_lru(None) {
                        break;
                    }
                }
            }
            if !self.admissible(horizon, hit) {
                break; // backpressure: wait for a release
            }
            let slot_idx = self.free_slot().unwrap();
            let q = self.queue.remove(qidx);
            self.prefill(slot_idx, q, hit)?;
        }
        Ok(())
    }

    /// Does `horizon` fit the pool right now, riding `hit` if present?
    fn admissible(&self, horizon: usize, hit: Option<PrefixHit>) -> bool {
        match hit {
            Some(h) => self.paged.can_admit_shared(horizon, h.len),
            None => self.paged.can_admit(horizon),
        }
    }

    /// Run the prefill executable chain over the first `min(len,
    /// s_prefill)` prompt tokens, splicing each GQA layer's K/V rows into
    /// slot `slot_idx`'s cache lane in place. Returns the final hidden
    /// state (for the optional head matmul) and the number of prompt
    /// tokens the window covered. Shared by the batched admission path and
    /// the speculative `spec_open`.
    fn prefill_window(&mut self, slot_idx: usize, prompt: &[u32]) -> Result<(Value, usize)> {
        let mcfg = &self.be.man().cfg;
        let (s_max, sp, head_dim) = (mcfg.s_max, mcfg.s_prefill, mcfg.head_dim);
        let plen = prompt.len().min(sp);
        let mut tokens: Vec<i32> = prompt.iter().take(plen).map(|&t| t as i32).collect();
        tokens.resize(sp, 0); // right-pad; causal masking isolates the pad
        let tok = val_i32(&[1, sp], &tokens)?;
        let t_exec = Instant::now();
        let mut x = self.be.run("embed_prefill", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_prefill {
                None => {}
                Some(exec) => {
                    let mut inputs: Vec<&Value> = vec![&x];
                    inputs.extend(blk.vals.iter());
                    let mut out = self.be.run(exec, &inputs)?;
                    x = out.remove(0);
                    if let Some(cache) = &mut self.caches[l] {
                        // splice rows [0, plen) of the prefill K/V into this
                        // slot's lane, in place (Values are host-resident)
                        let row = cache.kv_heads * head_dim;
                        let k_new = out[0].as_f32()?;
                        let kc = cache.k.as_f32_mut()?;
                        for p in 0..plen {
                            let dst = (slot_idx * s_max + p) * row;
                            kc.data[dst..dst + row].copy_from_slice(&k_new.data[p * row..(p + 1) * row]);
                        }
                        let v_new = out[1].as_f32()?;
                        let vc = cache.v.as_f32_mut()?;
                        for p in 0..plen {
                            let dst = (slot_idx * s_max + p) * row;
                            vc.data[dst..dst + row].copy_from_slice(&v_new.data[p * row..(p + 1) * row]);
                        }
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_prefill {
                let mut inputs: Vec<&Value> = vec![&x];
                inputs.extend(blk.vals.iter());
                x = self.be.run(exec, &inputs)?.remove(0);
            }
        }
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        Ok((x, plen))
    }

    /// Prefill a prompt at batch 1 and seed the slot's caches. Prompts
    /// longer than the prefill window leave their tail in `pending`, to be
    /// teacher-forced through decode steps before generation starts. On a
    /// prefix-cache hit the prefill executable is skipped entirely: the
    /// matched rows are imported and the whole unmatched suffix rides the
    /// same teacher-forced tail path (byte-identical by the bitwise
    /// prefill≡decode equivalence of the reference kernels).
    ///
    /// Pages for the sequence's *full horizon* are reserved here — the
    /// same amount `can_admit`/`can_admit_shared` checked — so
    /// concurrently admitted sequences can never jointly over-commit the
    /// pool and `grow` cannot fail mid-generation.
    fn prefill(&mut self, slot_idx: usize, q: Queued, hit: Option<PrefixHit>) -> Result<()> {
        let mcfg = &self.be.man().cfg;
        let (s_max, sp, v) = (mcfg.s_max, mcfg.s_prefill, mcfg.v);
        let Queued { id, req, t_submit, .. } = q;
        let horizon = req.horizon(s_max);
        self.trace.record(Event::Admitted {
            id,
            lane: slot_idx,
            hit: hit.is_some(),
            matched: hit.map(|h| h.len).unwrap_or(0),
        });
        if let Some(hit) = hit {
            // admit() checked can_admit_shared for this horizon, so the
            // booking cannot fail here short of an internal bug
            self.admit_prefix_hit(slot_idx, id, hit, horizon)?;
            self.metrics.prompt_tokens += req.prompt.len();
            // the unmatched suffix (>= 1 token by the lookup cap) is
            // teacher-forced through decode steps, exactly like a chunked
            // prompt tail; sampling begins when it is consumed
            let mut pending: VecDeque<u32> = req.prompt[hit.len..].iter().copied().collect();
            let first_pending = pending.pop_front().unwrap();
            let rng = Rng::new(req.sampling.seed);
            self.slots[slot_idx] = Some(Slot {
                id,
                req,
                rng,
                generated: vec![],
                len: hit.len,
                last_token: first_pending,
                pending,
                t_submit,
                t_first: None,
                t_last: None,
            });
            return Ok(());
        }
        if self.prefix.is_some() {
            self.metrics.prefix_misses += 1;
        }
        if self.cfg.prefill_budget.is_some() {
            // SLO-aware chunked prefill: admission only books the pages
            // and queues the WHOLE prompt for budgeted ingestion — no
            // forward runs here, so admitting a long prompt can never
            // stall the live lanes. `prefill_chunks` (and, for whatever
            // the budget leaves over, the teacher-forcing decode steps)
            // ingest the tokens; sampling starts when they are consumed,
            // exactly like the prefix-hit path above.
            self.paged.admit(id, horizon);
            self.metrics.prompt_tokens += req.prompt.len();
            if req.prompt.len() > sp {
                self.metrics.chunked_prefills += 1;
            }
            let mut pending: VecDeque<u32> = req.prompt.iter().copied().collect();
            let first_pending = pending.pop_front().unwrap();
            let rng = Rng::new(req.sampling.seed);
            self.slots[slot_idx] = Some(Slot {
                id,
                req,
                rng,
                generated: vec![],
                len: 0,
                last_token: first_pending,
                pending,
                t_submit,
                t_first: None,
                t_last: None,
            });
            return Ok(());
        }
        let chunked = req.prompt.len() > sp;
        let (x, plen) = self.prefill_window(slot_idx, &req.prompt)?;
        self.trace.record(Event::PrefillChunk { id, lane: slot_idx, tokens: plen });
        if chunked {
            // the prompt continues past the window: the true next token is
            // known, so skip the head matmul entirely and stream the tail
            // through decode steps.
            self.paged.admit(id, horizon);
            self.metrics.prefills += 1;
            self.metrics.prompt_tokens += req.prompt.len();
            self.metrics.chunked_prefills += 1;
            self.maybe_retain(&req.prompt, slot_idx, plen, req.prompt.len());
            let mut pending: VecDeque<u32> = req.prompt[plen..].iter().copied().collect();
            let first_pending = pending.pop_front().unwrap();
            let rng = Rng::new(req.sampling.seed);
            let slot = Slot {
                id,
                req,
                rng,
                generated: vec![],
                len: plen,
                last_token: first_pending,
                pending,
                t_submit,
                t_first: None,
                t_last: None,
            };
            self.slots[slot_idx] = Some(slot);
            return Ok(());
        }

        let t_exec = Instant::now();
        let logits =
            self.be.run("head_prefill", &[&x, &self.model.final_norm, &self.model.embed])?.remove(0);
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        self.paged.admit(id, horizon);
        self.metrics.prefills += 1;
        self.metrics.prompt_tokens += req.prompt.len();
        self.maybe_retain(&req.prompt, slot_idx, plen, req.prompt.len());

        let logits = val_to_tensor(&logits)?;
        // next token from the last prompt position, per-request policy
        let rowbase = (plen - 1) * v;
        let mut rng = Rng::new(req.sampling.seed);
        let first = sample(&logits.data[rowbase..rowbase + v], &req.sampling, &mut rng) as u32;

        let t_first = Instant::now();
        let slot = Slot {
            id,
            req,
            rng,
            generated: vec![first],
            len: plen,
            last_token: first,
            pending: VecDeque::new(),
            t_submit,
            t_first: Some(t_first),
            t_last: Some(t_first),
        };
        self.metrics
            .ttft
            .push(slot.t_first.unwrap().duration_since(slot.t_submit).as_secs_f64());
        self.metrics.generated_tokens += 1;
        if self.trace.enabled() {
            self.trace.record(Event::FirstToken { id });
            self.trace.record(Event::Token { id, tok: first });
        }
        self.events.push(StreamEvent::Token { id, tok: first });
        // immediate completion checks (max_new == 0 is rejected at submit,
        // so max_new == 1 is the only budget exhausted here). The horizon
        // check mirrors decode_step: a prompt of s_max-1 tokens fills the
        // cache with its first sample, and entering decode would write
        // past the compiled horizon.
        let reason = if first == EOS {
            Some(FinishReason::Eos)
        } else if slot.req.max_new <= 1 {
            Some(FinishReason::MaxNew)
        } else if slot.len + 1 >= s_max {
            Some(FinishReason::CacheHorizon)
        } else {
            None
        };
        if let Some(reason) = reason {
            self.finish(slot_idx, slot, reason);
            return Ok(());
        }
        self.slots[slot_idx] = Some(slot);
        Ok(())
    }

    /// One decode forward over the full compiled batch: embed -> blocks
    /// (updating the dense caches in place) -> optionally the LM head.
    /// Shared by the batched `decode_step` and the speculative sequential
    /// lowering; `execute_secs` accrues here.
    fn decode_forward(&mut self, tokens: &[i32], pos: &[i32], with_head: bool) -> Result<Option<Tensor>> {
        let bd = tokens.len();
        let tok = val_i32(&[bd, 1], tokens)?;
        let pos_val = val_i32(&[bd], pos)?;
        let t_exec = Instant::now();
        let mut x = self.be.run("embed_decode", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_decode {
                None => {}
                Some(exec) => {
                    if let Some(cache) = &mut self.caches[l] {
                        let mut inputs: Vec<&Value> = vec![&x, &cache.k, &cache.v, &pos_val];
                        inputs.extend(blk.vals.iter());
                        let mut out = self.be.run(exec, &inputs)?;
                        x = out.remove(0);
                        cache.v = out.pop().unwrap();
                        cache.k = out.pop().unwrap();
                    } else {
                        // linear attention: stateless decode
                        let mut inputs: Vec<&Value> = vec![&x];
                        inputs.extend(blk.vals.iter());
                        x = self.be.run(exec, &inputs)?.remove(0);
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_decode {
                let mut inputs: Vec<&Value> = vec![&x];
                inputs.extend(blk.vals.iter());
                x = self.be.run(exec, &inputs)?.remove(0);
            }
        }
        let logits = if with_head {
            let l = self
                .be
                .run("head_decode", &[&x, &self.model.final_norm, &self.model.embed])?
                .remove(0);
            Some(val_to_tensor(&l)?)
        } else {
            None
        };
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        Ok(logits)
    }

    /// One batched decode step over all active slots.
    fn decode_step(&mut self) -> Result<()> {
        let mcfg = &self.be.man().cfg;
        let (bd, v, s_max) = (mcfg.b_decode, mcfg.v, mcfg.s_max);
        let t_step = Instant::now();
        let mut tokens = vec![0i32; bd];
        let mut pos = vec![0i32; bd];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[i] = s.last_token as i32;
                pos[i] = s.len as i32;
            } else if let Some(sp) = &self.spec[i] {
                // mixed-mode serving: a live speculative sequence sharing
                // the lanes is parked at its own frontier, where the
                // garbage K/V write is dead by the masking rule (the old
                // position-0 write corrupted its committed stream, which
                // is why the modes used to be mutually exclusive)
                pos[i] = sp.len.min(s_max - 1) as i32;
            }
        }
        // the LM head is only needed if some slot will actually sample this
        // step; while every active slot is still teacher-forcing a chunked
        // prompt tail, its output would be discarded wholesale.
        let sampling = self.slots.iter().flatten().any(|s| s.pending.is_empty());
        let exec_before = self.metrics.execute_secs;
        let logits = self.decode_forward(&tokens, &pos, sampling)?;

        let mut to_finish = Vec::new();
        for i in 0..bd {
            let Some(slot) = &mut self.slots[i] else { continue };
            // no per-step page growth: the full horizon was reserved at
            // admission, and the done-checks below keep `len` inside it
            slot.len += 1;
            debug_assert!(slot.len < s_max);
            if let Some(next_prompt_tok) = slot.pending.pop_front() {
                // still consuming the prompt tail: the model's prediction is
                // discarded, the true prompt token is teacher-forced.
                slot.last_token = next_prompt_tok;
                continue;
            }
            let logits = logits.as_ref().expect("sampling slot implies head ran");
            let next =
                sample(&logits.data[i * v..(i + 1) * v], &slot.req.sampling, &mut slot.rng) as u32;
            let now = Instant::now();
            if slot.t_first.is_none() {
                // first *generated* token of a chunked prompt
                slot.t_first = Some(now);
                self.metrics.ttft.push(now.duration_since(slot.t_submit).as_secs_f64());
                self.trace.record(Event::FirstToken { id: slot.id });
            } else if let Some(prev) = slot.t_last {
                // gap since the previous generated token of this request
                self.metrics.itl.push(now.duration_since(prev).as_secs_f64());
            }
            slot.t_last = Some(now);
            slot.generated.push(next);
            slot.last_token = next;
            self.metrics.generated_tokens += 1;
            let id = slot.id;
            let reason = if next == EOS {
                Some(FinishReason::Eos)
            } else if slot.generated.len() >= slot.req.max_new {
                Some(FinishReason::MaxNew)
            } else if slot.len + 1 >= s_max {
                Some(FinishReason::CacheHorizon)
            } else {
                None
            };
            self.trace.record(Event::Token { id, tok: next });
            self.events.push(StreamEvent::Token { id, tok: next });
            if let Some(reason) = reason {
                to_finish.push((i, reason));
            }
        }
        for (i, reason) in to_finish {
            let slot = self.slots[i].take().unwrap();
            self.finish(i, slot, reason);
        }
        self.metrics.decode_steps += 1;
        let exec_delta = self.metrics.execute_secs - exec_before;
        self.metrics.sched_overhead_secs += (t_step.elapsed().as_secs_f64() - exec_delta).max(0.0);
        Ok(())
    }

    /// Terminal path for the batched slot that occupied `lane`. Before
    /// the pages go back to the pool, the sequence's *committed* tokens —
    /// prompt AND generated — are offered to the prefix cache
    /// (generated-token retention, DESIGN.md §9): a later prompt that
    /// extends this turn's full prompt+completion (the multi-turn
    /// pattern) then rides the whole turn instead of re-prefilling it.
    /// Cancelled sequences retain nothing — a partially teacher-forced
    /// prompt must never become a reusable segment.
    fn finish(&mut self, lane: usize, slot: Slot, reason: FinishReason) {
        if reason != FinishReason::Cancelled && self.prefix.is_some() {
            // lane rows [0, slot.len) hold prompt ++ generated minus the
            // newest sampled token (which has no K/V row yet), so a
            // retention capped at `slot.len` is always row-backed
            let mut toks = slot.req.prompt.clone();
            toks.extend_from_slice(&slot.generated);
            let ingested = slot.len.min(toks.len());
            self.maybe_retain(&toks, lane, ingested, slot.req.prompt.len());
        }
        self.paged.release(slot.id);
        let ttft = slot
            .t_first
            .map(|t| t.duration_since(slot.t_submit).as_secs_f64())
            .unwrap_or(0.0);
        let e2e = slot.t_submit.elapsed().as_secs_f64();
        self.emit_terminal(slot.id, slot.generated, reason, ttft, e2e);
    }

    /// Shared terminal-state protocol for every way a request ends:
    /// metrics, `Finished` event, and the `Response` record.
    fn emit_terminal(&mut self, id: u64, tokens: Vec<u32>, reason: FinishReason, ttft_secs: f64, e2e_secs: f64) {
        self.metrics.record_finish(reason);
        if reason != FinishReason::Cancelled {
            self.metrics.requests_completed += 1;
            self.metrics.e2e.push(e2e_secs);
        }
        self.trace.record(Event::Finished { id, reason: reason.as_str(), tokens: tokens.len() });
        self.events.push(StreamEvent::Finished { id, reason });
        self.finished.push(Response { id, tokens, finish: reason, ttft_secs, e2e_secs });
    }

    // ---- prefix-cache internals (`serving::prefixcache` holds the ----
    // ---- radix tree; `PagedKvManager` holds the shared accounting) ----

    /// Evict the least-recently-used retained segment without live
    /// references (skipping `protect`, the segment an admission is about
    /// to ride). Returns false when nothing is evictable.
    fn evict_prefix_lru(&mut self, protect: Option<u64>) -> bool {
        let Some(cache) = &self.prefix else { return false };
        let candidate = cache
            .lru_order()
            .into_iter()
            .find(|&id| Some(id) != protect && self.paged.seg_refs(id) == Some(0));
        let Some(id) = candidate else { return false };
        let seg_tokens = cache.rows(id).map(|s| s.len).unwrap_or(0);
        self.prefix.as_mut().unwrap().remove(id);
        let evicted = self.paged.evict_shared(id);
        debug_assert!(evicted, "unreferenced segment must evict cleanly");
        self.metrics.prefix_evictions += 1;
        self.prefix_generation += 1;
        self.trace.record(Event::PrefixEvict { seg: id, tokens: seg_tokens });
        true
    }

    /// Book pages for a prefix-cache hit and import its rows into `lane`
    /// for sequence `id`, reserving `positions` total positions — shared
    /// by the batched admission path and `spec_open`. Rolls the booking
    /// back if the import fails, and bumps the hit metrics.
    fn admit_prefix_hit(&mut self, lane: usize, id: u64, hit: PrefixHit, positions: usize) -> Result<()> {
        if !self.paged.admit_shared(id, positions, hit.seg_id, hit.len) {
            return Err(anyhow!("prefix hit admission: KV budget exhausted"));
        }
        if let Err(e) = self.import_segment(lane, hit.seg_id, hit.len) {
            self.paged.release(id);
            return Err(e);
        }
        self.metrics.prefix_hits += 1;
        self.metrics.prefix_tokens_saved += hit.len;
        if hit.gen_tokens > 0 {
            // part of the reused prefix was *generated* by an earlier
            // sequence (finish-time retention) — the multi-turn win
            self.metrics.prefix_gen_hits += 1;
            self.metrics.prefix_gen_tokens_saved += hit.gen_tokens;
        }
        Ok(())
    }

    /// Copy the first `len` positions of a retained segment into lane
    /// `lane` of every caching layer via `Backend::import_kv` (rows land
    /// at positions `[0, len)`, bitwise as exported). `len` may be
    /// shorter than the segment — a partial match imports only the
    /// matched rows, never another prompt's diverging tail.
    fn import_segment(&mut self, lane: usize, seg_id: u64, len: usize) -> Result<()> {
        let be = self.be.clone();
        let Some(cache) = &self.prefix else {
            return Err(anyhow!("prefix cache is disabled"));
        };
        let seg = cache.rows(seg_id)?;
        debug_assert_eq!(seg.layers.len(), self.caches.len());
        if len > seg.len {
            return Err(anyhow!("import of {len} rows from a {}-row segment", seg.len));
        }
        for (l, lc) in self.caches.iter_mut().enumerate() {
            let Some(lc) = lc else { continue };
            let Some((k_rows, v_rows)) = &seg.layers[l] else {
                return Err(anyhow!("prefix segment {seg_id} is missing layer {l} rows"));
            };
            let row = k_rows.len() / seg.len;
            if !be.import_kv(&mut lc.k, lane, 0, len, &k_rows[..len * row])?
                || !be.import_kv(&mut lc.v, lane, 0, len, &v_rows[..len * row])?
            {
                return Err(anyhow!("backend refused import_kv after exporting (layer {l})"));
            }
        }
        Ok(())
    }

    /// Export the first `len` positions of lane `lane` across all caching
    /// layers. `Ok(None)` means the backend cannot transfer KV — the
    /// caller disables the prefix cache.
    fn export_segment(&self, lane: usize, len: usize) -> Result<Option<KvSegment>> {
        let mut layers = Vec::with_capacity(self.caches.len());
        for lc in &self.caches {
            match lc {
                None => layers.push(None),
                Some(lc) => {
                    let Some(k_rows) = self.be.export_kv(&lc.k, lane, 0, len)? else {
                        return Ok(None);
                    };
                    let Some(v_rows) = self.be.export_kv(&lc.v, lane, 0, len)? else {
                        return Ok(None);
                    };
                    layers.push(Some((k_rows, v_rows)));
                }
            }
        }
        Ok(Some(KvSegment { len, layers }))
    }

    /// After lane `lane` ingested `ingested` tokens of `tokens` (a cold
    /// prefill's prompt window, or a finished sequence's full committed
    /// stream), retain the page-aligned prefix for future requests —
    /// unless it is already covered, too short, or neither the host
    /// retain budget nor the KV pool can take it even after evicting LRU
    /// unreferenced segments. The first `prompt_len` tokens are
    /// prompt-origin; anything past that was *generated* (finish-time
    /// retention), which the cache records so hits over it can be
    /// attributed. Retention is strictly best-effort and can never fail
    /// the (already admitted) request: a backend that cannot export —
    /// `Ok(None)` or an outright error — just disables the cache.
    fn maybe_retain(&mut self, tokens: &[u32], lane: usize, ingested: usize, prompt_len: usize) {
        let Some(cache) = &self.prefix else { return };
        let retain_len = align_down(ingested.min(tokens.len()), self.cfg.page_len);
        if retain_len == 0 || cache.covered(tokens, retain_len) {
            return;
        }
        // budgets first, export second: a page-aligned f32 segment's host
        // bytes equal its pool bytes, so both budgets are checkable before
        // paying for the row copies (otherwise every cold prefill under a
        // full, pinned retain budget would export and discard a segment)
        let pool_bytes = self.paged.shared_bytes(retain_len);
        loop {
            let cache = self.prefix.as_ref().unwrap();
            let fits = cache.fits_retain_budget(pool_bytes)
                && self.paged.allocated_bytes() + pool_bytes <= self.paged.budget_bytes();
            if fits {
                break;
            }
            if !self.evict_prefix_lru(None) {
                return; // cannot make room: skip retention
            }
        }
        let seg = match self.export_segment(lane, retain_len) {
            Ok(Some(seg)) => seg,
            // backend keeps its caches out of reach (or failed mid-export):
            // disable the cache rather than fail the admitted request
            // (every probe answer changes, so the digest must move too)
            Ok(None) | Err(_) => {
                self.prefix = None;
                self.prefix_generation += 1;
                return;
            }
        };
        debug_assert_eq!(seg.host_bytes(), pool_bytes, "aligned f32 rows: host == pool bytes");
        let seg_id = self.prefix.as_mut().unwrap().insert(tokens, seg, prompt_len.min(retain_len));
        let retained = self.paged.retain_shared(seg_id, retain_len);
        debug_assert!(retained, "pool fit was just checked");
        if !retained {
            self.prefix.as_mut().unwrap().remove(seg_id);
            return;
        }
        self.prefix_generation += 1;
    }

    /// The budgeted prefill-chunk phase of `step()` (no-op without
    /// `EngineConfig::prefill_budget`): spend up to the budget in prompt
    /// tokens teacher-forcing the pending tails of admitted slots, all in
    /// ONE multi-token pass over the decode lanes (fused when the backend
    /// offers it, the sequential lowering otherwise — identical K/V
    /// either way). The budget is allocated in lane order; no page ops
    /// are needed because admission reserved each sequence's full
    /// horizon. No logits are collected — every fed token is a known
    /// prompt token — so the vocab-sized head never runs here.
    ///
    /// The head-of-line bound this buys: a step's prompt-ingestion work
    /// is at most `budget` tokens, so admitting an arbitrarily long
    /// prompt delays a live lane's next token by at most one budget's
    /// worth of extra forward work (the regression test pins this).
    fn prefill_chunks(&mut self) -> Result<()> {
        let Some(budget) = self.cfg.prefill_budget else { return Ok(()) };
        let mut left = budget;
        // plan first (owned token chunks), then run one pass, then commit
        // slot state — a failed pass leaves every slot untouched, with
        // pages still matching the reserved horizon
        let mut plan: Vec<(usize, usize, Vec<u32>)> = Vec::new(); // (lane, start, chunk)
        for lane in 0..self.slots.len() {
            if left == 0 {
                break;
            }
            let Some(slot) = &self.slots[lane] else { continue };
            if slot.pending.is_empty() {
                continue;
            }
            // the chunk re-feeds `last_token` (the next unwritten
            // position's token) followed by the pending head; the final
            // pending token is deliberately left to become the new
            // `last_token`, whose row the sampling decode step writes
            let c = left.min(slot.pending.len());
            let mut chunk = Vec::with_capacity(c);
            chunk.push(slot.last_token);
            chunk.extend(slot.pending.iter().take(c - 1).copied());
            left -= c;
            plan.push((lane, slot.len, chunk));
        }
        if plan.is_empty() {
            return Ok(());
        }
        let feeds: Vec<LaneFeed> = plan
            .iter()
            .map(|(lane, start, chunk)| LaneFeed {
                lane: *lane,
                start: *start,
                tokens: chunk,
                collect_from: chunk.len(),
            })
            .collect();
        self.feeds_forward(&feeds)?;
        let mut done: Vec<usize> = Vec::new();
        let mut fed = 0usize;
        for (lane, _, chunk) in &plan {
            let slot = self.slots[*lane].as_mut().unwrap();
            let c = chunk.len();
            self.trace.record(Event::PrefillChunk { id: slot.id, lane: *lane, tokens: c });
            slot.len += c;
            for _ in 0..c - 1 {
                slot.pending.pop_front();
            }
            slot.last_token = slot.pending.pop_front().expect("chunk size is capped at pending");
            fed += c;
            if slot.pending.is_empty() {
                done.push(*lane);
            }
        }
        self.metrics.prefill_chunk_passes += 1;
        self.metrics.prefill_chunk_tokens += fed;
        // prompt fully ingested: offer its page-aligned prefix to the
        // cache now (the budgeted analog of the cold path's window-time
        // retention), so same-prefix requests already queued get hits
        for lane in done {
            let slot = self.slots[lane].as_ref().unwrap();
            let (prompt, ingested) = (slot.req.prompt.clone(), slot.len);
            let prompt_len = prompt.len();
            self.maybe_retain(&prompt, lane, ingested, prompt_len);
        }
        Ok(())
    }

    /// One engine iteration: admit waiting requests into free slots
    /// (running their prefills, or just booking pages under a
    /// `prefill_budget`), spend the prefill-chunk budget if one is
    /// configured, then run one batched decode step over the active
    /// slots. Returns the stream events produced by this step, in order.
    /// Wall time accrues here, so step-driven and `run_to_completion`
    /// callers see the same throughput metrics.
    pub fn step(&mut self) -> Result<Vec<StreamEvent>> {
        let t0 = Instant::now();
        let ts = self.trace.now_us();
        self.admit()?;
        self.prefill_chunks()?;
        if self.active() > 0 {
            self.decode_step()?;
        }
        self.steps += 1;
        self.metrics.wall_secs += t0.elapsed().as_secs_f64();
        if self.trace.enabled() {
            self.trace.record_at(
                ts,
                Event::Step {
                    step: (self.steps - 1) as u64,
                    active: self.active(),
                    queued: self.queue.len(),
                    dur_us: self.trace.now_us().saturating_sub(ts),
                },
            );
        }
        Ok(std::mem::take(&mut self.events))
    }

    /// Drive `step()` until queue and slots are empty; returns all
    /// responses (the streaming events are dropped — use `step()` directly
    /// to observe them).
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.is_idle() {
            // a stall is a step that started with nothing running and still
            // could not admit anything. `active_before` matters: when the
            // last running slot finishes *inside* this step, its pages are
            // released after admit() already ran, so the queue legitimately
            // stays put until the next iteration re-admits.
            let active_before = self.active();
            let queued_before = self.queue.len();
            self.step()?;
            if active_before == 0 && !self.queue.is_empty() && self.queue.len() == queued_before {
                if self.spec_active() > 0 {
                    // mixed mode: the queued request waits on lanes or KV
                    // pages held by speculative sequences, and nothing
                    // inside this loop will ever close them — that is a
                    // driver error, not a spin-wait
                    return Err(anyhow!(
                        "run_to_completion cannot admit: lanes/KV held by open speculative sequences"
                    ));
                }
                // submit-time validation guarantees every queued horizon
                // fits an empty pool, so an idle engine can always admit.
                debug_assert!(false, "engine stalled: queued request cannot be admitted");
                return Err(anyhow!("engine stalled: request cannot be admitted"));
            }
        }
        Ok(self.take_finished())
    }

    // ---- speculative-decoding API (`specdec::SpecBatch` drives it) ----
    //
    // A speculative sequence is an externally driven sequence: nothing is
    // sampled inside the engine, every token is teacher-forced, and the
    // caller reads raw logits rows. The primitives — `spec_open`
    // (prefill), `spec_extend_batch` (teacher-forced multi-token pass
    // over any subset of the open sequences), `spec_truncate` (KV
    // rollback) — are exactly the draft / verify / rollback state machine
    // of DESIGN.md §5/§6. Up to `b_decode` speculative sequences share
    // the decode lanes; lanes not named by a call are *parked*: they are
    // fed a dummy token at their own frontier position, whose K/V write
    // lands past their committed stream and is dead by the masking rule
    // (per-lane garbage-write isolation).

    /// Compiled cache horizon `s_max` (exposed for speculative drivers).
    pub fn cache_horizon(&self) -> usize {
        self.be.man().cfg.s_max
    }

    /// Number of decode lanes (`b_decode`) — the maximum concurrent
    /// speculative sequences an engine can hold open.
    pub fn decode_lanes(&self) -> usize {
        self.be.man().cfg.b_decode
    }

    /// Number of speculative sequences currently holding a lane.
    pub fn spec_active(&self) -> usize {
        self.spec.iter().filter(|s| s.is_some()).count()
    }

    fn spec_lane(&self, id: u64) -> Result<usize> {
        self.spec
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.id == id))
            .ok_or_else(|| anyhow!("unknown speculative sequence {id}"))
    }

    /// Lane currently held by speculative sequence `id`, if it is open —
    /// exposed so speculative drivers can label per-lane trace events.
    pub fn spec_lane_of(&self, id: u64) -> Option<usize> {
        self.spec_lane(id).ok()
    }

    /// The engine's lifecycle tracer (disabled unless one was configured).
    /// Drivers clone it to stamp their own events and to export the log.
    pub fn tracer(&self) -> &Tracer {
        &self.trace
    }

    /// Committed positions of a speculative sequence (== tokens whose K/V
    /// are in the cache).
    pub fn spec_len(&self, id: u64) -> Result<usize> {
        Ok(self.spec[self.spec_lane(id)?].as_ref().unwrap().len)
    }

    /// Open a speculative sequence: prefill `prompt` (chunked through
    /// teacher-forced decode steps when longer than the window) and return
    /// the handle id plus the logits row after the final prompt token.
    /// Unlike `submit`, nothing is sampled — the speculative driver owns
    /// the sampling policy. Pages are booked as the sequence actually
    /// grows (and handed back by `spec_truncate`), not for a horizon.
    pub fn spec_open(&mut self, prompt: &[u32]) -> Result<(u64, Vec<f32>)> {
        let mcfg = &self.be.man().cfg;
        let (s_max, sp, v) = (mcfg.s_max, mcfg.s_prefill, mcfg.v);
        if prompt.is_empty() {
            return Err(anyhow!("spec_open: empty prompt"));
        }
        if prompt.len() >= s_max {
            return Err(anyhow!(
                "spec_open: prompt of {} tokens cannot fit the cache horizon s_max={}",
                prompt.len(),
                s_max
            ));
        }
        // batched requests and speculative sequences coexist (mixed-mode
        // serving): every forward parks unfed live lanes — batched slots
        // included — at their own frontier, where garbage K/V writes are
        // dead by the masking rule.
        let Some(lane) = self.free_slot() else {
            return Err(anyhow!("spec_open: no free decode lane"));
        };
        let id = self.next_id;
        self.next_id += 1;
        // prefix-cache hit: import the matched rows and teacher-force only
        // the unmatched suffix — no prefill executable at all. The final
        // logits row is byte-identical to the cold path's.
        let hit = match &mut self.prefix {
            Some(p) => p.lookup(prompt),
            None => None,
        };
        if let Some(hit) = hit {
            self.admit_prefix_hit(lane, id, hit, hit.len)?;
            self.metrics.prompt_tokens += prompt.len();
            self.spec[lane] = Some(SpecSlot { id, len: hit.len });
            let tail = &prompt[hit.len..];
            let tailed = self.spec_extend(id, tail, tail.len() - 1).and_then(|mut rows| {
                rows.pop().ok_or_else(|| anyhow!("prefix-hit suffix produced no logits"))
            });
            return match tailed {
                Ok(row) => Ok((id, row)),
                Err(e) => {
                    self.spec_close(id);
                    Err(e)
                }
            };
        }
        if self.prefix.is_some() {
            self.metrics.prefix_misses += 1;
        }
        // book the prefill window's pages BEFORE running the multi-layer
        // forward (mirrors the batched path's admit-before-prefill), so a
        // budget rejection costs nothing
        if !self.paged.admit(id, prompt.len().min(sp)) {
            return Err(anyhow!("spec_open: KV budget exhausted"));
        }
        let (x, plen) = match self.prefill_window(lane, prompt) {
            Ok(v) => v,
            Err(e) => {
                self.paged.release(id);
                return Err(e);
            }
        };
        self.metrics.prefills += 1;
        self.metrics.prompt_tokens += prompt.len();
        self.maybe_retain(prompt, lane, plen, prompt.len());
        self.spec[lane] = Some(SpecSlot { id, len: plen });
        if prompt.len() > sp {
            // stream the prompt tail through teacher-forced decode steps;
            // only the final position's logits are needed. A mid-tail
            // failure (KV exhaustion) must tear the half-open sequence
            // down, or the lane and its pages leak with no handle to
            // close them by.
            self.metrics.chunked_prefills += 1;
            let tail = &prompt[plen..];
            let tailed = self.spec_extend(id, tail, tail.len() - 1).and_then(|mut rows| {
                rows.pop().ok_or_else(|| anyhow!("chunked spec prefill produced no logits"))
            });
            match tailed {
                Ok(row) => return Ok((id, row)),
                Err(e) => {
                    self.spec_close(id);
                    return Err(e);
                }
            }
        }
        let t_exec = Instant::now();
        let logits =
            self.be.run("head_prefill", &[&x, &self.model.final_norm, &self.model.embed])?.remove(0);
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        let logits = val_to_tensor(&logits)?;
        let rowbase = (plen - 1) * v;
        Ok((id, logits.data[rowbase..rowbase + v].to_vec()))
    }

    /// Teacher-force `tokens` through one speculative sequence — the
    /// single-sequence convenience over `spec_extend_batch`. Returns the
    /// logits row after each token from index `collect_from` on.
    pub fn spec_extend(&mut self, id: u64, tokens: &[u32], collect_from: usize) -> Result<Vec<Vec<f32>>> {
        let mut rows = self.spec_extend_batch(&[SpecFeed { id, tokens, collect_from }])?;
        Ok(rows.pop().unwrap())
    }

    /// Teacher-force every feed's tokens through its speculative sequence
    /// in lockstep — the multi-token verify pass (and the drafters'
    /// catch-up/draft steps), shared by all open speculative sequences.
    ///
    /// When the backend offers a fused multi-token decode
    /// (`Backend::run_fused`) and `EngineConfig::fused_verify` is on, the
    /// whole batch runs as ONE forward chain over the widest feed;
    /// otherwise it lowers to one decode forward per token index. The two
    /// lowerings produce identical logits.
    ///
    /// Isolation rule: lanes not named by a feed — other live speculative
    /// sequences, or lanes a short feed has finished with — are parked at
    /// their own frontier position, so their garbage K/V writes land past
    /// their committed stream where the masking rule makes them dead.
    /// Logits rows are returned per feed in call order; KV pages for
    /// every fed position are grown up front and handed back exactly if
    /// the pool cannot hold them all (all-or-nothing).
    pub fn spec_extend_batch(&mut self, feeds: &[SpecFeed]) -> Result<Vec<Vec<Vec<f32>>>> {
        let s_max = self.be.man().cfg.s_max;
        if feeds.is_empty() {
            return Ok(Vec::new());
        }
        // resolve + validate every feed before touching any state
        let mut lanes = Vec::with_capacity(feeds.len());
        let mut starts = Vec::with_capacity(feeds.len());
        for f in feeds {
            let lane = self.spec_lane(f.id)?;
            if lanes.contains(&lane) {
                return Err(anyhow!("spec_extend_batch: duplicate sequence {}", f.id));
            }
            if f.tokens.is_empty() {
                return Err(anyhow!("spec_extend_batch: empty token feed for sequence {}", f.id));
            }
            let len = self.spec[lane].as_ref().unwrap().len;
            if len + f.tokens.len() > s_max {
                return Err(anyhow!(
                    "spec_extend_batch: sequence {} would pass the cache horizon s_max={s_max}",
                    f.id
                ));
            }
            lanes.push(lane);
            starts.push(len);
        }
        // exact page accounting, all-or-nothing: grow every fed position
        // up front; on exhaustion hand back exactly what this call grew
        for (i, f) in feeds.iter().enumerate() {
            for _ in 0..f.tokens.len() {
                if !self.paged.grow(f.id) {
                    for (g, &s) in feeds.iter().zip(&starts).take(i + 1) {
                        self.paged.truncate(g.id, s);
                    }
                    return Err(anyhow!("spec_extend_batch: KV budget exhausted"));
                }
            }
        }
        let lane_feeds: Vec<LaneFeed> = feeds
            .iter()
            .zip(&lanes)
            .zip(&starts)
            .map(|((f, &lane), &start)| LaneFeed { lane, start, tokens: f.tokens, collect_from: f.collect_from })
            .collect();
        match self.feeds_forward(&lane_feeds) {
            Ok((rows, fused)) => {
                for (f, &lane) in feeds.iter().zip(&lanes) {
                    self.spec[lane].as_mut().unwrap().len += f.tokens.len();
                    self.metrics.spec_steps += f.tokens.len();
                }
                if fused {
                    self.metrics.spec_fused_passes += 1;
                }
                Ok(rows)
            }
            Err(e) => {
                // restore the pre-call invariant (pages == committed
                // len): the core commits nothing on failure, so the
                // recorded starts are exactly what this call grew past
                for (f, &start) in feeds.iter().zip(&starts) {
                    self.paged.truncate(f.id, start);
                }
                Err(e)
            }
        }
    }

    /// Run one teacher-forced multi-token pass over `feeds` — the shared
    /// core under `spec_extend_batch` and the budgeted `prefill_chunks`.
    /// Uses the backend's fused multi-token decode when offered and
    /// `EngineConfig::fused_verify` is on, lowering to one decode forward
    /// per token index otherwise; the two produce identical logits and
    /// K/V. Returns the collected rows per feed plus whether the fused
    /// path ran (callers attribute the pass to their own metric).
    /// Commits NO sequence/slot state — callers advance their own
    /// lengths on success, so a failed pass leaves the engine exactly as
    /// it was (modulo dead cache rows past the committed frontiers).
    fn feeds_forward(&mut self, feeds: &[LaneFeed]) -> Result<(Vec<Vec<Vec<f32>>>, bool)> {
        if self.cfg.fused_verify {
            if let Some(rows) = self.feeds_forward_fused(feeds)? {
                return Ok((rows, true));
            }
        }
        Ok((self.feeds_forward_sequential(feeds)?, false))
    }

    /// The fused lowering of `feeds_forward`: one decode-shaped forward
    /// chain over `[bd, m]` tokens (`m` = widest feed), with per-lane
    /// start positions. Returns `Ok(None)` when the backend does not
    /// fuse (callers fall back to the sequential lowering).
    fn feeds_forward_fused(&mut self, feeds: &[LaneFeed]) -> Result<Option<Vec<Vec<Vec<f32>>>>> {
        let mcfg = &self.be.man().cfg;
        let (bd, v, s_max) = (mcfg.b_decode, mcfg.v, mcfg.s_max);
        let m = feeds.iter().map(|f| f.tokens.len()).max().unwrap();
        // parked baseline: live lanes — speculative AND batched (mixed-
        // mode serving) — at their own frontier, free lanes at 0
        let mut pos = vec![0i32; bd];
        for (lane, p) in pos.iter_mut().enumerate() {
            if let Some(s) = &self.spec[lane] {
                *p = s.len.min(s_max - 1) as i32;
            } else if let Some(s) = &self.slots[lane] {
                *p = s.len.min(s_max - 1) as i32;
            }
        }
        let mut toks = vec![0i32; bd * m];
        for f in feeds {
            pos[f.lane] = f.start as i32;
            for (j, &t) in f.tokens.iter().enumerate() {
                toks[f.lane * m + j] = t as i32;
            }
        }
        let tok = val_i32(&[bd, m], &toks)?;
        let pos_val = val_i32(&[bd], &pos)?;
        let t_exec = Instant::now();
        let Some(mut out) = self.be.run_fused("embed_decode", &[&tok, &self.model.embed])? else {
            return Ok(None);
        };
        let mut x = out.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_decode {
                None => {}
                Some(exec) => {
                    if let Some(cache) = &mut self.caches[l] {
                        let mut inputs: Vec<&Value> = vec![&x, &cache.k, &cache.v, &pos_val];
                        inputs.extend(blk.vals.iter());
                        let mut out = fused_step(&self.be, exec, &inputs)?;
                        x = out.remove(0);
                        cache.v = out.pop().unwrap();
                        cache.k = out.pop().unwrap();
                    } else {
                        let mut inputs: Vec<&Value> = vec![&x];
                        inputs.extend(blk.vals.iter());
                        x = fused_step(&self.be, exec, &inputs)?.remove(0);
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_decode {
                let mut inputs: Vec<&Value> = vec![&x];
                inputs.extend(blk.vals.iter());
                x = fused_step(&self.be, exec, &inputs)?.remove(0);
            }
        }
        // the vocab-sized head runs only over the rows actually collected
        // (mirrors the sequential lowering, which skips non-collecting
        // steps): gather those hidden rows, one head call, scatter back.
        // The head is token-wise, so the gathered rows are bitwise
        // identical to a full-width head pass.
        let mut need: Vec<(usize, usize)> = Vec::new(); // (feed index, j)
        for (fi, f) in feeds.iter().enumerate() {
            for j in f.collect_from..f.tokens.len() {
                need.push((fi, j));
            }
        }
        let logits = if need.is_empty() {
            None
        } else {
            let xt = x.as_f32()?;
            let d = *xt.shape.last().unwrap();
            let mut xh = Vec::with_capacity(need.len() * d);
            for &(fi, j) in &need {
                let base = (feeds[fi].lane * m + j) * d;
                xh.extend_from_slice(&xt.data[base..base + d]);
            }
            let xh = Value::F32(Tensor::from_vec(&[need.len(), 1, d], xh));
            let l = fused_step(
                &self.be,
                "head_decode",
                &[&xh, &self.model.final_norm, &self.model.embed],
            )?
            .remove(0);
            Some(val_to_tensor(&l)?)
        };
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        let mut all_rows: Vec<Vec<Vec<f32>>> = feeds
            .iter()
            .map(|f| Vec::with_capacity(f.tokens.len().saturating_sub(f.collect_from)))
            .collect();
        if let Some(l) = &logits {
            for (r, &(fi, _)) in need.iter().enumerate() {
                all_rows[fi].push(l.data[r * v..(r + 1) * v].to_vec());
            }
        }
        Ok(Some(all_rows))
    }

    /// The sequential lowering of `feeds_forward`: one batched decode
    /// forward per token index, feeds advancing in lockstep (short feeds
    /// park once exhausted).
    fn feeds_forward_sequential(&mut self, feeds: &[LaneFeed]) -> Result<Vec<Vec<Vec<f32>>>> {
        let mcfg = &self.be.man().cfg;
        let (bd, v, s_max) = (mcfg.b_decode, mcfg.v, mcfg.s_max);
        let m = feeds.iter().map(|f| f.tokens.len()).max().unwrap();
        let mut all_rows: Vec<Vec<Vec<f32>>> = feeds
            .iter()
            .map(|f| Vec::with_capacity(f.tokens.len().saturating_sub(f.collect_from)))
            .collect();
        for j in 0..m {
            let mut toks = vec![0i32; bd];
            // parked baseline: every live lane — speculative and batched
            // alike — at its own frontier. Fed lanes ride their chain
            // position `start + j` (exhausted short feeds park at their
            // own new frontier `start + tokens`); the engine state still
            // holds `start` because the caller commits lengths only on
            // success. The horizon clamp only ever binds for a parked
            // lane sitting at s_max, whose overwritten row is dead after
            // any rollback.
            let mut pos = vec![0i32; bd];
            for (lane, p) in pos.iter_mut().enumerate() {
                if let Some(s) = &self.spec[lane] {
                    *p = s.len.min(s_max - 1) as i32;
                } else if let Some(s) = &self.slots[lane] {
                    *p = s.len.min(s_max - 1) as i32;
                }
            }
            let mut with_head = false;
            for f in feeds {
                pos[f.lane] = ((f.start + j.min(f.tokens.len())).min(s_max - 1)) as i32;
                if j < f.tokens.len() {
                    toks[f.lane] = f.tokens[j] as i32;
                    if j >= f.collect_from {
                        with_head = true;
                    }
                }
            }
            let logits = self.decode_forward(&toks, &pos, with_head)?;
            for (fi, f) in feeds.iter().enumerate() {
                if j < f.tokens.len() && j >= f.collect_from {
                    let l = logits.as_ref().expect("collected feed implies head ran");
                    all_rows[fi].push(l.data[f.lane * v..(f.lane + 1) * v].to_vec());
                }
            }
        }
        Ok(all_rows)
    }

    /// Rewind a speculative sequence to `new_len` committed positions —
    /// the KV rollback after a partial acceptance. Trailing pages are
    /// freed exactly (`PagedKvManager::truncate`); the stale cache rows
    /// beyond `new_len` are dead by construction, because decode attention
    /// masks at the fed position. Rewinding to >= the current length is a
    /// no-op and counts no rollback.
    pub fn spec_truncate(&mut self, id: u64, new_len: usize) -> Result<()> {
        let lane = self.spec_lane(id)?;
        let slot = self.spec[lane].as_mut().unwrap();
        if new_len < slot.len {
            slot.len = new_len;
            self.paged.truncate(id, new_len);
            self.metrics.spec_rollbacks += 1;
        }
        Ok(())
    }

    /// Release a speculative sequence's lane and all its KV pages.
    pub fn spec_close(&mut self, id: u64) {
        if let Ok(lane) = self.spec_lane(id) {
            self.spec[lane] = None;
            self.paged.release(id);
        }
    }

    /// `spec_close` that first offers the sequence's committed stream to
    /// the prefix cache — the speculative side of finish-time
    /// generated-token retention (DESIGN.md §9). `tokens` is the full
    /// committed stream (prompt plus generated tokens), `prompt_len` how
    /// many of them came from the prompt; retention is capped at the
    /// positions actually held in the lane's cache and is a plain close
    /// when the prefix cache is off or disabled.
    pub fn spec_close_retained(&mut self, id: u64, tokens: &[u32], prompt_len: usize) {
        if let Ok(lane) = self.spec_lane(id) {
            if self.prefix.is_some() {
                let len = self.spec[lane].as_ref().unwrap().len;
                self.maybe_retain(tokens, lane, len.min(tokens.len()), prompt_len);
            }
            self.spec[lane] = None;
            self.paged.release(id);
        }
    }
}

/// One executable of a fused decode chain. A backend that fused the
/// chain's first step must fuse them all: `None` mid-chain would leave
/// the dense caches half-updated, so it is an error, not a fallback.
fn fused_step(be: &SharedBackend, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
    be.run_fused(name, inputs)?.ok_or_else(|| {
        anyhow!("backend refused fused exec {name} mid-chain (fused decode is all-or-nothing)")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_config_builder_defaults() {
        let cfg = EngineConfig::new();
        assert_eq!(cfg.scheduler, SchedulerKind::Fifo);
        assert_eq!(cfg.page_len, 16);
        assert!(cfg.fused_verify, "the fused path is the default");
        assert!(!cfg.prefix_cache, "the prefix cache is opt-in");
        let cfg = cfg
            .kv_budget_bytes(1 << 20)
            .page_len(8)
            .max_queue(2)
            .scheduler(SchedulerKind::Priority)
            .fused_verify(false)
            .prefix_cache(true, 1 << 20);
        assert_eq!(cfg.kv_budget_bytes, 1 << 20);
        assert_eq!(cfg.page_len, 8);
        assert_eq!(cfg.max_queue, 2);
        assert_eq!(cfg.scheduler, SchedulerKind::Priority);
        assert!(!cfg.fused_verify);
        assert!(cfg.prefix_cache);
        assert_eq!(cfg.prefix_retain_budget, 1 << 20);
    }

    #[test]
    fn gen_request_builder() {
        let r = GenRequest::new(vec![1, 2], 4)
            .with_priority(3)
            .with_sampling(SamplingParams::temperature(0.5).with_seed(7));
        assert_eq!(r.priority, 3);
        assert_eq!(r.sampling.seed, 7);
        assert_eq!(r.horizon(100), 6);
        assert_eq!(r.horizon(5), 5, "horizon is capped at s_max");
    }

    /// The whole point of the owned-backend redesign: an engine (default
    /// build) can move to a server thread.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn engine_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Engine>();
    }
}
