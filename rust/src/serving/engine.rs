//! Continuous-batching inference engine over the AOT block executables.
//!
//! Slots are fixed by the decode executables' compiled batch (`b_decode`);
//! admission is gated by the variable-GQA paged KV manager; prefill runs
//! at batch 1 and seeds the slot's dense cache; decode steps all active
//! slots together with per-sequence positions (the paper's §4.1 point that
//! batched decode amortizes weight reads is physical here too). Greedy
//! sampling; stop on EOS / max_new / cache horizon.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::Literal;

use crate::arch::{Arch, AttnChoice};
use crate::config::Manifest;
use crate::data::world::EOS;
use crate::model::CompiledModel;
use crate::runtime::{lit_f32, lit_i32, lit_to_tensor, literal::tensor_to_lit, Registry};
use crate::weights::Store;

use super::kvcache::{PageCfg, PagedKvManager};
use super::metrics::EngineMetrics;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub ttft_secs: f64,
    pub e2e_secs: f64,
}

struct Slot {
    req: Request,
    generated: Vec<u32>,
    /// next position to write (== tokens so far)
    len: usize,
    last_token: u32,
    t_submit: Instant,
    t_first: Option<Instant>,
}

/// Per-layer decode cache (gqa layers only).
struct LayerCache {
    k: Literal,
    v: Literal,
    kv_heads: usize,
}

/// Exec names precomputed per layer (perf: the decode loop used to
/// `format!` two strings per layer per step — see EXPERIMENTS.md §Perf).
struct LayerExecs {
    attn_prefill: Option<String>,
    attn_decode: Option<String>,
    ffn_prefill: Option<String>,
    ffn_decode: Option<String>,
}

pub struct Engine<'a> {
    reg: &'a Registry,
    model: CompiledModel,
    caches: Vec<Option<LayerCache>>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(Request, Instant)>,
    execs: Vec<LayerExecs>,
    paged: PagedKvManager,
    pub metrics: EngineMetrics,
    finished: Vec<Response>,
    next_id: u64,
}

impl<'a> Engine<'a> {
    pub fn new(reg: &'a Registry, store: &Store, arch: &Arch, kv_budget_bytes: usize) -> Result<Engine<'a>> {
        let man = &reg.man;
        let cfg = &man.cfg;
        let model = CompiledModel::assemble(man, store, arch)?;
        let mut caches = Vec::with_capacity(arch.n_layers());
        for (l, (a, _)) in arch.layers.iter().enumerate() {
            let _ = l;
            match a {
                AttnChoice::Gqa { .. } => {
                    let kv = man.attn_variants[&a.name()].kv_heads;
                    let shape = [cfg.b_decode, cfg.s_max, kv, cfg.head_dim];
                    let zeros = vec![0f32; shape.iter().product()];
                    caches.push(Some(LayerCache {
                        k: lit_f32(&shape, &zeros)?,
                        v: lit_f32(&shape, &zeros)?,
                        kv_heads: kv,
                    }));
                }
                _ => caches.push(None),
            }
        }
        let paged = PagedKvManager::new(
            man,
            arch,
            PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: kv_budget_bytes },
        );
        let execs = (0..arch.n_layers())
            .map(|l| LayerExecs {
                attn_prefill: model.attn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                attn_decode: model.attn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
                ffn_prefill: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_prefill")),
                ffn_decode: model.ffn[l].prefix.as_ref().map(|p| format!("{p}_decode")),
            })
            .collect();
        Ok(Engine {
            reg,
            model,
            caches,
            slots: (0..cfg.b_decode).map(|_| None).collect(),
            queue: VecDeque::new(),
            execs,
            paged,
            metrics: EngineMetrics::default(),
            finished: Vec::new(),
            next_id: 1,
        })
    }

    pub fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((Request { id, prompt, max_new }, Instant::now()));
        id
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Admit queued requests into free slots (router policy: FIFO).
    fn admit(&mut self) -> Result<()> {
        while let Some(slot_idx) = self.free_slot() {
            let Some((req, _t)) = self.queue.front() else { break };
            let horizon = (req.prompt.len() + req.max_new).min(self.reg.man.cfg.s_max);
            if !self.paged.can_admit(horizon) {
                break; // backpressure: wait for a release
            }
            let (req, t_submit) = self.queue.pop_front().unwrap();
            self.prefill(slot_idx, req, t_submit)?;
        }
        Ok(())
    }

    /// Prefill a prompt at batch 1 and seed the slot's caches.
    fn prefill(&mut self, slot_idx: usize, req: Request, t_submit: Instant) -> Result<()> {
        let man: &Manifest = &self.reg.man;
        let cfg = &man.cfg;
        let sp = cfg.s_prefill;
        let plen = req.prompt.len().min(sp);
        let mut tokens: Vec<i32> = req.prompt.iter().take(plen).map(|&t| t as i32).collect();
        tokens.resize(sp, 0); // right-pad; causal masking isolates the pad
        let tok = lit_i32(&[1, sp], &tokens)?;
        let t_exec = Instant::now();
        let mut x = self.reg.run("embed_prefill", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_prefill {
                None => {}
                Some(exec) => {
                    let mut inputs: Vec<&Literal> = vec![&x];
                    inputs.extend(blk.lits.iter());
                    let mut out = self.reg.run(exec, &inputs)?;
                    x = out.remove(0);
                    if let Some(cache) = &mut self.caches[l] {
                        // copy rows [0, plen) of the prefill K/V into this slot
                        let k_new = lit_to_tensor(&out[0])?;
                        let v_new = lit_to_tensor(&out[1])?;
                        let mut kc = lit_to_tensor(&cache.k)?;
                        let mut vc = lit_to_tensor(&cache.v)?;
                        let row = cache.kv_heads * cfg.head_dim;
                        let smax = cfg.s_max;
                        for p in 0..plen {
                            let dst = (slot_idx * smax + p) * row;
                            let src = p * row;
                            kc.data[dst..dst + row].copy_from_slice(&k_new.data[src..src + row]);
                            vc.data[dst..dst + row].copy_from_slice(&v_new.data[src..src + row]);
                        }
                        cache.k = tensor_to_lit(&kc)?;
                        cache.v = tensor_to_lit(&vc)?;
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_prefill {
                let mut inputs: Vec<&Literal> = vec![&x];
                inputs.extend(blk.lits.iter());
                x = self.reg.run(exec, &inputs)?.remove(0);
            }
        }
        let logits =
            self.reg.run("head_prefill", &[&x, &self.model.final_norm, &self.model.embed])?.remove(0);
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        let logits = lit_to_tensor(&logits)?;
        // greedy next token from the last prompt position
        let v = cfg.v;
        let rowbase = (plen - 1) * v;
        let first = argmax(&logits.data[rowbase..rowbase + v]) as u32;

        self.paged.admit(req.id, plen);
        self.metrics.prefills += 1;
        self.metrics.prompt_tokens += plen;
        let slot = Slot {
            req,
            generated: vec![first],
            len: plen,
            last_token: first,
            t_submit,
            t_first: Some(Instant::now()),
        };
        self.metrics
            .ttft
            .push(slot.t_first.unwrap().duration_since(slot.t_submit).as_secs_f64());
        self.metrics.generated_tokens += 1;
        // immediate completion checks
        if first == EOS || slot.req.max_new <= 1 {
            self.finish(slot_idx, Some(slot));
            return Ok(());
        }
        self.slots[slot_idx] = Some(slot.take_ready());
        Ok(())
    }

    /// One batched decode step over all active slots.
    fn decode_step(&mut self) -> Result<()> {
        let man = &self.reg.man;
        let cfg = &man.cfg;
        let bd = cfg.b_decode;
        let t_step = Instant::now();
        let mut tokens = vec![0i32; bd];
        let mut pos = vec![0i32; bd];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[i] = s.last_token as i32;
                pos[i] = s.len as i32;
            }
        }
        let tok = lit_i32(&[bd, 1], &tokens)?;
        let pos_lit = lit_i32(&[bd], &pos)?;
        let t_exec = Instant::now();
        let mut x = self.reg.run("embed_decode", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            let blk = &self.model.attn[l];
            match &self.execs[l].attn_decode {
                None => {}
                Some(exec) => {
                    if let Some(cache) = &mut self.caches[l] {
                        let mut inputs: Vec<&Literal> = vec![&x, &cache.k, &cache.v, &pos_lit];
                        inputs.extend(blk.lits.iter());
                        let mut out = self.reg.run(exec, &inputs)?;
                        x = out.remove(0);
                        cache.v = out.pop().unwrap();
                        cache.k = out.pop().unwrap();
                    } else {
                        // linear attention: stateless decode
                        let mut inputs: Vec<&Literal> = vec![&x];
                        inputs.extend(blk.lits.iter());
                        x = self.reg.run(exec, &inputs)?.remove(0);
                    }
                }
            }
            let blk = &self.model.ffn[l];
            if let Some(exec) = &self.execs[l].ffn_decode {
                let mut inputs: Vec<&Literal> = vec![&x];
                inputs.extend(blk.lits.iter());
                x = self.reg.run(exec, &inputs)?.remove(0);
            }
        }
        let logits =
            self.reg.run("head_decode", &[&x, &self.model.final_norm, &self.model.embed])?.remove(0);
        self.metrics.execute_secs += t_exec.elapsed().as_secs_f64();
        let logits = lit_to_tensor(&logits)?;
        let v = cfg.v;

        let mut to_finish = Vec::new();
        for i in 0..bd {
            let Some(slot) = &mut self.slots[i] else { continue };
            let next = argmax(&logits.data[i * v..(i + 1) * v]) as u32;
            slot.len += 1;
            self.paged.grow(slot.req.id);
            slot.generated.push(next);
            slot.last_token = next;
            self.metrics.generated_tokens += 1;
            let done = next == EOS
                || slot.generated.len() >= slot.req.max_new
                || slot.len + 1 >= cfg.s_max;
            if done {
                to_finish.push(i);
            }
        }
        for i in to_finish {
            let slot = self.slots[i].take();
            self.finish(i, slot);
        }
        self.metrics.decode_steps += 1;
        self.metrics.sched_overhead_secs +=
            (t_step.elapsed().as_secs_f64() - t_exec.elapsed().as_secs_f64()).max(0.0);
        Ok(())
    }

    fn finish(&mut self, _slot_idx: usize, slot: Option<Slot>) {
        if let Some(slot) = slot {
            self.paged.release(slot.req.id);
            self.metrics.requests_completed += 1;
            self.metrics
                .e2e
                .push(slot.t_submit.elapsed().as_secs_f64());
            self.finished.push(Response {
                id: slot.req.id,
                tokens: slot.generated,
                ttft_secs: slot
                    .t_first
                    .map(|t| t.duration_since(slot.t_submit).as_secs_f64())
                    .unwrap_or(0.0),
                e2e_secs: slot.t_submit.elapsed().as_secs_f64(),
            });
        }
    }

    /// Drive the engine until queue and slots are empty; returns all
    /// responses. Records wall time into metrics.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        loop {
            self.admit()?;
            if self.active() == 0 {
                if self.queue.is_empty() {
                    break;
                }
                // queue non-empty but nothing admitted -> cache stuck
                if self.active() == 0 && self.free_slot().is_some() {
                    return Err(anyhow!("engine stalled: request cannot be admitted"));
                }
            }
            if self.active() > 0 {
                self.decode_step()?;
            }
        }
        self.metrics.wall_secs += t0.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.finished))
    }
}

impl<'a> Engine<'a> {
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

impl Slot {
    fn take_ready(self) -> Slot {
        self
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
