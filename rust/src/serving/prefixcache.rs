//! Prefix cache: radix-matched KV reuse so shared prompts prefill once.
//!
//! A fleet of requests sharing a long system prompt is the dominant
//! serving pattern at scale, yet a naive engine recomputes the full
//! prompt prefill for every one of them. This module keeps a token-level
//! **radix tree** mapping prompt prefixes to retained, page-aligned KV
//! segments (the dense rows exported from a lane after a cold prefill via
//! `Backend::export_kv`). On admission the engine looks up the longest
//! page-aligned match, imports the matched rows into the new lane
//! (`Backend::import_kv`) and prefills only the unmatched suffix through
//! teacher-forced decode steps — which the repo's bitwise
//! prefill≡decode equivalence makes **byte-identical** to the cold-miss
//! generation (asserted in the integration tests, greedy and seeded
//! sampling alike).
//!
//! Accounting lives in `PagedKvManager`: a retained segment's pages are
//! charged once (`retain_shared`), sequences admitted over it hold
//! references (`admit_shared`), and unreferenced segments are evicted in
//! LRU order under budget pressure — retention can never starve
//! admission, and a segment a live sequence rides is never evicted.
//!
//! Matches are page-aligned by construction: a partial-page overlap
//! cannot share pages in a paged allocator, so `lookup` only returns
//! multiples of `page_len` and anything shorter falls back to a full
//! prefill. A match is also capped at `prompt_len - 1` — the engine must
//! always feed at least one real token to produce the next-token logits.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

/// Dense K/V rows of one retained prefix, per layer: `None` for
/// cache-free layers (linear / no-op attention), `Some((k, v))` flats of
/// `len * kv_heads(l) * head_dim` f32s otherwise — per-layer variable
/// KV-head counts fall out of each layer keeping its own row width.
#[derive(Debug, Clone)]
pub struct KvSegment {
    /// Positions covered (page-aligned).
    pub len: usize,
    /// Per-layer `(k_rows, v_rows)` flats; `None` where the layer keeps
    /// no cache.
    pub layers: Vec<Option<(Vec<f32>, Vec<f32>)>>,
}

impl KvSegment {
    /// Host bytes this segment's rows occupy (for the retain budget).
    pub fn host_bytes(&self) -> usize {
        self.layers
            .iter()
            .flatten()
            .map(|(k, v)| (k.len() + v.len()) * std::mem::size_of::<f32>())
            .sum()
    }
}

/// A successful prefix lookup: the retained segment and how many prompt
/// tokens it covers.
#[derive(Debug, Clone, Copy)]
pub struct PrefixHit {
    /// Retained segment id (key into the cache and `PagedKvManager`'s
    /// shared-segment table).
    pub seg_id: u64,
    /// Matched token count (page-aligned, `>= page_len`).
    pub len: usize,
}

/// One radix-tree node: a compressed edge from its parent plus an
/// optional retained segment ending exactly at this node's depth.
#[derive(Debug)]
struct Node {
    /// Token label of the edge from the parent (empty only at the root).
    edge: Vec<u32>,
    /// Child node indices (looked up by the first token of their edge).
    children: Vec<usize>,
    /// Retained segment ending at this node, if any.
    seg: Option<u64>,
    /// Tokens from the root to this node.
    depth: usize,
    /// Parent node index (self-parent at the root).
    parent: usize,
}

/// A retained segment's bookkeeping inside the cache.
#[derive(Debug)]
struct Retained {
    seg: KvSegment,
    node: usize,
    /// Logical-clock stamp of the last lookup that used this segment.
    last_use: u64,
}

/// The radix-tree prefix cache an `Engine` owns when
/// `EngineConfig::prefix_cache` is on. Pure bookkeeping: the engine does
/// the exporting/importing and keeps `PagedKvManager` accounting in sync.
#[derive(Debug)]
pub struct PrefixCache {
    nodes: Vec<Node>,
    segs: HashMap<u64, Retained>,
    next_seg: u64,
    clock: u64,
    page_len: usize,
    retain_budget: usize,
    retained_bytes: usize,
}

/// Round `len` down to a page boundary.
pub fn align_down(len: usize, page_len: usize) -> usize {
    (len / page_len) * page_len
}

impl PrefixCache {
    /// An empty cache for `page_len`-position pages under a host retain
    /// budget of `retain_budget` bytes.
    pub fn new(page_len: usize, retain_budget: usize) -> PrefixCache {
        PrefixCache {
            nodes: vec![Node { edge: Vec::new(), children: Vec::new(), seg: None, depth: 0, parent: 0 }],
            segs: HashMap::new(),
            next_seg: 1,
            clock: 0,
            page_len,
            retain_budget,
            retained_bytes: 0,
        }
    }

    /// Retained segment count.
    pub fn segments(&self) -> usize {
        self.segs.len()
    }

    /// Host bytes across all retained segments' rows.
    pub fn retained_bytes(&self) -> usize {
        self.retained_bytes
    }

    /// The configured host retain budget in bytes.
    pub fn retain_budget(&self) -> usize {
        self.retain_budget
    }

    /// Walk the tree along `prompt` and return the best usable retained
    /// segment plus the page-aligned hit length, without touching LRU
    /// state. Partial matches *into* a deeper segment are usable too: a
    /// segment's first `m` rows correspond exactly to its first `m` path
    /// tokens, so any segment whose path shares `m >= page_len` aligned
    /// tokens with the prompt can serve those rows.
    fn best_match(&self, prompt: &[u32]) -> Option<(u64, usize)> {
        let mut cur = 0usize;
        let mut i = 0usize;
        // deepest segment on a fully-matched node, and (on divergence or
        // prompt exhaustion mid-path) the subtree that still shares the
        // first `i` prompt tokens
        let mut deepest: Option<(u64, usize)> = None;
        let mut frontier: Option<usize> = None;
        loop {
            let node = &self.nodes[cur];
            if let Some(seg) = node.seg {
                if node.depth > 0 {
                    deepest = Some((seg, node.depth));
                }
            }
            if i >= prompt.len() {
                frontier = node.children.first().copied();
                break;
            }
            let Some(&child) = node
                .children
                .iter()
                .find(|&&c| self.nodes[c].edge.first() == Some(&prompt[i]))
            else {
                // divergence at a node boundary: every subtree below this
                // node still shares the first `i` tokens, so any of them
                // can serve an aligned prefix of the match
                frontier = node.children.first().copied();
                break;
            };
            let edge = &self.nodes[child].edge;
            let common = edge.iter().zip(&prompt[i..]).take_while(|(a, b)| a == b).count();
            i += common;
            if common == edge.len() {
                cur = child;
                continue;
            }
            // diverged (or prompt ran out) inside the child's edge: the
            // child's whole subtree still shares the first `i` tokens
            frontier = Some(child);
            break;
        }
        // the hit must be page-aligned and leave >= 1 token to feed
        let m = align_down(i.min(prompt.len() - 1), self.page_len);
        if m == 0 {
            return None;
        }
        // any segment below the frontier shares >= m tokens: use its
        // first m rows (every leaf carries a segment, so this descent
        // always terminates on one)
        if let Some(mut n) = frontier {
            loop {
                if let Some(seg) = self.nodes[n].seg {
                    return Some((seg, m));
                }
                match self.nodes[n].children.first() {
                    Some(&c) => n = c,
                    None => break,
                }
            }
        }
        deepest.map(|(seg, depth)| (seg, depth.min(m)))
    }

    /// Longest page-aligned retained prefix of `prompt`, capped at
    /// `prompt.len() - 1` (at least one token must be fed to produce
    /// logits). Read-only: LRU state is untouched.
    pub fn matched_len(&self, prompt: &[u32]) -> usize {
        if prompt.len() <= 1 {
            return 0;
        }
        self.best_match(prompt).map(|(_, len)| len).unwrap_or(0)
    }

    /// `matched_len` that also returns the segment and bumps its LRU
    /// stamp — what admission calls when it commits to reusing the match.
    pub fn lookup(&mut self, prompt: &[u32]) -> Option<PrefixHit> {
        if prompt.len() <= 1 {
            return None;
        }
        let (seg_id, len) = self.best_match(prompt)?;
        self.clock += 1;
        self.segs.get_mut(&seg_id).unwrap().last_use = self.clock;
        Some(PrefixHit { seg_id, len })
    }

    /// Is `tokens[..len]` already fully covered by retained rows — i.e.
    /// does the tree contain that exact token path? (Every node subtree
    /// carries at least one segment, and any segment below the path
    /// serves its leading rows, so path containment is coverage.)
    /// Retention calls this to skip redundant re-exports.
    pub fn covered(&self, tokens: &[u32], len: usize) -> bool {
        let mut cur = 0usize;
        let mut i = 0usize;
        while i < len {
            let Some(&child) = self.nodes[cur]
                .children
                .iter()
                .find(|&&c| self.nodes[c].edge.first() == Some(&tokens[i]))
            else {
                return false;
            };
            let edge = &self.nodes[child].edge;
            let common = edge
                .iter()
                .zip(&tokens[i..len])
                .take_while(|(a, b)| a == b)
                .count();
            i += common;
            if common < edge.len() {
                return i == len;
            }
            cur = child;
        }
        true
    }

    /// Borrow a retained segment's rows for import into a lane.
    pub fn rows(&self, seg_id: u64) -> Result<&KvSegment> {
        self.segs
            .get(&seg_id)
            .map(|r| &r.seg)
            .ok_or_else(|| anyhow!("unknown prefix segment {seg_id}"))
    }

    /// Would retaining `bytes` more fit the host retain budget right now?
    pub fn fits_retain_budget(&self, bytes: usize) -> bool {
        self.retained_bytes + bytes <= self.retain_budget
    }

    /// Insert a retained segment covering `seg.len` tokens of `tokens`
    /// and return its id. The caller has already checked both budgets
    /// (`fits_retain_budget` + `PagedKvManager::retain_shared`).
    pub fn insert(&mut self, tokens: &[u32], seg: KvSegment) -> u64 {
        debug_assert!(seg.len > 0 && seg.len <= tokens.len());
        debug_assert!(seg.len % self.page_len == 0, "retained prefixes are page-aligned");
        let node = self.insert_path(&tokens[..seg.len]);
        debug_assert!(self.nodes[node].seg.is_none(), "caller deduplicates retained prefixes");
        let id = self.next_seg;
        self.next_seg += 1;
        self.nodes[node].seg = Some(id);
        self.clock += 1;
        self.retained_bytes += seg.host_bytes();
        self.segs.insert(id, Retained { seg, node, last_use: self.clock });
        id
    }

    /// Walk (splitting compressed edges as needed) to the node at exactly
    /// `tokens`' depth, creating it if absent.
    fn insert_path(&mut self, tokens: &[u32]) -> usize {
        let mut cur = 0usize;
        let mut i = 0usize;
        while i < tokens.len() {
            let child = self.nodes[cur]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c].edge.first() == Some(&tokens[i]));
            let Some(child) = child else {
                // no child shares the next token: one fresh leaf edge
                let idx = self.nodes.len();
                self.nodes.push(Node {
                    edge: tokens[i..].to_vec(),
                    children: Vec::new(),
                    seg: None,
                    depth: tokens.len(),
                    parent: cur,
                });
                self.nodes[cur].children.push(idx);
                return idx;
            };
            let edge = self.nodes[child].edge.clone();
            let common = edge.iter().zip(&tokens[i..]).take_while(|(a, b)| a == b).count();
            if common == edge.len() {
                cur = child;
                i += common;
                continue;
            }
            // split the edge at `common`: cur -> mid -> child
            let mid = self.nodes.len();
            self.nodes.push(Node {
                edge: edge[..common].to_vec(),
                children: vec![child],
                seg: None,
                depth: self.nodes[cur].depth + common,
                parent: cur,
            });
            let pos = self.nodes[cur].children.iter().position(|&c| c == child).unwrap();
            self.nodes[cur].children[pos] = mid;
            self.nodes[child].edge = edge[common..].to_vec();
            self.nodes[child].parent = mid;
            if i + common == tokens.len() {
                return mid;
            }
            let leaf = self.nodes.len();
            self.nodes.push(Node {
                edge: tokens[i + common..].to_vec(),
                children: Vec::new(),
                seg: None,
                depth: tokens.len(),
                parent: mid,
            });
            self.nodes[mid].children.push(leaf);
            return leaf;
        }
        cur
    }

    /// Drop a retained segment (after the caller evicted its pages from
    /// the `PagedKvManager`), pruning now-useless tree nodes upward.
    pub fn remove(&mut self, seg_id: u64) -> bool {
        let Some(retained) = self.segs.remove(&seg_id) else { return false };
        self.retained_bytes -= retained.seg.host_bytes();
        let mut cur = retained.node;
        self.nodes[cur].seg = None;
        // prune childless, segment-less nodes (slots become tombstones;
        // the tree is small and rebuilt per engine, so no free-list)
        while cur != 0 && self.nodes[cur].seg.is_none() && self.nodes[cur].children.is_empty() {
            let parent = self.nodes[cur].parent;
            let pos = self.nodes[parent].children.iter().position(|&c| c == cur).unwrap();
            self.nodes[parent].children.swap_remove(pos);
            cur = parent;
        }
        true
    }

    /// Retained segment ids, least-recently-used first — the eviction
    /// scan order. The caller skips segments with live references.
    pub fn lru_order(&self) -> Vec<u64> {
        let mut ids: Vec<(u64, u64)> = self.segs.iter().map(|(&id, r)| (r.last_use, id)).collect();
        ids.sort_unstable();
        ids.into_iter().map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(len: usize) -> KvSegment {
        // one caching layer with a 4-float row, one cache-free layer
        KvSegment { len, layers: vec![Some((vec![0.5; len * 4], vec![0.25; len * 4])), None] }
    }

    #[test]
    fn lookup_returns_longest_aligned_match() {
        let mut c = PrefixCache::new(4, 1 << 20);
        let base: Vec<u32> = (1..=16).collect();
        c.insert(&base[..8], seg(8));
        c.insert(&base, seg(16));
        // full 16-token prefix + one extra token: deepest match wins
        let mut p = base.clone();
        p.push(99);
        let hit = c.lookup(&p).unwrap();
        assert_eq!(hit.len, 16);
        // a 16-token prompt caps the match at len-1, aligned: the 16-deep
        // segment still serves its first 12 rows
        let hit = c.lookup(&base).unwrap();
        assert_eq!(hit.len, 12, "match must leave at least one token to feed");
        // diverging after 8 tokens: the shallow segment still matches
        let mut div = base[..8].to_vec();
        div.extend([77u32, 78, 79]);
        assert_eq!(c.lookup(&div).unwrap().len, 8);
        // diverging inside the first page: no match at all
        let other: Vec<u32> = (100..116).collect();
        assert!(c.lookup(&other).is_none());
        assert_eq!(c.matched_len(&other), 0);
    }

    #[test]
    fn partial_page_overlap_is_not_a_hit() {
        let mut c = PrefixCache::new(8, 1 << 20);
        let base: Vec<u32> = (1..=16).collect();
        c.insert(&base, seg(16));
        // shares only 5 tokens (< one page): falls back to full prefill
        let mut p = base[..5].to_vec();
        p.extend([50u32, 51, 52, 53, 54, 55]);
        assert!(c.lookup(&p).is_none());
        // shares 11 tokens: aligned match is exactly one page (8)
        let mut p = base[..11].to_vec();
        p.extend([60u32, 61]);
        assert_eq!(c.lookup(&p).unwrap().len, 8, "match must round down to the page boundary");
    }

    #[test]
    fn edge_splitting_keeps_matches_exact() {
        let mut c = PrefixCache::new(2, 1 << 20);
        // insert a long path first, then a shorter diverging one that
        // forces a mid-edge split
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        c.insert(&a, seg(8));
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        c.insert(&b, seg(4));
        let mut q = a.clone();
        q.push(42);
        assert_eq!(c.lookup(&q).unwrap().len, 8);
        let mut q = b.clone();
        q.push(42);
        // best match for the b-path prompt: the 4-deep segment (the
        // 8-deep one diverges at token 5)
        assert_eq!(c.lookup(&q).unwrap().len, 4);
        // and the shared 4-token prefix alone (plus a diverging tail)
        // also resolves to the 4-deep segment
        let q = vec![1u32, 2, 3, 4, 100, 101];
        assert_eq!(c.lookup(&q).unwrap().len, 4);
    }

    #[test]
    fn lru_order_tracks_lookups_and_remove_prunes() {
        let mut c = PrefixCache::new(2, 1 << 20);
        let a: Vec<u32> = vec![1, 2, 3, 4];
        let b: Vec<u32> = vec![9, 8, 7, 6];
        let ida = c.insert(&a, seg(4));
        let idb = c.insert(&b, seg(4));
        assert_eq!(c.segments(), 2);
        assert!(c.retained_bytes() > 0);
        // touch a: b becomes the LRU candidate
        let mut q = a.clone();
        q.push(5);
        c.lookup(&q).unwrap();
        assert_eq!(c.lru_order(), vec![idb, ida]);
        // evicting b removes its match and its bytes
        let bytes_before = c.retained_bytes();
        assert!(c.remove(idb));
        assert!(c.retained_bytes() < bytes_before);
        let mut q = b.clone();
        q.push(5);
        assert!(c.lookup(&q).is_none());
        assert!(!c.remove(idb), "double remove is a no-op");
        // a still matches after the prune
        let mut q = a.clone();
        q.push(5);
        assert_eq!(c.lookup(&q).unwrap().len, 4);
    }

    #[test]
    fn covered_is_exact_path_containment() {
        let mut c = PrefixCache::new(4, 1 << 20);
        let base: Vec<u32> = (1..=16).collect();
        c.insert(&base, seg(16));
        assert!(c.covered(&base, 16));
        assert!(c.covered(&base, 8), "a shorter prefix of a retained path is covered");
        let mut div = base[..8].to_vec();
        div.extend([50u32, 51, 52, 53]);
        assert!(c.covered(&div, 8));
        assert!(!c.covered(&div, 12), "the diverging tail is not covered");
        let mut ext = base.clone();
        ext.extend([60u32, 61, 62, 63]);
        assert!(!c.covered(&ext, 20), "an extension past the retained path is not covered");
    }

    #[test]
    fn retain_budget_accounting() {
        let one = seg(4).host_bytes();
        let mut c = PrefixCache::new(4, 2 * one);
        assert!(c.fits_retain_budget(one));
        c.insert(&[1, 2, 3, 4], seg(4));
        assert!(c.fits_retain_budget(one));
        let id = c.insert(&[5, 6, 7, 8], seg(4));
        assert!(!c.fits_retain_budget(one), "budget is full at two segments");
        c.remove(id);
        assert!(c.fits_retain_budget(one));
        assert_eq!(c.retain_budget(), 2 * one);
    }
}
