//! Inference engine for variable-block architectures (paper §6).
//!
//! The paper's TensorRT-LLM contribution — paged KV caching with
//! *different numbers of KV heads per layer*, plus linear-attention and
//! no-op blocks — reimplemented natively: the `kvcache` manager tracks
//! per-layer page tables whose page byte-size depends on that layer's KV
//! head count; the `engine` runs continuous batching over any `Backend`'s
//! decode executables (prefill b=1, batched decode with per-sequence
//! positions, chunked ingestion for prompts past the prefill window).

pub mod engine;
pub mod kvcache;
pub mod metrics;

pub use engine::{Engine, Request, Response};
pub use kvcache::PagedKvManager;
pub use metrics::EngineMetrics;
