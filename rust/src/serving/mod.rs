//! Inference serving for variable-block architectures (paper §6) — v2 API.
//!
//! The paper's TensorRT-LLM contribution — paged KV caching with
//! *different numbers of KV heads per layer*, plus linear-attention and
//! no-op blocks — reimplemented natively, behind a layered server core:
//!
//! * `engine` — the continuous-batching `Engine`. Owns its backend via a
//!   `SharedBackend` handle (movable to a server thread on the default
//!   build), is built through the `EngineConfig` builder, consumes
//!   `GenRequest`s with per-request `SamplingParams`, and is driven by the
//!   public `step()` event loop yielding `StreamEvent`s; `cancel(id)`
//!   frees a request's slot and KV pages mid-generation. A second,
//!   externally driven surface (`spec_open` / `spec_extend_batch` /
//!   `spec_truncate`) exposes teacher-forced multi-token passes — fused
//!   into one forward per batch when the backend supports it
//!   (`Backend::run_fused`) — and exact KV rollback, for up to
//!   `b_decode` concurrent `specdec` sequences sharing the decode lanes.
//!   With `EngineConfig::prefill_budget` set, admission stops running
//!   whole prefills inline: prompts are ingested a bounded number of
//!   tokens per `step()` through the same teacher-forced machinery,
//!   interleaved with live decode, with byte-identical outputs (SLO-aware
//!   chunked prefill, DESIGN.md §10).
//! * `scheduler` — pluggable admission policies (`Fifo` — the default,
//!   `Priority`, `ShortestPromptFirst`, `PrefixAffinity`; the ranked
//!   policies fold in a queue-aging term so nothing starves).
//! * `sampling` — greedy / temperature / top-k / top-p with a seeded
//!   per-request RNG stream for reproducibility.
//! * `kvcache` — the paged manager tracking per-layer page tables whose
//!   page byte-size depends on that layer's KV head count, plus
//!   refcounted *shared* retained-prefix segments charged once.
//! * `prefixcache` — the radix-tree prefix cache: prompts sharing a
//!   page-aligned prefix with a retained one import its K/V rows
//!   (`Backend::export_kv`/`import_kv`) and prefill only the unmatched
//!   suffix; a cache-hit generation is byte-identical to the cold miss.
//!   Segments are retained from cold prefills *and* at sequence finish
//!   over the full committed stream — prompt plus generated tokens — so
//!   multi-turn conversations whose next prompt extends the previous
//!   completion reuse whole turns (`PrefixHit::gen_tokens` > 0 marks
//!   those; cancelled sequences retain nothing). Retained segments can
//!   also *migrate* between engines: `Engine::export_prefix` clones the
//!   matched rows into a [`MigratedPrefix`] and `Engine::adopt_prefix`
//!   re-retains them under the destination's own budgets and segment ids
//!   — the data-parallel router (`crate::server`) uses this to move hot
//!   system prompts to wherever load goes (DESIGN.md §12).
//! * `metrics` — throughput, TTFT/ITL/e2e percentiles, finish-reason
//!   counts, prefix hit rates (generated-origin hits broken out), and
//!   chunked-prefill pass/token counters.
//!
//! The threaded async front-end over this engine — worker thread,
//! cloneable handles, per-request token streams — lives in
//! `crate::server` (default backend build only).

pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod prefixcache;
pub mod sampling;
pub mod scheduler;

pub use engine::{Engine, EngineConfig, FinishReason, GenRequest, Response, SpecFeed, StreamEvent};
pub use kvcache::PagedKvManager;
pub use metrics::EngineMetrics;
pub use prefixcache::{KvSegment, MigratedPrefix, PrefixCache, PrefixHit};
pub use sampling::SamplingParams;
pub use scheduler::{Scheduler, SchedulerKind};
