//! Per-request token sampling policies for the serving engine.
//!
//! Every request carries its own `SamplingParams` and a private seeded
//! `Rng` stream, so a batch can mix greedy and stochastic requests and a
//! stochastic request is bit-reproducible across runs: same weights +
//! same prompt + same seed => same tokens, regardless of what shares the
//! batch. The default is greedy (temperature 0), which is byte-identical
//! to the pre-v2 engine's NaN-safe argmax.

use crate::util::Rng;

/// Per-request sampling policy. `temperature == 0.0` means greedy argmax
/// (the default); otherwise logits are temperature-scaled, optionally
/// truncated to the `top_k` highest and to the `top_p` nucleus, and the
/// next token is drawn from the renormalized distribution.
///
/// ```
/// use puzzle::serving::SamplingParams;
/// let greedy = SamplingParams::greedy();
/// assert!(greedy.is_greedy(), "temperature 0 consumes no randomness");
/// let stochastic = SamplingParams::temperature(0.8).with_top_k(40).with_top_p(0.95).with_seed(7);
/// assert!(!stochastic.is_greedy());
/// assert_eq!((stochastic.top_k, stochastic.seed), (40, 7));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy; higher flattens the distribution.
    pub temperature: f32,
    /// Keep only the k highest logits before sampling (0 = no limit).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest prefix of the sorted
    /// distribution with cumulative probability >= top_p (1.0 = no limit).
    pub top_p: f32,
    /// Seed for this request's private RNG stream (reproducibility).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

impl SamplingParams {
    /// Greedy argmax (the default policy).
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    /// Temperature sampling at `t` (greedy otherwise unchanged).
    pub fn temperature(t: f32) -> SamplingParams {
        SamplingParams { temperature: t, ..SamplingParams::greedy() }
    }

    /// Set the private rng stream's seed.
    pub fn with_seed(mut self, seed: u64) -> SamplingParams {
        self.seed = seed;
        self
    }

    /// Keep only the `k` highest logits (0 = no limit).
    pub fn with_top_k(mut self, k: usize) -> SamplingParams {
        self.top_k = k;
        self
    }

    /// Nucleus truncation at cumulative probability `p` (1.0 = no limit).
    pub fn with_top_p(mut self, p: f32) -> SamplingParams {
        self.top_p = p;
        self
    }

    /// Whether this policy is greedy (consumes no randomness).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// NaN-safe greedy argmax: NaN logits are skipped (a NaN never wins);
/// all-NaN rows fall back to index 0.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if x > xs[b] => best = Some(i),
            _ => {}
        }
    }
    best.unwrap_or(0)
}

/// Candidates surviving temperature / top-k / top-p truncation, sorted by
/// logit descending, with their unnormalized probabilities. `degenerate`
/// flags rows where the probabilities carry no information (all-NaN or
/// every surviving logit -inf): callers take the best candidate without
/// consuming randomness. The nucleus cut always keeps >= 1 candidate
/// (`acc >= top_p` is first reached at some `cut >= 1`).
fn truncated(logits: &[f32], params: &SamplingParams) -> (Vec<(usize, f32)>, Vec<f64>, bool) {
    // candidates sorted by logit descending, NaNs dropped
    let mut cand: Vec<(usize, f32)> =
        logits.iter().enumerate().filter(|(_, x)| !x.is_nan()).map(|(i, &x)| (i, x)).collect();
    if cand.is_empty() {
        return (vec![(0, 0.0)], vec![1.0], true);
    }
    cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if params.top_k > 0 && params.top_k < cand.len() {
        cand.truncate(params.top_k);
    }
    let m = cand[0].1;
    if !m.is_finite() {
        // every surviving logit is -inf: degenerate row, fall back to best
        return (vec![cand[0]], vec![1.0], true);
    }
    let t = params.temperature as f64;
    let mut probs: Vec<f64> = cand.iter().map(|(_, x)| (((x - m) as f64) / t).exp()).collect();
    if params.top_p < 1.0 {
        let total: f64 = probs.iter().sum();
        let mut acc = 0.0;
        let mut cut = probs.len();
        for (i, p) in probs.iter().enumerate() {
            acc += p / total;
            if acc >= params.top_p as f64 {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        cand.truncate(cut);
    }
    (cand, probs, false)
}

/// Sample one token index from `logits` under `params`, advancing `rng`.
/// Greedy params never touch the RNG, so greedy requests stay
/// reproducible independent of batch composition.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> usize {
    if params.is_greedy() {
        return argmax(logits);
    }
    let (cand, probs, degenerate) = truncated(logits, params);
    if degenerate {
        return cand[0].0;
    }
    let total: f64 = probs.iter().sum();
    let mut u = rng.f64() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return cand[i].0;
        }
    }
    cand.last().unwrap().0
}

/// The full modified distribution `sample` would draw from, as sparse
/// `(token, prob)` pairs sorted by descending probability, summing to 1.
/// Greedy params yield a point mass on the argmax. This is the p/q
/// currency of speculative rejection sampling (`specdec`): verification
/// needs the *distribution* at each position, not one draw from it.
pub fn dist(logits: &[f32], params: &SamplingParams) -> Vec<(usize, f64)> {
    if params.is_greedy() {
        return vec![(argmax(logits), 1.0)];
    }
    let (cand, probs, degenerate) = truncated(logits, params);
    if degenerate {
        return vec![(cand[0].0, 1.0)];
    }
    let total: f64 = probs.iter().sum();
    // drop zero-mass tails (exp underflow at tiny temperatures): the
    // result is a *support*, every listed token must be drawable
    cand.iter()
        .zip(&probs)
        .map(|(&(i, _), &p)| (i, p / total))
        .filter(|&(_, p)| p > 0.0)
        .collect()
}

/// Draw one token from a sparse distribution (as produced by `dist` or
/// `specdec::accept::residual`). Point masses consume no randomness, so
/// greedy speculative decoding stays bit-reproducible.
pub fn draw(d: &[(usize, f64)], rng: &mut Rng) -> usize {
    debug_assert!(!d.is_empty(), "draw from an empty distribution");
    if d.len() == 1 {
        return d[0].0;
    }
    let total: f64 = d.iter().map(|(_, p)| p).sum();
    let mut u = rng.f64() * total;
    for &(i, p) in d {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    d.last().unwrap().0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ignores_nans() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[2.0, f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_matches_argmax_without_touching_rng() {
        let logits = [0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &mut rng), 1);
        assert_eq!(rng.next_u64(), before, "greedy must not consume randomness");
    }

    #[test]
    fn seeded_sampling_is_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let p = SamplingParams::temperature(1.0).with_seed(42);
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| sample(&logits, &p, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43), "different streams must differ");
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits = [0.5, 3.0, 2.9, -1.0];
        let p = SamplingParams::temperature(2.0).with_top_k(1).with_seed(5);
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn tiny_top_p_collapses_to_mode() {
        // one dominant logit: the nucleus at p=0.1 holds only the mode
        let logits = [0.0, 10.0, 0.1, 0.2];
        let p = SamplingParams::temperature(0.7).with_top_p(0.1).with_seed(3);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = [1.0, 1.1, 0.9, 1.05];
        let p = SamplingParams::temperature(5.0).with_seed(9);
        let mut rng = Rng::new(9);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "near-uniform logits at high temp must hit every bucket");
    }

    #[test]
    fn nucleus_is_never_empty() {
        // a microscopic top_p must still keep the mode — an empty nucleus
        // would make sampling impossible
        let logits = [0.1, 0.2, 0.3, 4.0];
        let p = SamplingParams::temperature(1.0).with_top_p(1e-9).with_seed(1);
        let d = dist(&logits, &p);
        assert_eq!(d.len(), 1, "tiny nucleus keeps exactly the mode");
        assert_eq!(d[0].0, 3);
        assert!((d[0].1 - 1.0).abs() < 1e-12);
        let mut rng = Rng::new(1);
        assert_eq!(sample(&logits, &p, &mut rng), 3);
        // near-uniform logits with top_p just above one candidate's mass
        let flat = [1.0f32; 8];
        let p = SamplingParams::temperature(1.0).with_top_p(0.13).with_seed(2);
        let d = dist(&flat, &p);
        assert!(!d.is_empty() && d.len() <= 2);
    }

    #[test]
    fn top_k_one_matches_greedy_for_any_temperature() {
        let logits: Vec<f32> = (0..64).map(|i| ((i * 13) % 29) as f32 * 0.17 - 1.0).collect();
        let g = argmax(&logits);
        for t in [0.1f32, 1.0, 4.0, 100.0] {
            let p = SamplingParams::temperature(t).with_top_k(1).with_seed(9);
            let mut rng = Rng::new(9);
            for _ in 0..10 {
                assert_eq!(sample(&logits, &p, &mut rng), g, "top_k=1 at t={t} must be greedy");
            }
            assert_eq!(dist(&logits, &p), vec![(g, 1.0)]);
        }
    }

    #[test]
    fn extreme_temperatures_keep_finite_logprobs() {
        let logits = [3.0f32, -2.0, 0.5, 1.0e4, -1.0e4];
        for t in [1e-8f32, 1e-3, 1e3, 1e8] {
            let p = SamplingParams::temperature(t).with_seed(4);
            let d = dist(&logits, &p);
            let total: f64 = d.iter().map(|(_, q)| q).sum();
            assert!((total - 1.0).abs() < 1e-9, "probs must sum to 1 at t={t}");
            for &(_, q) in &d {
                assert!(q.is_finite() && q > 0.0, "prob {q} at t={t}");
                assert!(q.ln().is_finite(), "logprob must be finite at t={t}");
            }
            let mut rng = Rng::new(4);
            let s = sample(&logits, &p, &mut rng);
            assert!(s < logits.len());
        }
        // t -> 0 collapses to the argmax, t -> inf spreads to all candidates
        let cold = dist(&logits, &SamplingParams::temperature(1e-8));
        assert_eq!(cold[0].0, 3);
        assert!(cold[0].1 > 0.999);
        let hot = dist(&logits, &SamplingParams::temperature(1e8));
        assert_eq!(hot.len(), logits.len());
    }

    #[test]
    fn seeded_streams_are_unaffected_by_interleaved_requests() {
        // two requests with private seeded rngs must see the same tokens
        // whether their draws are interleaved (batched serving) or not
        let logits: Vec<f32> = (0..32).map(|i| ((i * 11) % 17) as f32 * 0.25).collect();
        let pa = SamplingParams::temperature(0.9).with_seed(21);
        let pb = SamplingParams::temperature(1.3).with_top_k(8).with_seed(22);
        let solo = |p: &SamplingParams| {
            let mut rng = Rng::new(p.seed);
            (0..32).map(|_| sample(&logits, p, &mut rng)).collect::<Vec<_>>()
        };
        let (sa, sb) = (solo(&pa), solo(&pb));
        let mut ra = Rng::new(pa.seed);
        let mut rb = Rng::new(pb.seed);
        let mut ia = Vec::new();
        let mut ib = Vec::new();
        for step in 0..64 {
            // irregular interleaving, as under continuous batching
            if step % 3 != 0 && ia.len() < 32 {
                ia.push(sample(&logits, &pa, &mut ra));
            } else if ib.len() < 32 {
                ib.push(sample(&logits, &pb, &mut rb));
            }
        }
        assert_eq!(ia, sa, "stream A must not see stream B's draws");
        assert_eq!(ib, sb, "stream B must not see stream A's draws");
    }

    #[test]
    fn draw_matches_dist_support_and_point_mass_skips_rng() {
        let logits = [0.2f32, 1.7, -0.3, 0.9];
        let p = SamplingParams::temperature(0.8).with_seed(6);
        let d = dist(&logits, &p);
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let t = draw(&d, &mut rng);
            assert!(d.iter().any(|&(i, _)| i == t));
        }
        let mut rng = Rng::new(8);
        let before = rng.clone().next_u64();
        assert_eq!(draw(&[(5, 1.0)], &mut rng), 5);
        assert_eq!(rng.next_u64(), before, "point mass must not consume randomness");
    }

    #[test]
    fn neg_infinity_logits_are_never_sampled() {
        let logits = [f32::NEG_INFINITY, 1.0, f32::NEG_INFINITY, 0.5];
        let p = SamplingParams::temperature(1.5).with_seed(2);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = sample(&logits, &p, &mut rng);
            assert!(s == 1 || s == 3);
        }
    }
}
