//! Pluggable admission scheduling for the serving engine.
//!
//! The engine keeps waiting requests in arrival order and, whenever a
//! decode slot is free, asks its `Scheduler` which one to admit next. The
//! scheduler only ranks; capacity is still the engine's job — if the
//! picked request does not fit the KV budget right now, the engine waits
//! for a release rather than skipping ahead (no starvation by memory
//! footprint). `Fifo` is the default and reproduces the pre-v2 engine
//! byte for byte.
//!
//! Starvation-freedom: the length- and affinity-ranked policies fold the
//! `waited` aging term into their key, so a request's effective rank
//! improves by one every engine step it sits queued. Prompt lengths and
//! cached-prefix discounts are bounded (by `s_max`), so any waiting
//! request eventually dominates every ranking and is admitted — without
//! aging, a steady stream of short (or cache-hot) arrivals starves a
//! long prompt forever (the engine-level regression test pins this).

/// What a scheduler sees of one waiting request. Slice order passed to
/// `pick` is arrival order, so index 0 is always the oldest request.
#[derive(Debug, Clone)]
pub struct QueueView {
    /// Request id.
    pub id: u64,
    /// Larger = more urgent (only `Priority` looks at this; default 0).
    pub priority: i32,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Requested generation budget.
    pub max_new: usize,
    /// Prompt tokens a retained prefix-cache segment already covers (0
    /// when the cache is off or nothing matches) — what `PrefixAffinity`
    /// ranks by.
    pub cached_prefix: usize,
    /// Engine steps this request has spent waiting in the queue — the
    /// aging term that keeps ranked policies starvation-free.
    pub waited: usize,
}

/// Admission policy: rank the waiting requests.
///
/// Contract: `pick` returns an index into `queue` (arrival order) or
/// `None` when the queue is empty; it must not assume it will be called
/// once per request (the engine re-picks after every admission and every
/// release). Implementations must be `Send` so an engine can move to a
/// server thread.
pub trait Scheduler: Send {
    /// Stable lowercase policy label.
    fn name(&self) -> &'static str;
    /// Index of the request to admit next, or None when the queue is empty.
    fn pick(&mut self, queue: &[QueueView]) -> Option<usize>;
}

/// First-in first-out: admit strictly in arrival order (v1 behavior).
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, queue: &[QueueView]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Highest `priority` first; ties broken by arrival order.
pub struct Priority;

impl Scheduler for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&mut self, queue: &[QueueView]) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .max_by_key(|(i, q)| (q.priority, std::cmp::Reverse(*i)))
            .map(|(i, _)| i)
    }
}

/// Shortest prompt first (cheap prefills drain the queue fastest and
/// minimize mean TTFT under contention); ties broken by arrival order.
/// Each waited step discounts a request's effective length by one, so a
/// long prompt under a sustained stream of short arrivals is admitted
/// after at most `prompt_len` steps of waiting instead of starving.
pub struct ShortestPromptFirst;

impl Scheduler for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn pick(&mut self, queue: &[QueueView]) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by_key(|(i, q)| (q.prompt_len.saturating_sub(q.waited), *i))
            .map(|(i, _)| i)
    }
}

/// Longest cached prefix first: requests whose prompts ride a retained
/// prefix-cache segment skip most of their prefill, so admitting them
/// first drains the queue with the least compute (cache-aware admission,
/// the scheduling face of the prefix-cache subsystem); ties broken by
/// arrival order, so with the cache off this degrades to FIFO. Each
/// waited step adds one to a request's effective cached length, so a
/// cache-cold prompt under a sustained stream of cache-hot arrivals is
/// admitted after at most `s_max` steps of waiting instead of starving.
pub struct PrefixAffinity;

impl Scheduler for PrefixAffinity {
    fn name(&self) -> &'static str {
        "prefix"
    }

    fn pick(&mut self, queue: &[QueueView]) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .max_by_key(|(i, q)| (q.cached_prefix + q.waited, std::cmp::Reverse(*i)))
            .map(|(i, _)| i)
    }
}

/// Scheduler choice carried by `EngineConfig` (and the CLI's
/// `--scheduler` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    #[default]
    /// Arrival order (the default; byte-identical to the v1 engine).
    Fifo,
    /// Highest `GenRequest.priority` first, ties by arrival.
    Priority,
    /// Shortest prompt first (latency-oriented).
    ShortestPromptFirst,
    /// Longest cached prefix first (prefix-cache-aware admission).
    PrefixAffinity,
}

impl SchedulerKind {
    /// Instantiate the policy.
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(Fifo),
            SchedulerKind::Priority => Box::new(Priority),
            SchedulerKind::ShortestPromptFirst => Box::new(ShortestPromptFirst),
            SchedulerKind::PrefixAffinity => Box::new(PrefixAffinity),
        }
    }

    /// Parse a CLI name: fifo | priority | spf | prefix (aliases:
    /// shortest, shortest-prompt-first, prefix-affinity).
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s {
            "fifo" => Some(SchedulerKind::Fifo),
            "priority" => Some(SchedulerKind::Priority),
            "spf" | "shortest" | "shortest-prompt-first" => Some(SchedulerKind::ShortestPromptFirst),
            "prefix" | "prefix-affinity" => Some(SchedulerKind::PrefixAffinity),
            _ => None,
        }
    }

    /// Stable lowercase label.
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Priority => "priority",
            SchedulerKind::ShortestPromptFirst => "spf",
            SchedulerKind::PrefixAffinity => "prefix",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, priority: i32, prompt_len: usize) -> QueueView {
        QueueView { id, priority, prompt_len, max_new: 8, cached_prefix: 0, waited: 0 }
    }

    #[test]
    fn fifo_picks_oldest() {
        let mut s = Fifo;
        assert_eq!(s.pick(&[]), None);
        assert_eq!(s.pick(&[q(7, 0, 4), q(8, 9, 2)]), Some(0));
    }

    #[test]
    fn priority_picks_highest_then_oldest() {
        let mut s = Priority;
        assert_eq!(s.pick(&[q(1, 0, 4), q(2, 5, 4), q(3, 5, 4), q(4, 1, 4)]), Some(1));
        // all equal: degrade to FIFO
        assert_eq!(s.pick(&[q(1, 2, 4), q(2, 2, 4)]), Some(0));
        assert_eq!(s.pick(&[]), None);
    }

    #[test]
    fn spf_picks_shortest_then_oldest() {
        let mut s = ShortestPromptFirst;
        assert_eq!(s.pick(&[q(1, 0, 9), q(2, 0, 3), q(3, 0, 3)]), Some(1));
        assert_eq!(s.pick(&[]), None);
    }

    #[test]
    fn prefix_affinity_picks_longest_cached_then_oldest() {
        let mut s = PrefixAffinity;
        let qc = |id: u64, cached: usize| QueueView {
            id,
            priority: 0,
            prompt_len: 20,
            max_new: 8,
            cached_prefix: cached,
            waited: 0,
        };
        assert_eq!(s.pick(&[qc(1, 0), qc(2, 16), qc(3, 8), qc(4, 16)]), Some(1));
        // nothing cached: degrade to FIFO
        assert_eq!(s.pick(&[qc(1, 0), qc(2, 0)]), Some(0));
        assert_eq!(s.pick(&[]), None);
    }

    #[test]
    fn spf_aging_lifts_a_starved_long_prompt() {
        let mut s = ShortestPromptFirst;
        let aged = |id: u64, prompt_len: usize, waited: usize| {
            QueueView { id, priority: 0, prompt_len, max_new: 8, cached_prefix: 0, waited }
        };
        // a fresh short arrival still beats a long prompt early in its wait
        assert_eq!(s.pick(&[aged(1, 12, 4), aged(2, 3, 0)]), Some(1));
        // ...but once waited steps discount the long prompt below the
        // short one's length, the long prompt wins despite its size
        assert_eq!(s.pick(&[aged(1, 12, 10), aged(2, 3, 0)]), Some(0));
        // effective length saturates at 0; oldest wins the tie
        assert_eq!(s.pick(&[aged(1, 12, 50), aged(2, 3, 50)]), Some(0));
    }

    #[test]
    fn prefix_affinity_aging_lifts_a_cache_cold_prompt() {
        let mut s = PrefixAffinity;
        let aged = |id: u64, cached: usize, waited: usize| {
            QueueView { id, priority: 0, prompt_len: 20, max_new: 8, cached_prefix: cached, waited }
        };
        // fresh cache-hot arrivals win early...
        assert_eq!(s.pick(&[aged(1, 0, 4), aged(2, 16, 0)]), Some(1));
        // ...until the cold prompt's waited steps outgrow the discount
        assert_eq!(s.pick(&[aged(1, 0, 17), aged(2, 16, 0)]), Some(0));
        // equal effective keys: oldest wins
        assert_eq!(s.pick(&[aged(1, 0, 16), aged(2, 16, 0)]), Some(0));
    }

    #[test]
    fn kind_parses_cli_names() {
        assert_eq!(SchedulerKind::parse("fifo"), Some(SchedulerKind::Fifo));
        assert_eq!(SchedulerKind::parse("priority"), Some(SchedulerKind::Priority));
        assert_eq!(SchedulerKind::parse("spf"), Some(SchedulerKind::ShortestPromptFirst));
        assert_eq!(SchedulerKind::parse("shortest"), Some(SchedulerKind::ShortestPromptFirst));
        assert_eq!(SchedulerKind::parse("prefix"), Some(SchedulerKind::PrefixAffinity));
        assert_eq!(SchedulerKind::parse("lifo"), None);
        assert_eq!(SchedulerKind::default(), SchedulerKind::Fifo);
        assert_eq!(SchedulerKind::PrefixAffinity.name(), "prefix");
    }
}
