//! Paged KV-cache manager with per-layer variable KV-head counts.
//!
//! TensorRT-LLM assumed a uniform KV-head count across layers; Puzzle
//! architectures violate that (paper §6), so pages are tracked per layer
//! with layer-specific page byte-sizes: page_bytes(l) = 2 (K+V) ·
//! kv_heads(l) · head_dim · page_len · dtype_bytes. Layers with linear or
//! no-op attention allocate nothing. The manager does admission control
//! and accounting for the engine; the backing storage is the dense decode
//! cache literals (CPU PJRT device memory == host memory).

use std::collections::HashMap;

use crate::arch::{Arch, AttnChoice};
use crate::config::Manifest;

#[derive(Debug, Clone)]
/// Page-pool geometry and budget.
pub struct PageCfg {
    /// positions per page
    pub page_len: usize,
    /// bytes per cache element (f32 on this backend; 1 for FP8 accounting)
    pub dtype_bytes: usize,
    /// total byte budget for the cache pool
    pub budget_bytes: usize,
}

#[derive(Debug, Clone, Default)]
/// Pages held by one sequence.
pub struct SeqPages {
    /// pages held per layer (layers with kv_heads = 0 hold none),
    /// including pages backed by a shared retained segment
    pub per_layer: Vec<usize>,
    /// Occupied positions (== the sequence's committed length).
    pub positions: usize,
    /// Leading pages per caching layer backed by a shared segment — those
    /// bytes are charged to the segment, not to this sequence.
    shared_pages: usize,
    /// The shared segment this sequence holds a reference on, if any.
    seg: Option<u64>,
}

#[derive(Debug, Clone)]
/// A retained prefix segment: its pages are charged to the pool exactly
/// once, no matter how many sequences reference them. Segments come from
/// cold prompt prefills *and* from finished sequences' committed streams
/// (prompt plus generated tokens — finish-time retention); the accounting
/// here is origin-agnostic.
struct SharedSeg {
    /// pages per caching layer
    pages: usize,
    /// live sequence references (an unreferenced segment is evictable)
    refs: usize,
    /// total bytes charged for the segment across all caching layers
    bytes: usize,
}

#[derive(Debug)]
/// Admission control and exact byte accounting for the paged KV pool
/// (per-layer page tables; see the module docs). Besides per-sequence
/// pages it tracks *shared* retained-prefix segments (`retain_shared`):
/// a segment's bytes are charged once, sequences admitted over it
/// (`admit_shared`) hold references instead of copies, and an
/// unreferenced segment can be evicted (`evict_shared`) to make room —
/// the accounting substrate of the serving prefix cache.
pub struct PagedKvManager {
    cfg: PageCfg,
    /// kv heads per layer (0 = linear/no-op attention)
    kv_heads: Vec<usize>,
    head_dim: usize,
    allocated_bytes: usize,
    seqs: HashMap<u64, SeqPages>,
    shared: HashMap<u64, SharedSeg>,
}

impl PagedKvManager {
    /// A manager for `arch` over `man`'s shapes under `cfg`.
    pub fn new(man: &Manifest, arch: &Arch, cfg: PageCfg) -> PagedKvManager {
        let kv_heads = arch
            .layers
            .iter()
            .map(|(a, _)| match a {
                AttnChoice::Gqa { .. } => man.attn_variants[&a.name()].kv_heads,
                _ => 0,
            })
            .collect();
        PagedKvManager {
            cfg,
            kv_heads,
            head_dim: man.cfg.head_dim,
            allocated_bytes: 0,
            seqs: HashMap::new(),
            shared: HashMap::new(),
        }
    }

    /// Bytes per page at layer `l` (0 for cache-free layers).
    pub fn page_bytes(&self, l: usize) -> usize {
        2 * self.kv_heads[l] * self.head_dim * self.cfg.page_len * self.cfg.dtype_bytes
    }

    /// Bytes one sequence position costs across all layers.
    pub fn bytes_per_position(&self) -> usize {
        self.kv_heads
            .iter()
            .map(|&kv| 2 * kv * self.head_dim * self.cfg.dtype_bytes)
            .sum()
    }

    fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.cfg.page_len)
    }

    /// Bytes needed to grow a sequence to `positions`. Pages inside a
    /// shared-backed prefix are never charged: growing back through a
    /// region the sequence's segment still covers is free.
    fn bytes_to_grow(&self, seq: Option<&SeqPages>, positions: usize) -> usize {
        let target = self.pages_for(positions);
        (0..self.kv_heads.len())
            .map(|l| {
                let have = seq.map(|s| s.per_layer[l].max(s.shared_pages)).unwrap_or(0);
                let need = if self.kv_heads[l] == 0 { 0 } else { target };
                need.saturating_sub(have) * self.page_bytes(l)
            })
            .sum()
    }

    /// Bytes a fresh sequence of `max_total` positions costs when its
    /// first `shared_positions` positions are backed by a shared segment
    /// (those pages are already charged to the segment).
    fn bytes_for_new(&self, max_total: usize, shared_positions: usize) -> usize {
        let target = self.pages_for(max_total);
        let shared = self.pages_for(shared_positions).min(target);
        (0..self.kv_heads.len())
            .map(|l| {
                if self.kv_heads[l] == 0 {
                    0
                } else {
                    (target - shared) * self.page_bytes(l)
                }
            })
            .sum()
    }

    /// Pages per caching layer this sequence pays for itself (total minus
    /// the shared-segment-backed prefix).
    fn owned_pages(seq: &SeqPages, l: usize) -> usize {
        seq.per_layer[l].saturating_sub(seq.shared_pages)
    }

    /// Admission check: can a new sequence with `prompt_len` prompt and up
    /// to `max_total` positions be admitted right now? (Conservative: checks
    /// the full horizon so decode never deadlocks mid-generation.)
    pub fn can_admit(&self, max_total: usize) -> bool {
        self.allocated_bytes + self.bytes_to_grow(None, max_total) <= self.cfg.budget_bytes
    }

    /// `can_admit` for a sequence whose first `shared_positions` positions
    /// ride an already-retained shared segment.
    pub fn can_admit_shared(&self, max_total: usize, shared_positions: usize) -> bool {
        self.allocated_bytes + self.bytes_for_new(max_total, shared_positions) <= self.cfg.budget_bytes
    }

    /// Could a sequence of `max_total` positions EVER be admitted — i.e.
    /// do its pages fit an *empty* pool? The engine uses this at `submit`
    /// to reject unservable horizons instead of stalling later.
    pub fn fits_budget(&self, max_total: usize) -> bool {
        self.bytes_to_grow(None, max_total) <= self.cfg.budget_bytes
    }

    /// Total pool capacity in bytes.
    pub fn budget_bytes(&self) -> usize {
        self.cfg.budget_bytes
    }

    /// Allocate pages for a new sequence at `positions` occupied slots.
    /// Re-admitting a live `seq_id` is refused: silently replacing its
    /// page table would orphan the bytes already charged to it (the
    /// accounting leak this guard regression-tests against).
    pub fn admit(&mut self, seq_id: u64, positions: usize) -> bool {
        self.admit_inner(seq_id, positions, 0, None)
    }

    /// Admit a sequence whose first `shared_positions` positions are
    /// backed by retained segment `seg_id`: the sequence is charged only
    /// for pages beyond the shared prefix and holds a reference on the
    /// segment (pinning it against eviction) until it is released.
    pub fn admit_shared(&mut self, seq_id: u64, positions: usize, seg_id: u64, shared_positions: usize) -> bool {
        let shared = self.pages_for(shared_positions);
        match self.shared.get(&seg_id) {
            None => {
                debug_assert!(false, "admit_shared over unknown segment {seg_id}");
                return false;
            }
            Some(seg) => {
                debug_assert!(
                    shared <= seg.pages && shared_positions <= positions,
                    "admit_shared: shared prefix exceeds the segment or the horizon"
                );
            }
        }
        self.admit_inner(seq_id, positions, shared, Some(seg_id))
    }

    fn admit_inner(&mut self, seq_id: u64, positions: usize, shared_pages: usize, seg: Option<u64>) -> bool {
        if self.seqs.contains_key(&seq_id) {
            debug_assert!(false, "admit of already-present sequence {seq_id}");
            return false;
        }
        let grow = self.bytes_for_new(positions, shared_pages * self.cfg.page_len);
        if self.allocated_bytes + grow > self.cfg.budget_bytes {
            return false;
        }
        let target = self.pages_for(positions);
        let per_layer = self
            .kv_heads
            .iter()
            .map(|&kv| if kv == 0 { 0 } else { target })
            .collect();
        self.allocated_bytes += grow;
        if let Some(seg_id) = seg {
            self.shared.get_mut(&seg_id).unwrap().refs += 1;
        }
        self.seqs.insert(seq_id, SeqPages { per_layer, positions, shared_pages, seg });
        true
    }

    /// Bytes a retained segment of `positions` positions costs across all
    /// caching layers (what `retain_shared` would charge).
    pub fn shared_bytes(&self, positions: usize) -> usize {
        let pages = self.pages_for(positions);
        (0..self.kv_heads.len())
            .map(|l| if self.kv_heads[l] == 0 { 0 } else { pages * self.page_bytes(l) })
            .sum()
    }

    /// Charge a retained prefix segment of `positions` positions to the
    /// pool — once, regardless of how many sequences will reference it.
    /// Refuses duplicates and budget overruns.
    pub fn retain_shared(&mut self, seg_id: u64, positions: usize) -> bool {
        if self.shared.contains_key(&seg_id) {
            debug_assert!(false, "retain_shared of already-present segment {seg_id}");
            return false;
        }
        let bytes = self.shared_bytes(positions);
        if self.allocated_bytes + bytes > self.cfg.budget_bytes {
            return false;
        }
        self.allocated_bytes += bytes;
        self.shared.insert(seg_id, SharedSeg { pages: self.pages_for(positions), refs: 0, bytes });
        true
    }

    /// Free an *unreferenced* retained segment's pages. Returns false —
    /// and frees nothing — while any live sequence still references it
    /// (retention can be evicted, admitted work cannot).
    pub fn evict_shared(&mut self, seg_id: u64) -> bool {
        match self.shared.get(&seg_id) {
            Some(seg) if seg.refs == 0 => {
                let seg = self.shared.remove(&seg_id).unwrap();
                self.allocated_bytes -= seg.bytes;
                true
            }
            _ => false,
        }
    }

    /// Live sequence references on a retained segment (None if unknown).
    pub fn seg_refs(&self, seg_id: u64) -> Option<usize> {
        self.shared.get(&seg_id).map(|s| s.refs)
    }

    /// Bytes currently charged to retained shared segments.
    pub fn shared_allocated_bytes(&self) -> usize {
        self.shared.values().map(|s| s.bytes).sum()
    }

    /// Grow a sequence by one position (decode step); allocates new pages
    /// at page boundaries. Returns false if the pool is exhausted.
    pub fn grow(&mut self, seq_id: u64) -> bool {
        let Some(seq) = self.seqs.get(&seq_id) else { return false };
        let new_pos = seq.positions + 1;
        let grow = self.bytes_to_grow(Some(seq), new_pos);
        if self.allocated_bytes + grow > self.cfg.budget_bytes {
            return false;
        }
        self.allocated_bytes += grow;
        let target = self.pages_for(new_pos);
        let seq = self.seqs.get_mut(&seq_id).unwrap();
        for (l, p) in seq.per_layer.iter_mut().enumerate() {
            if self.kv_heads[l] != 0 {
                *p = target;
            }
        }
        seq.positions = new_pos;
        true
    }

    /// Rewind a sequence to `new_len` positions, freeing its trailing
    /// pages exactly — the speculative-decoding KV rollback primitive
    /// (rejected draft tokens hand their pages straight back to the
    /// pool). Truncating to zero is equivalent to `release`; truncating
    /// at or past the current length, or an unknown id, is a no-op.
    pub fn truncate(&mut self, seq_id: u64, new_len: usize) {
        if new_len == 0 {
            self.release(seq_id);
            return;
        }
        let target = self.pages_for(new_len);
        let page_bytes: Vec<usize> = (0..self.kv_heads.len()).map(|l| self.page_bytes(l)).collect();
        let Some(seq) = self.seqs.get_mut(&seq_id) else { return };
        if new_len >= seq.positions {
            return;
        }
        let shared = seq.shared_pages;
        let mut freed = 0usize;
        for (l, p) in seq.per_layer.iter_mut().enumerate() {
            let keep = target.min(*p);
            // only the sequence's own pages are freed; a shared-backed
            // prefix page belongs to its segment and is never handed back
            // here (the segment outlives any one sequence's rewind)
            let owned_before = p.saturating_sub(shared);
            let owned_after = keep.saturating_sub(shared);
            freed += (owned_before - owned_after) * page_bytes[l];
            *p = keep;
        }
        seq.positions = new_len;
        self.allocated_bytes -= freed;
    }

    /// Free all pages of a finished sequence (and drop its reference on a
    /// shared segment, if it held one — the segment's own bytes stay
    /// charged until `evict_shared`).
    pub fn release(&mut self, seq_id: u64) {
        if let Some(seq) = self.seqs.remove(&seq_id) {
            let freed: usize = (0..seq.per_layer.len())
                .map(|l| Self::owned_pages(&seq, l) * self.page_bytes(l))
                .sum();
            self.allocated_bytes -= freed;
            if let Some(seg_id) = seq.seg {
                if let Some(seg) = self.shared.get_mut(&seg_id) {
                    seg.refs -= 1;
                }
            }
        }
    }

    /// Bytes currently allocated across all sequences.
    pub fn allocated_bytes(&self) -> usize {
        self.allocated_bytes
    }

    /// Number of sequences holding pages.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::FfnChoice;
    use crate::config::{Manifest, TinyManifest};

    fn setup(arch_fn: impl Fn(usize) -> Arch) -> (Manifest, Arch) {
        let man = TinyManifest::synthetic();
        let arch = arch_fn(man.cfg.n_layers);
        (man, arch)
    }

    fn cfg(budget: usize) -> PageCfg {
        PageCfg { page_len: 16, dtype_bytes: 4, budget_bytes: budget }
    }

    #[test]
    fn variable_gqa_layers_have_different_page_sizes() {
        let (man, _) = setup(Arch::parent);
        let mut arch = Arch::parent(man.cfg.n_layers);
        arch.layers[0].0 = AttnChoice::Gqa { divisor: 4 };
        arch.layers[1].0 = AttnChoice::Linear;
        let mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert_eq!(mgr.page_bytes(1), 0); // linear attention: no cache
        assert!(mgr.page_bytes(0) < mgr.page_bytes(2)); // fewer kv heads -> smaller pages
        assert_eq!(mgr.page_bytes(0) * 4, mgr.page_bytes(2)); // divisor 4
    }

    #[test]
    fn admission_and_release_accounting() {
        let (man, arch) = setup(Arch::parent);
        let mgr_budget = 1 << 18;
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(mgr_budget));
        assert!(mgr.admit(1, 20)); // 2 pages/layer
        let b1 = mgr.allocated_bytes();
        assert!(b1 > 0);
        // 20 positions at page_len 16 = 2 pages on every caching layer
        let expected: usize = (0..man.cfg.n_layers).map(|l| 2 * mgr.page_bytes(l)).sum();
        assert_eq!(b1, expected);
        assert_eq!(mgr.active_seqs(), 1);
        assert!(mgr.admit(2, 5));
        let b2 = mgr.allocated_bytes();
        mgr.release(1);
        assert_eq!(mgr.allocated_bytes(), b2 - b1);
        mgr.release(2);
        assert_eq!(mgr.allocated_bytes(), 0);
        assert_eq!(mgr.active_seqs(), 0);
    }

    #[test]
    fn grow_allocates_only_at_page_boundary() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 16)); // exactly one page
        let b = mgr.allocated_bytes();
        assert!(mgr.grow(1)); // position 17 -> second page
        assert!(mgr.allocated_bytes() > b);
        let b2 = mgr.allocated_bytes();
        for _ in 0..14 {
            assert!(mgr.grow(1)); // up to 31: same page
        }
        assert_eq!(mgr.allocated_bytes(), b2);
    }

    #[test]
    fn budget_exhaustion_rejects() {
        let (man, arch) = setup(Arch::parent);
        let one_seq_bytes = {
            let mut probe = PagedKvManager::new(&man, &arch, cfg(usize::MAX / 2));
            probe.admit(1, 64);
            probe.allocated_bytes()
        };
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(one_seq_bytes + one_seq_bytes / 2));
        assert!(mgr.admit(1, 64));
        assert!(!mgr.admit(2, 64), "second sequence must be rejected");
        assert!(mgr.can_admit(16));
        // fits_budget ignores current occupancy: 64 positions still *fit*
        // the pool even while seq 1 holds it...
        assert!(mgr.fits_budget(64));
        assert!(!mgr.can_admit(64));
        // ...but a horizon beyond total capacity can never fit
        assert!(!mgr.fits_budget(64 * 16));
        mgr.release(1);
        assert!(mgr.admit(2, 64));
    }

    #[test]
    fn can_admit_is_exact_at_the_budget_boundary() {
        let (man, arch) = setup(Arch::parent);
        let page_len = 16;
        // budget for exactly 2 pages on every caching layer
        let probe = PagedKvManager::new(&man, &arch, cfg(0));
        let two_pages: usize = (0..man.cfg.n_layers).map(|l| 2 * probe.page_bytes(l)).sum();
        let mgr = PagedKvManager::new(&man, &arch, cfg(two_pages));
        // anything up to 2 full pages of positions fits exactly...
        assert!(mgr.can_admit(2 * page_len));
        // ...one more position needs a third page and must be refused
        assert!(!mgr.can_admit(2 * page_len + 1));
    }

    #[test]
    fn grow_rejects_at_exhaustion_without_corrupting_accounting() {
        let (man, arch) = setup(Arch::parent);
        let probe = PagedKvManager::new(&man, &arch, cfg(0));
        let one_page: usize = (0..man.cfg.n_layers).map(|l| probe.page_bytes(l)).sum();
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(one_page));
        assert!(mgr.admit(1, 16)); // fills the single page exactly
        let b = mgr.allocated_bytes();
        assert_eq!(b, one_page);
        assert!(!mgr.grow(1), "position 17 needs a second page: must fail");
        assert_eq!(mgr.allocated_bytes(), b, "failed grow must not leak bytes");
        // growing an unknown sequence is also a clean refusal
        assert!(!mgr.grow(999));
        assert_eq!(mgr.allocated_bytes(), b);
    }

    #[test]
    fn double_release_is_safe() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 20));
        assert!(mgr.admit(2, 20));
        let after_two = mgr.allocated_bytes();
        mgr.release(1);
        let after_one = mgr.allocated_bytes();
        mgr.release(1); // second release of the same id: no-op
        assert_eq!(mgr.allocated_bytes(), after_one);
        mgr.release(7); // never-admitted id: no-op
        assert_eq!(mgr.allocated_bytes(), after_one);
        assert_eq!(mgr.active_seqs(), 1);
        mgr.release(2);
        assert_eq!(mgr.allocated_bytes(), 0);
        assert!(after_two > after_one);
    }

    #[test]
    fn truncate_frees_trailing_pages_exactly() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        let pages = |n: usize| -> usize { (0..man.cfg.n_layers).map(|l| n * mgr.page_bytes(l)).sum() };
        assert!(mgr.admit(1, 40)); // 3 pages/layer at page_len 16
        assert_eq!(mgr.allocated_bytes(), pages(3));
        // rewind within the last page: nothing to free
        mgr.truncate(1, 33);
        assert_eq!(mgr.allocated_bytes(), pages(3));
        // rewind to a page boundary: exactly one trailing page per layer back
        mgr.truncate(1, 32);
        assert_eq!(mgr.allocated_bytes(), pages(2));
        // deep rewind: down to a single page per layer
        mgr.truncate(1, 1);
        assert_eq!(mgr.allocated_bytes(), pages(1));
        // the freed budget is usable again
        assert!(mgr.can_admit(32));
        mgr.release(1);
        assert_eq!(mgr.allocated_bytes(), 0);
    }

    #[test]
    fn truncate_to_zero_equals_release() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 20));
        assert!(mgr.allocated_bytes() > 0);
        mgr.truncate(1, 0);
        assert_eq!(mgr.allocated_bytes(), 0);
        assert_eq!(mgr.active_seqs(), 0);
        // the id is gone, exactly as after release: re-admission works
        assert!(mgr.admit(1, 20));
        assert_eq!(mgr.active_seqs(), 1);
    }

    #[test]
    fn truncate_past_current_len_is_noop() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 20));
        let b = mgr.allocated_bytes();
        mgr.truncate(1, 25); // beyond current positions
        assert_eq!(mgr.allocated_bytes(), b);
        mgr.truncate(1, 20); // exactly current positions
        assert_eq!(mgr.allocated_bytes(), b);
        mgr.truncate(999, 5); // unknown id
        assert_eq!(mgr.allocated_bytes(), b);
        assert_eq!(mgr.active_seqs(), 1);
    }

    #[test]
    fn truncate_then_grow_reaccounts() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 32)); // 2 pages/layer
        let two = mgr.allocated_bytes();
        mgr.truncate(1, 16); // back to 1 page/layer
        let one = mgr.allocated_bytes();
        assert!(one < two);
        // grow back across the page boundary: same accounting as before
        assert!(mgr.grow(1)); // position 17 -> second page again
        assert_eq!(mgr.allocated_bytes(), two);
    }

    #[test]
    fn duplicate_admit_is_refused_without_leaking() {
        // regression: admit of a live seq_id used to silently replace its
        // SeqPages, orphaning the bytes already charged to it
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.admit(1, 40)); // 3 pages/layer
        let b = mgr.allocated_bytes();
        let refused = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // debug builds assert; release builds must still refuse
            mgr.admit(1, 16)
        }));
        if let Ok(accepted) = refused {
            assert!(!accepted, "duplicate admit must be refused");
        }
        assert_eq!(mgr.allocated_bytes(), b, "refused duplicate must not change accounting");
        assert_eq!(mgr.active_seqs(), 1);
        mgr.release(1);
        assert_eq!(mgr.allocated_bytes(), 0, "the original pages must still be released exactly");
    }

    #[test]
    fn shared_segments_charge_once_and_refcount() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        let seg_bytes = mgr.shared_bytes(32); // 2 pages/layer
        assert!(seg_bytes > 0);
        assert!(mgr.retain_shared(100, 32));
        assert_eq!(mgr.allocated_bytes(), seg_bytes);
        assert_eq!(mgr.shared_allocated_bytes(), seg_bytes);
        assert_eq!(mgr.seg_refs(100), Some(0));

        // two sequences ride the same 32-position prefix toward a
        // 48-position horizon: each pays only its own 1 extra page/layer
        let own: usize = (0..man.cfg.n_layers).map(|l| mgr.page_bytes(l)).sum();
        assert!(mgr.admit_shared(1, 48, 100, 32));
        assert_eq!(mgr.allocated_bytes(), seg_bytes + own, "prefix bytes must be charged once");
        assert!(mgr.admit_shared(2, 48, 100, 32));
        assert_eq!(mgr.allocated_bytes(), seg_bytes + 2 * own);
        assert_eq!(mgr.seg_refs(100), Some(2));

        // a referenced segment is pinned
        assert!(!mgr.evict_shared(100), "a segment with live refs must not be evictable");
        assert_eq!(mgr.allocated_bytes(), seg_bytes + 2 * own);

        // releases drop refs and free exactly the owned bytes
        mgr.release(1);
        assert_eq!(mgr.allocated_bytes(), seg_bytes + own);
        assert_eq!(mgr.seg_refs(100), Some(1));
        mgr.release(2);
        assert_eq!(mgr.allocated_bytes(), seg_bytes);
        assert_eq!(mgr.seg_refs(100), Some(0));

        // now unreferenced: evictable, and the pool returns to empty
        assert!(mgr.evict_shared(100));
        assert_eq!(mgr.allocated_bytes(), 0);
        assert_eq!(mgr.seg_refs(100), None);
        assert!(!mgr.evict_shared(100), "double eviction is a no-op");
    }

    #[test]
    fn shared_truncate_never_frees_segment_pages() {
        let (man, arch) = setup(Arch::parent);
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1 << 20));
        assert!(mgr.retain_shared(7, 32));
        let seg_bytes = mgr.allocated_bytes();
        assert!(mgr.admit_shared(1, 48, 7, 32)); // 1 owned page/layer on top
        let full = mgr.allocated_bytes();
        // rewind into the shared region: only the owned page comes back
        mgr.truncate(1, 16);
        assert_eq!(mgr.allocated_bytes(), seg_bytes, "shared pages must stay charged to the segment");
        // grow back across the shared boundary re-charges exactly the owned page
        for _ in 16..48 {
            assert!(mgr.grow(1));
        }
        assert_eq!(mgr.allocated_bytes(), full);
        // truncate-to-zero == release: ref dropped, segment intact
        mgr.truncate(1, 0);
        assert_eq!(mgr.allocated_bytes(), seg_bytes);
        assert_eq!(mgr.seg_refs(7), Some(0));
    }

    #[test]
    fn can_admit_shared_discounts_the_prefix() {
        let (man, arch) = setup(Arch::parent);
        // budget: exactly one 2-page segment plus one extra page per layer
        let probe = PagedKvManager::new(&man, &arch, cfg(0));
        let page: usize = (0..man.cfg.n_layers).map(|l| probe.page_bytes(l)).sum();
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(3 * page));
        assert!(mgr.retain_shared(5, 32)); // 2 pages/layer charged
        // a cold 48-position horizon (3 pages) cannot fit the 1 remaining page...
        assert!(!mgr.can_admit(48));
        // ...but riding the retained 32-position prefix it costs only 1 page
        assert!(mgr.can_admit_shared(48, 32));
        assert!(mgr.admit_shared(1, 48, 5, 32));
        assert_eq!(mgr.allocated_bytes(), 3 * page);
    }

    #[test]
    fn noop_attention_frees_all_cache() {
        let (man, _) = setup(Arch::parent);
        let n = man.cfg.n_layers;
        let mut arch = Arch::parent(n);
        for l in 0..n {
            arch.layers[l] = (AttnChoice::NoOp, FfnChoice::Ratio(0));
        }
        let mut mgr = PagedKvManager::new(&man, &arch, cfg(1024));
        assert_eq!(mgr.bytes_per_position(), 0);
        assert!(mgr.admit(1, 1000)); // no cache, always admits
        assert_eq!(mgr.allocated_bytes(), 0);
    }
}
