//! Engine-level serving metrics (throughput / latency, Table 3's columns).

use crate::util::{mean, percentile};

#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub requests_completed: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub decode_steps: usize,
    pub prefills: usize,
    pub wall_secs: f64,
    /// per-request time-to-first-token (secs)
    pub ttft: Vec<f64>,
    /// per-request end-to-end latency (secs)
    pub e2e: Vec<f64>,
    /// engine-side scheduling overhead per decode step (non-execute time)
    pub sched_overhead_secs: f64,
    pub execute_secs: f64,
    /// prompts longer than the prefill window, ingested via chunked
    /// (teacher-forced) decode steps instead of being truncated
    pub chunked_prefills: usize,
    /// prompts rejected at submit (empty, or >= the cache horizon)
    pub rejected_prompts: usize,
}

impl EngineMetrics {
    /// Output tokens per second — Table 3's headline number.
    pub fn gen_throughput(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_secs
        }
    }

    pub fn total_throughput(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.prompt_tokens + self.generated_tokens) as f64 / self.wall_secs
        }
    }

    pub fn mean_ttft(&self) -> f64 {
        mean(&self.ttft)
    }

    pub fn p95_e2e(&self) -> f64 {
        percentile(&self.e2e, 95.0)
    }

    /// Fraction of wall time not spent executing blocks (L3 overhead; the
    /// perf pass drives this below 20%).
    pub fn overhead_frac(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.wall_secs - self.execute_secs).max(0.0) / self.wall_secs
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "reqs {} | gen {} tok | {:.1} tok/s (total {:.1}) | ttft {:.1} ms | p95 e2e {:.1} ms | overhead {:.1}% | chunked {} | rejected {}",
            self.requests_completed,
            self.generated_tokens,
            self.gen_throughput(),
            self.total_throughput(),
            self.mean_ttft() * 1e3,
            self.p95_e2e() * 1e3,
            self.overhead_frac() * 100.0,
            self.chunked_prefills,
            self.rejected_prompts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = EngineMetrics {
            generated_tokens: 100,
            prompt_tokens: 50,
            wall_secs: 2.0,
            ..Default::default()
        };
        assert_eq!(m.gen_throughput(), 50.0);
        assert_eq!(m.total_throughput(), 75.0);
    }
}
