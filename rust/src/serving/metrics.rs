//! Engine-level serving metrics (throughput / latency, Table 3's columns).

use crate::obs::{LatencySeries, MetricsRegistry};

use super::engine::FinishReason;

#[derive(Debug, Clone, Default)]
/// Engine-level counters and latency records (Table 3's columns).
pub struct EngineMetrics {
    /// Requests that ran to a natural finish (cancellations excluded).
    pub requests_completed: usize,
    /// Prompt tokens ingested.
    pub prompt_tokens: usize,
    /// Tokens sampled (or committed, for speculative serving).
    pub generated_tokens: usize,
    /// Batched decode forwards executed.
    pub decode_steps: usize,
    /// Prefill passes executed.
    pub prefills: usize,
    /// Wall-clock seconds inside `step()` / speculative drivers.
    pub wall_secs: f64,
    /// per-request time-to-first-token (secs; bounded — exact percentiles
    /// up to the reservoir cap, log-bucketed beyond, so a long-running
    /// server never grows this)
    pub ttft: LatencySeries,
    /// inter-token latency: gap between consecutive *generated* tokens of
    /// one request (secs, pooled across requests; SLO goodput scoring and
    /// the summary percentiles both read this)
    pub itl: LatencySeries,
    /// per-request end-to-end latency (secs; naturally finished requests)
    pub e2e: LatencySeries,
    /// engine-side scheduling overhead per decode step (non-execute time)
    pub sched_overhead_secs: f64,
    /// Seconds inside backend executions.
    pub execute_secs: f64,
    /// prompts longer than the prefill window, ingested via chunked
    /// (teacher-forced) decode steps instead of being truncated
    pub chunked_prefills: usize,
    /// budgeted prefill-chunk passes (`EngineConfig::prefill_budget`):
    /// one teacher-forced multi-token forward per step that ingested
    /// queued prompt chunks alongside live decode lanes
    pub prefill_chunk_passes: usize,
    /// prompt tokens ingested by those budgeted chunk passes (per step
    /// this never exceeds the configured budget — the head-of-line bound)
    pub prefill_chunk_tokens: usize,
    /// requests rejected at submit (empty / max_new == 0 / over-horizon /
    /// over-budget / queue full)
    pub rejected_prompts: usize,
    /// finish-reason histogram
    pub finished_eos: usize,
    /// Requests that exhausted their `max_new` budget.
    pub finished_max_new: usize,
    /// Requests that filled the cache horizon.
    pub finished_horizon: usize,
    /// Requests torn down by `cancel`.
    pub cancelled: usize,
    /// speculative decoding: draft tokens proposed by the child drafter
    pub draft_proposed: usize,
    /// draft tokens accepted by parent verification
    pub draft_accepted: usize,
    /// teacher-forced multi-token verify passes (parent side)
    pub spec_passes: usize,
    /// KV rollbacks after a partial acceptance (`spec_truncate` shrinks)
    pub spec_rollbacks: usize,
    /// teacher-forced decode steps (per sequence per token) driven by the
    /// spec API
    pub spec_steps: usize,
    /// fused multi-token forward chains (one per `spec_extend_batch` call
    /// the backend fused — each replaces up to `max feed × lanes`
    /// sequential decode forwards)
    pub spec_fused_passes: usize,
    /// prefix-cache hits: admissions that imported a retained prefix and
    /// prefilled only the unmatched suffix
    pub prefix_hits: usize,
    /// prefix-cache misses: admissions that ran a full cold prefill with
    /// the cache enabled
    pub prefix_misses: usize,
    /// prompt tokens whose K/V came from a retained prefix instead of
    /// being recomputed — prefill work saved
    pub prefix_tokens_saved: usize,
    /// retained prefix segments evicted (LRU, unreferenced only) under
    /// retain-budget or KV-pool pressure
    pub prefix_evictions: usize,
    /// prefix hits whose match reached into tokens *generated* by the
    /// retaining sequence (finish-time retention) — the multi-turn win a
    /// cold-prefill-only cache cannot score
    pub prefix_gen_hits: usize,
    /// matched tokens that were generated-origin across those hits
    pub prefix_gen_tokens_saved: usize,
}

impl EngineMetrics {
    /// Count one terminal state in the finish histogram.
    pub fn record_finish(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Eos => self.finished_eos += 1,
            FinishReason::MaxNew => self.finished_max_new += 1,
            FinishReason::CacheHorizon => self.finished_horizon += 1,
            FinishReason::Cancelled => self.cancelled += 1,
        }
    }

    /// Output tokens per second — Table 3's headline number.
    pub fn gen_throughput(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_secs
        }
    }

    /// Prompt + generated tokens per second.
    pub fn total_throughput(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.prompt_tokens + self.generated_tokens) as f64 / self.wall_secs
        }
    }

    /// Mean time-to-first-token, seconds.
    pub fn mean_ttft(&self) -> f64 {
        self.ttft.mean()
    }

    /// Median time-to-first-token, seconds.
    pub fn p50_ttft(&self) -> f64 {
        self.ttft.percentile(50.0)
    }

    /// 95th-percentile time-to-first-token, seconds.
    pub fn p95_ttft(&self) -> f64 {
        self.ttft.percentile(95.0)
    }

    /// Mean inter-token latency, seconds.
    pub fn mean_itl(&self) -> f64 {
        self.itl.mean()
    }

    /// Median inter-token latency, seconds.
    pub fn p50_itl(&self) -> f64 {
        self.itl.percentile(50.0)
    }

    /// 95th-percentile inter-token latency, seconds.
    pub fn p95_itl(&self) -> f64 {
        self.itl.percentile(95.0)
    }

    /// Median end-to-end latency, seconds.
    pub fn p50_e2e(&self) -> f64 {
        self.e2e.percentile(50.0)
    }

    /// 95th-percentile end-to-end latency, seconds.
    pub fn p95_e2e(&self) -> f64 {
        self.e2e.percentile(95.0)
    }

    /// Fraction of wall time not spent executing blocks (L3 overhead; the
    /// perf pass drives this below 20%).
    pub fn overhead_frac(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            (self.wall_secs - self.execute_secs).max(0.0) / self.wall_secs
        }
    }

    /// Mean draft acceptance rate accepted/proposed — 0.0 (not NaN) when
    /// no speculative requests ran.
    pub fn mean_acceptance(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Prefix-cache hit rate hits/(hits+misses) — 0.0 (not NaN) when the
    /// cache never saw an admission.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// Fold another engine's **counters** into this snapshot — the
    /// router's per-replica rollup (`RouterHandle::metrics_text`, the
    /// `bench-router` aggregate block). Latency series are deliberately
    /// NOT merged: their percentile reservoirs do not compose, so
    /// rollups report fleet-wide throughput counters and leave
    /// TTFT/ITL/e2e distributions per-replica.
    pub fn absorb(&mut self, o: &EngineMetrics) {
        self.requests_completed += o.requests_completed;
        self.prompt_tokens += o.prompt_tokens;
        self.generated_tokens += o.generated_tokens;
        self.decode_steps += o.decode_steps;
        self.prefills += o.prefills;
        self.wall_secs += o.wall_secs;
        self.sched_overhead_secs += o.sched_overhead_secs;
        self.execute_secs += o.execute_secs;
        self.chunked_prefills += o.chunked_prefills;
        self.prefill_chunk_passes += o.prefill_chunk_passes;
        self.prefill_chunk_tokens += o.prefill_chunk_tokens;
        self.rejected_prompts += o.rejected_prompts;
        self.finished_eos += o.finished_eos;
        self.finished_max_new += o.finished_max_new;
        self.finished_horizon += o.finished_horizon;
        self.cancelled += o.cancelled;
        self.draft_proposed += o.draft_proposed;
        self.draft_accepted += o.draft_accepted;
        self.spec_passes += o.spec_passes;
        self.spec_rollbacks += o.spec_rollbacks;
        self.spec_steps += o.spec_steps;
        self.spec_fused_passes += o.spec_fused_passes;
        self.prefix_hits += o.prefix_hits;
        self.prefix_misses += o.prefix_misses;
        self.prefix_tokens_saved += o.prefix_tokens_saved;
        self.prefix_evictions += o.prefix_evictions;
        self.prefix_gen_hits += o.prefix_gen_hits;
        self.prefix_gen_tokens_saved += o.prefix_gen_tokens_saved;
    }

    /// One-line operational summary (plus a spec section when drafting
    /// ran, and a prefix section when the cache saw traffic).
    pub fn summary(&self) -> String {
        let mut s = self.base_summary();
        if self.prefill_chunk_passes > 0 {
            s.push_str(&format!(
                " | chunk passes {} ({} tok)",
                self.prefill_chunk_passes, self.prefill_chunk_tokens
            ));
        }
        if self.draft_proposed > 0 {
            s.push_str(&format!(
                " | spec accepted/proposed {}/{} ({:.0}%) passes {} rollbacks {} fused {}",
                self.draft_accepted,
                self.draft_proposed,
                self.mean_acceptance() * 100.0,
                self.spec_passes,
                self.spec_rollbacks,
                self.spec_fused_passes
            ));
        }
        if self.prefix_hits + self.prefix_misses > 0 {
            s.push_str(&format!(
                " | prefix hit/miss {}/{} ({:.0}%) saved {} tok evicted {}",
                self.prefix_hits,
                self.prefix_misses,
                self.prefix_hit_rate() * 100.0,
                self.prefix_tokens_saved,
                self.prefix_evictions
            ));
            if self.prefix_gen_hits > 0 {
                s.push_str(&format!(
                    " gen-hit {} (+{} tok)",
                    self.prefix_gen_hits, self.prefix_gen_tokens_saved
                ));
            }
        }
        s
    }

    fn base_summary(&self) -> String {
        format!(
            "reqs {} | gen {} tok | {:.1} tok/s (total {:.1}) | ttft p50/p95 {:.1}/{:.1} ms | itl p50/p95 {:.1}/{:.1} ms | e2e p50/p95 {:.1}/{:.1} ms | overhead {:.1}% | finish eos/max/horizon {}/{}/{} | cancelled {} | chunked {} | rejected {}",
            self.requests_completed,
            self.generated_tokens,
            self.gen_throughput(),
            self.total_throughput(),
            self.p50_ttft() * 1e3,
            self.p95_ttft() * 1e3,
            self.p50_itl() * 1e3,
            self.p95_itl() * 1e3,
            self.p50_e2e() * 1e3,
            self.p95_e2e() * 1e3,
            self.overhead_frac() * 100.0,
            self.finished_eos,
            self.finished_max_new,
            self.finished_horizon,
            self.cancelled,
            self.chunked_prefills,
            self.rejected_prompts
        )
    }

    /// Snapshot every counter into a typed [`MetricsRegistry`] (the
    /// Prometheus bridge behind `ServerHandle::metrics_text`). Spec and
    /// prefix counters are always present — zero-valued when the feature
    /// saw no traffic — so scrapers get a stable schema.
    pub fn registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        let c = |v: usize| v as f64;
        r.counter("puzzle_requests_completed_total", "Requests that ran to a natural finish.", c(self.requests_completed));
        r.counter("puzzle_prompt_tokens_total", "Prompt tokens ingested.", c(self.prompt_tokens));
        r.counter("puzzle_generated_tokens_total", "Tokens sampled or committed.", c(self.generated_tokens));
        r.counter("puzzle_decode_steps_total", "Batched decode forwards executed.", c(self.decode_steps));
        r.counter("puzzle_prefills_total", "Prefill passes executed.", c(self.prefills));
        r.counter("puzzle_rejected_prompts_total", "Requests refused at submit.", c(self.rejected_prompts));
        r.counter("puzzle_finished_eos_total", "Requests finished on EOS.", c(self.finished_eos));
        r.counter("puzzle_finished_max_new_total", "Requests that exhausted max_new.", c(self.finished_max_new));
        r.counter("puzzle_finished_horizon_total", "Requests that filled the cache horizon.", c(self.finished_horizon));
        r.counter("puzzle_cancelled_total", "Requests torn down by cancel.", c(self.cancelled));
        r.counter("puzzle_chunked_prefills_total", "Over-window prompts ingested via chunked decode.", c(self.chunked_prefills));
        r.counter("puzzle_prefill_chunk_passes_total", "Budgeted prefill-chunk passes.", c(self.prefill_chunk_passes));
        r.counter("puzzle_prefill_chunk_tokens_total", "Prompt tokens ingested by budgeted chunk passes.", c(self.prefill_chunk_tokens));
        r.counter("puzzle_draft_proposed_total", "Draft tokens proposed by the child drafter.", c(self.draft_proposed));
        r.counter("puzzle_draft_accepted_total", "Draft tokens accepted by parent verification.", c(self.draft_accepted));
        r.counter("puzzle_spec_passes_total", "Teacher-forced multi-token verify passes.", c(self.spec_passes));
        r.counter("puzzle_spec_rollbacks_total", "KV rollbacks after partial acceptance.", c(self.spec_rollbacks));
        r.counter("puzzle_spec_fused_passes_total", "Fused multi-token forward chains.", c(self.spec_fused_passes));
        r.counter("puzzle_prefix_hits_total", "Admissions that imported a retained prefix.", c(self.prefix_hits));
        r.counter("puzzle_prefix_misses_total", "Admissions that ran a full cold prefill.", c(self.prefix_misses));
        r.counter("puzzle_prefix_tokens_saved_total", "Prompt tokens served from retained prefixes.", c(self.prefix_tokens_saved));
        r.counter("puzzle_prefix_evictions_total", "Retained prefix segments evicted.", c(self.prefix_evictions));
        r.counter("puzzle_prefix_gen_hits_total", "Prefix hits reaching into generated tokens.", c(self.prefix_gen_hits));
        r.counter("puzzle_prefix_gen_tokens_saved_total", "Generated-origin tokens matched by prefix hits.", c(self.prefix_gen_tokens_saved));
        r.counter("puzzle_wall_seconds_total", "Wall-clock seconds inside step()/speculative drivers.", self.wall_secs);
        r.counter("puzzle_execute_seconds_total", "Seconds inside backend executions.", self.execute_secs);
        r.counter("puzzle_sched_overhead_seconds_total", "Engine-side scheduling overhead seconds.", self.sched_overhead_secs);
        r.histogram("puzzle_ttft_seconds", "Per-request time to first token.", &self.ttft);
        r.histogram("puzzle_itl_seconds", "Inter-token latency, pooled across requests.", &self.itl);
        r.histogram("puzzle_e2e_seconds", "Per-request end-to-end latency.", &self.e2e);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = EngineMetrics {
            generated_tokens: 100,
            prompt_tokens: 50,
            wall_secs: 2.0,
            ..Default::default()
        };
        assert_eq!(m.gen_throughput(), 50.0);
        assert_eq!(m.total_throughput(), 75.0);
    }

    #[test]
    fn finish_reason_histogram() {
        let mut m = EngineMetrics::default();
        m.record_finish(FinishReason::Eos);
        m.record_finish(FinishReason::Eos);
        m.record_finish(FinishReason::MaxNew);
        m.record_finish(FinishReason::CacheHorizon);
        m.record_finish(FinishReason::Cancelled);
        assert_eq!(
            (m.finished_eos, m.finished_max_new, m.finished_horizon, m.cancelled),
            (2, 1, 1, 1)
        );
    }

    #[test]
    fn acceptance_rate_guards_zero_division() {
        let m = EngineMetrics::default();
        assert_eq!(m.mean_acceptance(), 0.0, "no spec requests: rate is 0, not NaN");
        assert!(!m.summary().contains("spec"), "spec section hidden when nothing was drafted");
        let m = EngineMetrics { draft_proposed: 8, draft_accepted: 6, spec_passes: 2, spec_rollbacks: 1, ..Default::default() };
        assert_eq!(m.mean_acceptance(), 0.75);
        let s = m.summary();
        assert!(s.contains("spec accepted/proposed 6/8 (75%)"), "summary was: {s}");
        assert!(s.contains("rollbacks 1"));
    }

    #[test]
    fn prefix_hit_rate_guards_zero_division() {
        let m = EngineMetrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no cache traffic: rate is 0, not NaN");
        assert!(!m.summary().contains("prefix"), "prefix section hidden without traffic");
        let m = EngineMetrics {
            prefix_hits: 3,
            prefix_misses: 1,
            prefix_tokens_saved: 48,
            prefix_evictions: 2,
            ..Default::default()
        };
        assert_eq!(m.prefix_hit_rate(), 0.75);
        let s = m.summary();
        assert!(s.contains("prefix hit/miss 3/1 (75%)"), "summary was: {s}");
        assert!(s.contains("saved 48 tok evicted 2"));
    }

    #[test]
    fn itl_percentiles_on_known_timeline() {
        // one request whose generated tokens landed at t = 0, 10, 20, 30,
        // 100 ms: four inter-token gaps of 10/10/10/70 ms — a p95 stall
        // the mean alone would hide
        let m = EngineMetrics { itl: vec![0.010, 0.010, 0.010, 0.070].into(), ..Default::default() };
        assert_eq!(m.p50_itl(), 0.010);
        assert_eq!(m.p95_itl(), 0.070);
        assert!((m.mean_itl() - 0.025).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("itl p50/p95 10.0/70.0 ms"), "summary was: {s}");
    }

    #[test]
    fn gen_hit_section_rides_the_prefix_summary() {
        let m = EngineMetrics {
            prefix_hits: 2,
            prefix_misses: 2,
            prefix_tokens_saved: 24,
            prefix_gen_hits: 1,
            prefix_gen_tokens_saved: 9,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("gen-hit 1 (+9 tok)"), "summary was: {s}");
        let m = EngineMetrics { prefix_hits: 1, prefix_misses: 0, ..Default::default() };
        assert!(!m.summary().contains("gen-hit"), "hidden when no generated-origin hits");
    }

    #[test]
    fn chunk_pass_section_hidden_without_budgeted_prefill() {
        let m = EngineMetrics::default();
        assert!(!m.summary().contains("chunk passes"), "hidden when no budgeted passes ran");
        let m = EngineMetrics { prefill_chunk_passes: 3, prefill_chunk_tokens: 41, ..Default::default() };
        let s = m.summary();
        assert!(s.contains("chunk passes 3 (41 tok)"), "summary was: {s}");
    }

    #[test]
    fn latency_percentiles() {
        let m = EngineMetrics {
            ttft: vec![0.010, 0.020, 0.030, 0.040, 0.100].into(),
            e2e: vec![0.1, 0.2, 0.3, 0.4, 0.5].into(),
            ..Default::default()
        };
        assert_eq!(m.p50_ttft(), 0.030);
        assert_eq!(m.p95_ttft(), 0.100);
        assert_eq!(m.p50_e2e(), 0.3);
        assert_eq!(m.p95_e2e(), 0.5);
        assert!(m.summary().contains("ttft p50/p95"));
    }

    #[test]
    fn absorb_sums_counters_and_leaves_latency_series_alone() {
        let mut a = EngineMetrics {
            requests_completed: 2,
            generated_tokens: 10,
            prefix_hits: 1,
            prefix_misses: 3,
            ttft: vec![0.010].into(),
            ..Default::default()
        };
        let b = EngineMetrics {
            requests_completed: 3,
            generated_tokens: 7,
            prefix_hits: 3,
            prefix_misses: 1,
            cancelled: 1,
            ttft: vec![0.999].into(),
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.requests_completed, 5);
        assert_eq!(a.generated_tokens, 17);
        assert_eq!(a.cancelled, 1);
        assert_eq!((a.prefix_hits, a.prefix_misses), (4, 4));
        assert_eq!(a.prefix_hit_rate(), 0.5, "aggregate rate is over summed hits+misses");
        assert_eq!(a.p95_ttft(), 0.010, "latency reservoirs are not merged");
    }

    #[test]
    fn registry_round_trips_prefix_spec_chunk_counters() {
        let m = EngineMetrics {
            generated_tokens: 64,
            prefix_hits: 3,
            prefix_tokens_saved: 48,
            draft_proposed: 8,
            draft_accepted: 6,
            spec_passes: 2,
            prefill_chunk_passes: 4,
            prefill_chunk_tokens: 41,
            ttft: vec![0.010, 0.020].into(),
            ..Default::default()
        };
        let text = m.registry().render();
        let v = |name: &str| crate::obs::scrape_value(&text, name).unwrap();
        assert_eq!(v("puzzle_generated_tokens_total"), 64.0);
        assert_eq!(v("puzzle_prefix_hits_total"), 3.0);
        assert_eq!(v("puzzle_prefix_tokens_saved_total"), 48.0);
        assert_eq!(v("puzzle_draft_proposed_total"), 8.0);
        assert_eq!(v("puzzle_draft_accepted_total"), 6.0);
        assert_eq!(v("puzzle_spec_passes_total"), 2.0);
        assert_eq!(v("puzzle_prefill_chunk_passes_total"), 4.0);
        assert_eq!(v("puzzle_prefill_chunk_tokens_total"), 41.0);
        assert_eq!(v("puzzle_ttft_seconds_count"), 2.0);
        assert!(text.contains("# TYPE puzzle_ttft_seconds histogram"));
    }
}
