//! One harness per paper table/figure (see DESIGN.md §5 for the index).
//! Each prints the paper-shaped rows and appends a JSON record to
//! `<run_dir>/report.json`. All harnesses share the pipeline's cached
//! stage artifacts (parent / library / scores), so the first experiment
//! pays the training cost and the rest reuse it.

use anyhow::{anyhow, Result};

use crate::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use crate::data::{corpus::sample_sequence, CorpusMix, World};
use crate::eval::{tasks, Evaluator};
use crate::gkd;
use crate::mip::{self, Constraints};
use crate::perf::{self, HwProfile, Scenario};
use crate::pipeline::Pipeline;
use crate::scoring::{self, Metric, ScoreTable};
use crate::serving::{EngineConfig, GenRequest};
use crate::train::LossSpec;
use crate::util::{Json, Rng};
use crate::weights::{compress, store::block_key, store::randomize_weights, Store};
use crate::info;

/// Shared context for regenerating paper tables/figures.
pub struct ExpCtx {
    /// The pipeline (backend, run dir, stage config) experiments draw on.
    pub pipe: Pipeline,
    /// The search space derived from the backend's head count.
    pub space: SearchSpace,
}

impl ExpCtx {
    /// Wrap a pipeline, deriving the full search space.
    pub fn new(pipe: Pipeline) -> ExpCtx {
        let space = SearchSpace::full(pipe.be.man().cfg.n_heads as u32);
        ExpCtx { pipe, space }
    }

    fn world(&self) -> &World {
        &self.pipe.world
    }

    /// The standard child: library + KL scores + MIP at 1.8x speedup.
    fn standard_child(&self) -> Result<(Store, Arch)> {
        let store = self.pipe.ensure_library(&self.space)?;
        let scores = self.pipe.ensure_scores(&self.space, Metric::Kl)?;
        let ct = self.pipe.default_cost_table();
        let sol = self.pipe.search_speedup(&self.space, &scores, &ct, 1.8)?;
        self.pipe.save_arch("std", &sol)?;
        Ok((store, sol.arch))
    }

    fn eval(&self, store: &Store, arch: &Arch) -> Result<crate::eval::EvalReport> {
        let ev = Evaluator::new(&*self.pipe.be, store, arch)?;
        ev.run_suite(self.world(), self.pipe.cfg.eval_questions, 7)
    }

    fn record(&self, name: &str, rows: Json) -> Result<()> {
        let path = self.pipe.run_dir.join("report.json");
        let mut report = if path.exists() {
            Json::parse(&std::fs::read_to_string(&path)?).unwrap_or(Json::obj())
        } else {
            Json::obj()
        };
        report.set(name, rows);
        std::fs::write(&path, report.to_pretty())?;
        Ok(())
    }
}

fn pct(child: f64, parent: f64) -> f64 {
    if parent.abs() < 1e-9 {
        100.0
    } else {
        100.0 * child / parent
    }
}

// ======================================================================
// Table 1 — GKD loss-combination ablation
// ======================================================================
/// Table 1: GKD loss-combination ablation (LM / cosine / KLD).
pub fn table1(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 1: GKD loss combinations (LM / cosine / KLD) ==");
    let (library, arch) = ctx.standard_child()?;
    let combos = [
        (false, false, false),
        (true, false, false),
        (true, false, true),
        (false, false, true),
        (true, true, false),
        (false, true, false),
        (true, true, true),
        (false, true, true), // the paper's winner (Eq. 4)
    ];
    println!("{:<12} {:>8} {:>9} {:>9} {:>9}", "combo", "SynthQA", "GenScore", "Accuracy", "valKLD");
    let mut rows = Vec::new();
    for (lm, cosine, kld) in combos {
        let spec = LossSpec { lm, cosine, kld };
        let mut store = library.clone();
        let steps = if lm || cosine || kld { ctx.pipe.cfg.gkd_steps / 2 } else { 0 };
        let rep = if steps > 0 {
            ctx.pipe.gkd_child(&mut store, &arch, spec, steps)?
        } else {
            // no uptraining row: eval straight after BLD; still need val KLD
            ctx.pipe.gkd_child(&mut store.clone(), &arch, LossSpec::gkd_best(), 0)?
        };
        let ev = ctx.eval(&store, &arch)?;
        println!(
            "{:<12} {:>8.2} {:>9.2} {:>9.2} {:>9.4}",
            spec.name(),
            ev.get("synthqa"),
            ev.get("genscore"),
            ev.accuracy(),
            rep.val_kld
        );
        rows.push(Json::from_pairs(vec![
            ("combo", Json::str(&spec.name())),
            ("synthqa", Json::num(ev.get("synthqa"))),
            ("genscore", Json::num(ev.get("genscore"))),
            ("accuracy", Json::num(ev.accuracy())),
            ("val_kld", Json::num(rep.val_kld)),
        ]));
    }
    ctx.record("table1", Json::Arr(rows))
}

// ======================================================================
// Table 2 — accuracy preservation across benchmarks
// ======================================================================
/// Table 2: accuracy preservation across benchmarks, child vs parent.
pub fn table2(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 2: child vs parent across benchmarks ==");
    let (library, arch) = ctx.standard_child()?;
    let mut child_store = library.clone();
    ctx.pipe.gkd_child(&mut child_store, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let parent_arch = Arch::parent(ctx.pipe.be.man().cfg.n_layers);
    let pe = ctx.eval(&library, &parent_arch)?;
    let ce = ctx.eval(&child_store, &arch)?;
    println!("{:<12} {:>8} {:>8} {:>11}", "benchmark", "parent", "child", "preserved%");
    let mut rows = Vec::new();
    for k in ["synthqa", "genscore", "synthmath", "contscore"] {
        let (p, c) = (pe.get(k), ce.get(k));
        println!("{:<12} {:>8.2} {:>8.2} {:>10.1}%", k, p, c, pct(c, p));
        rows.push(Json::from_pairs(vec![
            ("benchmark", Json::str(k)),
            ("parent", Json::num(p)),
            ("child", Json::num(c)),
            ("preserved", Json::num(pct(c, p))),
        ]));
    }
    println!(
        "{:<12} {:>8.2} {:>8.2} {:>10.1}%  (paper: 98.4% preserved)",
        "accuracy", pe.accuracy(), ce.accuracy(), pct(ce.accuracy(), pe.accuracy())
    );
    ctx.record("table2", Json::Arr(rows))
}

// ======================================================================
// Table 3 — serving throughput across scenarios
// ======================================================================
/// Table 3: serving throughput across scenarios (measured + modeled).
pub fn table3(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 3: throughput, parent vs child (measured CPU + modeled H100) ==");
    let (library, arch) = ctx.standard_child()?;
    let man = ctx.pipe.be.man();
    let c = &man.cfg;
    let parent_arch = Arch::parent(c.n_layers);
    let hw = HwProfile::h100_fp8();
    // scaled versions of the paper's 128/128 ... 2048/2048 scenarios
    let scen = [
        ("Chatbot", c.s_prefill / 4, c.s_prefill / 4),
        ("Text Generation", c.s_prefill / 8, c.s_prefill / 2),
        ("Summarization/RAG", c.s_prefill, c.s_prefill / 8),
    ];
    println!(
        "{:<18} {:>9} {:>12} {:>12} {:>9} {:>12}",
        "scenario", "in/out", "child tok/s", "parent tok/s", "speedup", "H100 model"
    );
    let mut rows = Vec::new();
    for (name, pin, pout) in scen {
        let mut tps = Vec::new();
        for a in [&arch, &parent_arch] {
            // warmup pass: compile every executable outside the timed region
            {
                let mut warm = EngineConfig::new().build(ctx.pipe.be.clone(), &library, a)?;
                warm.submit(GenRequest::new(vec![1, 5, 9], 2))?;
                warm.run_to_completion()?;
            }
            // best of 2 repetitions (the first run in a fresh process can
            // still hit allocator/XLA cold paths)
            let mut best = 0.0f64;
            for _rep in 0..2 {
                let mut eng = EngineConfig::new().build(ctx.pipe.be.clone(), &library, a)?;
                let mut rng = Rng::new(3);
                for _ in 0..c.b_decode * 2 {
                    let prompt = sample_sequence(ctx.world(), &ctx.pipe.mix, pin, &mut rng);
                    eng.submit(GenRequest::new(prompt, pout))?;
                }
                eng.run_to_completion()?;
                best = best.max(eng.metrics.gen_throughput());
            }
            tps.push(best);
        }
        let sc = Scenario { prefill: pin, decode: pout, batch: 64 };
        let model_speedup = perf::scenario_throughput(man, &arch, &hw, &sc)
            / perf::scenario_throughput(man, &parent_arch, &hw, &sc);
        println!(
            "{:<18} {:>9} {:>12.1} {:>12.1} {:>8.2}x {:>11.2}x",
            name,
            format!("{pin}/{pout}"),
            tps[0],
            tps[1],
            tps[0] / tps[1],
            model_speedup
        );
        rows.push(Json::from_pairs(vec![
            ("scenario", Json::str(name)),
            ("child_tps", Json::num(tps[0])),
            ("parent_tps", Json::num(tps[1])),
            ("speedup_measured", Json::num(tps[0] / tps[1])),
            ("speedup_h100_model", Json::num(model_speedup)),
        ]));
    }
    println!("(paper: up to 2.17x on H100 FP8)");
    ctx.record("table3", Json::Arr(rows))
}

// ======================================================================
// Figure 4 — blind preference proxy
// ======================================================================
/// Figure 4: blind-preference proxy (per-prompt answer correctness).
pub fn fig4(ctx: &ExpCtx) -> Result<()> {
    println!("== Figure 4: blind-preference proxy (per-prompt answer correctness) ==");
    let (library, arch) = ctx.standard_child()?;
    let mut child = library.clone();
    ctx.pipe.gkd_child(&mut child, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let parent_arch = Arch::parent(ctx.pipe.be.man().cfg.n_layers);
    let pe = Evaluator::new(&*ctx.pipe.be, &library, &parent_arch)?;
    let ce = Evaluator::new(&*ctx.pipe.be, &child, &arch)?;
    let mut rng = Rng::new(11);
    let qs = tasks::gen_questions(ctx.world(), ctx.pipe.cfg.eval_questions, &mut rng);
    let (mut both, mut p_only, mut c_only, mut neither) = (0, 0, 0, 0);
    for q in &qs {
        let pa = pe.greedy_accuracy(std::slice::from_ref(q))? > 50.0;
        let ca = ce.greedy_accuracy(std::slice::from_ref(q))? > 50.0;
        match (pa, ca) {
            (true, true) => both += 1,
            (true, false) => p_only += 1,
            (false, true) => c_only += 1,
            (false, false) => neither += 1,
        }
    }
    println!(
        "both good {both} | parent better {p_only} | child better {c_only} | neither {neither}"
    );
    ctx.record(
        "fig4",
        Json::from_pairs(vec![
            ("both", Json::num(both as f64)),
            ("parent_better", Json::num(p_only as f64)),
            ("child_better", Json::num(c_only as f64)),
            ("neither", Json::num(neither as f64)),
        ]),
    )
}

// ======================================================================
// Figure 5 — accuracy vs throughput frontier
// ======================================================================
/// Figure 5: accuracy-vs-throughput frontier.
pub fn fig5(ctx: &ExpCtx) -> Result<()> {
    println!("== Figure 5: accuracy vs throughput frontier ==");
    let library = ctx.pipe.ensure_library(&ctx.space)?;
    let scores = ctx.pipe.ensure_scores(&ctx.space, Metric::Kl)?;
    let ct = ctx.pipe.default_cost_table();
    println!("{:<14} {:>12} {:>9}", "model", "tok/s(H100)", "accuracy");
    let mut rows = Vec::new();
    let parent_arch = Arch::parent(ctx.pipe.be.man().cfg.n_layers);
    let pe = ctx.eval(&library, &parent_arch)?;
    println!("{:<14} {:>12.0} {:>9.2}", "parent", ct.arch_throughput(&parent_arch), pe.accuracy());
    rows.push(Json::arr_f64(&[ct.arch_throughput(&parent_arch), pe.accuracy()]));
    for speedup in [1.3, 1.8, 2.4, 3.2] {
        let sol = ctx.pipe.search_speedup(&ctx.space, &scores, &ct, speedup)?;
        let mut store = library.clone();
        ctx.pipe.gkd_child(&mut store, &sol.arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps / 2)?;
        let ev = ctx.eval(&store, &sol.arch)?;
        println!("{:<14} {:>12.0} {:>9.2}", format!("puzzle-{speedup}x"), sol.throughput, ev.accuracy());
        rows.push(Json::arr_f64(&[sol.throughput, ev.accuracy()]));
    }
    ctx.record("fig5", Json::Arr(rows))
}

// ======================================================================
// Figure 6 — per-layer runtime of the child relative to the parent
// ======================================================================
/// Figure 6: per-layer runtime of the child relative to the parent.
pub fn fig6(ctx: &ExpCtx) -> Result<()> {
    println!("== Figure 6: per-layer relative runtime of the chosen child ==");
    let (_, arch) = ctx.standard_child()?;
    let man = ctx.pipe.be.man();
    let hw = HwProfile::h100_fp8();
    let c = &man.cfg;
    let sc = Scenario { prefill: c.s_prefill, decode: c.s_prefill, batch: 64 };
    let per_layer = perf::arch_cost(man, &arch, &hw, &sc);
    println!("{:<6} {:>10} {:>10}  {}", "layer", "attn rel", "ffn rel", "choice");
    let mut rows = Vec::new();
    for (l, (ar, fr)) in per_layer.iter().enumerate() {
        let (a, f) = &arch.layers[l];
        println!("{:<6} {:>10.2} {:>10.2}  {}+{}", l, ar, fr, a.name(), f.name());
        rows.push(Json::arr_f64(&[*ar, *fr]));
    }
    ctx.record("fig6", Json::Arr(rows))
}

// ======================================================================
// Table 4 — long-context (RULER proxy) retention
// ======================================================================
/// Table 4: long-context (RULER-proxy) retention.
pub fn table4(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 4: RULER-proxy retention across context lengths ==");
    let (library, arch) = ctx.standard_child()?;
    let mut child = library.clone();
    ctx.pipe.gkd_child(&mut child, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let c = &ctx.pipe.be.man().cfg;
    let ctxs: Vec<usize> = [c.s_train / 2, c.s_train, c.s_train * 2, c.s_long]
        .into_iter()
        .filter(|&x| x <= c.s_long)
        .collect();
    let parent_arch = Arch::parent(c.n_layers);
    let pe = Evaluator::new(&*ctx.pipe.be, &library, &parent_arch)?;
    let ce = Evaluator::new(&*ctx.pipe.be, &child, &arch)?;
    let n = (ctx.pipe.cfg.eval_questions / 4).max(8);
    let pr = pe.run_ruler(ctx.world(), &ctxs, n, 5)?;
    let cr = ce.run_ruler(ctx.world(), &ctxs, n, 5)?;
    println!("{:<8} {:>8} {:>8} {:>11}   (trained at ctx {})", "context", "parent", "child", "preserved%", c.s_train);
    let mut rows = Vec::new();
    for ((cx, p), (_, ch)) in pr.iter().zip(&cr) {
        println!("{:<8} {:>8.2} {:>8.2} {:>10.1}%", cx, p, ch, pct(*ch, *p));
        rows.push(Json::arr_f64(&[*cx as f64, *p, *ch]));
    }
    ctx.record("table4", Json::Arr(rows))
}

// ======================================================================
// Table 5 — lightweight alignment finetune
// ======================================================================
/// Table 5: lightweight alignment finetune on the child.
pub fn table5(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 5: lightweight alignment on the child ==");
    let (library, arch) = ctx.standard_child()?;
    let mut child = library.clone();
    ctx.pipe.gkd_child(&mut child, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let before = ctx.eval(&child, &arch)?;
    // alignment = short LM finetune on the instruction mix
    let mut aligned = child.clone();
    let c = &ctx.pipe.be.man().cfg;
    let mut batcher = crate::data::Batcher::new(
        ctx.world().clone(),
        CorpusMix::align_mix(),
        c.b_train,
        c.s_train,
        99,
    );
    let cfg = gkd::GkdCfg {
        steps: ctx.pipe.cfg.gkd_steps / 2,
        lr: ctx.pipe.cfg.gkd_lr * 0.5,
        spec: LossSpec::lm_only(),
        warmup_frac: 0.1,
        log_every: 50,
    };
    gkd::run(&*ctx.pipe.be, &mut aligned, &arch, &mut batcher, &[], &cfg)?;
    let after = ctx.eval(&aligned, &arch)?;
    let parent_arch = Arch::parent(c.n_layers);
    let pe = ctx.eval(&library, &parent_arch)?;
    println!("{:<22} {:>8} {:>9} {:>9}", "model", "SynthQA", "GenScore", "Accuracy");
    for (name, e) in [("child+alignment", &after), ("child (before)", &before), ("parent", &pe)] {
        println!("{:<22} {:>8.2} {:>9.2} {:>9.2}", name, e.get("synthqa"), e.get("genscore"), e.accuracy());
    }
    ctx.record(
        "table5",
        Json::from_pairs(vec![
            ("before", Json::num(before.accuracy())),
            ("after", Json::num(after.accuracy())),
            ("parent", Json::num(pe.accuracy())),
            ("genscore_before", Json::num(before.get("genscore"))),
            ("genscore_after", Json::num(after.get("genscore"))),
        ]),
    )
}

// ======================================================================
// Table 7 — GKD token-budget sweep
// ======================================================================
/// Table 7: GKD token-budget sweep.
pub fn table7(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 7: GKD budget sweep ==");
    let (library, arch) = ctx.standard_child()?;
    let parent_arch = Arch::parent(ctx.pipe.be.man().cfg.n_layers);
    let pe = ctx.eval(&library, &parent_arch)?;
    println!("{:<10} {:>10} {:>9} {:>11}", "gkd steps", "tokens", "accuracy", "preserved%");
    let mut rows = Vec::new();
    for frac in [0.25, 0.5, 1.0] {
        let steps = ((ctx.pipe.cfg.gkd_steps as f64) * frac).max(1.0) as usize;
        let mut store = library.clone();
        let rep = ctx.pipe.gkd_child(&mut store, &arch, LossSpec::gkd_best(), steps)?;
        let ev = ctx.eval(&store, &arch)?;
        println!(
            "{:<10} {:>10} {:>9.2} {:>10.1}%",
            steps, rep.tokens, ev.accuracy(), pct(ev.accuracy(), pe.accuracy())
        );
        rows.push(Json::arr_f64(&[steps as f64, rep.tokens as f64, ev.accuracy()]));
    }
    ctx.record("table7", Json::Arr(rows))
}

// ======================================================================
// Table 8 — coupled vs decoupled BLD
// ======================================================================
/// Table 8: coupled vs decoupled BLD on a reduced space.
pub fn table8(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 8: coupled vs decoupled BLD (reduced space) ==");
    // reduced space as in §8.1.1
    let reduced = SearchSpace::reduced(
        vec![
            AttnChoice::Gqa { divisor: 1 },
            AttnChoice::Gqa { divisor: 2 },
            AttnChoice::Gqa { divisor: 4 },
            AttnChoice::NoOp,
        ],
        vec![FfnChoice::Ratio(0), FfnChoice::Ratio(3), FfnChoice::NoOp],
    );
    let ct = ctx.pipe.default_cost_table();
    let mut rows = Vec::new();
    println!("{:<12} {:>9} {:>12}", "bld mode", "accuracy", "tok/s(H100)");
    for mode in ["decoupled", "coupled"] {
        let mut store = ctx.pipe.ensure_parent()?;
        let mut batcher = ctx.pipe.batcher(0xc0de);
        if mode == "decoupled" {
            crate::bld::run_decoupled(&*ctx.pipe.be, &mut store, &reduced, &mut batcher, ctx.pipe.cfg.bld_steps, ctx.pipe.cfg.bld_lr)?;
        } else {
            crate::bld::run_coupled(&*ctx.pipe.be, &mut store, &reduced, &mut batcher, ctx.pipe.cfg.bld_steps / 2, ctx.pipe.cfg.bld_lr)?;
        }
        let val = ctx.pipe.val_batches(ctx.pipe.cfg.score_batches);
        let scores = scoring::score_library(&*ctx.pipe.be, &store, &reduced, &val, Metric::Kl)?;
        let sol = ctx.pipe.search_speedup(&reduced, &scores, &ct, 1.8)?;
        let mut child = store.clone();
        ctx.pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps / 2)?;
        let ev = ctx.eval(&child, &sol.arch)?;
        println!("{:<12} {:>9.2} {:>12.0}", mode, ev.accuracy(), sol.throughput);
        rows.push(Json::from_pairs(vec![
            ("mode", Json::str(mode)),
            ("accuracy", Json::num(ev.accuracy())),
            ("throughput", Json::num(sol.throughput)),
        ]));
    }
    ctx.record("table8", Json::Arr(rows))
}

// ======================================================================
// Table 9 — dataset composition (Distillation Mix vs Gutenberg)
// ======================================================================
/// Table 9: dataset composition (Distillation Mix vs narrative-only).
pub fn table9(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 9: dataset composition (mix vs narrative-only) ==");
    let ct = ctx.pipe.default_cost_table();
    let c = &ctx.pipe.be.man().cfg;
    let mut rows = Vec::new();
    println!("{:<22} {:>8} {:>9} {:>9}", "bld corpus", "SynthQA", "GenScore", "Accuracy");
    for mix in [CorpusMix::distillation_mix(), CorpusMix::gutenberg()] {
        let mut store = ctx.pipe.ensure_parent()?;
        let mut batcher = crate::data::Batcher::new(ctx.world().clone(), mix.clone(), c.b_train, c.s_train, 0xda7a);
        crate::bld::run_decoupled(&*ctx.pipe.be, &mut store, &ctx.space, &mut batcher, ctx.pipe.cfg.bld_steps, ctx.pipe.cfg.bld_lr)?;
        let val = ctx.pipe.val_batches(ctx.pipe.cfg.score_batches);
        let scores = scoring::score_library(&*ctx.pipe.be, &store, &ctx.space, &val, Metric::Kl)?;
        let sol = ctx.pipe.search_speedup(&ctx.space, &scores, &ct, 1.8)?;
        // Table 9 compares *without* GKD uptraining
        let ev = ctx.eval(&store, &sol.arch)?;
        println!("{:<22} {:>8.2} {:>9.2} {:>9.2}", mix.name, ev.get("synthqa"), ev.get("genscore"), ev.accuracy());
        rows.push(Json::from_pairs(vec![
            ("corpus", Json::str(&mix.name)),
            ("synthqa", Json::num(ev.get("synthqa"))),
            ("accuracy", Json::num(ev.accuracy())),
        ]));
    }
    ctx.record("table9", Json::Arr(rows))
}

// ======================================================================
// Table 10 — BLD token-budget sweep
// ======================================================================
/// Table 10: BLD token-budget sweep.
pub fn table10(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 10: BLD budget sweep ==");
    let ct = ctx.pipe.default_cost_table();
    let mut rows = Vec::new();
    println!("{:<12} {:>10} {:>9}", "bld steps", "tokens", "accuracy");
    for frac in [0.25, 0.5, 1.0] {
        let steps = ((ctx.pipe.cfg.bld_steps as f64) * frac).max(1.0) as usize;
        let mut store = ctx.pipe.ensure_parent()?;
        let mut batcher = ctx.pipe.batcher(0xb1d2);
        let rep = crate::bld::run_decoupled(&*ctx.pipe.be, &mut store, &ctx.space, &mut batcher, steps, ctx.pipe.cfg.bld_lr)?;
        let val = ctx.pipe.val_batches(ctx.pipe.cfg.score_batches);
        let scores = scoring::score_library(&*ctx.pipe.be, &store, &ctx.space, &val, Metric::Kl)?;
        let sol = ctx.pipe.search_speedup(&ctx.space, &scores, &ct, 1.8)?;
        let mut child = store.clone();
        ctx.pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps / 4)?;
        let ev = ctx.eval(&child, &sol.arch)?;
        println!("{:<12} {:>10} {:>9.2}", steps, rep.tokens, ev.accuracy());
        rows.push(Json::arr_f64(&[steps as f64, rep.tokens as f64, ev.accuracy()]));
    }
    ctx.record("table10", Json::Arr(rows))
}

// ======================================================================
// Figure 7 — KL vs LM-loss block scoring
// ======================================================================
/// Figure 7: KL vs LM-loss replace-1-block scoring.
pub fn fig7(ctx: &ExpCtx) -> Result<()> {
    println!("== Figure 7: KL vs LM-loss replace-1-block scoring ==");
    let library = ctx.pipe.ensure_library(&ctx.space)?;
    let ct = ctx.pipe.default_cost_table();
    let mut rows = Vec::new();
    println!("{:<10} {:>8} {:>12} {:>9}", "metric", "speedup", "tok/s(H100)", "accuracy");
    for metric in [Metric::Kl, Metric::LmLoss] {
        let scores = ctx.pipe.ensure_scores(&ctx.space, metric)?;
        for speedup in [1.5, 2.2] {
            let sol = ctx.pipe.search_speedup(&ctx.space, &scores, &ct, speedup)?;
            let mut child = library.clone();
            ctx.pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps / 2)?;
            let ev = ctx.eval(&child, &sol.arch)?;
            let mname = if metric == Metric::Kl { "KL" } else { "LM-loss" };
            println!("{:<10} {:>7.1}x {:>12.0} {:>9.2}", mname, speedup, sol.throughput, ev.accuracy());
            rows.push(Json::from_pairs(vec![
                ("metric", Json::str(mname)),
                ("throughput", Json::num(sol.throughput)),
                ("accuracy", Json::num(ev.accuracy())),
            ]));
        }
    }
    ctx.record("fig7", Json::Arr(rows))
}

// ======================================================================
// Table 11 — task-oriented (Half-MMLU) block scoring
// ======================================================================
/// Table 11: task-oriented (Half-SynthQA) block scoring.
pub fn table11(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 11: Half-SynthQA task-oriented scoring ==");
    let library = ctx.pipe.ensure_library(&ctx.space)?;
    let man = ctx.pipe.be.man();
    let n_layers = man.cfg.n_layers;
    // downstream scoring: accuracy drop on the "train" half (even relations)
    let mut rng = Rng::new(21);
    let train_qs = tasks::synth_qa(ctx.world(), ctx.pipe.cfg.eval_questions, &mut rng, Some(&|r| r % 2 == 0));
    let parent_arch = Arch::parent(n_layers);
    let pe = Evaluator::new(&*ctx.pipe.be, &library, &parent_arch)?;
    let parent_acc = pe.mc_accuracy(&train_qs)?;
    let mut ds_scores = ScoreTable { metric_name: "half_synthqa".into(), ..Default::default() };
    for l in 0..n_layers {
        for a in &ctx.space.attn {
            let cost = match a {
                AttnChoice::Gqa { divisor: 1 } => 0.0,
                _ => {
                    let mut arch = parent_arch.clone();
                    arch.layers[l].0 = *a;
                    let ev = Evaluator::new(&*ctx.pipe.be, &library, &arch)?;
                    (parent_acc - ev.mc_accuracy(&train_qs)?).max(0.0)
                }
            };
            ds_scores.set(l, "attn", &a.name(), cost);
        }
        for f in &ctx.space.ffn {
            let cost = match f {
                FfnChoice::Ratio(0) => 0.0,
                _ => {
                    let mut arch = parent_arch.clone();
                    arch.layers[l].1 = *f;
                    let ev = Evaluator::new(&*ctx.pipe.be, &library, &arch)?;
                    (parent_acc - ev.mc_accuracy(&train_qs)?).max(0.0)
                }
            };
            ds_scores.set(l, "ffn", &f.name(), cost);
        }
    }
    let kl_scores = ctx.pipe.ensure_scores(&ctx.space, Metric::Kl)?;
    let ct = ctx.pipe.default_cost_table();
    // eval on the held-out half (odd relations)
    let mut rng2 = Rng::new(22);
    let test_qs = tasks::synth_qa(ctx.world(), ctx.pipe.cfg.eval_questions, &mut rng2, Some(&|r| r % 2 == 1));
    println!("{:<28} {:>14}", "scoring", "half-QA (test)");
    let mut rows = Vec::new();
    for (name, table) in [("Half-SynthQA accuracy", &ds_scores), ("KL divergence", &kl_scores)] {
        let sol = ctx.pipe.search_speedup(&ctx.space, table, &ct, 1.8)?;
        let mut child = library.clone();
        ctx.pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps / 2)?;
        let ev = Evaluator::new(&*ctx.pipe.be, &child, &sol.arch)?;
        let acc = ev.mc_accuracy(&test_qs)?;
        println!("{:<28} {:>13.2}%", name, acc);
        rows.push(Json::from_pairs(vec![("scoring", Json::str(name)), ("test_acc", Json::num(acc))]));
    }
    ctx.record("table11", Json::Arr(rows))
}

// ======================================================================
// Table 12 — no-op-only search space
// ======================================================================
/// Table 12: no-op-only vs full search space.
pub fn table12(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 12: no-op-only vs full search space (pre-uptraining) ==");
    let library = ctx.pipe.ensure_library(&ctx.space)?;
    let ct = ctx.pipe.default_cost_table();
    let mut rows = Vec::new();
    println!("{:<18} {:>8} {:>12}", "space", "SynthQA", "tok/s(H100)");
    for (name, space) in [
        ("noop-only", SearchSpace::noop_only(ctx.pipe.be.man().cfg.n_heads as u32)),
        ("full", ctx.space.clone()),
    ] {
        let val = ctx.pipe.val_batches(ctx.pipe.cfg.score_batches);
        let scores = scoring::score_library(&*ctx.pipe.be, &library, &space, &val, Metric::Kl)?;
        let sol = ctx.pipe.search_speedup(&space, &scores, &ct, 1.8)?;
        let ev = ctx.eval(&library, &sol.arch)?;
        println!("{:<18} {:>8.2} {:>12.0}", name, ev.get("synthqa"), sol.throughput);
        rows.push(Json::from_pairs(vec![
            ("space", Json::str(name)),
            ("synthqa", Json::num(ev.get("synthqa"))),
            ("throughput", Json::num(sol.throughput)),
        ]));
    }
    ctx.record("table12", Json::Arr(rows))
}

// ======================================================================
// Table 13 — greedy vs MIP / Table 14 — param-max / Table 15 — random
// ======================================================================
/// Tables 13/14/15: greedy vs MIP vs param-max vs random search.
pub fn table13_14_15(ctx: &ExpCtx) -> Result<()> {
    println!("== Tables 13/14/15: search-algorithm ablations ==");
    let library = ctx.pipe.ensure_library(&ctx.space)?;
    let scores = ctx.pipe.ensure_scores(&ctx.space, Metric::Kl)?;
    let ct = ctx.pipe.default_cost_table();
    let n_layers = ctx.pipe.be.man().cfg.n_layers;
    let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
    let cons = Constraints { throughput_min: Some(parent_tp * 1.8), ..Default::default() };

    let mip_sol = mip::search_mip(&ctx.space, &scores, &ct, &cons, n_layers, &[], 1.0)?;
    let greedy_sol = mip::search_greedy(&ctx.space, &scores, &ct, &cons, n_layers)?;
    let pm_sol = mip::search_param_max(&ctx.space, &scores, &ct, &cons, n_layers)?;
    let mut rng = Rng::new(15);
    let rnd_sol = mip::search_random(&ctx.space, &scores, &ct, &cons, n_layers, &mut rng)?;

    println!("{:<22} {:>8} {:>9} {:>12}", "search", "SynthQA", "Accuracy", "tok/s(H100)");
    let mut rows = Vec::new();
    let mut eval_one = |name: &str, arch: &Arch, store: &Store, tp: f64| -> Result<()> {
        let ev = ctx.eval(store, arch)?;
        println!("{:<22} {:>8.2} {:>9.2} {:>12.0}", name, ev.get("synthqa"), ev.accuracy(), tp);
        rows.push(Json::from_pairs(vec![
            ("search", Json::str(name)),
            ("synthqa", Json::num(ev.get("synthqa"))),
            ("accuracy", Json::num(ev.accuracy())),
            ("throughput", Json::num(tp)),
        ]));
        Ok(())
    };
    eval_one("MIP", &mip_sol.arch, &library, mip_sol.throughput)?;
    eval_one("Greedy (8.2.2)", &greedy_sol.arch, &library, greedy_sol.throughput)?;
    eval_one("Param-max (8.2.3)", &pm_sol.arch, &library, pm_sol.throughput)?;
    eval_one("Random-from-library", &rnd_sol.arch, &library, rnd_sol.throughput)?;
    // parent-randomized baseline (Table 15's last row)
    let mut rand_store = library.clone();
    let mut rng2 = Rng::new(16);
    randomize_weights(&mut rand_store, &mut rng2);
    eval_one("Parent-randomized", &Arch::parent(n_layers), &rand_store, parent_tp)?;
    ctx.record("table13_14_15", Json::Arr(rows))
}

// ======================================================================
// Table 16 — GKD uptraining impact
// ======================================================================
/// Table 16: impact of GKD uptraining.
pub fn table16(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 16: impact of GKD uptraining ==");
    let (library, arch) = ctx.standard_child()?;
    let before = ctx.eval(&library, &arch)?;
    let mut after_store = library.clone();
    ctx.pipe.gkd_child(&mut after_store, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let after = ctx.eval(&after_store, &arch)?;
    let parent_arch = Arch::parent(ctx.pipe.be.man().cfg.n_layers);
    let pe = ctx.eval(&library, &parent_arch)?;
    println!("{:<20} {:>8} {:>9} {:>9}", "model", "SynthQA", "GenScore", "Accuracy");
    for (name, e) in [("parent", &pe), ("child (no GKD)", &before), ("child (GKD)", &after)] {
        println!("{:<20} {:>8.2} {:>9.2} {:>9.2}", name, e.get("synthqa"), e.get("genscore"), e.accuracy());
    }
    ctx.record(
        "table16",
        Json::from_pairs(vec![
            ("parent", Json::num(pe.accuracy())),
            ("child_no_gkd", Json::num(before.accuracy())),
            ("child_gkd", Json::num(after.accuracy())),
        ]),
    )
}

// ======================================================================
// Table 17 — vs Wanda 2:4 and low-rank factorization
// ======================================================================
/// Table 17: Puzzle vs Wanda 2:4 vs low-rank factorization.
pub fn table17(ctx: &ExpCtx) -> Result<()> {
    println!("== Table 17: Puzzle vs Wanda 2:4 vs low-rank ==");
    let (library, arch) = ctx.standard_child()?;
    let mut puzzle_store = library.clone();
    ctx.pipe.gkd_child(&mut puzzle_store, &arch, LossSpec::gkd_best(), ctx.pipe.cfg.gkd_steps)?;
    let man = ctx.pipe.be.man();
    let n_layers = man.cfg.n_layers;
    let parent_arch = Arch::parent(n_layers);

    // Wanda 2:4 on every projection of the parent (activation norms from a
    // calibration batch are approximated by uniform norms — the metric's
    // weight term dominates for our gaussian parents).
    let mut wanda_store = library.clone();
    for l in 0..n_layers {
        for (kind, variant, wnames) in [
            ("attn", "gqa_r1", vec!["wq", "wk", "wv", "wo"]),
            ("ffn", "r100", vec!["wg", "wu", "wd"]),
        ] {
            for w in wnames {
                let key = block_key(l, kind, variant, w);
                let t = wanda_store.get(&key)?.clone();
                let xn = vec![1.0f32; t.shape[0]];
                wanda_store.put(&key, compress::wanda_2_4(&t, &xn));
            }
        }
    }
    // low-rank (rank = 50%) on attention + FFN projections
    let mut lr_store = library.clone();
    for l in 0..n_layers {
        for (kind, variant, wnames) in [
            ("attn", "gqa_r1", vec!["wq", "wk", "wv", "wo"]),
            ("ffn", "r100", vec!["wg", "wu", "wd"]),
        ] {
            for w in wnames {
                let key = block_key(l, kind, variant, w);
                let t = lr_store.get(&key)?.clone();
                let rank = (t.shape[0].min(t.shape[1]) / 2).max(1);
                lr_store.put(&key, compress::low_rank(&t, rank));
            }
        }
    }
    let pe = ctx.eval(&library, &parent_arch)?;
    println!("{:<14} {:>8} {:>9} {:>9} {:>11}", "method", "SynthQA", "GenScore", "Accuracy", "preserved%");
    let mut rows = Vec::new();
    for (name, store, a) in [
        ("Puzzle", &puzzle_store, &arch),
        ("Wanda 2:4", &wanda_store, &parent_arch),
        ("Low-rank", &lr_store, &parent_arch),
        ("Parent", &library, &parent_arch),
    ] {
        let ev = ctx.eval(store, a)?;
        println!(
            "{:<14} {:>8.2} {:>9.2} {:>9.2} {:>10.1}%",
            name, ev.get("synthqa"), ev.get("genscore"), ev.accuracy(), pct(ev.accuracy(), pe.accuracy())
        );
        rows.push(Json::from_pairs(vec![
            ("method", Json::str(name)),
            ("accuracy", Json::num(ev.accuracy())),
            ("preserved", Json::num(pct(ev.accuracy(), pe.accuracy()))),
        ]));
    }
    ctx.record("table17", Json::Arr(rows))
}

// ======================================================================
// Figure 8 — MIP solutions across throughput targets (heatmap rows)
// ======================================================================
/// Figure 8: MIP architectures across throughput targets.
pub fn fig8(ctx: &ExpCtx) -> Result<()> {
    println!("== Figure 8: MIP architectures across throughput targets ==");
    let scores = ctx.pipe.ensure_scores(&ctx.space, Metric::Kl)?;
    let ct = ctx.pipe.default_cost_table();
    let man = ctx.pipe.be.man();
    let n_layers = man.cfg.n_layers;
    let hw = HwProfile::h100_fp8();
    let c = &man.cfg;
    let sc = Scenario { prefill: c.s_prefill, decode: c.s_prefill, batch: 64 };
    println!("rows = throughput targets; per layer: attn/ffn relative runtime (0-9 scale)");
    let mut rows = Vec::new();
    for speedup in [1.2, 1.5, 1.8, 2.2, 2.7, 3.3] {
        let sol = ctx.pipe.search_speedup(&ctx.space, &scores, &ct, speedup)?;
        let rel = perf::arch_cost(man, &sol.arch, &hw, &sc);
        let digits: String = rel
            .iter()
            .map(|(a, f)| {
                let da = (a * 9.0).round().min(9.0) as u32;
                let df = (f * 9.0).round().min(9.0) as u32;
                format!("{da}{df} ")
            })
            .collect();
        println!("{speedup:>4.1}x | {digits}");
        rows.push(Json::from_pairs(vec![
            ("speedup", Json::num(speedup)),
            ("arch", sol.arch.to_json()),
        ]));
        let _ = n_layers;
    }
    ctx.record("fig8", Json::Arr(rows))
}

/// Dispatch by experiment name.
pub fn run(ctx: &ExpCtx, name: &str) -> Result<()> {
    match name {
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "table5" => table5(ctx),
        "table7" => table7(ctx),
        "table8" => table8(ctx),
        "table9" => table9(ctx),
        "table10" => table10(ctx),
        "table11" => table11(ctx),
        "table12" => table12(ctx),
        "table13" | "table14" | "table15" => table13_14_15(ctx),
        "table16" => table16(ctx),
        "table17" => table17(ctx),
        "fig4" => fig4(ctx),
        "fig5" => fig5(ctx),
        "fig6" => fig6(ctx),
        "fig7" => fig7(ctx),
        "fig8" => fig8(ctx),
        "all" => {
            for n in [
                "table2", "table3", "fig6", "fig8", "table12", "table13", "table16", "table17",
                "table4", "table7", "table9", "table10", "fig5", "fig7", "table1", "table5",
                "table8", "table11", "fig4",
            ] {
                info!("--- running {n} ---");
                run(ctx, n)?;
            }
            Ok(())
        }
        _ => Err(anyhow!("unknown experiment {name}")),
    }
}
