//! `XlaBackend`: the PJRT-backed implementation of `Backend`, wrapping the
//! lazy-compiling `Registry` over an AOT artifact directory. Only built
//! with the `pjrt` cargo feature (requires the external `xla` crate and a
//! `make artifacts` run).

use std::path::Path;

use anyhow::Result;

use crate::config::Manifest;

use super::backend::{Backend, ExecStats};
use super::literal::{lit_to_val, val_to_lit};
use super::registry::Registry;
use super::value::Value;

pub struct XlaBackend {
    reg: Registry,
}

impl XlaBackend {
    /// Open the artifact directory for one model config
    /// (e.g. `artifacts/tiny`).
    pub fn open(dir: &Path) -> Result<XlaBackend> {
        Ok(XlaBackend { reg: Registry::open(dir)? })
    }

    pub fn registry(&self) -> &Registry {
        &self.reg
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn man(&self) -> &Manifest {
        &self.reg.man
    }

    fn run(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|v| val_to_lit(v)).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let out = self.reg.run(name, &refs)?;
        out.iter().map(lit_to_val).collect()
    }

    fn measured_secs(&self, name: &str) -> Option<f64> {
        self.reg.measured_secs(name)
    }

    fn stats_snapshot(&self) -> Vec<(String, ExecStats)> {
        self.reg.stats_snapshot()
    }

    fn run_warmup(&self, name: &str) -> Result<()> {
        self.reg.get(name).map(|_| ())
    }
}
