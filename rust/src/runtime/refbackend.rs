//! `RefBackend`: a hermetic pure-Rust interpreter for the manifest's block
//! executables. It implements the same executable contract the AOT/PJRT
//! path compiles (pre-norm GQA/linear attention with RoPE and KV-cache
//! I/O, SwiGLU/linear FFN, tied embed/head, and the hand-derived vjps)
//! directly on the in-crate `tensor` module, so the entire pipeline —
//! BLD, GKD, scoring, MIP inputs, serving — runs end-to-end with no
//! `artifacts/` directory, no `xla` crate, and no python step.
//!
//! Numerics mirror `python/compile/model.py` + `kernels/ref.py` (the same
//! oracles the Pallas kernels are tested against): rmsnorm with eps inside
//! the rsqrt, rotary embedding over split halves, causal softmax
//! attention with grouped KV heads, silu-gated FFN, residual adds.
//! Gradients are checked against central finite differences in the tests
//! below.

use std::sync::Mutex;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Manifest, TinyManifest, VariantLayout};
use crate::tensor::Tensor;

use super::backend::{Backend, ExecStats};
use super::value::Value;

/// Hermetic pure-Rust interpreter of the manifest's block executables
/// (see the module docs); the default backend for tests, CI, and demos.
pub struct RefBackend {
    man: Manifest,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl RefBackend {
    /// Build an interpreter over `man` (usually `Manifest::synthetic` or
    /// an `artifacts/` manifest; no weights are loaded here).
    pub fn new(man: Manifest) -> RefBackend {
        debug_assert!(man.cfg.head_dim % 2 == 0, "RoPE needs an even head_dim");
        RefBackend { man, stats: Mutex::new(HashMap::new()) }
    }

    /// The standard hermetic test backend: in-memory tiny manifest.
    pub fn tiny() -> RefBackend {
        RefBackend::new(TinyManifest::synthetic())
    }

    fn validate(&self, name: &str, inputs: &[&Value]) -> Result<()> {
        let sig = self
            .man
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("unknown exec {name} (not in manifest)"))?;
        if sig.in_shapes.len() != inputs.len() {
            bail!("exec {name}: expected {} inputs, got {}", sig.in_shapes.len(), inputs.len());
        }
        for (i, (v, (dtype, shape))) in inputs.iter().zip(sig.in_shapes.iter()).enumerate() {
            if v.shape() != shape.as_slice() {
                bail!("exec {name} input {i}: shape {:?} != manifest {:?}", v.shape(), shape);
            }
            if v.dtype_name() != dtype.as_str() {
                bail!("exec {name} input {i}: dtype {} != manifest {}", v.dtype_name(), dtype);
            }
        }
        Ok(())
    }

    fn dispatch(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let cfg = &self.man.cfg;
        let eps = cfg.eps as f32;
        let theta = cfg.rope_theta as f32;

        if name == "embed_train_vjp" {
            // (tokens, E, dx) -> (dE,)
            let tokens = inputs[0].as_i32()?;
            let e = inputs[1].as_f32()?;
            let dx = inputs[2].as_f32()?;
            let d = e.shape[1];
            let mut de = Tensor::zeros(&e.shape);
            for (row, &tok) in tokens.iter().enumerate() {
                let tok = tok as usize;
                for j in 0..d {
                    de.data[tok * d + j] += dx.data[row * d + j];
                }
            }
            return Ok(vec![Value::F32(de)]);
        }
        if name == "head_train_vjp" {
            // (x, norm, E, dlogits) -> (dx, dnorm, dE)
            let x = inputs[0].as_f32()?;
            let norm = inputs[1].as_f32()?;
            let e = inputs[2].as_f32()?;
            let dl = inputs[3].as_f32()?;
            let (v, d) = (e.shape[0], e.shape[1]);
            let t = x.numel() / d;
            let hn = rmsnorm_fwd(&x.data, &norm.data, d, eps);
            // dhn = dlogits @ E; dE = dlogitsᵀ @ hn
            let dhn = matmul(&dl.data, &e.data, t, v, d);
            let de = matmul_at_b(&dl.data, &hn, t, v, d);
            let (dx, dnorm) = rmsnorm_bwd(&x.data, &norm.data, &dhn, d, eps);
            return Ok(vec![
                Value::F32(Tensor::from_vec(&x.shape, dx)),
                Value::F32(Tensor::from_vec(&norm.shape, dnorm)),
                Value::F32(Tensor::from_vec(&e.shape, de)),
            ]);
        }
        if name.starts_with("embed_") {
            // (tokens, E) -> (x,)
            let tokens = inputs[0].as_i32()?;
            let e = inputs[1].as_f32()?;
            let (v, d) = (e.shape[0], e.shape[1]);
            let mut shape = inputs[0].shape().to_vec();
            shape.push(d);
            let mut out = vec![0f32; tokens.len() * d];
            for (row, &tok) in tokens.iter().enumerate() {
                let tok = tok as usize;
                if tok >= v {
                    bail!("{name}: token id {tok} out of vocab {v}");
                }
                out[row * d..(row + 1) * d].copy_from_slice(&e.data[tok * d..(tok + 1) * d]);
            }
            return Ok(vec![Value::F32(Tensor::from_vec(&shape, out))]);
        }
        if name.starts_with("head_") {
            // (x, norm, E) -> (logits,)
            let x = inputs[0].as_f32()?;
            let norm = inputs[1].as_f32()?;
            let e = inputs[2].as_f32()?;
            let (v, d) = (e.shape[0], e.shape[1]);
            let t = x.numel() / d;
            let hn = rmsnorm_fwd(&x.data, &norm.data, d, eps);
            let logits = matmul_a_bt(&hn, &e.data, t, v, d);
            let mut shape = x.shape.clone();
            *shape.last_mut().unwrap() = v;
            return Ok(vec![Value::F32(Tensor::from_vec(&shape, logits))]);
        }

        let (kind, rest) = if let Some(r) = name.strip_prefix("attn_") {
            ("attn", r)
        } else if let Some(r) = name.strip_prefix("ffn_") {
            ("ffn", r)
        } else {
            bail!("unrecognized exec name {name}");
        };
        let (variant, mode) = split_mode(rest)
            .ok_or_else(|| anyhow!("exec {name}: cannot split variant/mode"))?;
        let layout = if kind == "attn" {
            self.man.attn_variants.get(variant)
        } else {
            self.man.ffn_variants.get(variant)
        }
        .ok_or_else(|| anyhow!("exec {name}: unknown variant {variant}"))?;
        let nw = layout.weights.len();

        // weight slice position: decode GQA prepends (k_cache, v_cache, pos)
        let gqa_decode = kind == "attn" && variant != "linear" && mode == "decode";
        let wstart = if gqa_decode { 4 } else { 1 };
        let w: Vec<&Tensor> =
            inputs[wstart..wstart + nw].iter().map(|v| v.as_f32()).collect::<Result<_>>()?;
        let x = inputs[0].as_f32()?;

        match (kind, variant == "linear", mode) {
            // token-wise linear replacements: same math in every mode
            (_, true, "train_vjp") => {
                let dy = inputs[1 + nw].as_f32()?;
                let (dx, dws) = linear_vjp(x, &w, dy, eps);
                Ok(pack_grads(x, layout, dx, dws))
            }
            (_, true, _) => Ok(vec![Value::F32(linear_fwd(x, &w, eps))]),
            ("ffn", false, "train_vjp") => {
                let dy = inputs[1 + nw].as_f32()?;
                let (dx, dws) = ffn_vjp(x, &w, dy, eps);
                Ok(pack_grads(x, layout, dx, dws))
            }
            ("ffn", false, _) => Ok(vec![Value::F32(ffn_fwd(x, &w, eps))]),
            ("attn", false, "train_fwd") | ("attn", false, "long") => {
                let (y, _, _) = attn_gqa_fwd(cfg.n_heads, cfg.head_dim, layout.kv_heads, x, &w, eps, theta);
                Ok(vec![Value::F32(y)])
            }
            ("attn", false, "prefill") => {
                let kv = layout.kv_heads;
                let (b, s) = (x.shape[0], x.shape[1]);
                let (y, k, v) = attn_gqa_fwd(cfg.n_heads, cfg.head_dim, kv, x, &w, eps, theta);
                let kv_shape = vec![b, s, kv, cfg.head_dim];
                Ok(vec![
                    Value::F32(y),
                    Value::F32(Tensor::from_vec(&kv_shape, k)),
                    Value::F32(Tensor::from_vec(&kv_shape, v)),
                ])
            }
            ("attn", false, "decode") => {
                let kc = inputs[1].as_f32()?;
                let vc = inputs[2].as_f32()?;
                let pos = inputs[3].as_i32()?;
                let (y, kc2, vc2) =
                    attn_gqa_decode(cfg.n_heads, cfg.head_dim, layout.kv_heads, x, kc, vc, pos, &w, eps, theta)?;
                Ok(vec![Value::F32(y), Value::F32(kc2), Value::F32(vc2)])
            }
            ("attn", false, "train_vjp") => {
                let dy = inputs[1 + nw].as_f32()?;
                let (dx, dws) =
                    attn_gqa_vjp(cfg.n_heads, cfg.head_dim, layout.kv_heads, x, &w, dy, eps, theta);
                Ok(pack_grads(x, layout, dx, dws))
            }
            _ => bail!("exec {name}: unsupported mode {mode}"),
        }
    }

    /// Fused multi-token dispatch (`Backend::run_fused`): shapes come
    /// from the inputs, not the manifest, since the new-position count
    /// `m` varies per call. GQA decode gets a dedicated fused kernel;
    /// every other decode-mode executable (embed, head, FFN, linear
    /// attention) is token-wise and reuses the plain interpreter, which
    /// already derives its shapes from the inputs.
    fn dispatch_fused(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        let cfg = &self.man.cfg;
        if !name.ends_with("_decode") {
            bail!("fused execution is defined for decode-mode executables only, got {name}");
        }
        if let Some(rest) = name.strip_prefix("attn_") {
            let (variant, _) = split_mode(rest)
                .ok_or_else(|| anyhow!("exec {name}: cannot split variant/mode"))?;
            if variant != "linear" {
                let layout = self
                    .man
                    .attn_variants
                    .get(variant)
                    .ok_or_else(|| anyhow!("exec {name}: unknown variant {variant}"))?;
                let nw = layout.weights.len();
                if inputs.len() != 4 + nw {
                    bail!("fused exec {name}: expected {} inputs, got {}", 4 + nw, inputs.len());
                }
                let x = inputs[0].as_f32()?;
                let kc = inputs[1].as_f32()?;
                let vc = inputs[2].as_f32()?;
                let pos = inputs[3].as_i32()?;
                let w: Vec<&Tensor> =
                    inputs[4..4 + nw].iter().map(|v| v.as_f32()).collect::<Result<_>>()?;
                let (y, kc2, vc2) = attn_gqa_decode_fused(
                    cfg.n_heads,
                    cfg.head_dim,
                    layout.kv_heads,
                    x,
                    kc,
                    vc,
                    pos,
                    &w,
                    cfg.eps as f32,
                    cfg.rope_theta as f32,
                )?;
                return Ok(vec![Value::F32(y), Value::F32(kc2), Value::F32(vc2)]);
            }
        }
        self.dispatch(name, inputs)
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn man(&self) -> &Manifest {
        &self.man
    }

    fn run(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.validate(name, inputs)?;
        let t0 = Instant::now();
        let out = self.dispatch(name, inputs).with_context(|| format!("ref exec {name}"))?;
        let mut st = self.stats.lock().unwrap();
        let entry = st.entry(name.to_string()).or_default();
        entry.calls += 1;
        entry.total_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn run_fused(&self, name: &str, inputs: &[&Value]) -> Result<Option<Vec<Value>>> {
        let t0 = Instant::now();
        let out =
            self.dispatch_fused(name, inputs).with_context(|| format!("ref fused exec {name}"))?;
        // stats under a distinct key so fused passes are visible next to
        // the per-step decode numbers they amortize
        let mut st = self.stats.lock().unwrap();
        let entry = st.entry(format!("{name}__fused")).or_default();
        entry.calls += 1;
        entry.total_secs += t0.elapsed().as_secs_f64();
        Ok(Some(out))
    }

    fn export_kv(&self, cache: &Value, lane: usize, start: usize, len: usize) -> Result<Option<Vec<f32>>> {
        let (lane_stride, row, smax) = kv_cache_geometry(cache, lane)?;
        if start + len > smax {
            bail!("export_kv: rows [{start}, {}) exceed cache horizon {smax}", start + len);
        }
        let t = cache.as_f32()?;
        let base = lane * lane_stride + start * row;
        Ok(Some(t.data[base..base + len * row].to_vec()))
    }

    fn import_kv(&self, cache: &mut Value, lane: usize, at: usize, len: usize, rows: &[f32]) -> Result<bool> {
        let (lane_stride, row, smax) = kv_cache_geometry(cache, lane)?;
        if at + len > smax {
            bail!("import_kv: rows [{at}, {}) exceed cache horizon {smax}", at + len);
        }
        if rows.len() != len * row {
            bail!("import_kv: {} floats for {len} positions of row width {row}", rows.len());
        }
        let t = cache.as_f32_mut()?;
        let base = lane * lane_stride + at * row;
        t.data[base..base + len * row].copy_from_slice(rows);
        Ok(true)
    }

    fn measured_secs(&self, name: &str) -> Option<f64> {
        let st = self.stats.lock().unwrap();
        let e = st.get(name)?;
        if e.calls == 0 {
            None
        } else {
            Some(e.total_secs / e.calls as f64)
        }
    }

    fn stats_snapshot(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> =
            self.stats.lock().unwrap().iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }

    fn run_warmup(&self, name: &str) -> Result<()> {
        // nothing to compile, but preloading an unknown executable is still
        // a caller bug on every backend
        self.man
            .execs
            .get(name)
            .map(|_| ())
            .ok_or_else(|| anyhow!("unknown exec {name} (not in manifest)"))
    }
}

/// Validate a dense decode-cache value `[b, s_max, kv, head_dim]` against
/// `lane` and return `(lane_stride, row_width, s_max)` in f32 elements —
/// shared by the `export_kv`/`import_kv` cache-transfer pair, which is how
/// per-layer variable KV-head counts are honored (the row width comes from
/// each layer's own cache shape).
fn kv_cache_geometry(cache: &Value, lane: usize) -> Result<(usize, usize, usize)> {
    let shape = cache.shape();
    if shape.len() != 4 {
        bail!("kv transfer expects a [b, s_max, kv, head_dim] cache, got {shape:?}");
    }
    let (b, smax, kv, dh) = (shape[0], shape[1], shape[2], shape[3]);
    if lane >= b {
        bail!("kv transfer: lane {lane} out of {b} decode lanes");
    }
    Ok((smax * kv * dh, kv * dh, smax))
}

fn split_mode(rest: &str) -> Option<(&str, &str)> {
    for m in ["_train_fwd", "_train_vjp", "_prefill", "_decode", "_long"] {
        if let Some(v) = rest.strip_suffix(m) {
            return Some((v, &m[1..]));
        }
    }
    None
}

/// Wrap a vjp result as (dx, *dweights) values in manifest weight order.
fn pack_grads(x: &Tensor, layout: &VariantLayout, dx: Vec<f32>, dws: Vec<Vec<f32>>) -> Vec<Value> {
    let mut out = Vec::with_capacity(1 + dws.len());
    out.push(Value::F32(Tensor::from_vec(&x.shape, dx)));
    for ((_, shape), dw) in layout.weights.iter().zip(dws) {
        out.push(Value::F32(Tensor::from_vec(shape, dw)));
    }
    out
}

// ======================================================================
// dense math helpers (row-major flats)
// ======================================================================

/// a [m,k] @ b [k,n] -> [m,n]
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// aᵀ @ b with a [t,m], b [t,n] -> [m,n] (weight gradients)
fn matmul_at_b(a: &[f32], b: &[f32], t: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), t * m);
    debug_assert_eq!(b.len(), t * n);
    let mut out = vec![0f32; m * n];
    for r in 0..t {
        let arow = &a[r * m..(r + 1) * m];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// a @ bᵀ with a [t,n], b [m,n] -> [t,m] (activation gradients)
fn matmul_a_bt(a: &[f32], b: &[f32], t: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), t * n);
    debug_assert_eq!(b.len(), m * n);
    let mut out = vec![0f32; t * m];
    for r in 0..t {
        let arow = &a[r * n..(r + 1) * n];
        let orow = &mut out[r * m..(r + 1) * m];
        for i in 0..m {
            let brow = &b[i * n..(i + 1) * n];
            let mut acc = 0f32;
            for j in 0..n {
                acc += arow[j] * brow[j];
            }
            orow[i] = acc;
        }
    }
    out
}

fn add_vec(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// RMSNorm over rows of d: y = x / rms(x) * w.
fn rmsnorm_fwd(x: &[f32], w: &[f32], d: usize, eps: f32) -> Vec<f32> {
    let t = x.len() / d;
    let mut out = vec![0f32; x.len()];
    for row in 0..t {
        let xs = &x[row * d..(row + 1) * d];
        let ms = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        let os = &mut out[row * d..(row + 1) * d];
        for j in 0..d {
            os[j] = xs[j] * r * w[j];
        }
    }
    out
}

/// RMSNorm vjp: given dy on the normalized output, return (dx, dw).
fn rmsnorm_bwd(x: &[f32], w: &[f32], dy: &[f32], d: usize, eps: f32) -> (Vec<f32>, Vec<f32>) {
    let t = x.len() / d;
    let mut dx = vec![0f32; x.len()];
    let mut dw = vec![0f32; d];
    for row in 0..t {
        let xs = &x[row * d..(row + 1) * d];
        let dys = &dy[row * d..(row + 1) * d];
        let ms = xs.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        // a_j = dy_j * w_j; dx_j = r*a_j - (r^3/d) * x_j * Σ_k a_k x_k
        let mut ax = 0f32;
        for j in 0..d {
            ax += dys[j] * w[j] * xs[j];
        }
        let c = r * r * r / d as f32 * ax;
        let dxs = &mut dx[row * d..(row + 1) * d];
        for j in 0..d {
            dxs[j] = r * dys[j] * w[j] - c * xs[j];
            dw[j] += dys[j] * xs[j] * r;
        }
    }
    (dx, dw)
}

/// Rotary embedding in place over flat [t, heads, dh] with one position per
/// row. `sign` = 1.0 applies the rotation, -1.0 its inverse (the vjp).
fn rope(xs: &mut [f32], positions: &[f32], heads: usize, dh: usize, theta: f32, sign: f32) {
    let half = dh / 2;
    let freqs: Vec<f32> = (0..half).map(|j| theta.powf(-(j as f32) / half as f32)).collect();
    for (r, &pos) in positions.iter().enumerate() {
        for hh in 0..heads {
            let off = (r * heads + hh) * dh;
            for j in 0..half {
                let ang = pos * freqs[j];
                let (mut sn, cs) = ang.sin_cos();
                sn *= sign;
                let x1 = xs[off + j];
                let x2 = xs[off + half + j];
                xs[off + j] = x1 * cs - x2 * sn;
                xs[off + half + j] = x1 * sn + x2 * cs;
            }
        }
    }
}

/// Causal grouped-query attention: q [b,s,h,dh], k/v [b,s,kv,dh] (flats),
/// returns o [b,s,h,dh].
fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    h: usize,
    kv: usize,
    dh: usize,
) -> Vec<f32> {
    let group = h / kv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut o = vec![0f32; b * s * h * dh];
    let mut p = vec![0f32; s];
    for bi in 0..b {
        for hi in 0..h {
            let g = hi / group;
            for qi in 0..s {
                let qoff = ((bi * s + qi) * h + hi) * dh;
                softmax_row_causal(q, k, &mut p, bi, s, kv, dh, g, qi, qoff, scale);
                let ooff = qoff;
                for (ki, &pk) in p.iter().enumerate().take(qi + 1) {
                    let voff = ((bi * s + ki) * kv + g) * dh;
                    for j in 0..dh {
                        o[ooff + j] += pk * v[voff + j];
                    }
                }
            }
        }
    }
    o
}

/// One causal softmax row: fills p[0..=qi] with attention probabilities of
/// query (bi, qi, head with kv-group g) against k.
#[allow(clippy::too_many_arguments)]
fn softmax_row_causal(
    q: &[f32],
    k: &[f32],
    p: &mut [f32],
    bi: usize,
    s: usize,
    kv: usize,
    dh: usize,
    g: usize,
    qi: usize,
    qoff: usize,
    scale: f32,
) {
    let mut maxs = f32::NEG_INFINITY;
    for ki in 0..=qi {
        let koff = ((bi * s + ki) * kv + g) * dh;
        let mut dot = 0f32;
        for j in 0..dh {
            dot += q[qoff + j] * k[koff + j];
        }
        p[ki] = dot * scale;
        maxs = maxs.max(p[ki]);
    }
    let mut z = 0f32;
    for ki in 0..=qi {
        p[ki] = (p[ki] - maxs).exp();
        z += p[ki];
    }
    let inv = 1.0 / z;
    for ki in 0..=qi {
        p[ki] *= inv;
    }
}

/// Backward of `causal_attention`: returns (dq, dk, dv); dk/dv accumulate
/// over the query heads sharing each KV head.
#[allow(clippy::too_many_arguments)]
fn causal_attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    b: usize,
    s: usize,
    h: usize,
    kv: usize,
    dh: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let group = h / kv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = vec![0f32; b * s * h * dh];
    let mut dk = vec![0f32; b * s * kv * dh];
    let mut dv = vec![0f32; b * s * kv * dh];
    let mut p = vec![0f32; s];
    let mut dp = vec![0f32; s];
    for bi in 0..b {
        for hi in 0..h {
            let g = hi / group;
            for qi in 0..s {
                let qoff = ((bi * s + qi) * h + hi) * dh;
                softmax_row_causal(q, k, &mut p, bi, s, kv, dh, g, qi, qoff, scale);
                // dp = dO·Vᵀ, rowdot = Σ p·dp
                let mut rowdot = 0f32;
                for ki in 0..=qi {
                    let voff = ((bi * s + ki) * kv + g) * dh;
                    let mut dd = 0f32;
                    for j in 0..dh {
                        dd += dout[qoff + j] * v[voff + j];
                    }
                    dp[ki] = dd;
                    rowdot += p[ki] * dd;
                }
                // dS = P ⊙ (dP - rowdot); dQ += dS·K·scale; dK += dS·Q·scale;
                // dV += P·dO
                for ki in 0..=qi {
                    let ds = p[ki] * (dp[ki] - rowdot) * scale;
                    let koff = ((bi * s + ki) * kv + g) * dh;
                    for j in 0..dh {
                        dq[qoff + j] += ds * k[koff + j];
                        dk[koff + j] += ds * q[qoff + j];
                        dv[koff + j] += p[ki] * dout[qoff + j];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

// ======================================================================
// block implementations
// ======================================================================

/// Pre-norm GQA block forward. Returns (y, roped K flat [b,s,kv,dh],
/// V flat) — the K/V are what prefill hands to the serving cache.
fn attn_gqa_fwd(
    h: usize,
    dh: usize,
    kv: usize,
    x: &Tensor,
    w: &[&Tensor],
    eps: f32,
    theta: f32,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (b, s, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let t = b * s;
    let qd = h * dh;
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let mut qf = matmul(&hn, &w[1].data, t, d, qd);
    let mut kf = matmul(&hn, &w[2].data, t, d, kv * dh);
    let vf = matmul(&hn, &w[3].data, t, d, kv * dh);
    let positions: Vec<f32> = (0..t).map(|r| (r % s) as f32).collect();
    rope(&mut qf, &positions, h, dh, theta, 1.0);
    rope(&mut kf, &positions, kv, dh, theta, 1.0);
    let att = causal_attention(&qf, &kf, &vf, b, s, h, kv, dh);
    let proj = matmul(&att, &w[4].data, t, qd, d);
    let y = add_vec(&x.data, &proj);
    (Tensor::from_vec(&x.shape, y), kf, vf)
}

/// GQA block vjp: (dx, [dnorm, dwq, dwk, dwv, dwo]).
#[allow(clippy::too_many_arguments)]
fn attn_gqa_vjp(
    h: usize,
    dh: usize,
    kv: usize,
    x: &Tensor,
    w: &[&Tensor],
    dy: &Tensor,
    eps: f32,
    theta: f32,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let (b, s, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let t = b * s;
    let qd = h * dh;
    // recompute the primal (deliberate rematerialization, as in the AOT vjps)
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let mut qf = matmul(&hn, &w[1].data, t, d, qd);
    let mut kf = matmul(&hn, &w[2].data, t, d, kv * dh);
    let vf = matmul(&hn, &w[3].data, t, d, kv * dh);
    let positions: Vec<f32> = (0..t).map(|r| (r % s) as f32).collect();
    rope(&mut qf, &positions, h, dh, theta, 1.0);
    rope(&mut kf, &positions, kv, dh, theta, 1.0);
    let att = causal_attention(&qf, &kf, &vf, b, s, h, kv, dh);

    // y = x + att @ wo
    let datt = matmul_a_bt(&dy.data, &w[4].data, t, qd, d);
    let dwo = matmul_at_b(&att, &dy.data, t, qd, d);
    let (mut dq, mut dk, dv) = causal_attention_bwd(&qf, &kf, &vf, &datt, b, s, h, kv, dh);
    rope(&mut dq, &positions, h, dh, theta, -1.0);
    rope(&mut dk, &positions, kv, dh, theta, -1.0);
    let mut dhn = matmul_a_bt(&dq, &w[1].data, t, d, qd);
    let dhn_k = matmul_a_bt(&dk, &w[2].data, t, d, kv * dh);
    let dhn_v = matmul_a_bt(&dv, &w[3].data, t, d, kv * dh);
    for i in 0..dhn.len() {
        dhn[i] += dhn_k[i] + dhn_v[i];
    }
    let dwq = matmul_at_b(&hn, &dq, t, d, qd);
    let dwk = matmul_at_b(&hn, &dk, t, d, kv * dh);
    let dwv = matmul_at_b(&hn, &dv, t, d, kv * dh);
    let (dx_rms, dnorm) = rmsnorm_bwd(&x.data, &w[0].data, &dhn, d, eps);
    let dx = add_vec(&dy.data, &dx_rms);
    (dx, vec![dnorm, dwq, dwk, dwv, dwo])
}

/// Cached GQA decode step: writes the new roped K/V at each sequence's
/// position (functional update) and attends over cache positions <= pos.
#[allow(clippy::too_many_arguments)]
fn attn_gqa_decode(
    h: usize,
    dh: usize,
    kv: usize,
    x: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    pos: &[i32],
    w: &[&Tensor],
    eps: f32,
    theta: f32,
) -> Result<(Tensor, Tensor, Tensor)> {
    let (b, d) = (x.shape[0], x.shape[2]);
    let smax = kc.shape[1];
    let qd = h * dh;
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let mut qf = matmul(&hn, &w[1].data, b, d, qd);
    let mut kf = matmul(&hn, &w[2].data, b, d, kv * dh);
    let vf = matmul(&hn, &w[3].data, b, d, kv * dh);
    let positions: Vec<f32> = pos.iter().map(|&p| p as f32).collect();
    rope(&mut qf, &positions, h, dh, theta, 1.0);
    rope(&mut kf, &positions, kv, dh, theta, 1.0);
    let mut kc2 = kc.clone();
    let mut vc2 = vc.clone();
    let row = kv * dh;
    for bi in 0..b {
        let p = pos[bi] as usize;
        if p >= smax {
            bail!("decode position {p} >= cache capacity {smax}");
        }
        let dst = (bi * smax + p) * row;
        kc2.data[dst..dst + row].copy_from_slice(&kf[bi * row..(bi + 1) * row]);
        vc2.data[dst..dst + row].copy_from_slice(&vf[bi * row..(bi + 1) * row]);
    }
    // attend over the cache: same softmax row as self-attention, with the
    // cache playing the role of a length-smax sequence masked at pos
    let group = h / kv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut o = vec![0f32; b * qd];
    let mut p_row = vec![0f32; smax];
    for bi in 0..b {
        let pmax = pos[bi] as usize;
        for hi in 0..h {
            let g = hi / group;
            let qoff = bi * qd + hi * dh;
            softmax_row_causal(&qf, &kc2.data, &mut p_row, bi, smax, kv, dh, g, pmax, qoff, scale);
            for (ki, &pk) in p_row.iter().enumerate().take(pmax + 1) {
                let voff = ((bi * smax + ki) * kv + g) * dh;
                for j in 0..dh {
                    o[qoff + j] += pk * vc2.data[voff + j];
                }
            }
        }
    }
    let proj = matmul(&o, &w[4].data, b, qd, d);
    let y = add_vec(&x.data, &proj);
    Ok((Tensor::from_vec(&x.shape, y), kc2, vc2))
}

/// Fused multi-token cached GQA decode: `x` is `[b, m, d]` — `m` new
/// tokens per lane, lane `bi`'s j-th token at cache position
/// `pos[bi] + j` — writing all roped K/V rows first and then attending
/// each query over cache positions `<= pos[bi] + j` (prefill-style
/// attention against the existing cache). Arithmetic per row is
/// identical to `m` sequential `attn_gqa_decode` steps, so the fused and
/// sequential lowerings agree bitwise.
///
/// Rows that would land at or past the cache horizon are dropped and
/// their queries clamped to the last row: callers validate real feeds,
/// so out-of-range rows only come from parked/padded lanes whose output
/// is discarded and whose frontier rows are dead by the masking rule.
#[allow(clippy::too_many_arguments)]
fn attn_gqa_decode_fused(
    h: usize,
    dh: usize,
    kv: usize,
    x: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    pos: &[i32],
    w: &[&Tensor],
    eps: f32,
    theta: f32,
) -> Result<(Tensor, Tensor, Tensor)> {
    let (b, m, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let smax = kc.shape[1];
    if pos.len() != b {
        bail!("fused decode: {} positions for batch {b}", pos.len());
    }
    let t = b * m;
    let qd = h * dh;
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let mut qf = matmul(&hn, &w[1].data, t, d, qd);
    let mut kf = matmul(&hn, &w[2].data, t, d, kv * dh);
    let vf = matmul(&hn, &w[3].data, t, d, kv * dh);
    // one rotary position per row: lane bi's j-th token sits at pos[bi]+j
    let positions: Vec<f32> = (0..t).map(|r| (pos[r / m] as usize + r % m) as f32).collect();
    rope(&mut qf, &positions, h, dh, theta, 1.0);
    rope(&mut kf, &positions, kv, dh, theta, 1.0);
    let mut kc2 = kc.clone();
    let mut vc2 = vc.clone();
    let row = kv * dh;
    for bi in 0..b {
        for j in 0..m {
            let p = pos[bi] as usize + j;
            if p >= smax {
                continue; // padded/parked overflow: dropped, never read
            }
            let src = (bi * m + j) * row;
            let dst = (bi * smax + p) * row;
            kc2.data[dst..dst + row].copy_from_slice(&kf[src..src + row]);
            vc2.data[dst..dst + row].copy_from_slice(&vf[src..src + row]);
        }
    }
    // attend each new position over the cache, masked at that position —
    // same softmax row as the sequential step, new K/V already in place
    let group = h / kv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut o = vec![0f32; t * qd];
    let mut p_row = vec![0f32; smax];
    for bi in 0..b {
        for j in 0..m {
            let pmax = (pos[bi] as usize + j).min(smax - 1);
            for hi in 0..h {
                let g = hi / group;
                let qoff = (bi * m + j) * qd + hi * dh;
                softmax_row_causal(&qf, &kc2.data, &mut p_row, bi, smax, kv, dh, g, pmax, qoff, scale);
                for (ki, &pk) in p_row.iter().enumerate().take(pmax + 1) {
                    let voff = ((bi * smax + ki) * kv + g) * dh;
                    for jj in 0..dh {
                        o[qoff + jj] += pk * vc2.data[voff + jj];
                    }
                }
            }
        }
    }
    let proj = matmul(&o, &w[4].data, t, qd, d);
    let y = add_vec(&x.data, &proj);
    Ok((Tensor::from_vec(&x.shape, y), kc2, vc2))
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SwiGLU FFN block: y = x + (silu(hn@wg) ⊙ (hn@wu)) @ wd.
fn ffn_fwd(x: &Tensor, w: &[&Tensor], eps: f32) -> Tensor {
    let d = *x.shape.last().unwrap();
    let t = x.numel() / d;
    let i = w[1].shape[1];
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let g = matmul(&hn, &w[1].data, t, d, i);
    let u = matmul(&hn, &w[2].data, t, d, i);
    let z: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| gv * sigmoid(gv) * uv).collect();
    let proj = matmul(&z, &w[3].data, t, i, d);
    Tensor::from_vec(&x.shape, add_vec(&x.data, &proj))
}

/// SwiGLU vjp: (dx, [dnorm, dwg, dwu, dwd]).
fn ffn_vjp(x: &Tensor, w: &[&Tensor], dy: &Tensor, eps: f32) -> (Vec<f32>, Vec<Vec<f32>>) {
    let d = *x.shape.last().unwrap();
    let t = x.numel() / d;
    let i = w[1].shape[1];
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let g = matmul(&hn, &w[1].data, t, d, i);
    let u = matmul(&hn, &w[2].data, t, d, i);
    let sg: Vec<f32> = g.iter().map(|&gv| sigmoid(gv)).collect();
    let z: Vec<f32> = g.iter().zip(&sg).zip(&u).map(|((&gv, &s), &uv)| gv * s * uv).collect();

    let dz = matmul_a_bt(&dy.data, &w[3].data, t, i, d);
    let dwd = matmul_at_b(&z, &dy.data, t, i, d);
    let mut dg = vec![0f32; t * i];
    let mut du = vec![0f32; t * i];
    for idx in 0..t * i {
        let silu = g[idx] * sg[idx];
        du[idx] = dz[idx] * silu;
        // d silu(g)/dg = σ(g)·(1 + g·(1-σ(g)))
        dg[idx] = dz[idx] * u[idx] * sg[idx] * (1.0 + g[idx] * (1.0 - sg[idx]));
    }
    let mut dhn = matmul_a_bt(&dg, &w[1].data, t, d, i);
    let dhn_u = matmul_a_bt(&du, &w[2].data, t, d, i);
    for idx in 0..dhn.len() {
        dhn[idx] += dhn_u[idx];
    }
    let dwg = matmul_at_b(&hn, &dg, t, d, i);
    let dwu = matmul_at_b(&hn, &du, t, d, i);
    let (dx_rms, dnorm) = rmsnorm_bwd(&x.data, &w[0].data, &dhn, d, eps);
    let dx = add_vec(&dy.data, &dx_rms);
    (dx, vec![dnorm, dwg, dwu, dwd])
}

/// Token-wise linear replacement block (attention-linear / FFN-linear):
/// y = x + rmsnorm(x) @ wl.
fn linear_fwd(x: &Tensor, w: &[&Tensor], eps: f32) -> Tensor {
    let d = *x.shape.last().unwrap();
    let t = x.numel() / d;
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let proj = matmul(&hn, &w[1].data, t, d, d);
    Tensor::from_vec(&x.shape, add_vec(&x.data, &proj))
}

/// Linear block vjp: (dx, [dnorm, dwl]).
fn linear_vjp(x: &Tensor, w: &[&Tensor], dy: &Tensor, eps: f32) -> (Vec<f32>, Vec<Vec<f32>>) {
    let d = *x.shape.last().unwrap();
    let t = x.numel() / d;
    let hn = rmsnorm_fwd(&x.data, &w[0].data, d, eps);
    let dhn = matmul_a_bt(&dy.data, &w[1].data, t, d, d);
    let dwl = matmul_at_b(&hn, &dy.data, t, d, d);
    let (dx_rms, dnorm) = rmsnorm_bwd(&x.data, &w[0].data, &dhn, d, eps);
    let dx = add_vec(&dy.data, &dx_rms);
    (dx, vec![dnorm, dwl])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::value::val_i32;
    use crate::util::Rng;

    fn backend() -> RefBackend {
        RefBackend::tiny()
    }

    fn randt(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor::randn(shape, std, rng)
    }

    /// Scalar loss L = Σ y_0 ⊙ R over the first output of `exec`, where R
    /// is a fixed random cotangent — evaluated in f64 for fd stability.
    fn loss_of(be: &RefBackend, exec: &str, inputs: &[&Value], r: &[f32]) -> f64 {
        let out = be.run(exec, inputs).unwrap();
        let y = out[0].as_f32().unwrap();
        y.data.iter().zip(r).map(|(&a, &b)| a as f64 * b as f64).sum()
    }

    /// Check d(loss)/d(inputs[which]) from the vjp exec against central
    /// finite differences at a few coordinates.
    fn grad_check(exec_fwd: &str, exec_vjp: &str, n_weights: usize, which: usize) {
        let be = backend();
        let man = be.man().clone();
        let sig = man.execs[exec_fwd].clone();
        let mut rng = Rng::new(17);
        let vals: Vec<Value> = sig
            .in_shapes
            .iter()
            .map(|(_, s)| Value::F32(randt(s, 0.3, &mut rng)))
            .collect();
        let y_shape = &sig.out_shapes[0].1;
        let r = randt(y_shape, 1.0, &mut rng);

        // analytic grads: run the vjp with dy = R
        let dy = Value::F32(r.clone());
        let mut vjp_in: Vec<&Value> = vals.iter().collect();
        vjp_in.push(&dy);
        let grads = be.run(exec_vjp, &vjp_in).unwrap();
        assert_eq!(grads.len(), 1 + n_weights);
        let analytic = grads[which].as_f32().unwrap().clone();

        // finite differences on inputs[which]
        let x0 = vals[which].as_f32().unwrap().clone();
        let h = 1e-2f32;
        let step = (x0.numel() / 7).max(1);
        for idx in (0..x0.numel()).step_by(step) {
            let eval = |delta: f32| -> f64 {
                let mut xp = x0.clone();
                xp.data[idx] += delta;
                let vp = Value::F32(xp);
                let refs: Vec<&Value> =
                    vals.iter().enumerate().map(|(i, v)| if i == which { &vp } else { v }).collect();
                loss_of(&be, exec_fwd, &refs, &r.data)
            };
            let fd = ((eval(h) - eval(-h)) / (2.0 * h as f64)) as f32;
            let an = analytic.data[idx];
            assert!(
                (fd - an).abs() <= 2e-2 + 0.05 * an.abs(),
                "{exec_vjp} input {which} idx {idx}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn rmsnorm_bwd_matches_fd() {
        let mut rng = Rng::new(3);
        let d = 8;
        let x = randt(&[5, d], 0.5, &mut rng);
        let w = randt(&[d], 0.5, &mut rng);
        let r = randt(&[5, d], 1.0, &mut rng);
        let (dx, dw) = rmsnorm_bwd(&x.data, &w.data, &r.data, d, 1e-5);
        let loss = |xd: &[f32], wd: &[f32]| -> f64 {
            rmsnorm_fwd(xd, wd, d, 1e-5)
                .iter()
                .zip(&r.data)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum()
        };
        let h = 1e-3f32;
        for idx in [0, 7, 19, 33] {
            let mut xp = x.data.clone();
            xp[idx] += h;
            let mut xm = x.data.clone();
            xm[idx] -= h;
            let fd = ((loss(&xp, &w.data) - loss(&xm, &w.data)) / (2.0 * h as f64)) as f32;
            assert!((fd - dx[idx]).abs() < 1e-2, "dx[{idx}] fd {fd} vs {}", dx[idx]);
        }
        for idx in [0, 3] {
            let mut wp = w.data.clone();
            wp[idx] += h;
            let mut wm = w.data.clone();
            wm[idx] -= h;
            let fd = ((loss(&x.data, &wp) - loss(&x.data, &wm)) / (2.0 * h as f64)) as f32;
            assert!((fd - dw[idx]).abs() < 1e-2, "dw[{idx}] fd {fd} vs {}", dw[idx]);
        }
    }

    #[test]
    fn rope_inverse_roundtrips() {
        let mut rng = Rng::new(5);
        let (t, heads, dh) = (6, 2, 8);
        let x0 = randt(&[t, heads, dh], 1.0, &mut rng);
        let positions: Vec<f32> = (0..t).map(|i| i as f32).collect();
        let mut x = x0.data.clone();
        rope(&mut x, &positions, heads, dh, 10000.0, 1.0);
        rope(&mut x, &positions, heads, dh, 10000.0, -1.0);
        for (a, b) in x.iter().zip(&x0.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gqa_vjp_input_grad_matches_fd() {
        grad_check("attn_gqa_r2_train_fwd", "attn_gqa_r2_train_vjp", 5, 0);
    }

    #[test]
    fn gqa_vjp_weight_grads_match_fd() {
        for which in 1..=5 {
            grad_check("attn_gqa_r2_train_fwd", "attn_gqa_r2_train_vjp", 5, which);
        }
    }

    #[test]
    fn ffn_vjp_grads_match_fd() {
        for which in 0..=4 {
            grad_check("ffn_r50_train_fwd", "ffn_r50_train_vjp", 4, which);
        }
    }

    #[test]
    fn linear_vjp_grads_match_fd() {
        for which in 0..=2 {
            grad_check("attn_linear_train_fwd", "attn_linear_train_vjp", 2, which);
        }
    }

    #[test]
    fn head_vjp_grads_match_fd() {
        let be = backend();
        let c = be.man().cfg.clone();
        let mut rng = Rng::new(23);
        let x = randt(&[c.b_train, c.s_train, c.d], 0.3, &mut rng);
        let norm = randt(&[c.d], 0.5, &mut rng);
        let e = randt(&[c.v, c.d], 0.3, &mut rng);
        let r = randt(&[c.b_train, c.s_train, c.v], 1.0, &mut rng);
        let (xv, nv, ev, rv) = (
            Value::F32(x.clone()),
            Value::F32(norm.clone()),
            Value::F32(e.clone()),
            Value::F32(r.clone()),
        );
        let grads = be.run("head_train_vjp", &[&xv, &nv, &ev, &rv]).unwrap();
        assert_eq!(grads.len(), 3);
        let dx = grads[0].as_f32().unwrap();
        let h = 1e-2f32;
        for idx in (0..x.numel()).step_by(x.numel() / 5) {
            let eval = |delta: f32| -> f64 {
                let mut xp = x.clone();
                xp.data[idx] += delta;
                let v = Value::F32(xp);
                loss_of(&be, "head_train", &[&v, &nv, &ev], &r.data)
            };
            let fd = ((eval(h) - eval(-h)) / (2.0 * h as f64)) as f32;
            let an = dx.data[idx];
            assert!((fd - an).abs() <= 2e-2 + 0.05 * an.abs(), "head dx[{idx}]: {fd} vs {an}");
        }
    }

    #[test]
    fn embed_vjp_scatters_token_grads() {
        let be = backend();
        let c = be.man().cfg.clone();
        let mut rng = Rng::new(29);
        let (bt, st) = (c.b_train, c.s_train);
        let tokens: Vec<i32> = (0..bt * st).map(|i| (i % c.v) as i32).collect();
        let tok = val_i32(&[bt, st], &tokens).unwrap();
        let e = Value::F32(randt(&[c.v, c.d], 0.3, &mut rng));
        let dx = Value::F32(Tensor::ones(&[bt, st, c.d]));
        let de = be.run("embed_train_vjp", &[&tok, &e, &dx]).unwrap().remove(0);
        let de = de.as_f32().unwrap();
        // token 0 appears bt*st/v times, each contributing 1.0 per dim
        let expect = (bt * st / c.v) as f32;
        assert!((de.data[0] - expect).abs() < 1e-5, "{} vs {expect}", de.data[0]);
    }

    #[test]
    fn decode_matches_prefill_attention() {
        // prefill a short sequence, then decode the same tokens one at a
        // time into a cache: the final-position outputs must agree.
        let be = backend();
        let c = be.man().cfg.clone();
        let man = be.man();
        let mut rng = Rng::new(31);
        let layout = man.attn_variants["gqa_r2"].clone();
        let ws: Vec<Tensor> =
            layout.weights.iter().map(|(_, s)| randt(s, 0.2, &mut rng)).collect();
        let wvals: Vec<Value> = ws.iter().map(|t| Value::F32(t.clone())).collect();
        let (sp, d, kvh, dh) = (c.s_prefill, c.d, layout.kv_heads, c.head_dim);

        let x = randt(&[1, sp, d], 0.5, &mut rng);
        let xv = Value::F32(x.clone());
        let mut pre_in: Vec<&Value> = vec![&xv];
        pre_in.extend(wvals.iter());
        let pre = be.run("attn_gqa_r2_prefill", &pre_in).unwrap();
        let y_pre = pre[0].as_f32().unwrap().clone();
        let k_pre = pre[1].as_f32().unwrap().clone();
        let v_pre = pre[2].as_f32().unwrap().clone();

        // decode positions 0..n for batch lane 0 (lane 1 runs position 0)
        let (bd, smax) = (c.b_decode, c.s_max);
        let mut kc = Tensor::zeros(&[bd, smax, kvh, dh]);
        let mut vc = Tensor::zeros(&[bd, smax, kvh, dh]);
        let n = 5.min(sp);
        let mut last_y = vec![];
        for p in 0..n {
            let mut xd = Tensor::zeros(&[bd, 1, d]);
            xd.data[..d].copy_from_slice(&x.data[p * d..(p + 1) * d]);
            let xdv = Value::F32(xd);
            let kcv = Value::F32(kc.clone());
            let vcv = Value::F32(vc.clone());
            let pos = val_i32(&[bd], &vec![p as i32, 0][..bd]).unwrap();
            let mut di: Vec<&Value> = vec![&xdv, &kcv, &vcv, &pos];
            di.extend(wvals.iter());
            let mut out = be.run("attn_gqa_r2_decode", &di).unwrap();
            let y = out.remove(0);
            vc = out.pop().unwrap().as_f32().unwrap().clone();
            kc = out.pop().unwrap().as_f32().unwrap().clone();
            last_y = y.as_f32().unwrap().data[..d].to_vec();
        }
        // decode cache rows must equal the prefill K/V rows
        let row = kvh * dh;
        for p in 0..n {
            for j in 0..row {
                assert!(
                    (kc.data[p * row + j] - k_pre.data[p * row + j]).abs() < 1e-4,
                    "k cache mismatch at pos {p}"
                );
                assert!((vc.data[p * row + j] - v_pre.data[p * row + j]).abs() < 1e-4);
            }
        }
        // and the decode output at position n-1 must match prefill's row n-1
        for j in 0..d {
            let a = last_y[j];
            let b = y_pre.data[(n - 1) * d + j];
            assert!((a - b).abs() < 1e-4, "y mismatch at dim {j}: {a} vs {b}");
        }
    }

    #[test]
    fn validation_rejects_bad_shapes_and_names() {
        let be = backend();
        let c = be.man().cfg.clone();
        assert!(be.run("no_such_exec", &[]).is_err());
        let bad = Value::F32(Tensor::zeros(&[1, 2, 3]));
        let e = Value::F32(Tensor::zeros(&[c.v, c.d]));
        assert!(be.run("head_train", &[&bad, &bad, &e]).is_err());
        // wrong dtype: embed tokens must be i32
        let toks_f = Value::F32(Tensor::zeros(&[c.b_train, c.s_train]));
        assert!(be.run("embed_train", &[&toks_f, &e]).is_err());
    }

    #[test]
    fn kv_export_import_roundtrips_bitwise() {
        let be = backend();
        let c = be.man().cfg.clone();
        let (bd, smax, kv, dh) = (c.b_decode, c.s_max, 2usize, c.head_dim);
        let mut rng = Rng::new(77);
        let src = Value::F32(randt(&[bd, smax, kv, dh], 1.0, &mut rng));
        // export 5 positions of lane 1 starting at position 3
        let rows = be.export_kv(&src, 1, 3, 5).unwrap().expect("ref backend supports kv transfer");
        assert_eq!(rows.len(), 5 * kv * dh);
        // import them into lane 0 at position 0 of a zeroed cache
        let mut dst = Value::F32(Tensor::zeros(&[bd, smax, kv, dh]));
        assert!(be.import_kv(&mut dst, 0, 0, 5, &rows).unwrap());
        let (s, d) = (src.as_f32().unwrap(), dst.as_f32().unwrap());
        let row = kv * dh;
        for p in 0..5 {
            let from = (smax + 3 + p) * row; // lane 1, position 3 + p
            let to = p * row; // lane 0, position p
            assert_eq!(s.data[from..from + row], d.data[to..to + row], "row {p} must copy bitwise");
        }
        // untouched rows stay zero
        assert!(d.data[5 * row..6 * row].iter().all(|&x| x == 0.0));
        // bounds violations are errors, not silent clamps
        assert!(be.export_kv(&src, 0, smax - 2, 5).unwrap_err().to_string().contains("horizon"));
        assert!(be.import_kv(&mut dst, bd, 0, 1, &rows[..row]).is_err());
        assert!(be.import_kv(&mut dst, 0, 0, 2, &rows[..row]).is_err(), "row count mismatch");
    }

    #[test]
    fn stats_track_calls() {
        let be = backend();
        let c = be.man().cfg.clone();
        let tok = val_i32(&[c.b_train, c.s_train], &vec![1; c.b_train * c.s_train]).unwrap();
        let mut rng = Rng::new(1);
        let e = Value::F32(randt(&[c.v, c.d], 0.1, &mut rng));
        be.run("embed_train", &[&tok, &e]).unwrap();
        be.run("embed_train", &[&tok, &e]).unwrap();
        assert!(be.measured_secs("embed_train").is_some());
        let snap = be.stats_snapshot();
        assert_eq!(snap.iter().find(|(k, _)| k == "embed_train").unwrap().1.calls, 2);
    }
}
