//! Lazy executable registry: manifest name -> compiled PJRT executable.
//!
//! Compilation happens on first use and is cached for the process
//! lifetime; `run` executes with Literal inputs and unwraps the tuple
//! output (every artifact is lowered with return_tuple=True). Dispatch
//! counts and wall-clock are tracked per executable for the perf pass and
//! the measured-cost mode of the perf model (§4.1: "measure directly on
//! target hardware").

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::Manifest;

use super::backend::ExecStats;

pub struct Registry {
    pub man: Manifest,
    client: PjRtClient,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Registry {
    /// Open the artifact directory for one model config
    /// (e.g. `artifacts/tiny`).
    pub fn open(dir: &Path) -> Result<Registry> {
        let man = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Registry {
            man,
            client,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile (or fetch the cached) executable.
    pub fn get(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.man.exec_path(name)?;
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        self.stats
            .borrow_mut()
            .entry(name.to_string())
            .or_default()
            .compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Execute by name; returns the decomposed tuple outputs.
    pub fn run(&self, name: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self.get(name)?;
        let t0 = Instant::now();
        let out = exe
            .execute::<&Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let mut st = self.stats.borrow_mut();
        let entry = st.entry(name.to_string()).or_default();
        entry.calls += 1;
        entry.total_secs += t0.elapsed().as_secs_f64();
        Ok(parts)
    }

    /// Measured mean runtime per call for `name` (seconds); None if never
    /// run. Used as the "measured on target hardware" cost source.
    pub fn measured_secs(&self, name: &str) -> Option<f64> {
        let st = self.stats.borrow();
        let e = st.get(name)?;
        if e.calls == 0 {
            None
        } else {
            Some(e.total_secs / e.calls as f64)
        }
    }

    /// Snapshot of all per-exec stats (perf reporting).
    pub fn stats_snapshot(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }

    /// Warm the compile cache for a list of executables.
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n).with_context(|| format!("preloading {n}"))?;
        }
        Ok(())
    }
}
