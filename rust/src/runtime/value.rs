//! Backend-neutral runtime values: the currency every `Backend` speaks.
//!
//! A `Value` is a host-resident dense array, either f32 (activations,
//! weights, caches, logits) or i32 (token ids, positions). Backends that
//! keep device-side buffers (PJRT) convert at their boundary; the
//! reference backend operates on `Value`s directly.

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

#[derive(Debug, Clone, PartialEq)]
/// A host-resident dense array: f32 tensor or i32 buffer.
pub enum Value {
    /// Dense f32 tensor (activations, weights, caches, logits).
    F32(Tensor),
    /// Dense i32 buffer with an explicit shape (token ids, positions).
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    /// The value's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// Manifest dtype string ("float32" / "int32").
    pub fn dtype_name(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32 { .. } => "int32",
        }
    }

    /// Borrow as an f32 tensor; errors on i32 values.
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => Err(anyhow!("expected f32 value, got i32")),
        }
    }

    /// In-place mutable access (e.g. splicing rows into a host-resident
    /// KV cache without round-trip copies).
    pub fn as_f32_mut(&mut self) -> Result<&mut Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => Err(anyhow!("expected f32 value, got i32")),
        }
    }

    /// Borrow as an i32 slice; errors on f32 values.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            Value::F32(_) => Err(anyhow!("expected i32 value, got f32")),
        }
    }
}

/// f32 value with the given shape.
pub fn val_f32(shape: &[usize], data: &[f32]) -> Result<Value> {
    if shape.iter().product::<usize>() != data.len() {
        return Err(anyhow!("val_f32 shape {shape:?} != data len {}", data.len()));
    }
    Ok(Value::F32(Tensor::from_vec(shape, data.to_vec())))
}

/// i32 value with the given shape (token ids, positions).
pub fn val_i32(shape: &[usize], data: &[i32]) -> Result<Value> {
    if shape.iter().product::<usize>() != data.len() {
        return Err(anyhow!("val_i32 shape {shape:?} != data len {}", data.len()));
    }
    Ok(Value::I32 { shape: shape.to_vec(), data: data.to_vec() })
}

/// Wrap a tensor as an f32 value (clones the data).
pub fn tensor_to_val(t: &Tensor) -> Result<Value> {
    Ok(Value::F32(t.clone()))
}

/// Unwrap an f32 value into a tensor (clones the data).
pub fn val_to_tensor(v: &Value) -> Result<Tensor> {
    Ok(v.as_f32()?.clone())
}

/// Unwrap an f32 value into a flat vec (clones the data).
pub fn val_to_vec_f32(v: &Value) -> Result<Vec<f32>> {
    Ok(v.as_f32()?.data.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(val_f32(&[2, 3], &[0.0; 6]).is_ok());
        assert!(val_f32(&[2, 3], &[0.0; 5]).is_err());
        assert!(val_i32(&[4], &[1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn dtype_accessors() {
        let f = val_f32(&[2], &[1.0, 2.0]).unwrap();
        let i = val_i32(&[2], &[1, 2]).unwrap();
        assert!(f.as_f32().is_ok() && f.as_i32().is_err());
        assert!(i.as_i32().is_ok() && i.as_f32().is_err());
        assert_eq!(f.dtype_name(), "float32");
        assert_eq!(i.dtype_name(), "int32");
        assert_eq!(val_to_tensor(&f).unwrap().data, vec![1.0, 2.0]);
    }
}
