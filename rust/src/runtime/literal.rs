//! Literal <-> Tensor / Value conversion helpers (PJRT boundary only).

use anyhow::Result;
use xla::Literal;

use crate::tensor::Tensor;

use super::value::Value;

/// f32 literal with the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal with the given shape (token ids, positions).
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn tensor_to_lit(t: &Tensor) -> Result<Literal> {
    lit_f32(&t.shape, &t.data)
}

pub fn lit_to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn lit_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(Tensor::from_vec(&dims, l.to_vec::<f32>()?))
}

/// Backend-neutral `Value` -> PJRT literal.
pub fn val_to_lit(v: &Value) -> Result<Literal> {
    match v {
        Value::F32(t) => lit_f32(&t.shape, &t.data),
        Value::I32 { shape, data } => lit_i32(shape, data),
    }
}

/// PJRT literal -> `Value`. Every executable output in the manifest is
/// f32, so no dtype sniffing is needed.
pub fn lit_to_val(l: &Literal) -> Result<Value> {
    Ok(Value::F32(lit_to_tensor(l)?))
}
