//! The pluggable execution backend: everything above this trait (model
//! assembly, serving engine, BLD/GKD/train/scoring/eval drivers) speaks
//! only `Backend` + `Value`; everything below it owns how the manifest's
//! block executables actually run.
//!
//! Contract (shared by every implementation; see DESIGN.md for the full
//! executable-name grammar and shape table):
//!  * `run(name, inputs)` executes the manifest executable `name` with the
//!    manifest-declared input signature and returns the decomposed tuple
//!    outputs. Inputs are `(x, *weights)` for block forwards,
//!    `(x, *weights, dy)` for vjps, `(x, k_cache, v_cache, pos, *weights)`
//!    for cached GQA decode.
//!  * GQA prefill returns `(y, k, v)` (roped K and V for the serving
//!    cache); GQA decode returns `(y, k_cache', v_cache')`; vjps return
//!    `(dx, *dweights)` in manifest weight order; everything else returns
//!    a single output.
//!  * Per-executable call counts and wall clock are tracked so the perf
//!    pass and the measured-cost mode of the cost model (§4.1: "measure
//!    directly on target hardware") work on any backend.

use anyhow::{Context, Result};

use crate::config::Manifest;

use super::value::Value;

#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

pub trait Backend {
    /// Human-readable backend identifier ("ref", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The manifest this backend serves (model config, variant layouts,
    /// executable signatures).
    fn man(&self) -> &Manifest;

    /// Execute by name; returns the decomposed tuple outputs.
    fn run(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>>;

    /// Measured mean runtime per call for `name` (seconds); None if never
    /// run. The "measured on target hardware" cost source.
    fn measured_secs(&self, name: &str) -> Option<f64>;

    /// Snapshot of all per-exec stats (perf reporting), sorted by total
    /// time descending.
    fn stats_snapshot(&self) -> Vec<(String, ExecStats)>;

    /// Warm whatever per-executable caches exist (compilation for PJRT,
    /// a no-op for the reference interpreter).
    fn preload(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.run_warmup(n).with_context(|| format!("preloading {n}"))?;
        }
        Ok(())
    }

    /// Backend-specific warm step for one executable; default does nothing.
    fn run_warmup(&self, _name: &str) -> Result<()> {
        Ok(())
    }
}

/// How long-lived components (the serving `Engine`, the `Pipeline`) hold a
/// backend. `RefBackend` is `Send + Sync` (its stats sit behind a `Mutex`),
/// so the default build shares backends through an `Arc` that can be handed
/// to a server thread. The PJRT path wraps an `Rc`-based client that is
/// single-threaded by construction, so with the `pjrt` feature the shared
/// handle degrades to `Rc` and engines stay on the thread that built them.
#[cfg(not(feature = "pjrt"))]
pub type SharedBackend = std::sync::Arc<dyn Backend + Send + Sync>;
#[cfg(feature = "pjrt")]
pub type SharedBackend = std::rc::Rc<dyn Backend>;

/// Wrap a concrete backend in the build's `SharedBackend` handle.
#[cfg(not(feature = "pjrt"))]
pub fn share(be: impl Backend + Send + Sync + 'static) -> SharedBackend {
    std::sync::Arc::new(be)
}
#[cfg(feature = "pjrt")]
pub fn share(be: impl Backend + 'static) -> SharedBackend {
    std::rc::Rc::new(be)
}
