//! The pluggable execution backend: everything above this trait (model
//! assembly, serving engine, BLD/GKD/train/scoring/eval drivers) speaks
//! only `Backend` + `Value`; everything below it owns how the manifest's
//! block executables actually run.
//!
//! Contract (shared by every implementation; see DESIGN.md for the full
//! executable-name grammar and shape table):
//!  * `run(name, inputs)` executes the manifest executable `name` with the
//!    manifest-declared input signature and returns the decomposed tuple
//!    outputs. Inputs are `(x, *weights)` for block forwards,
//!    `(x, *weights, dy)` for vjps, `(x, k_cache, v_cache, pos, *weights)`
//!    for cached GQA decode.
//!  * GQA prefill returns `(y, k, v)` (roped K and V for the serving
//!    cache); GQA decode returns `(y, k_cache', v_cache')`; vjps return
//!    `(dx, *dweights)` in manifest weight order; everything else returns
//!    a single output.
//!  * Per-executable call counts and wall clock are tracked so the perf
//!    pass and the measured-cost mode of the cost model (§4.1: "measure
//!    directly on target hardware") work on any backend.

use anyhow::{Context, Result};

use crate::config::Manifest;

use super::value::Value;

/// Per-executable call accounting kept by every backend.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Number of completed `run` calls.
    pub calls: u64,
    /// Total wall-clock seconds across all calls.
    pub total_secs: f64,
    /// Seconds spent compiling/lowering (PJRT only; 0 on the interpreter).
    pub compile_secs: f64,
}

/// The pluggable execution backend. See the module docs for the
/// executable contract and DESIGN.md for the full name grammar.
pub trait Backend {
    /// Human-readable backend identifier ("ref", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The manifest this backend serves (model config, variant layouts,
    /// executable signatures).
    fn man(&self) -> &Manifest;

    /// Execute by name; returns the decomposed tuple outputs.
    fn run(&self, name: &str, inputs: &[&Value]) -> Result<Vec<Value>>;

    /// Fused multi-token decode: execute the *decode-mode* executable
    /// `name` over `m >= 1` new positions per batch lane in ONE pass —
    /// the physical form of speculative verification (prefill-style
    /// attention over the new positions against the existing cache).
    ///
    /// Shape contract (the decode contract with the position axis widened
    /// from 1 to `m`; `m` is read from the inputs, not the manifest):
    ///  * `embed_decode`: `(tokens i32 [b, m], E)` -> `(x [b, m, d])`
    ///  * `attn_{v}_decode` (GQA): `(x [b, m, d], k_cache, v_cache,
    ///    pos i32 [b], *weights)` -> `(y, k_cache', v_cache')`, where
    ///    `pos[i]` is lane i's FIRST new position: the roped K/V of lane
    ///    i's j-th token is written at `pos[i] + j` and its query attends
    ///    over cache positions `<= pos[i] + j`;
    ///  * linear attention / FFN / `head_decode`: token-wise, same inputs
    ///    as decode with the widened `x`.
    ///
    /// Returns `Ok(None)` when the backend cannot fuse (the default), in
    /// which case the caller must lower the pass to `m` sequential decode
    /// steps — the two lowerings must produce identical logits.
    fn run_fused(&self, name: &str, inputs: &[&Value]) -> Result<Option<Vec<Value>>> {
        let _ = (name, inputs);
        Ok(None)
    }

    /// Export `len` cache positions of lane `lane`, starting at position
    /// `start`, out of one layer's dense decode-cache value `cache`
    /// (shape `[b_decode, s_max, kv_heads, head_dim]`) as a host-resident
    /// row flat of `len * kv_heads * head_dim` f32s — one half of the
    /// cache-transfer contract behind the serving prefix cache (the other
    /// half is `import_kv`).
    ///
    /// Returns `Ok(None)` (the default) when the backend cannot move KV
    /// between lanes — e.g. a device-memory backend with no readback path
    /// — in which case the prefix cache disables itself for that engine.
    /// A backend that returns `Some` here must also implement `import_kv`
    /// such that export-then-import round-trips rows bitwise.
    fn export_kv(&self, cache: &Value, lane: usize, start: usize, len: usize) -> Result<Option<Vec<f32>>> {
        let _ = (cache, lane, start, len);
        Ok(None)
    }

    /// Import `len` positions of previously exported rows into lane
    /// `lane` of `cache` at position `at` (see `export_kv` for the row
    /// layout). Returns `Ok(false)` (the default) when the backend does
    /// not support cache transfer; `Ok(true)` after a successful write.
    fn import_kv(&self, cache: &mut Value, lane: usize, at: usize, len: usize, rows: &[f32]) -> Result<bool> {
        let _ = (cache, lane, at, len, rows);
        Ok(false)
    }

    /// Measured mean runtime per call for `name` (seconds); None if never
    /// run. The "measured on target hardware" cost source.
    fn measured_secs(&self, name: &str) -> Option<f64>;

    /// Snapshot of all per-exec stats (perf reporting), sorted by total
    /// time descending.
    fn stats_snapshot(&self) -> Vec<(String, ExecStats)>;

    /// Warm whatever per-executable caches exist (compilation for PJRT,
    /// a no-op for the reference interpreter).
    fn preload(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.run_warmup(n).with_context(|| format!("preloading {n}"))?;
        }
        Ok(())
    }

    /// Backend-specific warm step for one executable; default does nothing.
    fn run_warmup(&self, _name: &str) -> Result<()> {
        Ok(())
    }
}

/// How long-lived components (the serving `Engine`, the `Pipeline`) hold a
/// backend. `RefBackend` is `Send + Sync` (its stats sit behind a `Mutex`),
/// so the default build shares backends through an `Arc` that can be handed
/// to a server thread. The PJRT path wraps an `Rc`-based client that is
/// single-threaded by construction, so with the `pjrt` feature the shared
/// handle degrades to `Rc` and engines stay on the thread that built them.
#[cfg(not(feature = "pjrt"))]
pub type SharedBackend = std::sync::Arc<dyn Backend + Send + Sync>;
#[cfg(feature = "pjrt")]
/// The pjrt-feature handle: single-threaded `Rc` (see above).
pub type SharedBackend = std::rc::Rc<dyn Backend>;

/// Wrap a concrete backend in the build's `SharedBackend` handle.
#[cfg(not(feature = "pjrt"))]
pub fn share(be: impl Backend + Send + Sync + 'static) -> SharedBackend {
    std::sync::Arc::new(be)
}
#[cfg(feature = "pjrt")]
/// Wrap a concrete backend in the build's `SharedBackend` handle.
pub fn share(be: impl Backend + 'static) -> SharedBackend {
    std::rc::Rc::new(be)
}
