//! PJRT runtime: load AOT artifacts (HLO text), compile once per
//! executable, execute with Literal I/O, and chain block executables into
//! full models. The `xla` crate's PJRT client is `Rc`-based, so the whole
//! runtime is single-threaded by construction; the serving engine owns it
//! on a dedicated engine thread.

pub mod literal;
pub mod registry;

pub use literal::{lit_f32, lit_i32, lit_to_tensor, lit_to_vec_f32};
pub use registry::Registry;
