//! Pluggable execution runtime.
//!
//! The `Backend` trait abstracts executable lookup + execution over the
//! manifest's block executables; everything above it (model assembly, the
//! serving engine, the BLD/GKD/train/scoring/eval drivers) is
//! backend-agnostic and speaks host-side `Value`s.
//!
//! Implementations:
//!  * `RefBackend` (always built) — a hermetic pure-Rust interpreter of
//!    the block contract; runs the whole pipeline with no artifacts, no
//!    `xla` crate, and no python step. `Send + Sync`, so a `SharedBackend`
//!    (`Arc`) handle can be moved to a server thread.
//!  * `XlaBackend` (`pjrt` feature) — the original PJRT path: AOT HLO-text
//!    artifacts compiled once per executable. The `xla` crate's PJRT
//!    client is `Rc`-based, so that backend is single-threaded by
//!    construction; `SharedBackend` degrades to `Rc` under this feature.

pub mod backend;
pub mod refbackend;
pub mod value;

#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod registry;
#[cfg(feature = "pjrt")]
pub mod xla_backend;

pub use backend::{share, Backend, ExecStats, SharedBackend};
pub use refbackend::RefBackend;
pub use value::{tensor_to_val, val_f32, val_i32, val_to_tensor, val_to_vec_f32, Value};

#[cfg(feature = "pjrt")]
pub use registry::Registry;
#[cfg(feature = "pjrt")]
pub use xla_backend::XlaBackend;
