//! Global Knowledge Distillation uptraining (paper §5): short end-to-end
//! training of the reassembled child against the parent, with any
//! combination of LM / cosine / KLD losses (Table 1). Also drives parent
//! pretraining (LM-only, no parent) and the lightweight "alignment"
//! finetune (Table 5: instruction-mix data).

use anyhow::Result;

use crate::arch::Arch;
use crate::data::Batcher;
use crate::info;
use crate::model::CompiledModel;
use crate::runtime::Backend;
use crate::train::{eval_batch, lr_schedule, train_step, Adam, AdamCfg, LossSpec, StepMetrics};
use crate::weights::Store;

#[derive(Debug, Clone)]
/// GKD uptraining hyperparameters.
pub struct GkdCfg {
    /// Optimizer steps.
    pub steps: usize,
    /// Peak learning rate.
    pub lr: f32,
    /// Fraction of steps spent on linear warmup.
    pub warmup_frac: f32,
    /// Loss combination (LM / cosine / KLD weights).
    pub spec: LossSpec,
    /// Steps between progress log lines.
    pub log_every: usize,
}

impl Default for GkdCfg {
    fn default() -> Self {
        GkdCfg { steps: 100, lr: 1e-3, warmup_frac: 0.05, spec: LossSpec::gkd_best(), log_every: 20 }
    }
}

#[derive(Debug, Clone, Default)]
/// Outcome of one GKD run.
pub struct GkdReport {
    /// Optimizer steps taken.
    pub steps: usize,
    /// Training tokens consumed.
    pub tokens: u64,
    /// Metrics of the final training step.
    pub final_train: StepMetrics,
    /// validation KLD vs parent after training (Table 1's last column)
    pub val_kld: f64,
    /// Validation LM loss after training.
    pub val_lm: f64,
    /// training loss curve, sampled at log_every
    pub curve: Vec<(usize, f64)>,
}

/// Run GKD (or plain LM pretraining when `spec.lm`-only and parent unused).
/// The parent is re-assembled from the same store at the parent arch; for
/// pretraining pass `parent_needed = false` to skip the parent forward.
pub fn run(
    be: &dyn Backend,
    store: &mut Store,
    arch: &Arch,
    batcher: &mut Batcher,
    val_batches: &[crate::data::Batch],
    cfg: &GkdCfg,
) -> Result<GkdReport> {
    let man = be.man();
    let parent_arch = Arch::parent(man.cfg.n_layers);
    let parent_needed = cfg.spec.cosine || cfg.spec.kld;
    // snapshot parent weights so the child's updates can't drift the teacher
    // (parent shares the store; its own keys are untouched by child training
    // unless the child uses parent variants — which it does for unchanged
    // layers. The teacher must stay fixed, so clone the store.)
    let teacher_store = if parent_needed { Some(store.clone()) } else { None };
    let parent = teacher_store
        .as_ref()
        .map(|s| CompiledModel::assemble(man, s, &parent_arch))
        .transpose()?;

    let mut adam = Adam::new(AdamCfg { lr: cfg.lr, ..Default::default() });
    let warmup = (cfg.steps as f32 * cfg.warmup_frac) as u64;
    let mut report = GkdReport { steps: cfg.steps, ..Default::default() };

    for step in 0..cfg.steps {
        let batch = batcher.next_batch();
        report.tokens += (batch.b * batch.s) as u64;
        let ptrace = parent
            .as_ref()
            .map(|p| p.forward(be, "train", &batch.inputs, batch.b, batch.s))
            .transpose()?;
        let lr = lr_schedule(cfg.lr, step as u64, warmup, cfg.steps as u64);
        let m = train_step(be, store, arch, &mut adam, &batch, cfg.spec, ptrace.as_ref(), lr)?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            info!(
                "gkd[{}] step {step}/{}: loss {:.4} (lm {:.4} cos {:.4} kld {:.4})",
                cfg.spec.name(), cfg.steps, m.loss, m.lm, m.cosine, m.kld
            );
            report.curve.push((step, m.loss));
        }
        report.final_train = m;
    }

    // validation: LM loss + KLD vs the (frozen) teacher
    let val_parent = match &parent {
        Some(p) => Some(p),
        None => None,
    };
    let mut kld_sum = 0.0;
    let mut lm_sum = 0.0;
    for vb in val_batches {
        let ptrace = match val_parent {
            Some(p) => Some(p.forward(be, "train", &vb.inputs, vb.b, vb.s)?),
            None => None,
        };
        let (lm, kld) = eval_batch(be, store, arch, vb, ptrace.as_ref())?;
        lm_sum += lm;
        kld_sum += kld;
    }
    let n = val_batches.len().max(1) as f64;
    report.val_lm = lm_sum / n;
    report.val_kld = kld_sum / n;
    Ok(report)
}

/// Parent pretraining = LM-only training of the parent architecture.
pub fn pretrain_parent(
    be: &dyn Backend,
    store: &mut Store,
    batcher: &mut Batcher,
    val_batches: &[crate::data::Batch],
    steps: usize,
    lr: f32,
) -> Result<GkdReport> {
    let arch = Arch::parent(be.man().cfg.n_layers);
    let cfg = GkdCfg { steps, lr, spec: LossSpec::lm_only(), warmup_frac: 0.05, log_every: 20 };
    run(be, store, &arch, batcher, val_batches, &cfg)
}
