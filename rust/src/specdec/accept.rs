//! The speculative acceptance rule (Leviathan et al.'s rejection
//! sampling, reduced to exact-match under greedy).
//!
//! A draft token `d` drawn from the child's modified distribution `q` is
//! verified against the parent's modified distribution `p` at the same
//! position: accept with probability `min(1, p(d)/q(d))`; on rejection
//! the verifier samples the parent's correction token from the residual
//! `max(0, p - q)` renormalized. Over draft + accept + residual the
//! emitted token is distributed exactly as `p` — speculation changes
//! wall-clock, never the output law. Point-mass pairs (greedy) decide
//! deterministically and consume no randomness, which is what makes
//! greedy speculative decoding byte-identical to plain parent decoding.

use crate::util::Rng;

/// Probability of `tok` under a sparse `(token, prob)` distribution.
pub fn prob_of(d: &[(usize, f64)], tok: usize) -> f64 {
    d.iter().find(|&&(i, _)| i == tok).map(|&(_, p)| p).unwrap_or(0.0)
}

/// One acceptance decision for draft `d` proposed from `q`, verified
/// against `p`. Certain outcomes (`p(d) >= q(d)` accept, `p(d) == 0`
/// reject) consume no randomness.
pub fn accept(p: &[(usize, f64)], q: &[(usize, f64)], d: usize, rng: &mut Rng) -> bool {
    let pd = prob_of(p, d);
    let qd = prob_of(q, d);
    if pd >= qd {
        // covers the greedy match (1 >= 1) and any ratio >= 1
        return true;
    }
    if pd <= 0.0 {
        // covers the greedy mismatch (0 < 1) and tokens outside p's support
        return false;
    }
    rng.f64() < pd / qd
}

/// The residual distribution `max(0, p - q)`, renormalized — what the
/// verifier samples on rejection so the overall output law is exactly
/// `p`. Falls back to `p` itself when the residual carries no mass
/// (p == q up to float error, where any correction is unbiased anyway).
pub fn residual(p: &[(usize, f64)], q: &[(usize, f64)]) -> Vec<(usize, f64)> {
    let mut r: Vec<(usize, f64)> = p
        .iter()
        .map(|&(i, pi)| (i, (pi - prob_of(q, i)).max(0.0)))
        .filter(|&(_, x)| x > 0.0)
        .collect();
    let total: f64 = r.iter().map(|&(_, x)| x).sum();
    if total <= 1e-12 {
        return p.to_vec();
    }
    for (_, x) in r.iter_mut() {
        *x /= total;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::sampling::draw;

    #[test]
    fn greedy_point_masses_decide_without_randomness() {
        let p = vec![(7usize, 1.0)];
        let q_match = vec![(7usize, 1.0)];
        let q_miss = vec![(3usize, 1.0)];
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert!(accept(&p, &q_match, 7, &mut rng));
        assert!(!accept(&p, &q_miss, 3, &mut rng));
        assert_eq!(rng.next_u64(), before, "deterministic decisions must not touch the rng");
    }

    #[test]
    fn residual_removes_the_overlap() {
        let p = vec![(0usize, 0.5), (1, 0.3), (2, 0.2)];
        let q = vec![(0usize, 0.2), (1, 0.8)];
        let r = residual(&p, &q);
        // token 1 is over-proposed (0.8 > 0.3): no residual mass
        assert!(r.iter().all(|&(i, _)| i != 1));
        // remaining mass proportional to p - q: 0.3 and 0.2
        let r0 = prob_of(&r, 0);
        let r2 = prob_of(&r, 2);
        assert!((r0 - 0.6).abs() < 1e-12, "r0 = {r0}");
        assert!((r2 - 0.4).abs() < 1e-12, "r2 = {r2}");
    }

    #[test]
    fn identical_distributions_fall_back_to_p() {
        let p = vec![(0usize, 0.5), (1, 0.5)];
        let r = residual(&p, &p);
        assert_eq!(r, p, "zero residual mass must fall back to p");
    }

    /// The subsystem's statistical contract: draft from q, accept or
    /// resample from the residual — the emitted token is distributed as p,
    /// for a q that both under- and over-proposes.
    #[test]
    fn speculative_sampling_is_unbiased() {
        let p = vec![(0usize, 0.45), (1, 0.35), (2, 0.15), (3, 0.05)];
        let q = vec![(0usize, 0.10), (1, 0.60), (2, 0.05), (3, 0.25)];
        let n = 200_000usize;
        let mut draft_rng = Rng::new(11);
        let mut accept_rng = Rng::new(12);
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let d = draw(&q, &mut draft_rng);
            let tok = if accept(&p, &q, d, &mut accept_rng) {
                d
            } else {
                draw(&residual(&p, &q), &mut accept_rng)
            };
            counts[tok] += 1;
        }
        for (i, &(tok, pi)) in p.iter().enumerate() {
            let hat = counts[tok] as f64 / n as f64;
            assert!(
                (hat - pi).abs() < 0.01,
                "token {i}: empirical {hat:.4} vs target {pi:.4}"
            );
        }
    }
}
