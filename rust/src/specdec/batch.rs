//! Batched speculative decoding: N draft/verify sequences sharing the
//! child and parent engines' decode lanes.
//!
//! `SpecBatch` generalizes the single-lane session to a wave of
//! sequences advancing in lockstep (DESIGN.md §6). Per round, every
//! live lane drafts on the child (one *batched* decode forward per draft
//! step serves all lanes), then the parent verifies ALL lanes' drafts in
//! one fused multi-token pass (`Engine::spec_extend_batch` →
//! `Backend::run_fused`), and each lane accepts/commits/rolls back
//! independently with its own seeded rng streams. Requests beyond the
//! engines' lane count queue up and backfill freed lanes as sequences
//! finish — continuous batching for the speculative path.
//!
//! Per-sequence behavior is *identical* to `SpecSession`: greedy output
//! is byte-identical to plain greedy parent decoding for every sequence
//! in the batch, stochastic output follows exactly the parent's
//! distribution, and both engines return every rejected draft's KV pages
//! exactly. Lane isolation is the engine's parking rule: lanes a forward
//! does not feed are teacher-forced a dummy token at their own frontier,
//! where the write is dead by the attention masking rule.
//!
//! With `SpecConfig::engine` carrying `EngineConfig::prefix_cache`, BOTH
//! engines run the radix-tree prefix cache: a fleet of requests sharing
//! a system prompt prefills it once per engine — the drafter's `spec_open`
//! and the parent's reuse their own retained segments (each engine keeps
//! its own tree because per-layer KV-head counts differ between the two
//! architectures), and lanes backfilled mid-run hit the prefix their
//! predecessors retained. Finished sequences retain their full committed
//! stream (prompt + generated) on both engines, so a follow-up turn
//! extending a completion is a warm hit too. Hit or miss, outputs stay
//! byte-identical.
//!
//! Two driving surfaces share the same lane machinery: the batch call
//! `generate_many` (submit everything, block until done, responses in
//! request order) and the incremental `submit` / `tick` / `take_finished`
//! loop, which interleaves speculative sequences with external work —
//! the workload replay harness drives this surface one simulated tick at
//! a time and reads per-token `StreamEvent`s for latency scoring.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::Arch;
use crate::data::world::EOS;
use crate::obs::{Event, Tracer};
use crate::perf::HwProfile;
use crate::runtime::SharedBackend;
use crate::serving::sampling::{dist, draw, sample};
use crate::serving::{Engine, EngineMetrics, FinishReason, SamplingParams, SpecFeed, StreamEvent};
use crate::util::Rng;
use crate::weights::Store;

use super::accept;
use super::speedup::{KTuner, SpecModel};
use super::{SpecConfig, SpecResponse};

/// One speculative generation request (prompt + stopping budget +
/// per-request sampling policy with its private seed).
#[derive(Debug, Clone)]
pub struct SpecRequest {
    /// Prompt tokens (non-empty, shorter than the cache horizon).
    pub prompt: Vec<u32>,
    /// Maximum generated tokens (>= 1).
    pub max_new: usize,
    /// Sampling policy; greedy keeps the byte-equivalence invariant.
    pub sampling: SamplingParams,
}

impl SpecRequest {
    /// A greedy request.
    pub fn new(prompt: Vec<u32>, max_new: usize) -> SpecRequest {
        SpecRequest { prompt, max_new, sampling: SamplingParams::greedy() }
    }

    /// Override the sampling policy.
    pub fn with_sampling(mut self, sampling: SamplingParams) -> SpecRequest {
        self.sampling = sampling;
        self
    }
}

/// Per-lane state of one in-flight speculative sequence.
struct Lane {
    /// Batch-level request id (`submit`'s return; `StreamEvent` ids).
    id: u64,
    pid: u64,
    cid: u64,
    sampling: SamplingParams,
    greedy: bool,
    max_new: usize,
    /// Prompt token count: the prompt/generated boundary finish-time
    /// retention reports to the prefix cache.
    prompt_len: usize,
    /// `out` tokens already surfaced as `StreamEvent::Token`s.
    emitted: usize,
    /// accept/bonus draws; independent of draft draws or the rejection
    /// test would correlate with the proposal and bias the output law
    accept_rng: Rng,
    draft_rng: Rng,
    committed: Vec<u32>,
    out: Vec<u32>,
    resp: SpecResponse,
    // per-round scratch
    drafts: Vec<u32>,
    qdists: Vec<Vec<(usize, f64)>>,
    k_eff: usize,
    done: Option<FinishReason>,
}

/// A batched draft/verify driver over two engines sharing one backend:
/// the parent holds each sequence's verified truth, the child speculates
/// ahead, and up to `b_decode` sequences advance together per forward.
pub struct SpecBatch {
    parent: Engine,
    child: Engine,
    /// Construction parameters (draft length, adaptation, engine config).
    pub cfg: SpecConfig,
    tuner: Option<KTuner>,
    total_accepted: usize,
    total_attempted: usize,
    /// Live lanes (the incremental surface's in-flight sequences).
    lanes: Vec<Lane>,
    /// Admitted requests waiting for a free lane, FIFO.
    waiting: VecDeque<(u64, SpecRequest)>,
    /// Finished-but-unclaimed responses (`take_finished` drains).
    finished: Vec<(u64, SpecResponse)>,
    /// Pending stream events (`tick` drains).
    events: Vec<StreamEvent>,
    /// Lifecycle tracer shared with both engines (disabled by default).
    /// Batch ids are its request ids: the engines' own `spec_open`
    /// sequence ids never produce lifecycle events, so the id spaces
    /// cannot collide on per-request trace tracks.
    trace: Tracer,
    next_id: u64,
}

impl SpecBatch {
    /// Build the parent and child engines over one shared backend.
    /// `cfg.draft_k == 0` is rejected; `cfg.adapt_k_max = Some(k_max)`
    /// arms the online draft-length tuner (`KTuner` over the roofline
    /// `SpecModel` of this parent/child pair).
    pub fn new(
        be: SharedBackend,
        parent_store: &Store,
        parent_arch: &Arch,
        child_store: &Store,
        child_arch: &Arch,
        cfg: SpecConfig,
    ) -> Result<SpecBatch> {
        if cfg.draft_k == 0 {
            return Err(anyhow!("draft_k must be >= 1"));
        }
        let tuner = cfg.adapt_k_max.map(|k_max| {
            let man = be.man();
            let ctx = (man.cfg.s_max / 2).max(1);
            let model = SpecModel::new(man, parent_arch, child_arch, &HwProfile::h100_fp8(), ctx);
            KTuner::new(model, cfg.draft_k, k_max)
        });
        let parent = cfg.engine.clone().build(be.clone(), parent_store, parent_arch)?;
        let child = cfg.engine.clone().build(be, child_store, child_arch)?;
        let trace = parent.tracer().clone();
        Ok(SpecBatch {
            parent,
            child,
            cfg,
            tuner,
            total_accepted: 0,
            total_attempted: 0,
            lanes: Vec::new(),
            waiting: VecDeque::new(),
            finished: Vec::new(),
            events: Vec::new(),
            trace,
            next_id: 0,
        })
    }

    /// The lifecycle tracer both engines share (disabled unless
    /// `SpecConfig::engine` configured one). Drivers use it to stamp
    /// virtual ticks and to export the trace.
    pub fn tracer(&self) -> &Tracer {
        &self.trace
    }

    /// The parent engine's metrics: generation counters plus the
    /// speculative section (draft_proposed/accepted, passes, rollbacks,
    /// fused passes).
    pub fn parent_metrics(&self) -> &EngineMetrics {
        &self.parent.metrics
    }

    /// The child (drafter) engine's metrics.
    pub fn child_metrics(&self) -> &EngineMetrics {
        &self.child.metrics
    }

    /// Paged-KV bytes currently held by the (parent, child) engines —
    /// with the prefix cache off, both must return to zero between
    /// `generate_many` calls; with it on, exactly the retained segment
    /// bytes (`prefix_retained_bytes`) persist.
    pub fn kv_allocated_bytes(&self) -> (usize, usize) {
        (self.parent.kv_allocated_bytes(), self.child.kv_allocated_bytes())
    }

    /// Pool bytes the (parent, child) engines hold as retained prefix
    /// segments — the share of `kv_allocated_bytes` that deliberately
    /// outlives requests.
    pub fn prefix_retained_bytes(&self) -> (usize, usize) {
        (self.parent.prefix_retained_bytes(), self.child.prefix_retained_bytes())
    }

    /// Prompt tokens the (parent, child) engines served from retained
    /// prefixes instead of re-prefilling — the shared-system-prompt win.
    pub fn prefix_tokens_saved(&self) -> (usize, usize) {
        (
            self.parent.metrics.prefix_tokens_saved,
            self.child.metrics.prefix_tokens_saved,
        )
    }

    /// Concurrent speculative sequences the engines can hold
    /// (`min(b_decode)` of the two).
    pub fn lane_capacity(&self) -> usize {
        self.parent.decode_lanes().min(self.child.decode_lanes()).max(1)
    }

    /// The draft length the next round will use: the tuner's current
    /// choice under adaptation, the configured pin otherwise.
    pub fn current_draft_k(&self) -> usize {
        self.tuner.as_ref().map(|t| t.k()).unwrap_or(self.cfg.draft_k)
    }

    /// Running per-attempt acceptance rate α̂ across everything this
    /// batch has generated (0.0 before any verification).
    pub fn observed_alpha(&self) -> f64 {
        if self.total_attempted == 0 {
            0.0
        } else {
            self.total_accepted as f64 / self.total_attempted as f64
        }
    }

    /// Generate all `reqs` speculatively, sharing the engines' decode
    /// lanes: up to `lane_capacity()` sequences run concurrently and
    /// waiting requests backfill lanes as sequences finish. Responses
    /// come back in request order. Greedy sequences are byte-identical
    /// to plain greedy parent decoding; stochastic sequences draw from
    /// exactly the parent's modified distribution, reproducibly per seed.
    ///
    /// Errors abort the whole batch: every open lane is torn down (no
    /// pages or lanes leak, the engines stay reusable) but responses of
    /// already-finished sequences are discarded too. Speculative
    /// sequences book pages as they grow rather than reserving a horizon
    /// up front, so unlike `Engine::submit` a KV-budget exhaustion is
    /// reachable mid-run — size `SpecConfig::engine`'s
    /// `kv_budget_bytes` for `lane_capacity()` concurrent horizons.
    pub fn generate_many(&mut self, reqs: &[SpecRequest]) -> Result<Vec<SpecResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        for r in reqs {
            if r.max_new == 0 {
                return Err(anyhow!("max_new == 0: nothing to generate"));
            }
        }
        let mut ids = Vec::with_capacity(reqs.len());
        let res: Result<()> = (|| {
            for r in reqs {
                ids.push(self.submit(r.clone())?);
            }
            while !self.is_idle() {
                self.tick()?;
            }
            Ok(())
        })();
        if res.is_err() {
            // a submit-time rejection leaves earlier requests queued:
            // tear everything down so the engines stay reusable
            self.abort();
        }
        // the batch surface has no event consumer, and on error it
        // discards partial results
        self.events.clear();
        let mut by_id: HashMap<u64, SpecResponse> = self.take_finished().into_iter().collect();
        res?;
        ids.iter()
            .map(|id| {
                by_id.remove(id).ok_or_else(|| anyhow!("request {id} produced no response"))
            })
            .collect()
    }

    /// Admit one request to the incremental surface and return its id;
    /// it waits FIFO for a free lane and starts on a later `tick`.
    /// Submit-time validation (empty prompt, `max_new == 0`, prompt over
    /// the cache horizon) emits a `StreamEvent::Rejected` and errors
    /// without touching engine state — mirroring `Engine::submit`.
    pub fn submit(&mut self, req: SpecRequest) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let s_max = self.parent.cache_horizon();
        let cause = if req.prompt.is_empty() {
            Some("empty prompt".to_string())
        } else if req.max_new == 0 {
            Some("max_new == 0: nothing to generate".to_string())
        } else if req.prompt.len() >= s_max {
            Some(format!(
                "prompt of {} tokens cannot fit the cache horizon s_max={}",
                req.prompt.len(),
                s_max
            ))
        } else {
            None
        };
        if let Some(cause) = cause {
            self.parent.metrics.rejected_prompts += 1;
            if self.trace.enabled() {
                self.trace.record(Event::Rejected { id, cause: cause.clone() });
            }
            let err = anyhow!("request {id} rejected: {cause}");
            self.events.push(StreamEvent::Rejected { id, cause });
            return Err(err);
        }
        self.trace.record(Event::Submitted { id, prompt: req.prompt.len(), max_new: req.max_new });
        self.waiting.push_back((id, req));
        Ok(id)
    }

    /// Anything still in flight (live lanes or queued requests)?
    pub fn is_idle(&self) -> bool {
        self.lanes.is_empty() && self.waiting.is_empty()
    }

    /// Finished responses accumulated since the last call, as
    /// `(submit id, response)` pairs in finish order.
    pub fn take_finished(&mut self) -> Vec<(u64, SpecResponse)> {
        std::mem::take(&mut self.finished)
    }

    /// Advance every live sequence by ONE speculative round (draft →
    /// fused verify → accept/rollback), backfilling free lanes from the
    /// waiting queue before and after, and return the `StreamEvent`s the
    /// round produced — `Token` per committed token (the admission token
    /// included), then `Finished` once per sequence. An error aborts the
    /// whole in-flight set (`abort`), exactly like `generate_many`.
    pub fn tick(&mut self) -> Result<Vec<StreamEvent>> {
        // wall time accrues on the parent (the batch's metrics surface),
        // mirroring Engine::step — execute_secs lands on whichever engine
        // ran the forward, so parent overhead_frac stays meaningful under
        // speculative serving too
        let t0 = Instant::now();
        let r = self.tick_inner();
        self.parent.metrics.wall_secs += t0.elapsed().as_secs_f64();
        match r {
            Ok(()) => Ok(std::mem::take(&mut self.events)),
            Err(e) => {
                self.abort();
                Err(e)
            }
        }
    }

    fn tick_inner(&mut self) -> Result<()> {
        // open lanes, close any that finished at admission (EOS first
        // token / max_new == 1), and keep backfilling until stable
        loop {
            self.backfill()?;
            if !self.harvest() {
                break;
            }
        }
        if self.lanes.is_empty() {
            return Ok(());
        }
        let s_max = self.parent.cache_horizon();
        // the round borrows the engines and the lanes independently
        let mut lanes = std::mem::take(&mut self.lanes);
        let r = self.round(&mut lanes, s_max);
        self.lanes = lanes;
        r?;
        for lane in &mut self.lanes {
            while lane.emitted < lane.out.len() {
                if self.trace.enabled() {
                    if lane.emitted == 0 {
                        self.trace.record(Event::FirstToken { id: lane.id });
                    }
                    self.trace.record(Event::Token { id: lane.id, tok: lane.out[lane.emitted] });
                }
                self.events.push(StreamEvent::Token { id: lane.id, tok: lane.out[lane.emitted] });
                lane.emitted += 1;
            }
        }
        self.harvest();
        Ok(())
    }

    /// Tear down every live lane and drop the waiting queue — the
    /// incremental surface's cancel-all. Engines stay reusable; no pages
    /// or lanes leak; aborted sequences retain no prefix segments.
    /// Already-finished responses stay claimable via `take_finished`.
    pub fn abort(&mut self) {
        for lane in std::mem::take(&mut self.lanes) {
            self.parent.spec_close(lane.pid);
            self.child.spec_close(lane.cid);
        }
        self.waiting.clear();
        self.events.clear();
    }

    /// Open waiting requests into free lanes, FIFO, until capacity.
    fn backfill(&mut self) -> Result<()> {
        let capacity = self.lane_capacity();
        while self.lanes.len() < capacity {
            let Some((id, req)) = self.waiting.pop_front() else { break };
            let lane = self.open_lane(id, &req)?;
            self.lanes.push(lane);
        }
        Ok(())
    }

    /// Close every lane marked done: flush its remaining `Token` events,
    /// release both engines' lanes (retaining the committed stream for
    /// the prefix cache), emit `Finished`, and stash the response.
    /// Returns whether anything closed (freeing lanes to backfill).
    fn harvest(&mut self) -> bool {
        let mut closed = false;
        let mut i = 0;
        while i < self.lanes.len() {
            if self.lanes[i].done.is_some() {
                let mut lane = self.lanes.swap_remove(i);
                while lane.emitted < lane.out.len() {
                    if self.trace.enabled() {
                        if lane.emitted == 0 {
                            self.trace.record(Event::FirstToken { id: lane.id });
                        }
                        self.trace.record(Event::Token { id: lane.id, tok: lane.out[lane.emitted] });
                    }
                    self.events
                        .push(StreamEvent::Token { id: lane.id, tok: lane.out[lane.emitted] });
                    lane.emitted += 1;
                }
                let id = lane.id;
                let resp = self.close_lane(lane);
                self.trace.record(Event::Finished {
                    id,
                    reason: resp.finish.as_str(),
                    tokens: resp.tokens.len(),
                });
                self.events.push(StreamEvent::Finished { id, reason: resp.finish });
                self.finished.push((id, resp));
                closed = true;
                // re-examine index i: swap_remove moved another lane in
            } else {
                i += 1;
            }
        }
        closed
    }

    /// Open one sequence on both engines and take its first token from
    /// the parent prefill — the same sample the plain engine takes at
    /// admission, from the same (accept) stream as the session driver.
    fn open_lane(&mut self, id: u64, req: &SpecRequest) -> Result<Lane> {
        // prefix-cache hit/miss for the Admitted event comes from the
        // parent's counters around spec_open — the engine has no
        // lifecycle view of externally driven sequences
        let (hits0, saved0) =
            (self.parent.metrics.prefix_hits, self.parent.metrics.prefix_tokens_saved);
        let (pid, first) = self.parent.spec_open(&req.prompt)?;
        let cid = match self.child.spec_open(&req.prompt) {
            Ok((cid, _)) => cid,
            Err(e) => {
                self.parent.spec_close(pid);
                return Err(e);
            }
        };
        if self.trace.enabled() {
            self.trace.record(Event::Admitted {
                id,
                lane: self.parent.spec_lane_of(pid).unwrap_or(0),
                hit: self.parent.metrics.prefix_hits > hits0,
                matched: self.parent.metrics.prefix_tokens_saved - saved0,
            });
        }
        let mut accept_rng = Rng::new(req.sampling.seed);
        let draft_rng = Rng::new(req.sampling.seed ^ 0x5bec_dec0);
        let t0 = sample(&first, &req.sampling, &mut accept_rng) as u32;
        let mut committed = req.prompt.clone();
        committed.push(t0);
        let done = if t0 == EOS {
            Some(FinishReason::Eos)
        } else if req.max_new <= 1 {
            Some(FinishReason::MaxNew)
        } else {
            None
        };
        Ok(Lane {
            id,
            pid,
            cid,
            sampling: req.sampling,
            greedy: req.sampling.is_greedy(),
            max_new: req.max_new,
            prompt_len: req.prompt.len(),
            emitted: 0,
            accept_rng,
            draft_rng,
            committed,
            out: vec![t0],
            resp: SpecResponse {
                tokens: vec![],
                finish: FinishReason::MaxNew,
                parent_passes: 1,
                proposed: 0,
                accepted: 0,
                attempted: 0,
                rollbacks: 0,
            },
            drafts: Vec::new(),
            qdists: Vec::new(),
            k_eff: 0,
            done,
        })
    }

    /// One lockstep round over every live lane: draft on the child,
    /// verify all lanes in one batched parent pass, accept/commit/roll
    /// back per lane, feed the tuner.
    fn round(&mut self, lanes: &mut [Lane], s_max: usize) -> Result<()> {
        let k = self.current_draft_k();
        // pre-round finish checks, in the single-lane driver's order (the
        // max_new budget binds before the horizon check)
        for lane in lanes.iter_mut() {
            if lane.done.is_some() {
                continue;
            }
            if lane.out.len() >= lane.max_new {
                lane.done = Some(FinishReason::MaxNew);
            } else if lane.committed.len() >= s_max {
                lane.done = Some(FinishReason::CacheHorizon);
            }
        }
        let active: Vec<usize> =
            (0..lanes.len()).filter(|&i| lanes[i].done.is_none()).collect();
        if active.is_empty() {
            return Ok(());
        }
        // cap each draft so a full acceptance (k_eff + 1 tokens) never
        // overshoots max_new, and the committed stream never exceeds the
        // plain engine's CacheHorizon point (committed == s_max): this is
        // what keeps horizon-reaching prompts byte-identical
        for &i in &active {
            let lane = &mut lanes[i];
            lane.k_eff = k
                .min(lane.max_new - lane.out.len() - 1)
                .min(s_max - lane.committed.len() - 1);
            lane.drafts.clear();
            lane.qdists.clear();
        }
        // --- draft: the child catches up to each lane's committed stream
        // (one batched pass), then the lanes propose in lockstep, each
        // recording the modified distribution q it drew from ---
        let drafting: Vec<usize> =
            active.iter().copied().filter(|&i| lanes[i].k_eff > 0).collect();
        let mut rows: HashMap<usize, Vec<f32>> = HashMap::new();
        if !drafting.is_empty() {
            let mut cls = Vec::with_capacity(drafting.len());
            for &i in &drafting {
                cls.push(self.child.spec_len(lanes[i].cid)?);
            }
            let feeds: Vec<SpecFeed> = drafting
                .iter()
                .zip(&cls)
                .map(|(&i, &cl)| {
                    let toks = &lanes[i].committed[cl..];
                    SpecFeed { id: lanes[i].cid, tokens: toks, collect_from: toks.len() - 1 }
                })
                .collect();
            let out = self.child.spec_extend_batch(&feeds)?;
            drop(feeds);
            for (&i, mut r) in drafting.iter().zip(out) {
                let row =
                    r.pop().ok_or_else(|| anyhow!("child catch-up produced no logits"))?;
                rows.insert(i, row);
            }
            let mut live = drafting;
            loop {
                let mut continuing: Vec<usize> = Vec::new();
                for &i in &live {
                    let lane = &mut lanes[i];
                    let q = dist(&rows[&i], &lane.sampling);
                    let d = draw(&q, &mut lane.draft_rng) as u32;
                    lane.drafts.push(d);
                    lane.qdists.push(q);
                    if lane.drafts.len() < lane.k_eff && d != EOS {
                        continuing.push(i);
                    }
                }
                if continuing.is_empty() {
                    break;
                }
                let feeds: Vec<SpecFeed> = continuing
                    .iter()
                    .map(|&i| SpecFeed {
                        id: lanes[i].cid,
                        tokens: std::slice::from_ref(lanes[i].drafts.last().unwrap()),
                        collect_from: 0,
                    })
                    .collect();
                let out = self.child.spec_extend_batch(&feeds)?;
                drop(feeds);
                for (&i, mut r) in continuing.iter().zip(out) {
                    let row =
                        r.pop().ok_or_else(|| anyhow!("child draft step produced no logits"))?;
                    rows.insert(i, row);
                }
                live = continuing;
            }
        }
        // --- verify: ONE batched parent pass over every lane's newest
        // committed token plus its drafts; kd + 1 logit rows per lane ---
        let feed_tokens: Vec<(usize, Vec<u32>)> = active
            .iter()
            .map(|&i| {
                let lane = &lanes[i];
                let mut t = Vec::with_capacity(lane.drafts.len() + 1);
                t.push(*lane.committed.last().unwrap());
                t.extend_from_slice(&lane.drafts);
                (i, t)
            })
            .collect();
        let feeds: Vec<SpecFeed> = feed_tokens
            .iter()
            .map(|(i, t)| SpecFeed { id: lanes[*i].pid, tokens: t, collect_from: 0 })
            .collect();
        let vrows = self.parent.spec_extend_batch(&feeds)?;
        drop(feeds);
        // --- accept / commit / rollback, independently per lane ---
        let (mut round_accepted, mut round_attempted) = (0usize, 0usize);
        for ((iref, _), prows) in feed_tokens.iter().zip(vrows) {
            let i = *iref;
            let lane = &mut lanes[i];
            lane.resp.parent_passes += 1;
            let kd = lane.drafts.len();
            lane.resp.proposed += kd;
            let mut a = 0usize;
            let mut bonus_dist: Option<Vec<(usize, f64)>> = None;
            for t in 0..kd {
                lane.resp.attempted += 1;
                round_attempted += 1;
                let p = dist(&prows[t], &lane.sampling);
                let ok = if lane.greedy {
                    p[0].0 == lane.drafts[t] as usize
                } else {
                    accept::accept(&p, &lane.qdists[t], lane.drafts[t] as usize, &mut lane.accept_rng)
                };
                if !ok {
                    bonus_dist =
                        Some(if lane.greedy { p } else { accept::residual(&p, &lane.qdists[t]) });
                    break;
                }
                a += 1;
            }
            lane.resp.accepted += a;
            round_accepted += a;
            // the pass always nets one parent-sampled token: bonus from
            // the last row on full acceptance, residual-corrected on a
            // rejection (drawn before commit so the rng order matches the
            // single-lane driver even when EOS cuts the commit short)
            let bonus_dist = bonus_dist.unwrap_or_else(|| dist(&prows[kd], &lane.sampling));
            let bonus = draw(&bonus_dist, &mut lane.accept_rng) as u32;
            for t in 0..a {
                let d = lane.drafts[t];
                lane.out.push(d);
                lane.committed.push(d);
                if d == EOS {
                    lane.done = Some(FinishReason::Eos);
                    break;
                }
            }
            if lane.done.is_none() {
                lane.out.push(bonus);
                lane.committed.push(bonus);
                // same precedence as the plain engine's decode_step
                lane.done = if bonus == EOS {
                    Some(FinishReason::Eos)
                } else if lane.out.len() >= lane.max_new {
                    Some(FinishReason::MaxNew)
                } else if lane.committed.len() >= s_max {
                    Some(FinishReason::CacheHorizon)
                } else {
                    None
                };
            }
            if self.trace.enabled() {
                self.trace.record(Event::SpecRound {
                    id: lanes[i].id,
                    lane: self.parent.spec_lane_of(lanes[i].pid).unwrap_or(0),
                    drafted: kd,
                    accepted: a,
                    rolled_back: kd - a,
                });
            }
            // --- rollback: rejected drafts hand their pages back; other
            // lanes' pages are untouched (asserted in the tests) ---
            self.rollback_lane(lanes, i)?;
        }
        if let Some(t) = self.tuner.as_mut() {
            t.observe(round_accepted, round_attempted);
        }
        self.total_accepted += round_accepted;
        self.total_attempted += round_attempted;
        Ok(())
    }

    /// Restore one lane's engines to the inter-round invariant: each
    /// holds KV for every committed token except the newest (which the
    /// next pass feeds). Frees the rejected drafts' pages exactly.
    fn rollback_lane(&mut self, lanes: &mut [Lane], i: usize) -> Result<()> {
        let lane = &mut lanes[i];
        let target = lane.committed.len() - 1;
        if self.parent.spec_len(lane.pid)? > target {
            self.parent.spec_truncate(lane.pid, target)?;
            lane.resp.rollbacks += 1;
        }
        if self.child.spec_len(lane.cid)? > target {
            self.child.spec_truncate(lane.cid, target)?;
            lane.resp.rollbacks += 1;
        }
        Ok(())
    }

    /// Close a finished lane on both engines — retaining the committed
    /// stream (prompt + generated) as a prefix segment when the cache is
    /// on, so the conversation's next turn starts warm — stamp its
    /// response, and fold its counters into the parent engine's metrics.
    fn close_lane(&mut self, mut lane: Lane) -> SpecResponse {
        self.parent.spec_close_retained(lane.pid, &lane.committed, lane.prompt_len);
        self.child.spec_close_retained(lane.cid, &lane.committed, lane.prompt_len);
        lane.resp.tokens = std::mem::take(&mut lane.out);
        lane.resp.finish = lane.done.unwrap_or(FinishReason::MaxNew);
        let resp = lane.resp;
        self.parent.metrics.draft_proposed += resp.proposed;
        self.parent.metrics.draft_accepted += resp.accepted;
        self.parent.metrics.spec_passes += resp.parent_passes.saturating_sub(1);
        self.parent.metrics.generated_tokens += resp.tokens.len();
        self.parent.metrics.record_finish(resp.finish);
        self.parent.metrics.requests_completed += 1;
        resp
    }
}
