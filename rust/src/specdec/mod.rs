//! Speculative decoding: the Puzzle child drafts, the parent verifies.
//!
//! Puzzle's output is a child retaining ~98% of the parent's behavior at
//! a fraction of the cost — a near-ideal *draft model* for speculative
//! decoding of its own parent, which turns the NAS result into a
//! serving-time speedup rather than only a standalone model. The loop
//! (per round, DESIGN.md §5):
//!
//! 1. **draft** — the child engine proposes up to `draft_k` tokens from
//!    its own state, one cheap decode step each, recording the modified
//!    distribution `q` it drew every token from;
//! 2. **verify** — the parent engine runs ONE teacher-forced multi-token
//!    pass (`Engine::spec_extend`) over the newest committed token plus
//!    all drafts, yielding the parent distribution `p` at every position;
//! 3. **accept** — the longest draft prefix survives: exact argmax match
//!    under greedy (making greedy speculative output byte-identical to
//!    plain parent decoding), `min(1, p/q)` rejection sampling under
//!    stochastic `SamplingParams` (making the output law exactly `p`);
//!    the pass always nets one parent-sampled token (bonus on full
//!    acceptance, residual-corrected token on rejection);
//! 4. **rollback** — both engines rewind to the committed stream
//!    (`Engine::spec_truncate` -> `PagedKvManager::truncate`), handing
//!    the rejected drafts' KV pages straight back to the pool.
//!
//! `speedup` holds the analytic model (expected tokens/pass over α and
//! k, roofline-costed) that ranks candidate children by *draft value* —
//! the bridge from the MIP/NAS stage to serving throughput.

pub mod accept;
pub mod speedup;

use anyhow::{anyhow, Result};

use crate::arch::Arch;
use crate::data::world::EOS;
use crate::runtime::SharedBackend;
use crate::serving::sampling::{dist, draw, sample};
use crate::serving::{Engine, EngineConfig, EngineMetrics, FinishReason, SamplingParams};
use crate::util::Rng;
use crate::weights::Store;

pub use speedup::{expected_tokens_per_pass, rank_drafters, SpecModel};

/// Session construction parameters.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Draft tokens proposed per round (>= 1).
    pub draft_k: usize,
    /// Engine construction for BOTH engines (KV budget, page length).
    pub engine: EngineConfig,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { draft_k: 4, engine: EngineConfig::default() }
    }
}

/// One speculative generation result, with the counters the speedup
/// model is validated against.
#[derive(Debug, Clone)]
pub struct SpecResponse {
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Parent forwards: 1 prefill + one per verify pass.
    pub parent_passes: usize,
    /// Draft tokens proposed by the child.
    pub proposed: usize,
    /// Draft tokens accepted by verification.
    pub accepted: usize,
    /// Acceptance trials actually reached (a pass stops verifying at its
    /// first rejection) — the α̂ denominator consistent with the
    /// geometric model of `speedup::expected_tokens_per_pass`.
    pub attempted: usize,
    /// KV rollbacks across both engines.
    pub rollbacks: usize,
}

impl SpecResponse {
    /// Amortized generated tokens per parent forward — the speculative
    /// headline: > 1 means the parent ran fewer times than tokens out.
    pub fn tokens_per_pass(&self) -> f64 {
        if self.parent_passes == 0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.parent_passes as f64
        }
    }

    /// Tokens per *verify* pass, prefill excluded — directly comparable
    /// to `expected_tokens_per_pass(acceptance_rate(), draft_k)`.
    pub fn tokens_per_verify_pass(&self) -> f64 {
        if self.parent_passes <= 1 {
            0.0
        } else {
            (self.tokens.len() - 1) as f64 / (self.parent_passes - 1) as f64
        }
    }

    /// Per-attempt acceptance rate estimate α̂.
    pub fn acceptance_rate(&self) -> f64 {
        if self.attempted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.attempted as f64
        }
    }
}

/// A draft/verify session over two engines sharing one backend: the
/// parent holds the verified truth, the child speculates ahead. Both
/// engines keep their own KV caches and page accounting; the session
/// maintains the invariant that between rounds each engine has exactly
/// the committed stream minus its newest token in cache.
pub struct SpecSession {
    parent: Engine,
    child: Engine,
    pub cfg: SpecConfig,
}

impl SpecSession {
    pub fn new(
        be: SharedBackend,
        parent_store: &Store,
        parent_arch: &Arch,
        child_store: &Store,
        child_arch: &Arch,
        cfg: SpecConfig,
    ) -> Result<SpecSession> {
        if cfg.draft_k == 0 {
            return Err(anyhow!("draft_k must be >= 1"));
        }
        let parent = cfg.engine.clone().build(be.clone(), parent_store, parent_arch)?;
        let child = cfg.engine.clone().build(be, child_store, child_arch)?;
        Ok(SpecSession { parent, child, cfg })
    }

    /// The parent engine's metrics: generation counters plus the
    /// speculative section (draft_proposed/accepted, passes, rollbacks).
    pub fn parent_metrics(&self) -> &EngineMetrics {
        &self.parent.metrics
    }

    pub fn child_metrics(&self) -> &EngineMetrics {
        &self.child.metrics
    }

    /// Paged-KV bytes currently held by the (parent, child) engines —
    /// both must return to zero between requests (exact rollback).
    pub fn kv_allocated_bytes(&self) -> (usize, usize) {
        (self.parent.kv_allocated_bytes(), self.child.kv_allocated_bytes())
    }

    /// Generate up to `max_new` tokens speculatively. Greedy sampling is
    /// byte-identical to plain greedy decoding on the parent engine;
    /// stochastic sampling draws from exactly the parent's modified
    /// distribution (rejection-sampling correctness), reproducible per
    /// seed though not draw-for-draw identical to the plain engine.
    pub fn generate(&mut self, prompt: &[u32], max_new: usize, sampling: SamplingParams) -> Result<SpecResponse> {
        if max_new == 0 {
            return Err(anyhow!("max_new == 0: nothing to generate"));
        }
        let rollbacks_before =
            self.parent.metrics.spec_rollbacks + self.child.metrics.spec_rollbacks;
        let (pid, first_logits) = self.parent.spec_open(prompt)?;
        let cid = match self.child.spec_open(prompt) {
            Ok((cid, _)) => cid,
            Err(e) => {
                self.parent.spec_close(pid);
                return Err(e);
            }
        };
        let res = self.run_rounds(pid, cid, prompt, &first_logits, max_new, sampling);
        self.parent.spec_close(pid);
        self.child.spec_close(cid);
        let mut resp = res?;
        resp.rollbacks =
            self.parent.metrics.spec_rollbacks + self.child.metrics.spec_rollbacks - rollbacks_before;
        self.parent.metrics.draft_proposed += resp.proposed;
        self.parent.metrics.draft_accepted += resp.accepted;
        self.parent.metrics.spec_passes += resp.parent_passes.saturating_sub(1);
        self.parent.metrics.generated_tokens += resp.tokens.len();
        self.parent.metrics.record_finish(resp.finish);
        self.parent.metrics.requests_completed += 1;
        Ok(resp)
    }

    fn run_rounds(
        &mut self,
        pid: u64,
        cid: u64,
        prompt: &[u32],
        first_logits: &[f32],
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<SpecResponse> {
        let greedy = sampling.is_greedy();
        let s_max = self.parent.cache_horizon();
        let k = self.cfg.draft_k;
        // two private streams: accept/bonus draws must be independent of
        // draft draws, or the rejection test would correlate with the
        // proposal and bias the output law
        let mut accept_rng = Rng::new(sampling.seed);
        let mut draft_rng = Rng::new(sampling.seed ^ 0x5bec_dec0);
        let mut committed: Vec<u32> = prompt.to_vec();
        let mut out: Vec<u32> = Vec::new();
        let mut resp = SpecResponse {
            tokens: vec![],
            finish: FinishReason::MaxNew,
            parent_passes: 1,
            proposed: 0,
            accepted: 0,
            attempted: 0,
            rollbacks: 0,
        };
        // token 1 comes from the parent prefill itself — the same sample
        // the plain engine takes at admission
        let t0 = sample(first_logits, &sampling, &mut accept_rng) as u32;
        out.push(t0);
        committed.push(t0);
        if t0 == EOS {
            resp.finish = FinishReason::Eos;
            resp.tokens = out;
            return Ok(resp);
        }
        'rounds: while out.len() < max_new {
            if committed.len() >= s_max {
                // only reachable when the prompt itself fills the horizon
                // minus one: the plain engine finishes CacheHorizon right
                // after its first sample too (at prefill, or on the first
                // decode step of a chunked prompt)
                resp.finish = FinishReason::CacheHorizon;
                break;
            }
            // cap the draft so a full acceptance (k_eff + 1 tokens) never
            // overshoots max_new, and the committed stream never exceeds
            // the plain engine's CacheHorizon point (committed == s_max):
            // this is what keeps horizon-reaching prompts byte-identical
            let k_eff = k.min(max_new - out.len() - 1).min(s_max - committed.len() - 1);
            // --- draft: child catches up to the committed stream, then
            // proposes, recording each position's q ---
            let mut drafts: Vec<u32> = Vec::new();
            let mut qdists: Vec<Vec<(usize, f64)>> = Vec::new();
            if k_eff > 0 {
                let cl = self.child.spec_len(cid)?;
                let missing = &committed[cl..];
                let mut row = self
                    .child
                    .spec_extend(cid, missing, missing.len() - 1)?
                    .pop()
                    .ok_or_else(|| anyhow!("child catch-up produced no logits"))?;
                loop {
                    let q = dist(&row, &sampling);
                    let d = draw(&q, &mut draft_rng) as u32;
                    drafts.push(d);
                    qdists.push(q);
                    if drafts.len() == k_eff || d == EOS {
                        break;
                    }
                    row = self
                        .child
                        .spec_extend(cid, &[d], 0)?
                        .pop()
                        .ok_or_else(|| anyhow!("child draft step produced no logits"))?;
                }
            }
            let kd = drafts.len();
            // --- verify: ONE parent pass over the newest committed token
            // plus all drafts, kd + 1 logit rows out ---
            let mut feed: Vec<u32> = Vec::with_capacity(kd + 1);
            feed.push(*committed.last().unwrap());
            feed.extend_from_slice(&drafts);
            let rows = self.parent.spec_extend(pid, &feed, 0)?;
            resp.parent_passes += 1;
            resp.proposed += kd;
            // --- accept: longest surviving prefix + the parent's token ---
            let mut a = 0usize;
            let mut bonus_dist: Option<Vec<(usize, f64)>> = None;
            for i in 0..kd {
                resp.attempted += 1;
                let p = dist(&rows[i], &sampling);
                let ok = if greedy {
                    p[0].0 == drafts[i] as usize
                } else {
                    accept::accept(&p, &qdists[i], drafts[i] as usize, &mut accept_rng)
                };
                if !ok {
                    bonus_dist = Some(if greedy { p } else { accept::residual(&p, &qdists[i]) });
                    break;
                }
                a += 1;
            }
            resp.accepted += a;
            let bonus_dist = bonus_dist.unwrap_or_else(|| dist(&rows[kd], &sampling));
            let bonus = draw(&bonus_dist, &mut accept_rng) as u32;
            // --- commit: accepted drafts, then the parent's own token ---
            for &d in drafts.iter().take(a) {
                out.push(d);
                committed.push(d);
                if d == EOS {
                    resp.finish = FinishReason::Eos;
                    self.rollback(pid, cid, committed.len())?;
                    break 'rounds;
                }
            }
            out.push(bonus);
            committed.push(bonus);
            // same precedence as the plain engine's decode_step
            let done = if bonus == EOS {
                Some(FinishReason::Eos)
            } else if out.len() >= max_new {
                Some(FinishReason::MaxNew)
            } else if committed.len() >= s_max {
                Some(FinishReason::CacheHorizon)
            } else {
                None
            };
            // --- rollback: rejected drafts hand their pages back ---
            self.rollback(pid, cid, committed.len())?;
            if let Some(f) = done {
                resp.finish = f;
                break;
            }
        }
        resp.tokens = out;
        Ok(resp)
    }

    /// Restore both engines to the inter-round invariant: each holds KV
    /// for every committed token except the newest (which the next pass
    /// feeds). Frees the trailing pages of rejected drafts exactly.
    fn rollback(&mut self, pid: u64, cid: u64, committed_len: usize) -> Result<()> {
        let target = committed_len - 1;
        self.parent.spec_truncate(pid, target)?;
        if self.child.spec_len(cid)? > target {
            self.child.spec_truncate(cid, target)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TinyManifest;
    use crate::runtime::{share, RefBackend};
    use crate::weights::store::init_parent;

    #[test]
    fn draft_k_zero_is_rejected() {
        let be = share(RefBackend::new(TinyManifest::synthetic()));
        let mut rng = Rng::new(1);
        let store = init_parent(be.man(), &mut rng);
        let arch = Arch::parent(be.man().cfg.n_layers);
        let cfg = SpecConfig { draft_k: 0, ..Default::default() };
        assert!(SpecSession::new(be, &store, &arch, &store, &arch, cfg).is_err());
    }

    #[test]
    fn max_new_zero_is_rejected() {
        let be = share(RefBackend::new(TinyManifest::synthetic()));
        let mut rng = Rng::new(2);
        let store = init_parent(be.man(), &mut rng);
        let arch = Arch::parent(be.man().cfg.n_layers);
        let mut sess =
            SpecSession::new(be, &store, &arch, &store, &arch, SpecConfig::default()).unwrap();
        assert!(sess.generate(&[1, 2, 3], 0, SamplingParams::greedy()).is_err());
        // the failed request must not leak lanes: a real one still works
        let r = sess.generate(&[1, 2, 3], 4, SamplingParams::greedy()).unwrap();
        assert!(!r.tokens.is_empty());
    }
}
