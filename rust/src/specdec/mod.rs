//! Speculative decoding: the Puzzle child drafts, the parent verifies.
//!
//! Puzzle's output is a child retaining ~98% of the parent's behavior at
//! a fraction of the cost — a near-ideal *draft model* for speculative
//! decoding of its own parent, which turns the NAS result into a
//! serving-time speedup rather than only a standalone model. The loop
//! (per round and per sequence, DESIGN.md §5/§6):
//!
//! 1. **draft** — the child engine proposes up to `draft_k` tokens from
//!    its own state, one cheap decode step each, recording the modified
//!    distribution `q` it drew every token from;
//! 2. **verify** — the parent engine runs ONE teacher-forced multi-token
//!    pass (`Engine::spec_extend_batch`) over the newest committed token
//!    plus all drafts, yielding the parent distribution `p` at every
//!    position;
//! 3. **accept** — the longest draft prefix survives: exact argmax match
//!    under greedy (making greedy speculative output byte-identical to
//!    plain parent decoding), `min(1, p/q)` rejection sampling under
//!    stochastic `SamplingParams` (making the output law exactly `p`);
//!    the pass always nets one parent-sampled token (bonus on full
//!    acceptance, residual-corrected token on rejection);
//! 4. **rollback** — both engines rewind to the committed stream
//!    (`Engine::spec_truncate` -> `PagedKvManager::truncate`), handing
//!    the rejected drafts' KV pages straight back to the pool.
//!
//! `batch::SpecBatch` drives N such sequences concurrently over the
//! engines' shared decode lanes (one fused verify forward serves the
//! whole batch); `SpecSession` is its single-sequence convenience. The
//! batch also exposes an incremental `submit`/`tick`/`take_finished`
//! surface — one speculative round per tick, with per-token
//! `StreamEvent`s — which the workload replay harness drives alongside
//! plain engines for latency scoring.
//! `speedup` holds the analytic model (expected tokens/pass over α and
//! k, roofline-costed) that ranks candidate children by *draft value* —
//! the bridge from the MIP/NAS stage to serving throughput — plus the
//! score-table α estimator and the online `draft_k` tuner.

pub mod accept;
pub mod batch;
pub mod speedup;

use anyhow::{anyhow, Result};

use crate::arch::Arch;
use crate::runtime::SharedBackend;
use crate::serving::{EngineConfig, EngineMetrics, FinishReason, SamplingParams};
use crate::weights::Store;

pub use batch::{SpecBatch, SpecRequest};
pub use speedup::{
    estimate_alpha, expected_tokens_per_pass, rank_drafters, rank_drafters_estimated, KTuner,
    SpecModel,
};

/// Session/batch construction parameters.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    /// Draft tokens proposed per round (>= 1): the pin when `adapt_k_max`
    /// is `None`, the starting point otherwise.
    pub draft_k: usize,
    /// Online `draft_k` tuning: `Some(k_max)` re-tunes the draft length
    /// every round to `SpecModel::best_k` at the running acceptance rate
    /// (capped at `k_max`); `None` pins `draft_k`. Adaptation only gates
    /// wall-clock — the greedy byte-equivalence invariant is unaffected.
    /// The tuner costs rounds on the paper's deployment roofline
    /// (`HwProfile::h100_fp8`), a *proxy* when serving on other hardware
    /// (notably the CPU reference backend): the measured α̂ is real, the
    /// draft/verify cost ratio is modeled.
    pub adapt_k_max: Option<usize>,
    /// Engine construction for BOTH engines (KV budget, page length).
    pub engine: EngineConfig,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { draft_k: 4, adapt_k_max: None, engine: EngineConfig::default() }
    }
}

/// One speculative generation result, with the counters the speedup
/// model is validated against.
#[derive(Debug, Clone)]
pub struct SpecResponse {
    /// Generated tokens (prompt excluded), in order.
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub finish: FinishReason,
    /// Parent forwards attributed to this sequence: 1 prefill + one per
    /// verify pass (a fused batched pass counts once per participant).
    pub parent_passes: usize,
    /// Draft tokens proposed by the child.
    pub proposed: usize,
    /// Draft tokens accepted by verification.
    pub accepted: usize,
    /// Acceptance trials actually reached (a pass stops verifying at its
    /// first rejection) — the α̂ denominator consistent with the
    /// geometric model of `speedup::expected_tokens_per_pass`.
    pub attempted: usize,
    /// KV rollbacks across both engines.
    pub rollbacks: usize,
}

impl SpecResponse {
    /// Amortized generated tokens per parent forward — the speculative
    /// headline: > 1 means the parent ran fewer times than tokens out.
    pub fn tokens_per_pass(&self) -> f64 {
        if self.parent_passes == 0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.parent_passes as f64
        }
    }

    /// Tokens per *verify* pass, prefill excluded — directly comparable
    /// to `expected_tokens_per_pass(acceptance_rate(), draft_k)`.
    pub fn tokens_per_verify_pass(&self) -> f64 {
        if self.parent_passes <= 1 {
            0.0
        } else {
            (self.tokens.len() - 1) as f64 / (self.parent_passes - 1) as f64
        }
    }

    /// Per-attempt acceptance rate estimate α̂.
    pub fn acceptance_rate(&self) -> f64 {
        if self.attempted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.attempted as f64
        }
    }
}

/// A single-sequence draft/verify session — the convenience wrapper over
/// `SpecBatch` for callers generating one stream at a time. The parent
/// engine holds the verified truth, the child speculates ahead; both
/// keep their own KV caches and page accounting, and between rounds each
/// holds exactly the committed stream minus its newest token in cache.
pub struct SpecSession {
    batch: SpecBatch,
}

impl SpecSession {
    /// Build the parent and child engines over one shared backend.
    /// `cfg.draft_k == 0` is rejected.
    pub fn new(
        be: SharedBackend,
        parent_store: &Store,
        parent_arch: &Arch,
        child_store: &Store,
        child_arch: &Arch,
        cfg: SpecConfig,
    ) -> Result<SpecSession> {
        Ok(SpecSession {
            batch: SpecBatch::new(be, parent_store, parent_arch, child_store, child_arch, cfg)?,
        })
    }

    /// The session's configuration.
    pub fn cfg(&self) -> &SpecConfig {
        &self.batch.cfg
    }

    /// The parent engine's metrics: generation counters plus the
    /// speculative section (draft_proposed/accepted, passes, rollbacks).
    pub fn parent_metrics(&self) -> &EngineMetrics {
        self.batch.parent_metrics()
    }

    /// The child (drafter) engine's metrics.
    pub fn child_metrics(&self) -> &EngineMetrics {
        self.batch.child_metrics()
    }

    /// Paged-KV bytes currently held by the (parent, child) engines —
    /// both must return to zero between requests (exact rollback).
    pub fn kv_allocated_bytes(&self) -> (usize, usize) {
        self.batch.kv_allocated_bytes()
    }

    /// Generate up to `max_new` tokens speculatively. Greedy sampling is
    /// byte-identical to plain greedy decoding on the parent engine;
    /// stochastic sampling draws from exactly the parent's modified
    /// distribution (rejection-sampling correctness), reproducible per
    /// seed though not draw-for-draw identical to the plain engine.
    pub fn generate(&mut self, prompt: &[u32], max_new: usize, sampling: SamplingParams) -> Result<SpecResponse> {
        let req = SpecRequest { prompt: prompt.to_vec(), max_new, sampling };
        let mut out = self.batch.generate_many(&[req])?;
        out.pop().ok_or_else(|| anyhow!("speculative batch returned no response"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TinyManifest;
    use crate::runtime::{share, RefBackend};
    use crate::util::Rng;
    use crate::weights::store::init_parent;

    #[test]
    fn draft_k_zero_is_rejected() {
        let be = share(RefBackend::new(TinyManifest::synthetic()));
        let mut rng = Rng::new(1);
        let store = init_parent(be.man(), &mut rng);
        let arch = Arch::parent(be.man().cfg.n_layers);
        let cfg = SpecConfig { draft_k: 0, ..Default::default() };
        assert!(SpecSession::new(be, &store, &arch, &store, &arch, cfg).is_err());
    }

    #[test]
    fn max_new_zero_is_rejected() {
        let be = share(RefBackend::new(TinyManifest::synthetic()));
        let mut rng = Rng::new(2);
        let store = init_parent(be.man(), &mut rng);
        let arch = Arch::parent(be.man().cfg.n_layers);
        let mut sess =
            SpecSession::new(be, &store, &arch, &store, &arch, SpecConfig::default()).unwrap();
        assert!(sess.generate(&[1, 2, 3], 0, SamplingParams::greedy()).is_err());
        // the failed request must not leak lanes: a real one still works
        let r = sess.generate(&[1, 2, 3], 4, SamplingParams::greedy()).unwrap();
        assert!(!r.tokens.is_empty());
        assert_eq!(sess.kv_allocated_bytes(), (0, 0));
    }
}
