//! Analytic speedup model for speculative decoding: expected tokens per
//! parent pass as a function of acceptance rate α and draft length k,
//! costed per block through the same roofline currency as the MIP's
//! `perf::CostTable`. This is what ties the NAS stage to serving
//! throughput: a good Puzzle child is precisely a *cheap architecture
//! with high α against its parent*, and `rank_drafters` scores candidate
//! children by that "draft value" instead of standalone quality alone.

use crate::arch::Arch;
use crate::config::Manifest;
use crate::perf::{arch_block_cost, BlockCost, HwProfile};

/// Expected tokens emitted per verify pass at per-position acceptance
/// rate `alpha` and draft length `k`, under the standard geometric model
/// (positions accept independently; the pass emits the accepted prefix
/// plus one parent token): E = (1 - α^{k+1}) / (1 - α), reaching k + 1
/// at α = 1.
pub fn expected_tokens_per_pass(alpha: f64, k: usize) -> f64 {
    let alpha = alpha.clamp(0.0, 1.0);
    if 1.0 - alpha < 1e-9 {
        return (k + 1) as f64;
    }
    (1.0 - alpha.powi(k as i32 + 1)) / (1.0 - alpha)
}

/// Roofline cost model of one speculative round versus plain parent
/// decoding: the child pays k sequential draft steps, the parent verifies
/// k + 1 positions in one fused multi-token pass.
#[derive(Debug, Clone)]
pub struct SpecModel {
    pub hw: HwProfile,
    /// mean decode context the model is evaluated at
    pub ctx: usize,
    parent: BlockCost,
    child: BlockCost,
}

impl SpecModel {
    pub fn new(man: &Manifest, parent: &Arch, child: &Arch, hw: &HwProfile, ctx: usize) -> SpecModel {
        SpecModel {
            hw: hw.clone(),
            ctx,
            parent: arch_block_cost(man, parent),
            child: arch_block_cost(man, child),
        }
    }

    /// One plain parent decode step — the baseline per-token cost (the
    /// same `BlockCost` roofline the MIP's `CostTable` is built on).
    pub fn parent_step_secs(&self) -> f64 {
        self.parent.decode_step_time(&self.hw, 1, self.ctx)
    }

    /// One child draft step.
    pub fn child_step_secs(&self) -> f64 {
        self.child.decode_step_time(&self.hw, 1, self.ctx)
    }

    /// The parent's fused verify pass over `m` teacher-forced tokens —
    /// the amortization speculative decoding banks on.
    pub fn verify_pass_secs(&self, m: usize) -> f64 {
        self.parent.multi_token_pass_time(&self.hw, m, self.ctx)
    }

    /// Modeled wall-clock speedup of speculative decoding over plain
    /// parent decoding at acceptance rate `alpha` and draft length `k`:
    /// tokens-per-round / round-cost, normalized by the baseline rate.
    pub fn speedup(&self, alpha: f64, k: usize) -> f64 {
        let e = expected_tokens_per_pass(alpha, k);
        let round = self.child_step_secs() * k as f64 + self.verify_pass_secs(k + 1);
        e * self.parent_step_secs() / round
    }

    /// The draft length maximizing modeled speedup in `1..=k_max`.
    pub fn best_k(&self, alpha: f64, k_max: usize) -> (usize, f64) {
        (1..=k_max.max(1))
            .map(|k| (k, self.speedup(alpha, k)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap()
    }
}

/// Rank candidate drafter architectures by modeled speedup at draft
/// length `k`. Each candidate carries its (estimated or measured)
/// acceptance rate α against the parent. Returns `(candidate index,
/// modeled speedup)` sorted best-first — the NAS-to-serving bridge: run
/// it over the MIP's solution slices to pick the child worth deploying
/// as the parent's drafter.
pub fn rank_drafters(
    man: &Manifest,
    parent: &Arch,
    candidates: &[(Arch, f64)],
    hw: &HwProfile,
    ctx: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    let mut ranked: Vec<(usize, f64)> = candidates
        .iter()
        .enumerate()
        .map(|(i, (child, alpha))| (i, SpecModel::new(man, parent, child, hw, ctx).speedup(*alpha, k)))
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{AttnChoice, FfnChoice};
    use crate::config::ModelCfg;

    /// Llama-70B-scale shape descriptors (no weights are allocated): the
    /// tiny CI manifest is launch-overhead-dominated on the roofline,
    /// which would hide exactly the amortization effects this model is
    /// about, so the model tests run at the paper's deployment scale.
    fn paper_scale() -> Manifest {
        Manifest::synthetic(ModelCfg {
            name: "llama70b-ish".into(),
            d: 8192,
            n_layers: 80,
            n_heads: 64,
            head_dim: 128,
            i: 28672,
            v: 128256,
            s_train: 8,
            b_train: 1,
            s_prefill: 2048,
            b_decode: 1,
            s_max: 4096,
            s_long: 4096,
            rope_theta: 10000.0,
            eps: 1e-5,
        })
    }

    #[test]
    fn expected_tokens_limits_and_monotonicity() {
        // α = 0: only the parent's own token survives each pass
        assert_eq!(expected_tokens_per_pass(0.0, 4), 1.0);
        // α = 1: full draft plus the bonus token
        assert_eq!(expected_tokens_per_pass(1.0, 4), 5.0);
        // monotone in α and in k
        assert!(expected_tokens_per_pass(0.8, 4) > expected_tokens_per_pass(0.5, 4));
        assert!(expected_tokens_per_pass(0.8, 8) > expected_tokens_per_pass(0.8, 4));
        // geometric identity at α = 1/2, k = 2: 1 + 1/2 + 1/4
        assert!((expected_tokens_per_pass(0.5, 2) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn cheap_child_with_high_alpha_speeds_up() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut child = parent.clone();
        for l in 0..n {
            child.layers[l] = (AttnChoice::Gqa { divisor: 4 }, FfnChoice::Ratio(5));
        }
        let hw = HwProfile::h100_fp8();
        let m = SpecModel::new(&man, &parent, &child, &hw, 512);
        assert!(m.child_step_secs() < m.parent_step_secs(), "child must be cheaper");
        // decode is bandwidth-bound: a fused k+1-token pass is far cheaper
        // than k+1 separate steps
        assert!(m.verify_pass_secs(5) < 5.0 * m.parent_step_secs());
        let s = m.speedup(0.9, 4);
        assert!(s > 1.0, "high-α cheap drafter must be a modeled win, got {s:.3}");
        // a drafter that is never right cannot win
        assert!(m.speedup(0.0, 4) < 1.0);
    }

    #[test]
    fn best_k_grows_with_alpha() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut child = parent.clone();
        for l in 0..n {
            child.layers[l] = (AttnChoice::Linear, FfnChoice::Ratio(6));
        }
        let hw = HwProfile::h100_fp8();
        let m = SpecModel::new(&man, &parent, &child, &hw, 512);
        let (k_lo, _) = m.best_k(0.3, 16);
        let (k_hi, _) = m.best_k(0.95, 16);
        assert!(k_hi >= k_lo, "higher acceptance sustains longer drafts ({k_lo} vs {k_hi})");
    }

    #[test]
    fn rank_drafters_prefers_cheaper_at_equal_alpha() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut cheap = parent.clone();
        for l in 0..n {
            cheap.layers[l] = (AttnChoice::Gqa { divisor: 4 }, FfnChoice::Ratio(6));
        }
        let expensive = parent.clone();
        let hw = HwProfile::h100_fp8();
        let ranked = rank_drafters(&man, &parent, &[(expensive, 0.8), (cheap, 0.8)], &hw, 512, 4);
        assert_eq!(ranked[0].0, 1, "same α: the cheaper drafter must rank first");
        // and a much better α can outweigh a cost disadvantage
        let mut mid = parent.clone();
        mid.layers[0] = (AttnChoice::Gqa { divisor: 2 }, FfnChoice::Ratio(2));
        let ranked = rank_drafters(&man, &parent, &[(mid, 0.95), (Arch::parent(n), 0.1)], &hw, 512, 4);
        assert_eq!(ranked[0].0, 0);
    }
}
