//! Analytic speedup model for speculative decoding: expected tokens per
//! parent pass as a function of acceptance rate α and draft length k,
//! costed per block through the same roofline currency as the MIP's
//! `perf::CostTable`. This is what ties the NAS stage to serving
//! throughput: a good Puzzle child is precisely a *cheap architecture
//! with high α against its parent*, and `rank_drafters` scores candidate
//! children by that "draft value" instead of standalone quality alone.
//! `estimate_alpha` predicts a candidate's α straight from the
//! replace-1-block score table (no speculative run needed), and `KTuner`
//! closes the loop at serving time by re-tuning the draft length to the
//! *measured* acceptance rate.

use crate::arch::Arch;
use crate::config::Manifest;
use crate::perf::{arch_block_cost, BlockCost, HwProfile};
use crate::scoring::ScoreTable;

/// Expected tokens emitted per verify pass at per-position acceptance
/// rate `alpha` and draft length `k`, under the standard geometric model
/// (positions accept independently; the pass emits the accepted prefix
/// plus one parent token): E = (1 - α^{k+1}) / (1 - α), reaching k + 1
/// at α = 1.
///
/// ```
/// use puzzle::specdec::expected_tokens_per_pass;
/// // a drafter that is never right still nets the parent's own token...
/// assert_eq!(expected_tokens_per_pass(0.0, 4), 1.0);
/// // ...a perfect drafter nets the full draft plus the bonus token...
/// assert_eq!(expected_tokens_per_pass(1.0, 4), 5.0);
/// // ...and at α = 1/2, k = 2 the geometric sum is 1 + 1/2 + 1/4
/// assert!((expected_tokens_per_pass(0.5, 2) - 1.75).abs() < 1e-12);
/// ```
pub fn expected_tokens_per_pass(alpha: f64, k: usize) -> f64 {
    let alpha = alpha.clamp(0.0, 1.0);
    if 1.0 - alpha < 1e-9 {
        return (k + 1) as f64;
    }
    (1.0 - alpha.powi(k as i32 + 1)) / (1.0 - alpha)
}

/// Roofline cost model of one speculative round versus plain parent
/// decoding: the child pays k sequential draft steps, the parent verifies
/// k + 1 positions in one fused multi-token pass.
#[derive(Debug, Clone)]
pub struct SpecModel {
    /// Hardware profile the round is costed against.
    pub hw: HwProfile,
    /// mean decode context the model is evaluated at
    pub ctx: usize,
    parent: BlockCost,
    child: BlockCost,
}

impl SpecModel {
    /// A model of `child` drafting for `parent` on `hw` at context `ctx`.
    pub fn new(man: &Manifest, parent: &Arch, child: &Arch, hw: &HwProfile, ctx: usize) -> SpecModel {
        SpecModel {
            hw: hw.clone(),
            ctx,
            parent: arch_block_cost(man, parent),
            child: arch_block_cost(man, child),
        }
    }

    /// One plain parent decode step — the baseline per-token cost (the
    /// same `BlockCost` roofline the MIP's `CostTable` is built on).
    pub fn parent_step_secs(&self) -> f64 {
        self.parent.decode_step_time(&self.hw, 1, self.ctx)
    }

    /// One child draft step.
    pub fn child_step_secs(&self) -> f64 {
        self.child.decode_step_time(&self.hw, 1, self.ctx)
    }

    /// The parent's fused verify pass over `m` teacher-forced tokens —
    /// the amortization speculative decoding banks on.
    pub fn verify_pass_secs(&self, m: usize) -> f64 {
        self.parent.multi_token_pass_time(&self.hw, m, self.ctx)
    }

    /// Modeled wall-clock speedup of speculative decoding over plain
    /// parent decoding at acceptance rate `alpha` and draft length `k`:
    /// tokens-per-round / round-cost, normalized by the baseline rate.
    pub fn speedup(&self, alpha: f64, k: usize) -> f64 {
        let e = expected_tokens_per_pass(alpha, k);
        let round = self.child_step_secs() * k as f64 + self.verify_pass_secs(k + 1);
        e * self.parent_step_secs() / round
    }

    /// The draft length maximizing modeled speedup in `1..=k_max`.
    pub fn best_k(&self, alpha: f64, k_max: usize) -> (usize, f64) {
        (1..=k_max.max(1))
            .map(|k| (k, self.speedup(alpha, k)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap()
    }
}

/// Rank candidate drafter architectures by modeled speedup at draft
/// length `k`. Each candidate carries its (estimated or measured)
/// acceptance rate α against the parent. Returns `(candidate index,
/// modeled speedup)` sorted best-first — the NAS-to-serving bridge: run
/// it over the MIP's solution slices to pick the child worth deploying
/// as the parent's drafter.
pub fn rank_drafters(
    man: &Manifest,
    parent: &Arch,
    candidates: &[(Arch, f64)],
    hw: &HwProfile,
    ctx: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    let mut ranked: Vec<(usize, f64)> = candidates
        .iter()
        .enumerate()
        .map(|(i, (child, alpha))| (i, SpecModel::new(man, parent, child, hw, ctx).speedup(*alpha, k)))
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    ranked
}

/// Estimate a candidate drafter's per-position acceptance rate α from
/// the replace-1-block score table, with no speculative run.
///
/// Derivation. Under greedy-free speculative sampling the acceptance
/// probability at one position is exactly the distributions' overlap,
/// `α = Σ_x min(p(x), q(x)) = 1 − TV(p, q)` (Leviathan et al.). The
/// score table measures each block substitution's KL divergence to the
/// parent on held-out data, and the decomposed-NAS assumption the whole
/// search rests on (paper §4.2) is that these penalties add, so
/// `KL(p‖q) ≈ ScoreTable::arch_cost(child)` — the same additive
/// surrogate the MIP maximizes quality with. The Bretagnolle–Huber
/// inequality then bounds total variation by
/// `TV(p, q) ≤ sqrt(1 − exp(−KL))`, giving
///
/// `α̂ = 1 − sqrt(1 − exp(−KL))`.
///
/// B–H is preferred over Pinsker (`TV ≤ sqrt(KL/2)`) because it stays
/// informative at large KL: α̂ decays smoothly to 0 instead of going
/// negative beyond KL = 2. The estimate is exact at KL = 0 (the parent
/// drafting for itself accepts everything) and monotone decreasing in
/// the table cost, which is all `rank_drafters` needs to order
/// candidates; it is a lower bound in expectation, so modeled speedups
/// fed from it are conservative.
///
/// ```
/// use puzzle::arch::Arch;
/// use puzzle::scoring::ScoreTable;
/// use puzzle::specdec::estimate_alpha;
/// // the parent scores 0 everywhere: it drafts for itself with α = 1
/// let table = ScoreTable::default();
/// assert_eq!(estimate_alpha(&table, &Arch::parent(3)), 1.0);
/// ```
pub fn estimate_alpha(table: &ScoreTable, child: &Arch) -> f64 {
    let kl = table.arch_cost(child).max(0.0);
    1.0 - (1.0 - (-kl).exp()).max(0.0).sqrt()
}

/// `rank_drafters` with every candidate's α *predicted* from the score
/// table (`estimate_alpha`) instead of measured — draft value becomes a
/// pure function of the NAS artifacts, so the MIP's solution slices can
/// be ranked for deployment before any child is ever run speculatively.
pub fn rank_drafters_estimated(
    man: &Manifest,
    parent: &Arch,
    candidates: &[Arch],
    table: &ScoreTable,
    hw: &HwProfile,
    ctx: usize,
    k: usize,
) -> Vec<(usize, f64)> {
    let scored: Vec<(Arch, f64)> =
        candidates.iter().map(|c| (c.clone(), estimate_alpha(table, c))).collect();
    rank_drafters(man, parent, &scored, hw, ctx, k)
}

/// Minimum (decayed) verified positions before the tuner trusts its α̂
/// and starts re-tuning the draft length.
const KTUNER_WARMUP: f64 = 16.0;

/// Per-round decay of the acceptance counters: recent rounds dominate
/// α̂ (effective window ≈ 1/(1 − decay) rounds), so a mid-stream
/// acceptance collapse moves the estimate within a few rounds instead of
/// being averaged away by a long history.
const KTUNER_DECAY: f64 = 0.9;

/// Online draft-length controller: accumulates the measured acceptance
/// counts round by round under an exponential decay and, once past a
/// short warmup, re-tunes the draft length to `SpecModel::best_k` at the
/// windowed α̂ — so a drafter whose acceptance collapses mid-stream
/// stops paying for long drafts within a few rounds, and a hot one
/// stretches toward `k_max`. Changing k between rounds only gates
/// wall-clock: the byte-equivalence invariant is per position, not per
/// draft length.
#[derive(Debug, Clone)]
pub struct KTuner {
    model: SpecModel,
    k_max: usize,
    k: usize,
    accepted: f64,
    attempted: f64,
    warm: bool,
}

impl KTuner {
    /// Start at `k0` (clamped to `1..=k_max`), tuning over `model`.
    pub fn new(model: SpecModel, k0: usize, k_max: usize) -> KTuner {
        let k_max = k_max.max(1);
        KTuner { model, k_max, k: k0.clamp(1, k_max), accepted: 0.0, attempted: 0.0, warm: false }
    }

    /// Fold one round's acceptance counts in (decaying the history) and
    /// re-tune once warm.
    pub fn observe(&mut self, accepted: usize, attempted: usize) {
        self.accepted = self.accepted * KTUNER_DECAY + accepted as f64;
        self.attempted = self.attempted * KTUNER_DECAY + attempted as f64;
        // warmth latches: once enough positions have been verified the
        // tuner keeps re-tuning even if an adapted-down k makes single
        // rounds small (k could otherwise get stuck at 1 forever)
        self.warm = self.warm || self.attempted >= KTUNER_WARMUP;
        if self.warm {
            self.k = self.model.best_k(self.alpha_hat(), self.k_max).0;
        }
    }

    /// Decay-windowed per-attempt acceptance rate (0.0 before any
    /// observation).
    pub fn alpha_hat(&self) -> f64 {
        if self.attempted <= 0.0 {
            0.0
        } else {
            self.accepted / self.attempted
        }
    }

    /// The draft length the next round should use.
    pub fn k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{AttnChoice, FfnChoice};
    use crate::config::ModelCfg;

    /// Llama-70B-scale shape descriptors (no weights are allocated): the
    /// tiny CI manifest is launch-overhead-dominated on the roofline,
    /// which would hide exactly the amortization effects this model is
    /// about, so the model tests run at the paper's deployment scale.
    fn paper_scale() -> Manifest {
        Manifest::synthetic(ModelCfg {
            name: "llama70b-ish".into(),
            d: 8192,
            n_layers: 80,
            n_heads: 64,
            head_dim: 128,
            i: 28672,
            v: 128256,
            s_train: 8,
            b_train: 1,
            s_prefill: 2048,
            b_decode: 1,
            s_max: 4096,
            s_long: 4096,
            rope_theta: 10000.0,
            eps: 1e-5,
        })
    }

    #[test]
    fn expected_tokens_limits_and_monotonicity() {
        // α = 0: only the parent's own token survives each pass
        assert_eq!(expected_tokens_per_pass(0.0, 4), 1.0);
        // α = 1: full draft plus the bonus token
        assert_eq!(expected_tokens_per_pass(1.0, 4), 5.0);
        // monotone in α and in k
        assert!(expected_tokens_per_pass(0.8, 4) > expected_tokens_per_pass(0.5, 4));
        assert!(expected_tokens_per_pass(0.8, 8) > expected_tokens_per_pass(0.8, 4));
        // geometric identity at α = 1/2, k = 2: 1 + 1/2 + 1/4
        assert!((expected_tokens_per_pass(0.5, 2) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn cheap_child_with_high_alpha_speeds_up() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut child = parent.clone();
        for l in 0..n {
            child.layers[l] = (AttnChoice::Gqa { divisor: 4 }, FfnChoice::Ratio(5));
        }
        let hw = HwProfile::h100_fp8();
        let m = SpecModel::new(&man, &parent, &child, &hw, 512);
        assert!(m.child_step_secs() < m.parent_step_secs(), "child must be cheaper");
        // decode is bandwidth-bound: a fused k+1-token pass is far cheaper
        // than k+1 separate steps
        assert!(m.verify_pass_secs(5) < 5.0 * m.parent_step_secs());
        let s = m.speedup(0.9, 4);
        assert!(s > 1.0, "high-α cheap drafter must be a modeled win, got {s:.3}");
        // a drafter that is never right cannot win
        assert!(m.speedup(0.0, 4) < 1.0);
    }

    #[test]
    fn best_k_grows_with_alpha() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut child = parent.clone();
        for l in 0..n {
            child.layers[l] = (AttnChoice::Linear, FfnChoice::Ratio(6));
        }
        let hw = HwProfile::h100_fp8();
        let m = SpecModel::new(&man, &parent, &child, &hw, 512);
        let (k_lo, _) = m.best_k(0.3, 16);
        let (k_hi, _) = m.best_k(0.95, 16);
        assert!(k_hi >= k_lo, "higher acceptance sustains longer drafts ({k_lo} vs {k_hi})");
    }

    #[test]
    fn alpha_estimator_tracks_the_score_table() {
        let n = 4usize;
        let parent = Arch::parent(n);
        let mut table = ScoreTable { metric_name: "kl".into(), ..Default::default() };
        for l in 0..n {
            table.set(l, "attn", "gqa_r4", 0.05);
            table.set(l, "attn", "linear", 1.5);
            table.set(l, "ffn", "r25", 0.1);
        }
        // parent blocks score 0 by construction: α̂ is exactly 1
        assert_eq!(estimate_alpha(&table, &parent), 1.0);
        // a light substitution keeps α̂ high...
        let mut light = parent.clone();
        light.layers[0].0 = AttnChoice::Gqa { divisor: 4 };
        let a_light = estimate_alpha(&table, &light);
        assert!(a_light > 0.7, "light child must keep a high α̂, got {a_light:.3}");
        // ...heavier substitution strictly lowers it, and α̂ stays in [0, 1]
        let mut heavy = light.clone();
        for l in 0..n {
            heavy.layers[l].0 = AttnChoice::Linear;
        }
        let a_heavy = estimate_alpha(&table, &heavy);
        assert!(a_heavy < a_light, "more KL must mean less acceptance");
        assert!((0.0..=1.0).contains(&a_heavy) && (0.0..=1.0).contains(&a_light));
    }

    #[test]
    fn estimated_ranking_prefers_the_low_kl_drafter_at_equal_cost() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        // two children with identical compute cost but different scores
        let mut good = parent.clone();
        let mut bad = parent.clone();
        for l in 0..n {
            good.layers[l].0 = AttnChoice::Gqa { divisor: 4 };
            bad.layers[l].0 = AttnChoice::Gqa { divisor: 4 };
        }
        let mut table = ScoreTable { metric_name: "kl".into(), ..Default::default() };
        for l in 0..n {
            table.set(l, "attn", "gqa_r4", 0.001);
        }
        // `bad` additionally swaps in FFNs the table scores terribly
        for l in 0..n {
            bad.layers[l].1 = FfnChoice::Ratio(6); // "r10"
            table.set(l, "ffn", "r10", 2.0);
        }
        let hw = HwProfile::h100_fp8();
        let ranked =
            rank_drafters_estimated(&man, &parent, &[bad.clone(), good.clone()], &table, &hw, 512, 4);
        assert_eq!(ranked.len(), 2);
        // `bad` is CHEAPER (smaller FFN) yet its predicted α is so low the
        // well-matched child must still win the draft-value ranking
        assert_eq!(ranked[0].0, 1, "score-table α must drive the ranking");
    }

    #[test]
    fn ktuner_adapts_k_downward_when_alpha_collapses() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut child = parent.clone();
        for l in 0..n {
            child.layers[l] = (AttnChoice::Linear, FfnChoice::Ratio(6));
        }
        let hw = HwProfile::h100_fp8();
        let model = SpecModel::new(&man, &parent, &child, &hw, 512);
        let k0 = 6usize;
        // a hot drafter holds (or stretches) the draft length
        let mut hot = KTuner::new(model.clone(), k0, 12);
        assert_eq!(hot.k(), k0, "the pin holds until warmup");
        for _ in 0..8 {
            hot.observe(6, 6);
        }
        assert!(hot.alpha_hat() > 0.99);
        assert!(hot.k() >= k0, "near-perfect acceptance must sustain long drafts");
        // a MID-STREAM collapse re-tunes within a few rounds: the decayed
        // window keeps the long hot history from averaging it away
        for _ in 0..12 {
            hot.observe(0, 6);
        }
        assert!(hot.alpha_hat() < 0.5, "the window must forget the hot past");
        assert!(hot.k() < k0, "collapse must shorten drafts, got {}", hot.k());
        // a drafter that is cold from the start is cut back hard
        let mut cold = KTuner::new(model, k0, 12);
        for _ in 0..8 {
            cold.observe(0, 6);
        }
        assert_eq!(cold.alpha_hat(), 0.0);
        assert!(cold.k() < k0, "collapsed acceptance must shorten drafts, got {}", cold.k());
        assert_eq!(cold.k(), 1, "at α = 0 every drafted token is wasted work");
    }

    #[test]
    fn rank_drafters_prefers_cheaper_at_equal_alpha() {
        let man = paper_scale();
        let n = man.cfg.n_layers;
        let parent = Arch::parent(n);
        let mut cheap = parent.clone();
        for l in 0..n {
            cheap.layers[l] = (AttnChoice::Gqa { divisor: 4 }, FfnChoice::Ratio(6));
        }
        let expensive = parent.clone();
        let hw = HwProfile::h100_fp8();
        let ranked = rank_drafters(&man, &parent, &[(expensive, 0.8), (cheap, 0.8)], &hw, 512, 4);
        assert_eq!(ranked[0].0, 1, "same α: the cheaper drafter must rank first");
        // and a much better α can outweigh a cost disadvantage
        let mut mid = parent.clone();
        mid.layers[0] = (AttnChoice::Gqa { divisor: 2 }, FfnChoice::Ratio(2));
        let ranked = rank_drafters(&man, &parent, &[(mid, 0.95), (Arch::parent(n), 0.1)], &hw, 512, 4);
        assert_eq!(ranked[0].0, 0);
    }
}
