//! Synthetic data substrate ("FactWorld").
//!
//! The paper's training/eval data (FineWeb/Dolma/Buzz "Distillation Mix",
//! Project Gutenberg, MMLU/MT-Bench/GSM8K/RULER) is closed or web-scale;
//! we substitute a deterministic synthetic language whose structure gives
//! every benchmark a measurable signal at laptop scale:
//!
//!  * a world of (entity, relation) -> value facts — knowledge benchmarks
//!    (SynthQA = MMLU proxy) test whether facts seen in pretraining are
//!    stored in the weights;
//!  * a Markov narrative process — perplexity/continuation benchmarks
//!    (ContScore = HellaSwag proxy);
//!  * digit arithmetic — SynthMath (GSM8K proxy);
//!  * an instruction form of the facts — GenScore (MT-Bench proxy) and the
//!    alignment-finetune experiment (Table 5);
//!  * long-context needle/variable-tracking/frequent-words tasks over
//!    narrative filler — RULER proxy (Table 4).
//!
//! Dataset-composition experiments (Table 9) contrast the full mix with a
//! narrative-only "Gutenberg" analog.

pub mod corpus;
pub mod world;

pub use corpus::{Batch, Batcher, CorpusMix, Domain};
pub use world::{Vocab, World};
