//! Domain sentence generators and corpus mixes ("Distillation Mix").

use super::world::{World, BOS, EOS, EQ, PLUS, QRY, SEP};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Synthetic corpus domains (the "Distillation Mix" components).
pub enum Domain {
    /// (entity, relation) -> value statements.
    Facts,
    /// Digit arithmetic.
    Math,
    /// Markov narrative filler.
    Narrative,
    /// Code-shaped token patterns.
    Code,
    /// Instruction-form facts.
    Instruct,
}

impl Domain {
    /// Domain name for reports and mix definitions.
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Facts => "facts",
            Domain::Math => "math",
            Domain::Narrative => "narrative",
            Domain::Code => "code",
            Domain::Instruct => "instruct",
        }
    }
}

/// A weighted mix of domains — the analog of the paper's dataset mixtures.
#[derive(Debug, Clone)]
pub struct CorpusMix {
    /// Mix name for reports.
    pub name: String,
    /// (domain, weight) pairs; weights need not sum to 1.
    pub domains: Vec<(Domain, f64)>,
}

impl CorpusMix {
    /// The paper's diverse "Distillation Mix" analog.
    pub fn distillation_mix() -> CorpusMix {
        CorpusMix {
            name: "distillation_mix".into(),
            domains: vec![
                (Domain::Facts, 0.32),
                (Domain::Math, 0.15),
                (Domain::Narrative, 0.28),
                (Domain::Code, 0.10),
                (Domain::Instruct, 0.15),
            ],
        }
    }

    /// Narrative-only mix — the "Project Gutenberg" analog (Table 9):
    /// literary text without STEM/conversational coverage.
    pub fn gutenberg() -> CorpusMix {
        CorpusMix { name: "gutenberg".into(), domains: vec![(Domain::Narrative, 1.0)] }
    }

    /// Instruction-only mix for the lightweight-alignment experiment
    /// (Table 5 analog).
    pub fn align_mix() -> CorpusMix {
        CorpusMix {
            name: "align_mix".into(),
            domains: vec![(Domain::Instruct, 0.8), (Domain::Facts, 0.2)],
        }
    }

    fn sample_domain(&self, rng: &mut Rng) -> Domain {
        let total: f64 = self.domains.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64() * total;
        for (d, w) in &self.domains {
            u -= w;
            if u <= 0.0 {
                return *d;
            }
        }
        self.domains.last().unwrap().0
    }
}

/// Append one sentence of `domain` to `out`.
pub fn gen_sentence(world: &World, domain: Domain, rng: &mut Rng, out: &mut Vec<u32>) {
    let v = &world.vocab;
    match domain {
        Domain::Facts => {
            let e = rng.below(v.n_entities as usize) as u32;
            let r = rng.below(v.n_relations as usize) as u32;
            out.extend_from_slice(&[v.entity(e), v.relation(r), SEP, world.fact_value(e, r), EOS]);
        }
        Domain::Instruct => {
            // question form of the same facts; answering these well is what
            // GenScore measures and what alignment finetuning improves.
            let e = rng.below(v.n_entities as usize) as u32;
            let r = rng.below(v.n_relations as usize) as u32;
            out.extend_from_slice(&[
                QRY,
                v.entity(e),
                v.relation(r),
                SEP,
                world.fact_value(e, r),
                EOS,
            ]);
        }
        Domain::Math => {
            let a = rng.below(10) as u32;
            let b = rng.below(10) as u32;
            let c = a + b;
            out.extend_from_slice(&[v.digit(a), PLUS, v.digit(b), EQ]);
            if c >= 10 {
                out.push(v.digit(c / 10));
            }
            out.push(v.digit(c % 10));
            out.push(EOS);
        }
        Domain::Narrative => {
            let len = rng.range(8, 24);
            let mut cur = v.filler(rng.below(v.n_filler() as usize) as u32);
            out.push(cur);
            for _ in 0..len {
                // mostly follow the world's Markov process; occasionally jump
                cur = if rng.f32() < 0.85 {
                    world.narrative_successor(cur, rng, 3)
                } else {
                    v.filler(rng.below(v.n_filler() as usize) as u32)
                };
                out.push(cur);
            }
            out.push(EOS);
        }
        Domain::Code => {
            // balanced-bracket sequences: filler tokens 0..8 act as 4
            // open/close pairs; models must learn the matching structure.
            let mut stack: Vec<u32> = Vec::new();
            let mut budget = rng.range(6, 20);
            while budget > 0 || !stack.is_empty() {
                let open = budget > 0 && (stack.len() < 4) && (stack.is_empty() || rng.f32() < 0.5);
                if open {
                    let pair = rng.below(4) as u32;
                    out.push(v.filler(pair * 2));
                    stack.push(pair);
                    budget -= 1;
                } else if let Some(pair) = stack.pop() {
                    out.push(v.filler(pair * 2 + 1));
                }
            }
            out.push(EOS);
        }
    }
}

/// A token sequence sampled from a mix: sentences concatenated after BOS.
pub fn sample_sequence(world: &World, mix: &CorpusMix, len: usize, rng: &mut Rng) -> Vec<u32> {
    let mut out = Vec::with_capacity(len + 32);
    out.push(BOS);
    while out.len() < len + 1 {
        let d = mix.sample_domain(rng);
        gen_sentence(world, d, rng, &mut out);
    }
    out.truncate(len + 1);
    out
}

/// A training batch: inputs [b, s] and next-token targets [b, s].
#[derive(Debug, Clone)]
pub struct Batch {
    /// Sequences per batch.
    pub b: usize,
    /// Tokens per sequence.
    pub s: usize,
    /// Token ids, row-major [b, s].
    pub inputs: Vec<i32>,
    /// Next-token targets, row-major [b, s].
    pub targets: Vec<i32>,
}

/// Streaming batcher over a (world, mix): infinite deterministic stream.
pub struct Batcher {
    world: World,
    mix: CorpusMix,
    b: usize,
    s: usize,
    rng: Rng,
    /// Total tokens produced so far (throughput accounting).
    pub tokens_served: u64,
}

impl Batcher {
    /// A deterministic stream over (world, mix) from `seed`.
    pub fn new(world: World, mix: CorpusMix, b: usize, s: usize, seed: u64) -> Batcher {
        Batcher { world, mix, b, s, rng: Rng::new(seed), tokens_served: 0 }
    }

    /// Produce the next [b, s] batch with next-token targets.
    pub fn next_batch(&mut self) -> Batch {
        let (b, s) = (self.b, self.s);
        let mut inputs = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            let seq = sample_sequence(&self.world, &self.mix, s, &mut self.rng);
            inputs.extend(seq[..s].iter().map(|&t| t as i32));
            targets.extend(seq[1..=s].iter().map(|&t| t as i32));
        }
        self.tokens_served += (b * s) as u64;
        Batch { b, s, inputs, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(42, 256)
    }

    #[test]
    fn sequences_have_exact_len_and_valid_tokens() {
        let w = world();
        let mix = CorpusMix::distillation_mix();
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let seq = sample_sequence(&w, &mix, 64, &mut rng);
            assert_eq!(seq.len(), 65);
            assert_eq!(seq[0], BOS);
            assert!(seq.iter().all(|&t| t < w.vocab.size));
        }
    }

    #[test]
    fn facts_in_corpus_match_world_truth() {
        let w = world();
        let mut rng = Rng::new(2);
        let mut s = Vec::new();
        gen_sentence(&w, Domain::Facts, &mut rng, &mut s);
        assert_eq!(s.len(), 5);
        assert_eq!(s[2], SEP);
        let (e_tok, r_tok, v_tok) = (s[0], s[1], s[3]);
        let e = e_tok - w.vocab.ent0;
        let r = r_tok - w.vocab.rel0;
        assert_eq!(w.fact_value(e, r), v_tok);
    }

    #[test]
    fn math_sentences_are_correct() {
        let w = world();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let mut s = Vec::new();
            gen_sentence(&w, Domain::Math, &mut rng, &mut s);
            let d0 = w.vocab.dig0;
            let a = s[0] - d0;
            assert_eq!(s[1], PLUS);
            let b = s[2] - d0;
            assert_eq!(s[3], EQ);
            let c = if s.len() == 7 { 10 * (s[4] - d0) + (s[5] - d0) } else { s[4] - d0 };
            assert_eq!(a + b, c);
        }
    }

    #[test]
    fn code_sentences_are_balanced() {
        let w = world();
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let mut s = Vec::new();
            gen_sentence(&w, Domain::Code, &mut rng, &mut s);
            let mut stack = Vec::new();
            for &t in &s[..s.len() - 1] {
                let idx = t - w.vocab.fil0;
                if idx % 2 == 0 {
                    stack.push(idx / 2);
                } else {
                    assert_eq!(stack.pop(), Some(idx / 2), "mismatched bracket");
                }
            }
            assert!(stack.is_empty());
        }
    }

    #[test]
    fn batcher_is_deterministic_and_shifted() {
        let mk = || Batcher::new(world(), CorpusMix::distillation_mix(), 2, 32, 9);
        let b1 = mk().next_batch();
        let b2 = mk().next_batch();
        assert_eq!(b1.inputs, b2.inputs);
        // targets are inputs shifted by one within each row
        assert_eq!(b1.inputs[1], b1.targets[0]);
        assert_eq!(b1.inputs.len(), 64);
    }

    #[test]
    fn gutenberg_has_no_facts() {
        let w = world();
        let mix = CorpusMix::gutenberg();
        let mut rng = Rng::new(5);
        let seq = sample_sequence(&w, &mix, 256, &mut rng);
        let n_value_toks = seq.iter().filter(|&&t| w.vocab.is_value(t)).count();
        assert_eq!(n_value_toks, 0, "narrative-only mix must not leak fact values");
    }
}
