//! The FactWorld vocabulary layout and ground-truth fact table.

use crate::util::Rng;

/// Special token ids (fixed across vocab sizes).
pub const PAD: u32 = 0;
/// Beginning-of-sequence marker.
pub const BOS: u32 = 1;
/// End-of-sequence marker (generation stops here).
pub const EOS: u32 = 2;
/// Fact separator ("is").
pub const SEP: u32 = 3; // "is"
/// Question marker.
pub const QRY: u32 = 4; // question marker
/// Equality marker in arithmetic statements.
pub const EQ: u32 = 5;
/// Plus sign in arithmetic statements.
pub const PLUS: u32 = 6;
/// Frequent-words query marker (long-context task).
pub const FRQ: u32 = 7; // frequent-words query marker

/// Vocabulary layout: contiguous id blocks for each token class.
#[derive(Debug, Clone)]
pub struct Vocab {
    /// Total vocabulary size.
    pub size: u32,
    /// Number of entity tokens.
    pub n_entities: u32,
    /// Number of relation tokens.
    pub n_relations: u32,
    /// Number of value tokens.
    pub n_values: u32,
    /// First entity token id.
    pub ent0: u32,
    /// First relation token id.
    pub rel0: u32,
    /// First value token id.
    pub val0: u32,
    /// First digit token id (10 digits).
    pub dig0: u32,
    /// First filler (narrative) token id.
    pub fil0: u32,
}

impl Vocab {
    /// Derive the layout for a vocabulary of `v` tokens.
    pub fn for_size(v: u32) -> Vocab {
        assert!(v >= 128, "vocab too small for FactWorld layout");
        // proportions tuned so filler keeps >= 1/3 of the vocab
        let n_entities = v / 6;
        let n_relations = (v / 32).max(4);
        let n_values = v / 8;
        let ent0 = 8;
        let rel0 = ent0 + n_entities;
        let val0 = rel0 + n_relations;
        let dig0 = val0 + n_values;
        let fil0 = dig0 + 10;
        assert!(fil0 + 16 < v, "vocab layout overflow");
        Vocab { size: v, n_entities, n_relations, n_values, ent0, rel0, val0, dig0, fil0 }
    }

    /// Number of filler tokens.
    pub fn n_filler(&self) -> u32 {
        self.size - self.fil0
    }

    /// The i-th entity token (wrapping).
    pub fn entity(&self, i: u32) -> u32 {
        self.ent0 + (i % self.n_entities)
    }

    /// The i-th relation token (wrapping).
    pub fn relation(&self, i: u32) -> u32 {
        self.rel0 + (i % self.n_relations)
    }

    /// The i-th value token (wrapping).
    pub fn value(&self, i: u32) -> u32 {
        self.val0 + (i % self.n_values)
    }

    /// The token for digit `d` (0..=9).
    pub fn digit(&self, d: u32) -> u32 {
        debug_assert!(d < 10);
        self.dig0 + d
    }

    /// The i-th filler token (wrapping).
    pub fn filler(&self, i: u32) -> u32 {
        self.fil0 + (i % self.n_filler())
    }

    /// Whether `t` lies in the value block.
    pub fn is_value(&self, t: u32) -> bool {
        t >= self.val0 && t < self.dig0
    }
}

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51afd7ed558ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ceb9fe1a85ec53);
    x ^ (x >> 33)
}

/// A deterministic world: the fact table and the narrative Markov process
/// are pure functions of the seed, so train data, eval questions and
/// distractors all agree without storing anything.
#[derive(Debug, Clone)]
pub struct World {
    /// World seed: all facts and narratives derive from it.
    pub seed: u64,
    /// The vocabulary layout.
    pub vocab: Vocab,
}

impl World {
    /// A world over a fresh vocabulary layout for `vocab_size` tokens.
    pub fn new(seed: u64, vocab_size: u32) -> World {
        World { seed, vocab: Vocab::for_size(vocab_size) }
    }

    /// Ground truth: value token for fact (entity e, relation r).
    pub fn fact_value(&self, e: u32, r: u32) -> u32 {
        let h = mix64(self.seed ^ ((e as u64) << 32) ^ (r as u64) ^ 0xfac7);
        self.vocab.value((h % self.vocab.n_values as u64) as u32)
    }

    /// Markov narrative: each filler token has `branch` successor
    /// candidates fixed by the world seed.
    pub fn narrative_successor(&self, cur: u32, rng: &mut Rng, branch: u32) -> u32 {
        let pick = rng.below(branch as usize) as u64;
        let h = mix64(self.seed ^ ((cur as u64) << 24) ^ (pick << 8) ^ 0x9a77);
        self.vocab.filler((h % self.vocab.n_filler() as u64) as u32)
    }

    /// Deterministic "most likely" successor (used to build true
    /// continuations for ContScore).
    pub fn narrative_mode_successor(&self, cur: u32) -> u32 {
        let h = mix64(self.seed ^ ((cur as u64) << 24) ^ 0x9a77);
        self.vocab.filler((h % self.vocab.n_filler() as u64) as u32)
    }

    /// Alias chain for variable tracking: entity e's alias target.
    pub fn alias_of(&self, e: u32, hop: u32) -> u32 {
        let h = mix64(self.seed ^ ((e as u64) << 16) ^ ((hop as u64) << 40) ^ 0xa11a5);
        self.vocab.entity((h % self.vocab.n_entities as u64) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_blocks_disjoint() {
        for v in [256u32, 512] {
            let vc = Vocab::for_size(v);
            assert!(vc.ent0 > FRQ);
            assert!(vc.rel0 > vc.ent0 && vc.val0 > vc.rel0);
            assert!(vc.dig0 > vc.val0 && vc.fil0 == vc.dig0 + 10);
            assert!(vc.fil0 < v);
            assert!(vc.n_filler() >= v / 3, "filler too small for v={v}");
        }
    }

    #[test]
    fn facts_deterministic_and_varied() {
        let w = World::new(7, 256);
        assert_eq!(w.fact_value(3, 1), w.fact_value(3, 1));
        let vals: std::collections::HashSet<u32> =
            (0..40).map(|e| w.fact_value(e, 0)).collect();
        assert!(vals.len() > 8, "fact table should be diverse, got {}", vals.len());
        // facts land in the value block
        for e in 0..10 {
            assert!(w.vocab.is_value(w.fact_value(e, 2)));
        }
    }

    #[test]
    fn different_seeds_different_worlds() {
        let a = World::new(1, 256);
        let b = World::new(2, 256);
        let same = (0..64).filter(|&e| a.fact_value(e, 0) == b.fact_value(e, 0)).count();
        assert!(same < 20);
    }

    #[test]
    fn narrative_successors_in_filler_block() {
        let w = World::new(3, 256);
        let mut rng = Rng::new(0);
        let mut cur = w.vocab.filler(5);
        for _ in 0..100 {
            cur = w.narrative_successor(cur, &mut rng, 4);
            assert!(cur >= w.vocab.fil0 && cur < w.vocab.size);
        }
    }
}
