//! Hand-rolled infrastructure (the offline build has no serde/clap/rand/
//! tokio): JSON, RNG, CLI args, logging, timing.

pub mod args;
pub mod json;
pub mod log;
pub mod rng;

pub use args::Args;
pub use json::Json;
pub use rng::Rng;

use std::time::Instant;

/// Scope timer: returns elapsed seconds.
pub struct Timer(Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed seconds since `start`.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since `start`.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Mean of a slice (0.0 if empty).
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// p-th percentile (0..=100) of a slice by nearest-rank.
pub fn percentile(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
