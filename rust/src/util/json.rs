//! Minimal JSON parser/serializer (no serde available offline).
//!
//! Used for artifact manifests, architecture descriptors, score tables and
//! experiment reports. Supports the full JSON grammar minus exotic number
//! forms; preserves object insertion order (important for stable diffs of
//! generated reports).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
/// A parsed JSON value (hand-rolled; serde is reserved for stores).
pub enum Json {
    /// JSON null.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object as ordered key-value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Set `key` on an object (replacing an existing entry).
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            if let Some(e) = m.iter_mut().find(|(k, _)| k == key) {
                e.1 = val;
            } else {
                m.push((key.to_string(), val));
            }
        } else {
            panic!("Json::set on non-object");
        }
    }

    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Index into an array.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize, if integral.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key-value slice, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: object from key/value pairs.
    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// A number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// A string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// An array of numbers.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    /// An array of numbers from usizes.
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    /// Pretty-printed serialization (2-space indent).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (utf-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Ordered map helper for callers that want BTreeMap semantics.
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(m) => m.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n\"y"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\n\"y"));
    }

    #[test]
    fn parses_python_manifest_style() {
        let src = "{\n \"execs\": {\n  \"a\": {\"file\": \"a.hlo.txt\", \"in\": [{\"dtype\": \"float32\", \"shape\": [4, 1, 64]}]}\n }\n}";
        let v = Json::parse(src).unwrap();
        let shape = v.get("execs").unwrap().get("a").unwrap().get("in").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut o = Json::obj();
        o.set("x", Json::Num(1.0));
        o.set("x", Json::Num(2.0));
        o.set("y", Json::str("z"));
        assert_eq!(o.get("x").unwrap().as_f64(), Some(2.0));
        assert_eq!(o.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::from_pairs(vec![("k", Json::arr_f64(&[1.0, 2.5])), ("s", Json::str("v"))]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
