//! Tiny CLI argument parser: `cmd subcommand --key value --flag`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
/// Parsed command line: positionals + `--key value` pairs + flags.
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv tokens.
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let t = &argv[i];
            if let Some(key) = t.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    a.kv.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.kv.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(key.to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        a
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    /// Raw value of `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// usize value of `--key`, or `default`.
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// u64 value of `--key`, or `default`.
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// f64 value of `--key`, or `default`.
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether `--key` was given as a bare flag (or `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&s(&["exp", "table3", "--steps", "100", "--fast", "--lr=0.01"]));
        assert_eq!(a.positional, vec!["exp", "table3"]);
        assert_eq!(a.usize("steps", 0), 100);
        assert!(a.flag("fast"));
        assert_eq!(a.f64("lr", 0.0), 0.01);
        assert_eq!(a.str("missing", "d"), "d");
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&s(&["--verbose"]));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }
}
