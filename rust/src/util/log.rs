//! Minimal leveled logger writing to stderr with elapsed wall-clock.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=quiet 1=warn 2=info 3=debug

/// Set the global verbosity (0=quiet 1=warn 2=info 3=debug).
pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Current global verbosity.
pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

fn t0() -> Instant {
    use std::sync::OnceLock;
    static T0: OnceLock<Instant> = OnceLock::new();
    *T0.get_or_init(Instant::now)
}

/// Write one line at `lvl` if the global level allows it.
pub fn log(lvl: u8, tag: &str, msg: &str) {
    if lvl <= level() {
        let dt = t0().elapsed().as_secs_f64();
        eprintln!("[{dt:8.2}s {tag}] {msg}");
    }
}

#[macro_export]
/// Log at info level with `format!` arguments.
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log(2, "info", &format!($($arg)*)) };
}

#[macro_export]
/// Log at warn level with `format!` arguments.
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::log(1, "warn", &format!($($arg)*)) };
}

#[macro_export]
/// Log at debug level with `format!` arguments.
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log(3, "debug", &format!($($arg)*)) };
}

/// Initialize the epoch (call early in main so timestamps start near 0).
pub fn init() {
    let _ = t0();
}
