//! PCG32 random number generator + sampling helpers (no `rand` offline).
//!
//! Deterministic, splittable, and fast. Every stochastic stage of the
//! pipeline (corpus generation, weight init, data order, random-architecture
//! baselines) takes an explicit `Rng` so runs are reproducible end to end.

#[derive(Debug, Clone)]
/// PCG32 stream (state + stream-selector increment).
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// A stream seeded by `seed` (different seeds, independent streams).
    pub fn new(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    /// Derive an independent stream (for parallel jobs / substages).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Uniformly chosen element (panics on empty).
    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }

    /// Zipf-distributed index in [0, n) with exponent `s` (via rejection-free
    /// inverse-CDF over precomputed weights is overkill; linear scan on
    /// normalized harmonic weights is fine for n <= a few thousand).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // cache-free: compute cumulative on the fly with the known total
        let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * total;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

/// Precomputed Zipf sampler for hot loops (corpus generation).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// A table over ranks 1..=n with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    /// Draw one rank index in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!(c > 700 && c < 1300, "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_head_heavy() {
        let t = ZipfTable::new(100, 1.1);
        let mut r = Rng::new(11);
        let mut head = 0;
        for _ in 0..5000 {
            if t.sample(&mut r) < 10 {
                head += 1;
            }
        }
        assert!(head > 2500, "zipf head mass {head}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut r = Rng::new(9);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
