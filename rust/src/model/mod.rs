//! Child-model assembly: turn (Arch, Store) into chained executable calls.
//!
//! This is the heart of the "puzzle pieces" runtime contract: a model is a
//! per-layer list of (executable prefix, weight values); heterogeneous
//! architectures are assembled by the coordinator with zero recompilation
//! because every block executable takes its weights as parameters. The
//! whole module is generic over the execution `Backend` — it never touches
//! PJRT or any other concrete runtime.

use anyhow::{anyhow, Result};

use crate::arch::{Arch, AttnChoice, FfnChoice};
use crate::config::Manifest;
use crate::runtime::{tensor_to_val, val_i32, val_to_tensor, Backend, Value};
use crate::tensor::Tensor;
use crate::weights::Store;

/// One subblock ready to execute: exec name prefix + weight values.
pub struct BlockWeights {
    /// e.g. "attn_gqa_r2" — exec names are `{prefix}_{mode}`. None = NoOp.
    pub prefix: Option<String>,
    /// Weight values in manifest order.
    pub vals: Vec<Value>,
    /// Variant name (for page sizing and reports).
    pub variant: String,
    /// KV head count (GQA variants; 0 otherwise).
    pub kv_heads: usize,
}

/// A fully assembled child (or parent) model.
pub struct CompiledModel {
    /// The architecture this model realizes.
    pub arch: Arch,
    /// Per-layer attention subblocks.
    pub attn: Vec<BlockWeights>,
    /// Per-layer FFN subblocks.
    pub ffn: Vec<BlockWeights>,
    /// Tied embedding matrix value.
    pub embed: Value,
    /// Final RMSNorm weight value.
    pub final_norm: Value,
}

/// Per-layer activations recorded during a forward pass; the inputs each
/// vjp executable needs on the backward chain (rematerialization of the
/// block internals happens inside the vjp executables).
pub struct Trace {
    /// input to layer i's attention subblock, i = 0..L (x_0 = embeddings)
    pub attn_in: Vec<Value>,
    /// input to layer i's FFN subblock (= attention subblock output)
    pub ffn_in: Vec<Value>,
    /// final hidden state (input to the LM head)
    pub hidden: Value,
    /// logits as a host tensor [B, S, V]
    pub logits: Tensor,
}

impl CompiledModel {
    /// Assemble from an architecture + weight store. Weights for each
    /// chosen variant must already exist in the store (parent variants
    /// from init/training, others from the BLD block library).
    pub fn assemble(man: &Manifest, store: &Store, arch: &Arch) -> Result<CompiledModel> {
        let mut attn = Vec::with_capacity(arch.n_layers());
        let mut ffn = Vec::with_capacity(arch.n_layers());
        for (l, (a, f)) in arch.layers.iter().enumerate() {
            attn.push(Self::subblock(man, store, l, "attn", a.exec_prefix(), &a.name())?);
            ffn.push(Self::subblock(man, store, l, "ffn", f.exec_prefix(), &f.name())?);
        }
        Ok(CompiledModel {
            arch: arch.clone(),
            attn,
            ffn,
            embed: tensor_to_val(store.get("embed")?)?,
            final_norm: tensor_to_val(store.get("final_norm")?)?,
        })
    }

    fn subblock(
        man: &Manifest,
        store: &Store,
        layer: usize,
        kind: &str,
        prefix: Option<String>,
        variant: &str,
    ) -> Result<BlockWeights> {
        let Some(prefix) = prefix else {
            return Ok(BlockWeights { prefix: None, vals: vec![], variant: variant.into(), kv_heads: 0 });
        };
        let layout = if kind == "attn" {
            man.attn_variants.get(variant)
        } else {
            man.ffn_variants.get(variant)
        }
        .ok_or_else(|| anyhow!("variant {variant} not in manifest"))?;
        let ws = store.block(layer, kind, variant, layout)?;
        let vals = ws.iter().map(|t| tensor_to_val(t)).collect::<Result<Vec<_>>>()?;
        Ok(BlockWeights { prefix: Some(prefix), vals, variant: variant.into(), kv_heads: layout.kv_heads })
    }

    /// Forward pass in a sequence-parallel mode ("train", "prefill",
    /// "long"), recording the trace needed for the backward chain and
    /// scoring. `tokens` is [b, s] row-major.
    pub fn forward(&self, be: &dyn Backend, mode: &str, tokens: &[i32], b: usize, s: usize) -> Result<Trace> {
        let tok = val_i32(&[b, s], tokens)?;
        let mut x = be
            .run(&format!("embed_{mode}"), &[&tok, &self.embed])?
            .remove(0);
        let mut attn_in = Vec::with_capacity(self.attn.len());
        let mut ffn_in = Vec::with_capacity(self.ffn.len());
        for l in 0..self.attn.len() {
            attn_in.push(x.clone());
            x = run_subblock(be, &self.attn[l], mode, x)?;
            ffn_in.push(x.clone());
            x = run_subblock(be, &self.ffn[l], mode, x)?;
        }
        let logits_val = be
            .run(&format!("head_{mode}"), &[&x, &self.final_norm, &self.embed])?
            .remove(0);
        let logits = val_to_tensor(&logits_val)?;
        Ok(Trace { attn_in, ffn_in, hidden: x, logits })
    }

    /// Number of parameters actually used by this architecture.
    pub fn param_count(&self, man: &Manifest) -> usize {
        let mut n = man.cfg.v * man.cfg.d + man.cfg.d; // embed + final norm
        for (a, f) in &self.arch.layers {
            if let Some(l) = man.attn_layout(a) {
                n += l.param_count();
            }
            if let Some(l) = man.ffn_layout(f) {
                n += l.param_count();
            }
        }
        n
    }
}

/// Execute one subblock in `mode` ("train_fwd" is spelled "train" here and
/// mapped to the train_fwd executable); NoOp passes the activation through.
pub fn run_subblock(be: &dyn Backend, blk: &BlockWeights, mode: &str, x: Value) -> Result<Value> {
    let Some(prefix) = &blk.prefix else { return Ok(x) };
    let exec = match mode {
        "train" => format!("{prefix}_train_fwd"),
        m => format!("{prefix}_{m}"),
    };
    let mut inputs: Vec<&Value> = vec![&x];
    inputs.extend(blk.vals.iter());
    // gqa prefill returns (y, k, v) — callers on the scoring/train path
    // only need y; the serving engine uses its own prefill loop.
    Ok(be.run(&exec, &inputs)?.remove(0))
}

/// Backward through one subblock: (dx, dweights). NoOp passes dy through.
pub fn vjp_subblock(
    be: &dyn Backend,
    blk: &BlockWeights,
    x: &Value,
    dy: Value,
) -> Result<(Value, Vec<Value>)> {
    let Some(prefix) = &blk.prefix else { return Ok((dy, vec![])) };
    let exec = format!("{prefix}_train_vjp");
    let mut inputs: Vec<&Value> = vec![x];
    inputs.extend(blk.vals.iter());
    inputs.push(&dy);
    let mut out = be.run(&exec, &inputs)?;
    let dx = out.remove(0);
    Ok((dx, out))
}

/// Weight keys (store naming) that this architecture trains.
pub fn trainable_keys(man: &Manifest, arch: &Arch) -> Vec<String> {
    use crate::weights::store::block_key;
    let mut keys = vec!["embed".to_string(), "final_norm".to_string()];
    for (l, (a, f)) in arch.layers.iter().enumerate() {
        if let Some(layout) = man.attn_layout(a) {
            for (w, _) in &layout.weights {
                keys.push(block_key(l, "attn", &a.name(), w));
            }
        }
        if let Some(layout) = man.ffn_layout(f) {
            for (w, _) in &layout.weights {
                keys.push(block_key(l, "ffn", &f.name(), w));
            }
        }
    }
    keys
}

/// Convenience: variant choice for layer `l` as (attn, ffn) names.
pub fn layer_names(arch: &Arch, l: usize) -> (String, String) {
    let (a, f) = &arch.layers[l];
    (a.name(), f.name())
}

#[allow(unused)]
fn _type_checks(a: AttnChoice, f: FfnChoice) {
    let _ = (a, f);
}
