//! End-to-end pipeline orchestration (Figure 1's three stages) with a run
//! directory for stage checkpoints, so expensive stages (parent pretrain,
//! BLD, scoring) are computed once and shared by every experiment.
//!
//! Stage artifacts under `<run_dir>/`:
//!
//! ```text
//! parent.pzw           — pretrained parent weights
//! library.pzw          — parent + trained block library (after BLD)
//! scores_<metric>.json — replace-1-block score table
//! arch_<tag>.json      — MIP solutions per constraint slice
//! child_<tag>.pzw      — GKD-uptrained child weights
//! ```

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::arch::{Arch, SearchSpace};
use crate::data::{Batcher, CorpusMix, World};
use crate::gkd::{self, GkdCfg};
use crate::mip::{self, Constraints, Solution};
use crate::perf::{CostTable, HwProfile, Scenario};
use crate::runtime::SharedBackend;
use crate::scoring::{self, Metric, ScoreTable};
use crate::train::LossSpec;
use crate::util::{Json, Rng};
use crate::weights::{store::init_parent, Store};
use crate::{bld, info};

#[derive(Debug, Clone)]
/// Per-stage step/lr/size knobs for one pipeline run.
pub struct StageCfg {
    /// Parent pretraining steps.
    pub parent_steps: usize,
    /// Parent pretraining learning rate.
    pub parent_lr: f32,
    /// BLD steps per job.
    pub bld_steps: usize,
    /// BLD learning rate.
    pub bld_lr: f32,
    /// GKD uptraining steps.
    pub gkd_steps: usize,
    /// GKD learning rate.
    pub gkd_lr: f32,
    /// Validation batches for replace-1-block scoring.
    pub score_batches: usize,
    /// Questions per eval benchmark.
    pub eval_questions: usize,
    /// Master seed (world, data order, inits).
    pub seed: u64,
}

impl StageCfg {
    /// Small-but-meaningful defaults for the tiny config on one CPU core.
    pub fn fast() -> StageCfg {
        StageCfg {
            parent_steps: 600,
            parent_lr: 3e-3,
            bld_steps: 40,
            bld_lr: 4e-3,
            gkd_steps: 60,
            gkd_lr: 1e-3,
            score_batches: 2,
            eval_questions: 48,
            seed: 42,
        }
    }

    /// `fast` with the training-step counts scaled by `mult`.
    pub fn scaled(mult: f64) -> StageCfg {
        let f = StageCfg::fast();
        StageCfg {
            parent_steps: (f.parent_steps as f64 * mult) as usize,
            bld_steps: (f.bld_steps as f64 * mult) as usize,
            gkd_steps: (f.gkd_steps as f64 * mult) as usize,
            ..f
        }
    }
}

/// Stage orchestrator: backend + run directory + stage config.
pub struct Pipeline {
    /// Owned backend handle; clone it to hand engines their own copy.
    pub be: SharedBackend,
    /// Run directory holding stage checkpoints.
    pub run_dir: PathBuf,
    /// The synthetic data world.
    pub world: World,
    /// Training corpus mix.
    pub mix: CorpusMix,
    /// Stage knobs.
    pub cfg: StageCfg,
}

/// A parent/child weight+arch pair ready for speculative serving: the
/// Puzzle child drafts, the parent verifies.
pub struct SpecPair {
    /// Parent (verifier) weights.
    pub parent_store: Store,
    /// Parent architecture.
    pub parent_arch: Arch,
    /// Drafter weights (GKD-uptrained).
    pub child_store: Store,
    /// Drafter architecture.
    pub child_arch: Arch,
}

/// Stable short fingerprint of an architecture (FNV-1a over its JSON),
/// used to key per-arch stage artifacts like the uptrained drafter.
fn arch_fingerprint(arch: &Arch) -> String {
    let s = arch.to_json().to_string();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

impl Pipeline {
    /// A pipeline over `be`, checkpointing into `run_dir`.
    pub fn new(be: SharedBackend, run_dir: &Path, cfg: StageCfg) -> Result<Pipeline> {
        std::fs::create_dir_all(run_dir)?;
        let world = World::new(cfg.seed, be.man().cfg.v as u32);
        Ok(Pipeline {
            be,
            run_dir: run_dir.to_path_buf(),
            world,
            mix: CorpusMix::distillation_mix(),
            cfg,
        })
    }

    /// A training-data stream whose seed mixes in `seed_tag`.
    pub fn batcher(&self, seed_tag: u64) -> Batcher {
        let c = &self.be.man().cfg;
        Batcher::new(self.world.clone(), self.mix.clone(), c.b_train, c.s_train, self.cfg.seed ^ seed_tag)
    }

    /// `n` deterministic validation batches (fixed seed tag).
    pub fn val_batches(&self, n: usize) -> Vec<crate::data::Batch> {
        let mut b = self.batcher(0x7a1);
        (0..n).map(|_| b.next_batch()).collect()
    }

    /// Stage 0: pretrain (or load) the parent.
    pub fn ensure_parent(&self) -> Result<Store> {
        let path = self.run_dir.join("parent.pzw");
        if path.exists() {
            info!("parent: loading {}", path.display());
            return Store::load(&path);
        }
        info!("parent: pretraining {} steps", self.cfg.parent_steps);
        let mut rng = Rng::new(self.cfg.seed);
        let mut store = init_parent(self.be.man(), &mut rng);
        let mut batcher = self.batcher(0x9a5e);
        let val = self.val_batches(2);
        let report = gkd::pretrain_parent(
            &*self.be,
            &mut store,
            &mut batcher,
            &val,
            self.cfg.parent_steps,
            self.cfg.parent_lr,
        )?;
        info!(
            "parent: final lm {:.4}, val lm {:.4} ({} tokens)",
            report.final_train.lm, report.val_lm, report.tokens
        );
        // persist the loss curve for the e2e record
        let curve = Json::Arr(
            report
                .curve
                .iter()
                .map(|(s, l)| Json::arr_f64(&[*s as f64, *l]))
                .collect(),
        );
        std::fs::write(self.run_dir.join("parent_curve.json"), curve.to_string())?;
        store.save(&path)?;
        Ok(store)
    }

    /// Stage 1: BLD block library (decoupled by default).
    pub fn ensure_library(&self, space: &SearchSpace) -> Result<Store> {
        let path = self.run_dir.join("library.pzw");
        if path.exists() {
            info!("library: loading {}", path.display());
            return Store::load(&path);
        }
        let mut store = self.ensure_parent()?;
        let mut batcher = self.batcher(0xb1d);
        let report =
            bld::run_decoupled(&*self.be, &mut store, space, &mut batcher, self.cfg.bld_steps, self.cfg.bld_lr)?;
        let mean_nmse: f64 =
            report.final_loss.values().sum::<f64>() / report.final_loss.len().max(1) as f64;
        info!(
            "library: {} jobs, {} steps, {} tokens, mean final nmse {:.4}",
            report.jobs, report.steps, report.tokens, mean_nmse
        );
        store.save(&path)?;
        Ok(store)
    }

    /// Stage 2a: replace-1-block scores.
    pub fn ensure_scores(&self, space: &SearchSpace, metric: Metric) -> Result<ScoreTable> {
        let name = match metric {
            Metric::Kl => "kl",
            Metric::LmLoss => "lm",
        };
        let path = self.run_dir.join(format!("scores_{name}.json"));
        if path.exists() {
            let j = Json::parse(&std::fs::read_to_string(&path)?)
                .map_err(|e| anyhow!("score table parse: {e}"))?;
            return ScoreTable::from_json(&j).ok_or_else(|| anyhow!("bad score table"));
        }
        let store = self.ensure_library(space)?;
        let val = self.val_batches(self.cfg.score_batches);
        let table = scoring::score_library(&*self.be, &store, space, &val, metric)?;
        std::fs::write(&path, table.to_json().to_pretty())?;
        Ok(table)
    }

    /// Stage 2b: MIP search under a throughput-speedup slice.
    pub fn search_speedup(
        &self,
        space: &SearchSpace,
        scores: &ScoreTable,
        ct: &CostTable,
        speedup: f64,
    ) -> Result<Solution> {
        let n_layers = self.be.man().cfg.n_layers;
        let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
        let cons = Constraints { throughput_min: Some(parent_tp * speedup), ..Default::default() };
        let sol = mip::search_mip(space, scores, ct, &cons, n_layers, &[], 1.0)?;
        info!(
            "search: speedup {:.2}x -> cost {:.4}, tp {:.0} (parent {:.0}), params {:.2}M",
            speedup, sol.cost, sol.throughput, parent_tp, sol.params / 1e6
        );
        Ok(sol)
    }

    /// Stage 3: GKD uptraining of a child.
    pub fn gkd_child(&self, store: &mut Store, arch: &Arch, spec: LossSpec, steps: usize) -> Result<gkd::GkdReport> {
        let mut batcher = self.batcher(0x6cd);
        let val = self.val_batches(2);
        let cfg = GkdCfg { steps, lr: self.cfg.gkd_lr, spec, warmup_frac: 0.1, log_every: 20 };
        gkd::run(&*self.be, store, arch, &mut batcher, &val, &cfg)
    }

    /// Stage 4: load (or build) the parent+child weight/arch pair that
    /// speculative decoding serves (`specdec::SpecSession`): parent
    /// weights from `library.pzw` (a superset of `parent.pzw` that also
    /// holds the trained block library), the child architecture from
    /// `draft_arch` (an `arch_<tag>.json` file) or — when no arch is
    /// pinned — the *draft-value* winner among MIP solutions at several
    /// speedup slices around `speedup`: each candidate's acceptance rate
    /// is predicted straight from the score table
    /// (`specdec::estimate_alpha`) and `rank_drafters_estimated` orders
    /// them by modeled speculative speedup, so the default drafter is the
    /// one worth deploying, not merely the one slice searched. The child
    /// weights are GKD-uptrained once and cached per architecture.
    pub fn ensure_spec_pair(
        &self,
        space: &SearchSpace,
        metric: Metric,
        speedup: f64,
        draft_arch: Option<&Path>,
    ) -> Result<SpecPair> {
        let library = self.ensure_library(space)?;
        let parent_arch = Arch::parent(self.be.man().cfg.n_layers);
        let child_arch = match draft_arch {
            Some(p) => {
                let j = Json::parse(&std::fs::read_to_string(p)?)
                    .map_err(|e| anyhow!("draft arch parse: {e}"))?;
                let aj = j.get("arch").unwrap_or(&j);
                Arch::from_json(aj)
                    .ok_or_else(|| anyhow!("bad draft architecture in {}", p.display()))?
            }
            None => {
                let scores = self.ensure_scores(space, metric)?;
                let ct = self.default_cost_table();
                // candidate slices: cheaper, requested, and more aggressive
                let mut candidates: Vec<Arch> = Vec::new();
                for slice in [speedup * 0.75, speedup, speedup * 1.5] {
                    match self.search_speedup(space, &scores, &ct, slice) {
                        Ok(sol) => {
                            if !candidates.iter().any(|c| c.signature() == sol.arch.signature()) {
                                candidates.push(sol.arch);
                            }
                        }
                        Err(e) => info!("spec drafter: slice {slice:.2}x infeasible ({e})"),
                    }
                }
                if candidates.is_empty() {
                    return Err(anyhow!("no feasible drafter architecture at any speedup slice"));
                }
                let hw = HwProfile::h100_fp8();
                let ctx = (self.be.man().cfg.s_max / 2).max(1);
                let ranked = crate::specdec::rank_drafters_estimated(
                    self.be.man(),
                    &parent_arch,
                    &candidates,
                    &scores,
                    &hw,
                    ctx,
                    4,
                );
                for (rank, (idx, value)) in ranked.iter().enumerate() {
                    info!(
                        "spec drafter rank {}: {} (estimated α̂ {:.2}, modeled speculative speedup {:.2}x)",
                        rank + 1,
                        candidates[*idx].signature(),
                        crate::specdec::estimate_alpha(&scores, &candidates[*idx]),
                        value
                    );
                }
                candidates[ranked[0].0].clone()
            }
        };
        // cache keyed by the drafter architecture: a different --draft-arch
        // (or a different search result) must never reuse weights that were
        // GKD-uptrained for another child
        let child_path = self.run_dir.join(format!("child_spec_{}.pzw", arch_fingerprint(&child_arch)));
        let child_store = if child_path.exists() {
            info!("spec child: loading {}", child_path.display());
            Store::load(&child_path)?
        } else {
            info!("spec child: GKD-uptraining the drafter ({} steps)", self.cfg.gkd_steps);
            let mut child = library.clone();
            let rep = self.gkd_child(&mut child, &child_arch, LossSpec::gkd_best(), self.cfg.gkd_steps)?;
            info!("spec child: val KLD {:.4} after uptraining", rep.val_kld);
            child.save(&child_path)?;
            child
        };
        Ok(SpecPair { parent_store: library, parent_arch, child_store, child_arch })
    }

    /// Default hardware + scenario for searches on this config.
    pub fn default_cost_table(&self) -> CostTable {
        let hw = HwProfile::h100_fp8();
        let c = &self.be.man().cfg;
        let sc = Scenario { prefill: c.s_prefill, decode: c.s_prefill, batch: 64 };
        CostTable::modeled(self.be.man(), &hw, &sc)
    }

    /// Persist a search solution as `arch_<tag>.json` in the run dir.
    pub fn save_arch(&self, tag: &str, sol: &Solution) -> Result<()> {
        let j = Json::from_pairs(vec![
            ("arch", sol.arch.to_json()),
            ("cost", Json::num(sol.cost)),
            ("throughput", Json::num(sol.throughput)),
            ("params", Json::num(sol.params)),
        ]);
        std::fs::write(self.run_dir.join(format!("arch_{tag}.json")), j.to_pretty())?;
        Ok(())
    }
}
