//! Timestamp source for the tracer: deterministic virtual ticks or wall clock.
//!
//! The workload harness replays traces on an integer tick clock so runs are
//! byte-reproducible; the async server runs on real time. Both feed the same
//! `Tracer`, so the clock is abstracted behind a single `now_us()` that
//! returns microseconds: wall mode measures from an epoch captured at
//! construction, virtual mode maps one tick to [`TICK_US`] microseconds and
//! only advances when the driver calls [`Clock::set_tick`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Microseconds per virtual tick (1 tick = 1 ms keeps Perfetto scales sane).
pub const TICK_US: u64 = 1_000;

/// A monotonic timestamp source in microseconds.
///
/// `Virtual` holds the current tick (stored, never measured) so identical
/// replays stamp identical timestamps; `Wall` measures elapsed time since the
/// instant the clock was built.
#[derive(Debug)]
pub enum Clock {
    /// Deterministic tick clock driven by [`Clock::set_tick`].
    Virtual(AtomicU64),
    /// Real time relative to the construction instant.
    Wall(Instant),
}

impl Clock {
    /// A virtual tick clock starting at tick 0.
    pub fn virtual_ticks() -> Clock {
        Clock::Virtual(AtomicU64::new(0))
    }

    /// A wall clock with its epoch at the call instant.
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    /// Current timestamp in microseconds.
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Virtual(t) => t.load(Ordering::Relaxed) * TICK_US,
            Clock::Wall(t0) => t0.elapsed().as_micros() as u64,
        }
    }

    /// Advance a virtual clock to `tick` (no-op on a wall clock).
    pub fn set_tick(&self, tick: u64) {
        if let Clock::Virtual(t) = self {
            t.store(tick, Ordering::Relaxed);
        }
    }

    /// Whether this is the deterministic virtual tick clock (the SLO
    /// monitor uses this to pick tick- vs wall-based latency budgets).
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_driven_not_measured() {
        let c = Clock::virtual_ticks();
        assert_eq!(c.now_us(), 0);
        c.set_tick(7);
        assert_eq!(c.now_us(), 7 * TICK_US);
        c.set_tick(7);
        assert_eq!(c.now_us(), 7 * TICK_US);
    }

    #[test]
    fn wall_clock_is_monotone_and_ignores_set_tick() {
        let c = Clock::wall();
        let a = c.now_us();
        c.set_tick(1_000_000);
        let b = c.now_us();
        assert!(b >= a);
    }
}
