//! Live SLO burn-rate monitor over trace rings (DESIGN.md §13).
//!
//! The workload harness scores goodput *after* a replay finishes; a live
//! fleet needs the same `(TTFT, ITL)` judgment *while serving*. This
//! module folds finished-request records out of one or more trace rings
//! (single engine, or the merged router + replica fleet) into
//! [`SloRecord`]s, evaluates them against the same lenient/strict budgets
//! the harness gates on — microsecond conversions of
//! `workload::report::default_profiles` (virtual clock) or
//! `default_wall_profiles` (wall clock) — and renders multi-window
//! **burn rates** as registry gauges.
//!
//! Burn rate is the SRE error-budget form: with objective `o` (target
//! goodput fraction), a window whose miss fraction is `m` burns budget at
//! `m / (1 - o)` — 1.0 means exactly on budget, >1 means the error budget
//! is being consumed faster than it accrues. Two windows (1 minute and
//! 5 minutes of timeline, virtual or wall) make the classic multi-window
//! alert pair: the short window catches a fresh regression fast, the long
//! window filters blips.

use super::clock::TICK_US;
use super::registry::MetricsRegistry;
use super::trace::{merge_logs, request_spans, Event, TraceLog};

/// Short burn window: 1 minute of timeline (virtual or wall), µs.
pub const WINDOW_SHORT_US: u64 = 60_000_000;
/// Long burn window: 5 minutes of timeline, µs.
pub const WINDOW_LONG_US: u64 = 300_000_000;

/// One `(TTFT, ITL)` latency budget in microseconds plus the goodput
/// objective its error budget is measured against.
#[derive(Debug, Clone, Copy)]
pub struct BurnProfile {
    /// Profile label (matches the harness profile it mirrors).
    pub name: &'static str,
    /// Time-to-first-token budget, µs.
    pub ttft_us: u64,
    /// Per-gap inter-token budget, µs.
    pub itl_us: u64,
    /// Goodput objective (fraction of requests that must meet the SLO);
    /// the error budget is `1 - objective`.
    pub objective: f64,
}

impl BurnProfile {
    /// Did this finished-request record meet the budget? Records without
    /// a first token never do (nothing arrived on time).
    pub fn met_by(&self, r: &SloRecord) -> bool {
        r.ttft_us.is_some_and(|t| t <= self.ttft_us) && r.max_gap_us <= self.itl_us
    }
}

/// The monitor's two profiles for the given clock domain: µs conversions
/// of the harness tick budgets (virtual) or wall budgets (wall), with a
/// tight objective on the lenient budget and a loose one on the strict
/// budget — lenient misses should be rare, strict misses are expected
/// under load and meant to trend, not page.
pub fn burn_profiles(virtual_clock: bool) -> [BurnProfile; 2] {
    if virtual_clock {
        // `default_profiles` in ticks, times TICK_US.
        [
            BurnProfile {
                name: "lenient",
                ttft_us: 48 * TICK_US,
                itl_us: 6 * TICK_US,
                objective: 0.99,
            },
            BurnProfile { name: "strict", ttft_us: 3 * TICK_US, itl_us: TICK_US, objective: 0.90 },
        ]
    } else {
        // `default_wall_profiles` in seconds, times 1e6.
        [
            BurnProfile {
                name: "wall_lenient",
                ttft_us: 30_000_000,
                itl_us: 5_000_000,
                objective: 0.99,
            },
            BurnProfile {
                name: "wall_strict",
                ttft_us: 1_000_000,
                itl_us: 250_000,
                objective: 0.90,
            },
        ]
    }
}

/// One finished request's latency facts, folded out of a trace ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SloRecord {
    /// Finish timestamp, µs — the window key.
    pub finish_us: u64,
    /// Submit → first token, µs (from the router's door when the log has
    /// a `routed` record for the request, i.e. placement time counts).
    pub ttft_us: Option<u64>,
    /// Worst inter-token gap, µs (first→second token onward; 0 with
    /// fewer than 2 tokens).
    pub max_gap_us: u64,
}

/// Fold every *finished* request in the given rings (merged onto their
/// shared timeline) into [`SloRecord`]s. TTFT is measured from the
/// router-submit timestamp when present — the fleet view charges
/// placement and queue-hop time against the budget, exactly like the
/// wall-clock harness charges submit-to-first-token.
pub fn fold_requests(logs: &[&TraceLog]) -> Vec<SloRecord> {
    let merged = merge_logs(logs);
    // Worst inter-token gap per id, from consecutive Token records.
    let mut gaps: std::collections::BTreeMap<u64, (u64, u64)> = std::collections::BTreeMap::new();
    for r in &merged.recs {
        if let Event::Token { id, .. } = &r.ev {
            let e = gaps.entry(*id).or_insert((r.ts_us, 0));
            e.1 = e.1.max(r.ts_us - e.0);
            e.0 = r.ts_us;
        }
    }
    request_spans(&merged)
        .into_iter()
        .filter(|s| s.reason.is_some_and(|r| r != "cancelled"))
        .filter_map(|s| {
            let finish = s.finish_us?;
            let start = s.route_us.unwrap_or(s.submit_us);
            Some(SloRecord {
                finish_us: finish,
                ttft_us: s.first_us.map(|f| f - start),
                max_gap_us: gaps.get(&s.id).map_or(0, |&(_, g)| g),
            })
        })
        .collect()
}

/// One profile × window evaluation.
#[derive(Debug, Clone, Copy)]
pub struct BurnRate {
    /// Profile label.
    pub profile: &'static str,
    /// Window length, µs.
    pub window_us: u64,
    /// Requests that finished inside the window.
    pub total: usize,
    /// Of those, requests that met the budget.
    pub met: usize,
    /// `met / total` (1.0 for an empty window — no traffic, no misses).
    pub goodput: f64,
    /// `(1 - goodput) / (1 - objective)`: error-budget consumption rate.
    pub burn: f64,
}

/// Evaluate every profile over the standard short/long window pair
/// ending at `now_us`. An empty window reports goodput 1.0 and burn 0 —
/// silence is not an outage.
pub fn burn_rates(records: &[SloRecord], profiles: &[BurnProfile], now_us: u64) -> Vec<BurnRate> {
    let mut out = Vec::with_capacity(profiles.len() * 2);
    for p in profiles {
        for window_us in [WINDOW_SHORT_US, WINDOW_LONG_US] {
            let lo = now_us.saturating_sub(window_us);
            let in_window: Vec<&SloRecord> =
                records.iter().filter(|r| r.finish_us > lo && r.finish_us <= now_us).collect();
            let total = in_window.len();
            let met = in_window.iter().filter(|r| p.met_by(r)).count();
            let goodput = if total == 0 { 1.0 } else { met as f64 / total as f64 };
            let burn = (1.0 - goodput) / (1.0 - p.objective);
            out.push(BurnRate { profile: p.name, window_us, total, met, goodput, burn });
        }
    }
    out
}

/// Register the burn evaluations as gauges:
/// `puzzle_slo_<profile>_{goodput,burn_rate}_{1m,5m}` plus one
/// `puzzle_slo_window_requests_{1m,5m}` pair (so a scrape can tell "all
/// met" from "no traffic" at a glance).
pub fn register_gauges(reg: &mut MetricsRegistry, rates: &[BurnRate]) {
    let win = |us: u64| if us == WINDOW_SHORT_US { "1m" } else { "5m" };
    let mut seen_windows: Vec<u64> = Vec::new();
    for r in rates {
        if !seen_windows.contains(&r.window_us) {
            seen_windows.push(r.window_us);
            reg.gauge(
                &format!("puzzle_slo_window_requests_{}", win(r.window_us)),
                "Requests finished inside the burn window.",
                r.total as f64,
            );
        }
        reg.gauge(
            &format!("puzzle_slo_{}_goodput_{}", r.profile, win(r.window_us)),
            "Windowed goodput: fraction of finished requests meeting the SLO.",
            r.goodput,
        );
        reg.gauge(
            &format!("puzzle_slo_{}_burn_rate_{}", r.profile, win(r.window_us)),
            "Error-budget burn rate: (1 - goodput) / (1 - objective).",
            r.burn,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::scrape_value;
    use crate::obs::Tracer;

    #[test]
    fn profiles_mirror_the_harness_budgets() {
        let [lenient, strict] = burn_profiles(true);
        assert_eq!((lenient.ttft_us, lenient.itl_us), (48 * TICK_US, 6 * TICK_US));
        assert_eq!((strict.ttft_us, strict.itl_us), (3 * TICK_US, TICK_US));
        assert!(strict.objective < lenient.objective, "strict budgets get a looser objective");
        let [wl, ws] = burn_profiles(false);
        assert_eq!((wl.ttft_us, wl.itl_us), (30_000_000, 5_000_000));
        assert_eq!((ws.ttft_us, ws.itl_us), (1_000_000, 250_000));
    }

    #[test]
    fn fold_measures_ttft_from_the_router_door_and_worst_gap() {
        let t = Tracer::virtual_ticks(64);
        t.record(Event::Routed {
            id: 1,
            replica: 0,
            matched: 0,
            depth: 0,
            reason: "load",
            probes: vec![(0, 0)],
        });
        t.set_virtual_tick(2);
        t.record(Event::Submitted { id: 1, prompt: 4, max_new: 4 });
        t.set_virtual_tick(3);
        t.record(Event::Admitted { id: 1, lane: 0, hit: false, matched: 0 });
        t.set_virtual_tick(5);
        t.record(Event::FirstToken { id: 1 });
        t.record(Event::Token { id: 1, tok: 7 });
        t.set_virtual_tick(6);
        t.record(Event::Token { id: 1, tok: 8 });
        t.set_virtual_tick(9);
        t.record(Event::Token { id: 1, tok: 9 });
        t.record(Event::Finished { id: 1, reason: "eos", tokens: 3 });
        // An unfinished request must not produce a record.
        t.record(Event::Submitted { id: 2, prompt: 4, max_new: 4 });
        let log = t.snapshot();
        let recs = fold_requests(&[&log]);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].ttft_us, Some(5 * TICK_US), "TTFT charges placement time");
        assert_eq!(recs[0].max_gap_us, 3 * TICK_US, "worst of the 1- and 3-tick gaps");
        assert_eq!(recs[0].finish_us, 9 * TICK_US);
    }

    #[test]
    fn cancelled_requests_are_excluded() {
        let t = Tracer::virtual_ticks(64);
        t.record(Event::Submitted { id: 1, prompt: 4, max_new: 4 });
        t.set_virtual_tick(1);
        t.record(Event::Finished { id: 1, reason: "cancelled", tokens: 0 });
        assert!(fold_requests(&[&t.snapshot()]).is_empty());
    }

    #[test]
    fn burn_is_miss_fraction_over_error_budget() {
        let p = BurnProfile { name: "t", ttft_us: 100, itl_us: 100, objective: 0.9 };
        // 4 in-window records, 3 meet → goodput 0.75, burn 2.5.
        let recs: Vec<SloRecord> = (0..4)
            .map(|i| SloRecord {
                finish_us: 1_000 + i,
                ttft_us: Some(if i == 0 { 500 } else { 50 }),
                max_gap_us: 0,
            })
            .collect();
        let rates = burn_rates(&recs, &[p], 10_000);
        assert_eq!(rates.len(), 2, "one short and one long window");
        for r in &rates {
            assert_eq!((r.total, r.met), (4, 3));
            assert!((r.goodput - 0.75).abs() < 1e-12);
            assert!((r.burn - 2.5).abs() < 1e-12);
        }
        // Records outside the window fall out of the evaluation.
        let old = vec![SloRecord { finish_us: 10, ttft_us: Some(500), max_gap_us: 0 }];
        let r = &burn_rates(&old, &[p], WINDOW_SHORT_US + 1_000)[0];
        assert_eq!((r.total, r.goodput.to_bits()), (0, 1.0f64.to_bits()));
        assert_eq!(r.burn, 0.0, "no traffic is not an outage");
    }

    #[test]
    fn gauges_render_per_profile_and_window() {
        let recs = vec![SloRecord { finish_us: 1_000, ttft_us: Some(999_999), max_gap_us: 0 }];
        let rates = burn_rates(&recs, &burn_profiles(true), 2_000);
        let mut reg = MetricsRegistry::new();
        register_gauges(&mut reg, &rates);
        let text = reg.render();
        assert_eq!(scrape_value(&text, "puzzle_slo_window_requests_1m"), Some(1.0));
        assert_eq!(scrape_value(&text, "puzzle_slo_lenient_goodput_1m"), Some(1.0));
        assert_eq!(scrape_value(&text, "puzzle_slo_lenient_burn_rate_5m"), Some(0.0));
        // TTFT of ~1s blows the 3-tick strict budget → nonzero burn.
        let strict = scrape_value(&text, "puzzle_slo_strict_burn_rate_1m").unwrap();
        assert!(strict > 0.0, "strict miss must surface as burn");
    }
}
