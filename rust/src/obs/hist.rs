//! Log-bucketed histograms and bounded latency accumulators.
//!
//! `EngineMetrics` used to keep every TTFT/ITL/e2e sample in an unbounded
//! `Vec<f64>`, which grows forever in a long-running server. The
//! [`LatencySeries`] here is the bounded replacement: an exact mean via a
//! running sum, a power-of-two [`LogHistogram`] for percentiles at any
//! sample count, and a capped reservoir that keeps percentiles *exact*
//! (nearest-rank, matching [`crate::util::percentile`]) until the cap is
//! exceeded. Past the cap, a percentile falls back to the histogram and is
//! correct to within one log2 bucket.

/// Number of power-of-two buckets in [`LogHistogram::latency`]:
/// `1 µs · 2^i` upper edges for `i in 0..28` spans 1 µs to ~134 s.
pub const LATENCY_BUCKETS: usize = 28;

/// Histogram over power-of-two buckets. Bucket `i` counts values in
/// `(lo·2^(i-1), lo·2^i]` (bucket 0 additionally takes everything ≤ `lo`,
/// the last bucket everything larger than its edge).
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    lo: f64,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl LogHistogram {
    /// A histogram with `buckets` power-of-two buckets whose first upper
    /// edge is `lo`.
    pub fn new(lo: f64, buckets: usize) -> LogHistogram {
        LogHistogram { lo, counts: vec![0; buckets.max(1)], count: 0, sum: 0.0, max: 0.0 }
    }

    /// The standard latency shape: 1 µs … ~134 s in 28 buckets.
    pub fn latency() -> LogHistogram {
        LogHistogram::new(1e-6, LATENCY_BUCKETS)
    }

    /// Record one sample (negative samples clamp to 0).
    pub fn observe(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        let mut i = 0;
        let mut edge = self.lo;
        while v > edge && i + 1 < self.counts.len() {
            edge *= 2.0;
            i += 1;
        }
        self.counts[i] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (exact, not bucketed).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean over all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank quantile estimate: the upper edge of the bucket holding
    /// the rank-`⌈p/100·n⌉` sample, clamped to the observed max. The exact
    /// value lies in the same bucket, i.e. within a factor of 2 below the
    /// returned edge.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        let mut edge = self.lo;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return edge.min(self.max);
            }
            if i + 1 < self.counts.len() {
                edge *= 2.0;
            }
        }
        self.max
    }

    /// `(upper_edge, cumulative_count)` per bucket, for Prometheus
    /// `_bucket{le=...}` lines (the `+Inf` bucket is implied by `count`).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut edge = self.lo;
        let mut acc = 0u64;
        for c in &self.counts {
            acc += c;
            out.push((edge, acc));
            edge *= 2.0;
        }
        out
    }
}

/// Reservoir capacity of a [`LatencySeries`]: percentiles stay exact below
/// this many samples.
pub const RESERVOIR_CAP: usize = 4096;

/// Bounded latency accumulator: exact below [`RESERVOIR_CAP`] samples,
/// one-bucket-accurate above, O(cap) memory forever.
///
/// The reservoir uses deterministic Algorithm-R replacement (fixed-seed
/// xorshift), so two identical sample streams produce identical state.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySeries {
    hist: LogHistogram,
    reservoir: Vec<f64>,
    seen: u64,
    rng: u64,
}

impl Default for LatencySeries {
    fn default() -> LatencySeries {
        LatencySeries {
            hist: LogHistogram::latency(),
            reservoir: Vec::new(),
            seen: 0,
            rng: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl LatencySeries {
    /// An empty series.
    pub fn new() -> LatencySeries {
        LatencySeries::default()
    }

    fn next_rng(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    /// Record one sample in seconds.
    pub fn push(&mut self, v: f64) {
        self.hist.observe(v);
        self.seen += 1;
        if self.reservoir.len() < RESERVOIR_CAP {
            self.reservoir.push(v);
        } else {
            let j = self.next_rng() % self.seen;
            if (j as usize) < RESERVOIR_CAP {
                self.reservoir[j as usize] = v;
            }
        }
    }

    /// Samples recorded over the series' lifetime.
    pub fn count(&self) -> u64 {
        self.seen
    }

    /// Number of samples currently held (≤ [`RESERVOIR_CAP`]).
    pub fn len(&self) -> usize {
        self.reservoir.len()
    }

    /// Whether any sample was recorded.
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Exact mean over all samples ever pushed.
    pub fn mean(&self) -> f64 {
        self.hist.mean()
    }

    /// Nearest-rank percentile: exact while `count() ≤` [`RESERVOIR_CAP`],
    /// histogram-bucketed (within one power-of-two bucket) beyond.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.seen <= RESERVOIR_CAP as u64 {
            crate::util::percentile(&self.reservoir, p)
        } else {
            self.hist.quantile(p)
        }
    }

    /// The backing histogram (for Prometheus exposition).
    pub fn hist(&self) -> &LogHistogram {
        &self.hist
    }
}

impl From<Vec<f64>> for LatencySeries {
    fn from(v: Vec<f64>) -> LatencySeries {
        let mut s = LatencySeries::new();
        for x in v {
            s.push(x);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::percentile;

    #[test]
    fn exact_below_cap() {
        let mut s = LatencySeries::new();
        let vals: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        for &v in &vals {
            s.push(v);
        }
        assert_eq!(s.percentile(50.0), percentile(&vals, 50.0));
        assert_eq!(s.percentile(95.0), percentile(&vals, 95.0));
        assert!((s.mean() - vals.iter().sum::<f64>() / 100.0).abs() < 1e-12);
    }

    #[test]
    fn within_one_bucket_above_cap_on_known_timeline() {
        // A known timeline long enough to overflow the reservoir: latencies
        // cycle deterministically over three decades (0.5 ms … 0.4 s).
        let mut s = LatencySeries::new();
        let mut exact = Vec::new();
        for i in 0..(RESERVOIR_CAP * 3) {
            let v = match i % 10 {
                0..=4 => 0.0005 * (1.0 + (i % 7) as f64 / 7.0),
                5..=7 => 0.02 * (1.0 + (i % 5) as f64 / 5.0),
                8 => 0.1,
                _ => 0.4,
            };
            s.push(v);
            exact.push(v);
        }
        assert!(s.count() > RESERVOIR_CAP as u64);
        for p in [50.0, 95.0] {
            let e = percentile(&exact, p);
            let got = s.percentile(p);
            // The estimate is the upper edge of the bucket holding the exact
            // nearest-rank value: within a factor of 2 on either side.
            assert!(got >= e * 0.999, "p{p}: {got} < exact {e}");
            assert!(got <= e * 2.0 * 1.001, "p{p}: {got} > 2x exact {e}");
        }
        // Memory stays bounded.
        assert_eq!(s.len(), RESERVOIR_CAP);
    }

    #[test]
    fn histogram_quantile_clamps_to_max() {
        let mut h = LogHistogram::latency();
        for _ in 0..10 {
            h.observe(3e-3);
        }
        // Bucket edge above 3 ms is 4.096 ms; clamped to observed max.
        assert_eq!(h.quantile(50.0), 3e-3);
        assert_eq!(h.quantile(100.0), 3e-3);
        assert_eq!(h.count(), 10);
    }

    #[test]
    fn from_vec_matches_pushes() {
        let a: LatencySeries = vec![0.1, 0.2, 0.3].into();
        let mut b = LatencySeries::new();
        for v in [0.1, 0.2, 0.3] {
            b.push(v);
        }
        assert_eq!(a, b);
    }
}
