//! Typed metrics registry with Prometheus text exposition.
//!
//! A [`MetricsRegistry`] is a snapshot, not a live store: producers
//! (`EngineMetrics`, server stats) build one on demand from their own
//! counters, so the hot path keeps its plain-field accounting and the
//! registry only exists while rendering. [`MetricsRegistry::render`] emits
//! the Prometheus text exposition format (`# HELP`/`# TYPE` + samples;
//! histograms as cumulative `_bucket{le=...}` plus `_sum`/`_count`).

use std::fmt::Write as _;

use super::hist::LatencySeries;

enum Value {
    Counter(f64),
    Gauge(f64),
    Hist { buckets: Vec<(f64, u64)>, sum: f64, count: u64 },
}

struct Metric {
    name: String,
    help: String,
    value: Value,
}

/// An ordered collection of named metric snapshots.
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: Vec<Metric>,
}

fn fmt_num(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{}", v);
    }
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn push(&mut self, name: &str, help: &str, value: Value) {
        self.metrics.push(Metric { name: name.to_string(), help: help.to_string(), value });
    }

    /// Add a monotonically-increasing counter.
    pub fn counter(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, Value::Counter(v));
    }

    /// Add a point-in-time gauge.
    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.push(name, help, Value::Gauge(v));
    }

    /// Add a histogram snapshot from a latency series.
    pub fn histogram(&mut self, name: &str, help: &str, s: &LatencySeries) {
        self.push(
            name,
            help,
            Value::Hist {
                buckets: s.hist().cumulative(),
                sum: s.hist().sum(),
                count: s.hist().count(),
            },
        );
    }

    /// Append every metric of `other`, preserving order — the router's
    /// per-replica rollup builds fleet-level and per-replica sections as
    /// separate registries and merges them into one scrape payload.
    /// Names are not deduplicated: callers namespace their sections
    /// (e.g. a `puzzle_router_replica_<i>_` prefix) so families stay
    /// unique in the rendered exposition.
    pub fn merge(&mut self, other: MetricsRegistry) {
        self.metrics.extend(other.metrics);
    }

    /// Number of metric families registered.
    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// Render the Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for m in &self.metrics {
            let _ = writeln!(out, "# HELP {} {}", m.name, m.help);
            match &m.value {
                Value::Counter(v) => {
                    let _ = writeln!(out, "# TYPE {} counter", m.name);
                    let _ = write!(out, "{} ", m.name);
                    fmt_num(&mut out, *v);
                    out.push('\n');
                }
                Value::Gauge(v) => {
                    let _ = writeln!(out, "# TYPE {} gauge", m.name);
                    let _ = write!(out, "{} ", m.name);
                    fmt_num(&mut out, *v);
                    out.push('\n');
                }
                Value::Hist { buckets, sum, count } => {
                    let _ = writeln!(out, "# TYPE {} histogram", m.name);
                    for (le, c) in buckets {
                        let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", m.name, le, c);
                    }
                    let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", m.name, count);
                    let _ = write!(out, "{}_sum ", m.name);
                    fmt_num(&mut out, *sum);
                    out.push('\n');
                    let _ = writeln!(out, "{}_count {}", m.name, count);
                }
            }
        }
        out
    }
}

/// Parse the value of one plain sample line (`name value`) back out of a
/// rendered exposition; `None` if the metric is absent. Exists so tests and
/// callers can round-trip snapshots without a Prometheus client.
pub fn scrape_value(text: &str, name: &str) -> Option<f64> {
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        if it.next() == Some(name) {
            return it.next().and_then(|v| v.parse().ok());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_scrapes_back() {
        let mut r = MetricsRegistry::new();
        r.counter("puzzle_prefills_total", "Completed prefill passes.", 42.0);
        r.gauge("puzzle_active_lanes", "Occupied decode lanes.", 3.0);
        let mut s = LatencySeries::new();
        s.push(0.002);
        s.push(0.004);
        r.histogram("puzzle_ttft_seconds", "Time to first token.", &s);
        let text = r.render();
        assert!(text.contains("# TYPE puzzle_prefills_total counter"));
        assert!(text.contains("# TYPE puzzle_ttft_seconds histogram"));
        assert!(text.contains("puzzle_ttft_seconds_count 2"));
        assert!(text.contains("_bucket{le=\"+Inf\"} 2"));
        assert_eq!(scrape_value(&text, "puzzle_prefills_total"), Some(42.0));
        assert_eq!(scrape_value(&text, "puzzle_active_lanes"), Some(3.0));
        assert_eq!(scrape_value(&text, "puzzle_ttft_seconds_count"), Some(2.0));
        assert_eq!(scrape_value(&text, "absent_metric"), None);
    }

    #[test]
    fn merge_appends_in_order() {
        let mut fleet = MetricsRegistry::new();
        fleet.counter("puzzle_router_routed_total", "Requests routed.", 7.0);
        let mut replica = MetricsRegistry::new();
        replica.gauge("puzzle_router_replica_0_depth", "In-flight on replica 0.", 2.0);
        fleet.merge(replica);
        assert_eq!(fleet.len(), 2);
        let text = fleet.render();
        assert_eq!(scrape_value(&text, "puzzle_router_routed_total"), Some(7.0));
        assert_eq!(scrape_value(&text, "puzzle_router_replica_0_depth"), Some(2.0));
        let routed = text.find("puzzle_router_routed_total").unwrap();
        let depth = text.find("puzzle_router_replica_0_depth").unwrap();
        assert!(routed < depth, "merged metrics keep their section order");
    }
}
