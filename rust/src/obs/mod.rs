//! Observability: request-lifecycle tracing, step timelines, and a typed
//! metrics registry with Prometheus exposition.
//!
//! Three pieces, deliberately decoupled from the serving layer:
//!
//! * [`Tracer`] — a cloneable handle over a bounded ring buffer of typed
//!   [`Event`]s. Disabled (the default) it is a single branch per call and
//!   allocates nothing, so the engine, speculative batch, and async server
//!   thread it through unconditionally. Timestamps come from a [`Clock`]
//!   that is either the workload harness's deterministic virtual tick
//!   counter or a wall-clock epoch, so the same event grammar covers
//!   reproducible replays and live serving.
//! * Exporters — [`jsonl`] (one object per line, byte-stable under the
//!   virtual clock) and [`chrome_trace`] (Perfetto-loadable trace-event
//!   JSON with per-lane and per-request tracks), plus [`request_spans`]
//!   which rebuilds `queued → prefill → decode` segments that tile each
//!   request's end-to-end time exactly.
//! * [`MetricsRegistry`] — snapshot counters/gauges/histograms rendered in
//!   the Prometheus text exposition format; [`LatencySeries`] backs the
//!   engine's latency percentiles with bounded memory (exact up to a capped
//!   reservoir, within one log2 bucket beyond). Registries compose:
//!   [`MetricsRegistry::merge`] appends one snapshot onto another, which
//!   is how the data-parallel router rolls fleet-level counters and
//!   namespaced per-replica sections into a single scrape payload
//!   (DESIGN.md §12).
//!
//! At fleet scope (DESIGN.md §13) the same grammar covers the router:
//! every ring shares ONE [`Clock`] ([`Tracer::with_clock`]), so
//! [`merge_logs`] / [`merge_fleet`] / [`fleet_jsonl`] can rebase the
//! router ring plus N replica rings onto a single timeline, and the
//! [`slo`] module folds the merged rings into live multi-window SLO
//! burn-rate gauges.

pub mod clock;
pub mod export;
pub mod hist;
pub mod registry;
pub mod slo;
pub mod trace;

pub use clock::{Clock, TICK_US};
pub use export::{chrome_trace, fleet_jsonl, jsonl, merge_fleet, FleetLog};
pub use hist::{LatencySeries, LogHistogram, LATENCY_BUCKETS, RESERVOIR_CAP};
pub use registry::{scrape_value, MetricsRegistry};
pub use trace::{
    merge_logs, request_spans, Event, Rec, RequestSpans, TraceLog, Tracer, DEFAULT_RING_CAP,
};
