//! Bounded ring-buffer event recorder for the request lifecycle.
//!
//! Every stage a request moves through — submitted, admitted (with prefix
//! hit/miss), prefill chunks, tokens, finish — plus engine-step timeline,
//! speculative rounds and backend exec totals is a typed [`Event`]. The
//! [`Tracer`] is a cheap cloneable handle: disabled (the default) it is a
//! `None` check and records nothing, so serving paths can call it
//! unconditionally; enabled it stamps each event from its [`Clock`] and
//! pushes into a bounded ring that overwrites the oldest record when full
//! (the `dropped` counter says how many were lost).
//!
//! At fleet scope (DESIGN.md §13) the router owns its own ring for the
//! placement-side events — [`Event::Routed`], migration begin/end,
//! [`Event::RouterShed`], [`Event::ProbeRound`] — and every ring in the
//! fleet is built over ONE shared [`Clock`] ([`Tracer::with_clock`]), so
//! timestamps from the router and all N replicas live on a single
//! timeline and a request's lifecycle is stitchable across rings by its
//! globally unique id (`replica << REPLICA_SHIFT | local`).

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Mutex};

use super::clock::Clock;
use crate::runtime::ExecStats;

/// Default ring capacity (events), generous for bench-scale traces.
pub const DEFAULT_RING_CAP: usize = 1 << 16;

/// One typed trace event. `id` is the engine/batch request id.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Request accepted into the waiting queue.
    Submitted {
        /// Request id.
        id: u64,
        /// Prompt length in tokens.
        prompt: usize,
        /// Decode budget in tokens.
        max_new: usize,
    },
    /// Request refused at the door (queue full, prompt too long, ...).
    Rejected {
        /// Request id.
        id: u64,
        /// Human-readable refusal cause.
        cause: String,
    },
    /// Scheduler moved the request from the queue onto a decode lane.
    Admitted {
        /// Request id.
        id: u64,
        /// Decode lane index the request landed on.
        lane: usize,
        /// Whether the prefix cache matched part of the prompt.
        hit: bool,
        /// Matched prefix length in tokens (0 on miss).
        matched: usize,
    },
    /// One prefill pass over `tokens` prompt tokens (budgeted chunk or the
    /// whole window when prefill is unchunked).
    PrefillChunk {
        /// Request id.
        id: u64,
        /// Decode lane index.
        lane: usize,
        /// Prompt tokens ingested by this pass.
        tokens: usize,
    },
    /// First generated token left the engine (TTFT boundary).
    FirstToken {
        /// Request id.
        id: u64,
    },
    /// One generated token.
    Token {
        /// Request id.
        id: u64,
        /// Token id emitted.
        tok: u32,
    },
    /// Request left the engine.
    Finished {
        /// Request id.
        id: u64,
        /// Finish reason (`FinishReason::as_str`), or `"cancelled"`.
        reason: &'static str,
        /// Generated-token count at finish.
        tokens: usize,
    },
    /// One speculative round on one lane: child drafted, parent verified.
    SpecRound {
        /// Batch request id.
        id: u64,
        /// Parent decode lane index.
        lane: usize,
        /// Draft tokens proposed this round.
        drafted: usize,
        /// Draft tokens the parent accepted.
        accepted: usize,
        /// Draft tokens rolled back (`drafted - accepted`).
        rolled_back: usize,
    },
    /// One engine scheduler step (admission + prefill chunks + decode).
    Step {
        /// Step ordinal.
        step: u64,
        /// Active decode lanes after the step.
        active: usize,
        /// Requests still queued after the step.
        queued: usize,
        /// Step duration in microseconds (0 on the virtual clock, which
        /// does not advance inside a step).
        dur_us: u64,
    },
    /// Prefix-cache segment evicted to make room.
    PrefixEvict {
        /// Evicted segment id.
        seg: u64,
        /// Tokens the segment covered.
        tokens: usize,
    },
    /// Cumulative per-executable backend timing, bridged from [`ExecStats`]
    /// at export time (not per call — that would be far too hot).
    ExecTotal {
        /// Executable name.
        name: String,
        /// Total invocations.
        calls: u64,
        /// Total seconds inside the executable.
        secs: f64,
    },
    /// Router placed a request on a replica (recorded on the router ring
    /// with the timestamp of the submit's *entry*, so the gap to the
    /// replica's own `Submitted` is the placement+channel cost).
    Routed {
        /// The replica-assigned, globally unique request id.
        id: u64,
        /// Chosen replica index.
        replica: usize,
        /// The chosen replica's prefix match for the prompt, tokens.
        matched: usize,
        /// The chosen replica's in-flight depth at placement.
        depth: usize,
        /// Why this replica won: `affinity` (longest match), `load`
        /// (cold, shallowest queue), `spill` (best match was overloaded),
        /// or `fallback` (earlier candidates raced to full).
        reason: &'static str,
        /// Per-replica `(match_len, depth)` probe results, by replica id.
        probes: Vec<(usize, usize)>,
    },
    /// Cross-replica prefix migration started (span start; paired with
    /// [`Event::MigrationEnd`] by `mig`).
    MigrationBegin {
        /// Router-assigned migration ordinal (1-based).
        mig: u64,
        /// Source replica holding the segment.
        src: usize,
        /// Destination replica the segment moves to.
        dst: usize,
    },
    /// Cross-replica prefix migration finished (span end).
    MigrationEnd {
        /// Router-assigned migration ordinal (matches the begin).
        mig: u64,
        /// Source replica.
        src: usize,
        /// Destination replica.
        dst: usize,
        /// The source's segment id (0 when the export found no match).
        seg: u64,
        /// Tokens of retained prefix in the payload (0 on no match).
        tokens: usize,
        /// Whether the destination actually adopted the segment — only
        /// adopted migrations count in `RouterStats::migrations`.
        adopted: bool,
    },
    /// Request shed at the router's door (every replica full).
    RouterShed {
        /// Replica count that all reported full.
        replicas: usize,
    },
    /// One placement probe round: how many replicas answered over the
    /// control channel vs from the cached radix digest.
    ProbeRound {
        /// Replicas probed over the control channel this round.
        probed: usize,
        /// Replicas served from the digest cache (no round-trip).
        cached: usize,
    },
}

impl Event {
    /// Stable lowercase tag used by the JSONL exporter.
    pub fn tag(&self) -> &'static str {
        match self {
            Event::Submitted { .. } => "submitted",
            Event::Rejected { .. } => "rejected",
            Event::Admitted { .. } => "admitted",
            Event::PrefillChunk { .. } => "prefill_chunk",
            Event::FirstToken { .. } => "first_token",
            Event::Token { .. } => "token",
            Event::Finished { .. } => "finished",
            Event::SpecRound { .. } => "spec_round",
            Event::Step { .. } => "step",
            Event::PrefixEvict { .. } => "prefix_evict",
            Event::ExecTotal { .. } => "exec_total",
            Event::Routed { .. } => "routed",
            Event::MigrationBegin { .. } => "migration_begin",
            Event::MigrationEnd { .. } => "migration_end",
            Event::RouterShed { .. } => "router_shed",
            Event::ProbeRound { .. } => "probe_round",
        }
    }
}

/// A recorded event with its timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct Rec {
    /// Timestamp in microseconds from the tracer's clock.
    pub ts_us: u64,
    /// The event payload.
    pub ev: Event,
}

struct Ring {
    cap: usize,
    dropped: u64,
    recs: VecDeque<Rec>,
}

struct Shared {
    /// `Arc` so N tracers (router + replicas) can share ONE timebase —
    /// the precondition for merging their rings onto a single timeline.
    clock: Arc<Clock>,
    ring: Mutex<Ring>,
}

/// Cheap cloneable tracing handle. Disabled is the default and costs one
/// branch per call site; enabled handles share one clock and one ring.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Shared>>,
}

impl fmt::Debug for Tracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tracer(enabled={})", self.enabled())
    }
}

impl Tracer {
    /// A disabled tracer: records nothing, allocates nothing.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    fn enabled_with(clock: Clock, cap: usize) -> Tracer {
        Tracer::with_clock(Arc::new(clock), cap)
    }

    /// An enabled tracer with its own ring over an existing clock. Fleet
    /// tracing builds every ring (router + each replica) over ONE shared
    /// clock so their timestamps merge onto a single timeline; a virtual
    /// tick stamped anywhere then advances the whole fleet.
    pub fn with_clock(clock: Arc<Clock>, cap: usize) -> Tracer {
        Tracer {
            inner: Some(Arc::new(Shared {
                clock,
                ring: Mutex::new(Ring { cap: cap.max(1), dropped: 0, recs: VecDeque::new() }),
            })),
        }
    }

    /// An enabled tracer on the deterministic virtual tick clock.
    pub fn virtual_ticks(cap: usize) -> Tracer {
        Tracer::enabled_with(Clock::virtual_ticks(), cap)
    }

    /// An enabled tracer on the wall clock (epoch = now).
    pub fn wall(cap: usize) -> Tracer {
        Tracer::enabled_with(Clock::wall(), cap)
    }

    /// Whether events are being recorded.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The tracer's clock handle (`None` when disabled) — clone it into
    /// [`Tracer::with_clock`] to build sibling rings on the same timebase.
    pub fn clock(&self) -> Option<Arc<Clock>> {
        self.inner.as_ref().map(|s| s.clock.clone())
    }

    /// Whether the clock is the deterministic virtual tick clock (false
    /// when disabled or on wall time).
    pub fn is_virtual(&self) -> bool {
        self.inner.as_ref().is_some_and(|s| s.clock.is_virtual())
    }

    /// Events overwritten because the ring was full — cheap (no ring
    /// copy), for the `trace_dropped_events` exposition counter.
    pub fn dropped(&self) -> u64 {
        match &self.inner {
            None => 0,
            Some(s) => s.ring.lock().unwrap().dropped,
        }
    }

    /// Current clock reading in microseconds (0 when disabled).
    pub fn now_us(&self) -> u64 {
        match &self.inner {
            Some(s) => s.clock.now_us(),
            None => 0,
        }
    }

    /// Advance the virtual clock (no-op when disabled or on wall clock).
    pub fn set_virtual_tick(&self, tick: u64) {
        if let Some(s) = &self.inner {
            s.clock.set_tick(tick);
        }
    }

    /// Record `ev` stamped with the current clock reading.
    pub fn record(&self, ev: Event) {
        if let Some(s) = &self.inner {
            let ts = s.clock.now_us();
            s.push(ts, ev);
        }
    }

    /// Record `ev` with an explicit timestamp (used for span starts measured
    /// before the work they cover).
    pub fn record_at(&self, ts_us: u64, ev: Event) {
        if let Some(s) = &self.inner {
            s.push(ts_us, ev);
        }
    }

    /// Bridge cumulative backend timing ([`crate::runtime::Backend::stats_snapshot`])
    /// into the trace as [`Event::ExecTotal`] records.
    pub fn record_exec_totals(&self, stats: &[(String, ExecStats)]) {
        if !self.enabled() {
            return;
        }
        for (name, s) in stats {
            self.record(Event::ExecTotal { name: name.clone(), calls: s.calls, secs: s.total_secs });
        }
    }

    /// Copy out everything currently in the ring.
    pub fn snapshot(&self) -> TraceLog {
        match &self.inner {
            None => TraceLog::default(),
            Some(s) => {
                let ring = s.ring.lock().unwrap();
                TraceLog { recs: ring.recs.iter().cloned().collect(), dropped: ring.dropped }
            }
        }
    }
}

impl Shared {
    fn push(&self, ts_us: u64, ev: Event) {
        let mut ring = self.ring.lock().unwrap();
        if ring.recs.len() == ring.cap {
            ring.recs.pop_front();
            ring.dropped += 1;
        }
        ring.recs.push_back(Rec { ts_us, ev });
    }
}

/// A snapshot of the ring: recorded events in order plus the overwrite count.
#[derive(Debug, Clone, Default)]
pub struct TraceLog {
    /// Events oldest-first.
    pub recs: Vec<Rec>,
    /// Events overwritten because the ring was full.
    pub dropped: u64,
}

/// Per-request lifecycle boundaries reconstructed from a [`TraceLog`].
///
/// The three segments partition the request's end-to-end time exactly:
/// `queued + prefill + decode == e2e` whenever all boundaries were recorded
/// (each is a difference of the same four timestamps). On a merged fleet
/// log (the router ring's [`Event::Routed`] plus the owning replica's
/// lifecycle) a fourth leading segment appears — `placement` (router
/// submit → replica submit) — and the four together tile
/// [`RequestSpans::routed_e2e_us`] exactly, telescoping over the same
/// five timestamps.
#[derive(Debug, Clone)]
pub struct RequestSpans {
    /// Request id.
    pub id: u64,
    /// Router-submit timestamp (µs), when a [`Event::Routed`] record for
    /// this id is in the log (fleet scope only).
    pub route_us: Option<u64>,
    /// The replica the router placed the request on (fleet scope only).
    pub replica: Option<usize>,
    /// Submission timestamp (µs).
    pub submit_us: u64,
    /// Admission timestamp, if the request left the queue.
    pub admit_us: Option<u64>,
    /// First-token timestamp, if any token was generated.
    pub first_us: Option<u64>,
    /// Finish timestamp, if the request completed or was cancelled.
    pub finish_us: Option<u64>,
    /// Decode lane, once admitted.
    pub lane: Option<usize>,
    /// Whether admission hit the prefix cache.
    pub hit: bool,
    /// Matched prefix length in tokens.
    pub matched: usize,
    /// Finish reason, once finished.
    pub reason: Option<&'static str>,
    /// Generated tokens at finish.
    pub tokens: usize,
}

impl RequestSpans {
    /// Placement + channel hop: router submit → replica submit (fleet
    /// logs only).
    pub fn placement_us(&self) -> Option<u64> {
        self.route_us.map(|r| self.submit_us - r)
    }

    /// End-to-end from the router's door: router submit → finish. With
    /// all five boundaries present,
    /// `placement + queued + prefill + decode == routed_e2e` exactly.
    pub fn routed_e2e_us(&self) -> Option<u64> {
        match (self.route_us, self.finish_us) {
            (Some(r), Some(e)) => Some(e - r),
            _ => None,
        }
    }

    /// Scheduler wait: submit → admit.
    pub fn queued_us(&self) -> Option<u64> {
        self.admit_us.map(|a| a - self.submit_us)
    }

    /// Prefill: admit → first token.
    pub fn prefill_us(&self) -> Option<u64> {
        match (self.admit_us, self.first_us) {
            (Some(a), Some(f)) => Some(f - a),
            _ => None,
        }
    }

    /// Decode: first token → finish.
    pub fn decode_us(&self) -> Option<u64> {
        match (self.first_us, self.finish_us) {
            (Some(f), Some(e)) => Some(e - f),
            _ => None,
        }
    }

    /// End-to-end: submit → finish.
    pub fn e2e_us(&self) -> Option<u64> {
        self.finish_us.map(|e| e - self.submit_us)
    }
}

/// Reconstruct per-request span boundaries, ordered by first appearance.
pub fn request_spans(log: &TraceLog) -> Vec<RequestSpans> {
    let mut order: Vec<u64> = Vec::new();
    let mut spans: std::collections::BTreeMap<u64, RequestSpans> = std::collections::BTreeMap::new();
    for r in &log.recs {
        let (id, ts) = match &r.ev {
            Event::Submitted { id, .. }
            | Event::Admitted { id, .. }
            | Event::FirstToken { id }
            | Event::Finished { id, .. }
            | Event::Routed { id, .. } => (*id, r.ts_us),
            _ => continue,
        };
        let e = spans.entry(id).or_insert_with(|| {
            order.push(id);
            RequestSpans {
                id,
                route_us: None,
                replica: None,
                submit_us: ts,
                admit_us: None,
                first_us: None,
                finish_us: None,
                lane: None,
                hit: false,
                matched: 0,
                reason: None,
                tokens: 0,
            }
        });
        match &r.ev {
            Event::Submitted { .. } => e.submit_us = ts,
            Event::Routed { replica, .. } => {
                e.route_us = Some(ts);
                e.replica = Some(*replica);
            }
            Event::Admitted { lane, hit, matched, .. } => {
                e.admit_us = Some(ts);
                e.lane = Some(*lane);
                e.hit = *hit;
                e.matched = *matched;
            }
            Event::FirstToken { .. } => {
                if e.first_us.is_none() {
                    e.first_us = Some(ts);
                }
            }
            Event::Finished { reason, tokens, .. } => {
                e.finish_us = Some(ts);
                e.reason = Some(reason);
                e.tokens = *tokens;
            }
            _ => {}
        }
    }
    order.into_iter().filter_map(|id| spans.remove(&id)).collect()
}

/// Merge N ring snapshots (which MUST share a clock — see
/// [`Tracer::with_clock`]) into one log, stable-sorted by timestamp so
/// cross-ring order follows the shared timeline and same-timestamp
/// records keep their (ring, recording) order. `dropped` counts sum.
/// Feeding the result to [`request_spans`] stitches routed lifecycles:
/// the router's `Routed` record and the owning replica's
/// submit/admit/first/finish land in one [`RequestSpans`].
pub fn merge_logs(logs: &[&TraceLog]) -> TraceLog {
    let mut recs: Vec<Rec> = Vec::with_capacity(logs.iter().map(|l| l.recs.len()).sum());
    for l in logs {
        recs.extend(l.recs.iter().cloned());
    }
    recs.sort_by_key(|r| r.ts_us); // stable: preserves per-ring order on ties
    TraceLog { recs, dropped: logs.iter().map(|l| l.dropped).sum() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        t.record(Event::FirstToken { id: 1 });
        t.set_virtual_tick(5);
        assert_eq!(t.now_us(), 0);
        let log = t.snapshot();
        assert!(log.recs.is_empty());
        assert_eq!(log.dropped, 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = Tracer::enabled_with(Clock::virtual_ticks(), 3);
        for i in 0..5u64 {
            t.set_virtual_tick(i);
            t.record(Event::FirstToken { id: i });
        }
        let log = t.snapshot();
        assert_eq!(log.dropped, 2);
        let ids: Vec<u64> = log
            .recs
            .iter()
            .map(|r| match r.ev {
                Event::FirstToken { id } => id,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn spans_partition_e2e_exactly() {
        let t = Tracer::virtual_ticks(64);
        t.record(Event::Submitted { id: 9, prompt: 4, max_new: 8 });
        t.set_virtual_tick(3);
        t.record(Event::Admitted { id: 9, lane: 1, hit: true, matched: 2 });
        t.set_virtual_tick(5);
        t.record(Event::FirstToken { id: 9 });
        t.set_virtual_tick(11);
        t.record(Event::Finished { id: 9, reason: "eos", tokens: 8 });
        let spans = request_spans(&t.snapshot());
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.lane, Some(1));
        assert!(s.hit);
        assert_eq!(s.matched, 2);
        assert_eq!(
            s.queued_us().unwrap() + s.prefill_us().unwrap() + s.decode_us().unwrap(),
            s.e2e_us().unwrap()
        );
        assert_eq!(s.e2e_us().unwrap(), 11 * super::super::clock::TICK_US);
    }
}
