//! Trace exporters: JSONL event log and Chrome trace-event JSON.
//!
//! The JSONL form is one compact JSON object per line in recording order —
//! under the virtual clock it is byte-identical across replays of the same
//! seeded trace, which is what the determinism tests pin. The Chrome form
//! loads in Perfetto / `chrome://tracing`: one track for the engine step
//! timeline, one per decode lane (prefill chunks, spec rounds), one per
//! request (nested `queued` / `prefill` / `decode` spans inside a `request`
//! span), plus backend exec totals and prefix-cache evictions.
//!
//! At fleet scope, [`merge_fleet`] rebases the router ring plus N replica
//! rings (which share one clock — [`super::Tracer::with_clock`]) onto a
//! single multi-process timeline: the router is pid 0, replica `r` is
//! pid `r + 1`, and every routed request additionally gets a pid-0 track
//! whose `placement → queued → prefill → decode` children tile the
//! router-submit → finish span exactly. [`fleet_jsonl`] is the matching
//! line format with a `pid` field per record, byte-stable under the
//! virtual clock like the single-ring form.

use crate::util::Json;

use super::trace::{merge_logs, request_spans, Event, TraceLog};

/// Append one event's payload fields to `o` in a fixed per-variant order
/// (shared by the single-ring and fleet JSONL forms).
fn rec_fields(o: &mut Json, ev: &Event) {
    match ev {
        Event::Submitted { id, prompt, max_new } => {
            o.set("id", Json::num(*id as f64));
            o.set("prompt", Json::num(*prompt as f64));
            o.set("max_new", Json::num(*max_new as f64));
        }
        Event::Rejected { id, cause } => {
            o.set("id", Json::num(*id as f64));
            o.set("cause", Json::str(cause));
        }
        Event::Admitted { id, lane, hit, matched } => {
            o.set("id", Json::num(*id as f64));
            o.set("lane", Json::num(*lane as f64));
            o.set("hit", Json::Bool(*hit));
            o.set("matched", Json::num(*matched as f64));
        }
        Event::PrefillChunk { id, lane, tokens } => {
            o.set("id", Json::num(*id as f64));
            o.set("lane", Json::num(*lane as f64));
            o.set("tokens", Json::num(*tokens as f64));
        }
        Event::FirstToken { id } => {
            o.set("id", Json::num(*id as f64));
        }
        Event::Token { id, tok } => {
            o.set("id", Json::num(*id as f64));
            o.set("tok", Json::num(*tok as f64));
        }
        Event::Finished { id, reason, tokens } => {
            o.set("id", Json::num(*id as f64));
            o.set("reason", Json::str(reason));
            o.set("tokens", Json::num(*tokens as f64));
        }
        Event::SpecRound { id, lane, drafted, accepted, rolled_back } => {
            o.set("id", Json::num(*id as f64));
            o.set("lane", Json::num(*lane as f64));
            o.set("drafted", Json::num(*drafted as f64));
            o.set("accepted", Json::num(*accepted as f64));
            o.set("rolled_back", Json::num(*rolled_back as f64));
        }
        Event::Step { step, active, queued, dur_us } => {
            o.set("step", Json::num(*step as f64));
            o.set("active", Json::num(*active as f64));
            o.set("queued", Json::num(*queued as f64));
            o.set("dur_us", Json::num(*dur_us as f64));
        }
        Event::PrefixEvict { seg, tokens } => {
            o.set("seg", Json::num(*seg as f64));
            o.set("tokens", Json::num(*tokens as f64));
        }
        Event::ExecTotal { name, calls, secs } => {
            o.set("name", Json::str(name));
            o.set("calls", Json::num(*calls as f64));
            o.set("secs", Json::num(*secs));
        }
        Event::Routed { id, replica, matched, depth, reason, probes } => {
            o.set("id", Json::num(*id as f64));
            o.set("replica", Json::num(*replica as f64));
            o.set("matched", Json::num(*matched as f64));
            o.set("depth", Json::num(*depth as f64));
            o.set("reason", Json::str(reason));
            o.set(
                "probes",
                Json::Arr(
                    probes
                        .iter()
                        .map(|(m, d)| {
                            Json::Arr(vec![Json::num(*m as f64), Json::num(*d as f64)])
                        })
                        .collect(),
                ),
            );
        }
        Event::MigrationBegin { mig, src, dst } => {
            o.set("mig", Json::num(*mig as f64));
            o.set("src", Json::num(*src as f64));
            o.set("dst", Json::num(*dst as f64));
        }
        Event::MigrationEnd { mig, src, dst, seg, tokens, adopted } => {
            o.set("mig", Json::num(*mig as f64));
            o.set("src", Json::num(*src as f64));
            o.set("dst", Json::num(*dst as f64));
            o.set("seg", Json::num(*seg as f64));
            o.set("tokens", Json::num(*tokens as f64));
            o.set("adopted", Json::Bool(*adopted));
        }
        Event::RouterShed { replicas } => {
            o.set("replicas", Json::num(*replicas as f64));
        }
        Event::ProbeRound { probed, cached } => {
            o.set("probed", Json::num(*probed as f64));
            o.set("cached", Json::num(*cached as f64));
        }
    }
}

/// Serialize the log as one compact JSON object per line (`ts` first, then
/// the event tag, then its fields in a fixed order).
pub fn jsonl(log: &TraceLog) -> String {
    let mut out = String::new();
    for r in &log.recs {
        let mut o = Json::obj();
        o.set("ts", Json::num(r.ts_us as f64));
        o.set("ev", Json::str(r.ev.tag()));
        rec_fields(&mut o, &r.ev);
        out.push_str(&o.to_string());
        out.push('\n');
    }
    out
}

const TID_ENGINE: u64 = 0;
const TID_BACKEND: u64 = 1;
const TID_PREFIX: u64 = 2;
const TID_LANE_BASE: u64 = 100;
const TID_REQ_BASE: u64 = 1_000;

/// The router's tracks in a merged fleet trace (pid 0).
const TID_ROUTER: u64 = 0;
const TID_MIGRATIONS: u64 = 1;

fn ev_base(name: &str, ph: &str, ts: u64, pid: u64, tid: u64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::str(name));
    o.set("ph", Json::str(ph));
    o.set("ts", Json::num(ts as f64));
    o.set("pid", Json::num(pid as f64));
    o.set("tid", Json::num(tid as f64));
    o
}

fn complete(name: &str, ts: u64, dur: u64, pid: u64, tid: u64, args: Json) -> Json {
    let mut o = ev_base(name, "X", ts, pid, tid);
    o.set("dur", Json::num(dur as f64));
    o.set("args", args);
    o
}

fn instant(name: &str, ts: u64, pid: u64, tid: u64, args: Json) -> Json {
    let mut o = ev_base(name, "i", ts, pid, tid);
    o.set("s", Json::str("t"));
    o.set("args", args);
    o
}

fn thread_name(pid: u64, tid: u64, name: &str) -> Json {
    let mut o = ev_base("thread_name", "M", 0, pid, tid);
    o.set("args", Json::from_pairs(vec![("name", Json::str(name))]));
    o
}

fn process_name(pid: u64, name: &str) -> Json {
    let mut o = ev_base("process_name", "M", 0, pid, TID_ENGINE);
    o.set("args", Json::from_pairs(vec![("name", Json::str(name))]));
    o
}

/// Build a Chrome trace-event JSON document from the log.
///
/// Track layout: tid 0 = engine step timeline, tid 1 = backend exec totals,
/// tid 2 = prefix-cache evictions, tid 100+lane = per-lane chunk/spec-round
/// instants, tid 1000+id = per-request lifecycle spans.
pub fn chrome_trace(log: &TraceLog) -> Json {
    let mut events: Vec<Json> = Vec::new();
    events.push(process_name(1, "puzzle-serve"));
    emit_log_tracks(&mut events, log, 1, 0);
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::str("ms"));
    doc.set("traceEvents", Json::Arr(events));
    doc
}

/// Emit one ring's full track set (engine steps, backend, prefix, lanes,
/// request lifecycles) under process `pid`, with every timestamp rebased
/// by `t0` — the shared-timeline origin a fleet merge subtracts.
fn emit_log_tracks(events: &mut Vec<Json>, log: &TraceLog, pid: u64, t0: u64) {
    let rb = |ts: u64| ts.saturating_sub(t0);
    let last_ts = log.recs.iter().map(|r| rb(r.ts_us)).max().unwrap_or(0);

    let mut lanes: Vec<u64> = Vec::new();
    let mut have_backend = false;
    let mut have_prefix = false;
    for r in &log.recs {
        match &r.ev {
            Event::PrefillChunk { lane, .. } | Event::SpecRound { lane, .. } => {
                let l = *lane as u64;
                if !lanes.contains(&l) {
                    lanes.push(l);
                }
            }
            Event::ExecTotal { .. } => have_backend = true,
            Event::PrefixEvict { .. } => have_prefix = true,
            _ => {}
        }
    }
    lanes.sort_unstable();
    let spans = request_spans(log);

    events.push(thread_name(pid, TID_ENGINE, "engine steps"));
    if have_backend {
        events.push(thread_name(pid, TID_BACKEND, "backend execs"));
    }
    if have_prefix {
        events.push(thread_name(pid, TID_PREFIX, "prefix cache"));
    }
    for &l in &lanes {
        events.push(thread_name(pid, TID_LANE_BASE + l, &format!("lane{l}")));
    }
    for s in &spans {
        events.push(thread_name(pid, TID_REQ_BASE + s.id, &format!("req{}", s.id)));
    }

    // Engine track: step spans plus door rejections, sorted by timestamp
    // with spans before instants at the same tick.
    let mut engine: Vec<(u64, u8, Json)> = Vec::new();
    if log.dropped > 0 {
        engine.push((
            0,
            1,
            instant(
                "ring_dropped",
                0,
                pid,
                TID_ENGINE,
                Json::from_pairs(vec![("count", Json::num(log.dropped as f64))]),
            ),
        ));
    }
    for r in &log.recs {
        match &r.ev {
            Event::Step { step, active, queued, dur_us } => {
                engine.push((
                    rb(r.ts_us),
                    0,
                    complete(
                        "step",
                        rb(r.ts_us),
                        (*dur_us).max(1),
                        pid,
                        TID_ENGINE,
                        Json::from_pairs(vec![
                            ("step", Json::num(*step as f64)),
                            ("active", Json::num(*active as f64)),
                            ("queued", Json::num(*queued as f64)),
                        ]),
                    ),
                ));
            }
            Event::Rejected { id, cause } => {
                engine.push((
                    rb(r.ts_us),
                    1,
                    instant(
                        "rejected",
                        rb(r.ts_us),
                        pid,
                        TID_ENGINE,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("cause", Json::str(cause)),
                        ]),
                    ),
                ));
            }
            _ => {}
        }
    }
    engine.sort_by_key(|(ts, kind, _)| (*ts, *kind));
    events.extend(engine.into_iter().map(|(_, _, e)| e));

    if have_backend {
        for r in &log.recs {
            if let Event::ExecTotal { name, calls, secs } = &r.ev {
                events.push(instant(
                    name,
                    rb(r.ts_us),
                    pid,
                    TID_BACKEND,
                    Json::from_pairs(vec![
                        ("calls", Json::num(*calls as f64)),
                        ("total_ms", Json::num(secs * 1e3)),
                    ]),
                ));
            }
        }
    }
    if have_prefix {
        for r in &log.recs {
            if let Event::PrefixEvict { seg, tokens } = &r.ev {
                events.push(instant(
                    "prefix_evict",
                    rb(r.ts_us),
                    pid,
                    TID_PREFIX,
                    Json::from_pairs(vec![
                        ("seg", Json::num(*seg as f64)),
                        ("tokens", Json::num(*tokens as f64)),
                    ]),
                ));
            }
        }
    }
    for &l in &lanes {
        for r in &log.recs {
            match &r.ev {
                Event::PrefillChunk { id, lane, tokens } if *lane as u64 == l => {
                    events.push(instant(
                        "prefill_chunk",
                        rb(r.ts_us),
                        pid,
                        TID_LANE_BASE + l,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("tokens", Json::num(*tokens as f64)),
                        ]),
                    ));
                }
                Event::SpecRound { id, lane, drafted, accepted, rolled_back }
                    if *lane as u64 == l =>
                {
                    events.push(instant(
                        "spec_round",
                        rb(r.ts_us),
                        pid,
                        TID_LANE_BASE + l,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("drafted", Json::num(*drafted as f64)),
                            ("accepted", Json::num(*accepted as f64)),
                            ("rolled_back", Json::num(*rolled_back as f64)),
                        ]),
                    ));
                }
                _ => {}
            }
        }
    }

    // Request tracks: an enclosing `request` span with the lifecycle
    // segments nested inside it (equal-boundary zero-width spans allowed).
    for s in &spans {
        let tid = TID_REQ_BASE + s.id;
        let submit = rb(s.submit_us);
        let end = s.finish_us.map(rb).unwrap_or(last_ts).max(submit);
        let mut args = Json::obj();
        args.set("id", Json::num(s.id as f64));
        args.set("hit", Json::Bool(s.hit));
        args.set("matched", Json::num(s.matched as f64));
        args.set("tokens", Json::num(s.tokens as f64));
        if let Some(rs) = s.reason {
            args.set("reason", Json::str(rs));
        }
        events.push(complete("request", submit, end - submit, pid, tid, args));
        if let Some(a) = s.admit_us.map(rb) {
            events.push(complete("queued", submit, a - submit, pid, tid, Json::obj()));
            if let Some(f) = s.first_us.map(rb) {
                events.push(complete("prefill", a, f - a, pid, tid, Json::obj()));
                if let Some(e) = s.finish_us.map(rb) {
                    events.push(complete("decode", f, e - f, pid, tid, Json::obj()));
                }
            }
        }
    }
}

/// One fleet's ring snapshots: the router's placement-side ring plus one
/// ring per replica, all recorded over ONE shared clock.
#[derive(Debug, Clone, Default)]
pub struct FleetLog {
    /// The router ring (`routed` / migration / shed / probe records).
    pub router: TraceLog,
    /// Replica rings, indexed by replica id.
    pub replicas: Vec<TraceLog>,
}

impl FleetLog {
    /// Sum of events overwritten across every ring in the fleet.
    pub fn dropped(&self) -> u64 {
        self.router.dropped + self.replicas.iter().map(|l| l.dropped).sum::<u64>()
    }

    /// All rings merged onto the shared timeline (router first, so
    /// same-timestamp `routed` records sort before the replica's
    /// `submitted`), ready for [`request_spans`] stitching.
    pub fn merged(&self) -> TraceLog {
        let mut logs: Vec<&TraceLog> = vec![&self.router];
        logs.extend(self.replicas.iter());
        merge_logs(&logs)
    }

    /// The earliest timestamp across every ring — the merge's timeline
    /// origin (everything is rebased so the trace starts at 0).
    fn t0(&self) -> u64 {
        std::iter::once(&self.router)
            .chain(self.replicas.iter())
            .flat_map(|l| l.recs.iter().map(|r| r.ts_us))
            .min()
            .unwrap_or(0)
    }
}

/// Merge a fleet's rings into one Chrome trace-event document on a single
/// rebased timeline: the router is **pid 0** (tid 0 = routing instants,
/// tid 1 = migration spans, tid 1000+id = stitched per-request lifecycle
/// tracks), replica `r` is **pid r+1** with its full single-engine track
/// set. Each routed request's pid-0 track nests
/// `placement → queued → prefill → decode` spans that tile the
/// router-submit → finish interval exactly (`verify_trace.py --fleet`
/// checks this structurally).
pub fn merge_fleet(fleet: &FleetLog) -> Json {
    let t0 = fleet.t0();
    let rb = |ts: u64| ts.saturating_sub(t0);
    let mut events: Vec<Json> = Vec::new();

    events.push(process_name(0, "puzzle-router"));
    for r in 0..fleet.replicas.len() {
        events.push(process_name(r as u64 + 1, &format!("puzzle-replica-{r}")));
    }
    events.push(thread_name(0, TID_ROUTER, "routing"));

    // Router timeline (tid 0): placement, shed, and probe-round instants
    // in recording order; ring loss surfaces like the engine track's.
    if fleet.router.dropped > 0 {
        events.push(instant(
            "ring_dropped",
            0,
            0,
            TID_ROUTER,
            Json::from_pairs(vec![("count", Json::num(fleet.router.dropped as f64))]),
        ));
    }
    let mut router_line: Vec<(u64, Json)> = Vec::new();
    for r in &fleet.router.recs {
        match &r.ev {
            Event::Routed { id, replica, matched, depth, reason, probes } => {
                router_line.push((
                    rb(r.ts_us),
                    instant(
                        "routed",
                        rb(r.ts_us),
                        0,
                        TID_ROUTER,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("replica", Json::num(*replica as f64)),
                            ("matched", Json::num(*matched as f64)),
                            ("depth", Json::num(*depth as f64)),
                            ("reason", Json::str(reason)),
                            (
                                "probes",
                                Json::str(
                                    &probes
                                        .iter()
                                        .map(|(m, d)| format!("{m}/{d}"))
                                        .collect::<Vec<_>>()
                                        .join(" "),
                                ),
                            ),
                        ]),
                    ),
                ));
            }
            Event::RouterShed { replicas } => {
                router_line.push((
                    rb(r.ts_us),
                    instant(
                        "router_shed",
                        rb(r.ts_us),
                        0,
                        TID_ROUTER,
                        Json::from_pairs(vec![("replicas", Json::num(*replicas as f64))]),
                    ),
                ));
            }
            Event::ProbeRound { probed, cached } => {
                router_line.push((
                    rb(r.ts_us),
                    instant(
                        "probe_round",
                        rb(r.ts_us),
                        0,
                        TID_ROUTER,
                        Json::from_pairs(vec![
                            ("probed", Json::num(*probed as f64)),
                            ("cached", Json::num(*cached as f64)),
                        ]),
                    ),
                ));
            }
            _ => {}
        }
    }
    router_line.sort_by_key(|(ts, _)| *ts);
    events.extend(router_line.into_iter().map(|(_, e)| e));

    // Migration track (tid 1): begin/end records paired by `mig` into
    // complete spans; a begin without its end becomes an instant marker
    // so partial records stay visible instead of vanishing.
    let mut begins: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
    let mut migrations: Vec<(u64, Json)> = Vec::new();
    for r in &fleet.router.recs {
        match &r.ev {
            Event::MigrationBegin { mig, .. } => {
                begins.insert(*mig, rb(r.ts_us));
            }
            Event::MigrationEnd { mig, src, dst, seg, tokens, adopted } => {
                let Some(start) = begins.remove(mig) else { continue };
                migrations.push((
                    start,
                    complete(
                        "migration",
                        start,
                        rb(r.ts_us) - start,
                        0,
                        TID_MIGRATIONS,
                        Json::from_pairs(vec![
                            ("mig", Json::num(*mig as f64)),
                            ("src", Json::num(*src as f64)),
                            ("dst", Json::num(*dst as f64)),
                            ("seg", Json::num(*seg as f64)),
                            ("tokens", Json::num(*tokens as f64)),
                            ("adopted", Json::Bool(*adopted)),
                        ]),
                    ),
                ));
            }
            _ => {}
        }
    }
    for (mig, ts) in begins {
        migrations.push((
            ts,
            instant(
                "migration_unpaired",
                ts,
                0,
                TID_MIGRATIONS,
                Json::from_pairs(vec![("mig", Json::num(mig as f64))]),
            ),
        ));
    }
    if !migrations.is_empty() {
        events.push(thread_name(0, TID_MIGRATIONS, "migrations"));
        migrations.sort_by_key(|(ts, _)| *ts);
        events.extend(migrations.into_iter().map(|(_, e)| e));
    }

    // Stitched per-request lifecycle tracks on the router pid: the global
    // id names the track, and the four children tile router-submit →
    // finish exactly (placement covers the placement+queue-hop gap the
    // replica-local view cannot see).
    let merged = fleet.merged();
    let last_ts = merged.recs.iter().map(|r| rb(r.ts_us)).max().unwrap_or(0);
    for s in request_spans(&merged) {
        let Some(route) = s.route_us.map(rb) else { continue };
        let tid = TID_REQ_BASE + s.id;
        events.push(thread_name(0, tid, &format!("req{}", s.id)));
        let end = s.finish_us.map(rb).unwrap_or(last_ts).max(route);
        let mut args = Json::obj();
        args.set("id", Json::num(s.id as f64));
        args.set("replica", Json::num(s.replica.unwrap_or(0) as f64));
        args.set("hit", Json::Bool(s.hit));
        args.set("matched", Json::num(s.matched as f64));
        args.set("tokens", Json::num(s.tokens as f64));
        if let Some(rs) = s.reason {
            args.set("reason", Json::str(rs));
        }
        events.push(complete("request", route, end - route, 0, tid, args));
        let submit = rb(s.submit_us);
        events.push(complete("placement", route, submit - route, 0, tid, Json::obj()));
        if let Some(a) = s.admit_us.map(rb) {
            events.push(complete("queued", submit, a - submit, 0, tid, Json::obj()));
            if let Some(f) = s.first_us.map(rb) {
                events.push(complete("prefill", a, f - a, 0, tid, Json::obj()));
                if let Some(e) = s.finish_us.map(rb) {
                    events.push(complete("decode", f, e - f, 0, tid, Json::obj()));
                }
            }
        }
    }

    // Each replica's own process, rebased onto the same timeline.
    for (r, log) in fleet.replicas.iter().enumerate() {
        emit_log_tracks(&mut events, log, r as u64 + 1, t0);
    }

    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::str("ms"));
    doc.set("traceEvents", Json::Arr(events));
    doc
}

/// The fleet's JSONL form: every ring's records merged onto the shared
/// timeline (rebased to start at 0), one object per line with the owning
/// process — `ts`, then `pid` (0 = router, r+1 = replica r), then the
/// event tag and fields. Same-timestamp records order router-first then
/// by replica, each ring keeping its recording order, so the bytes are
/// stable across identical virtual-clock replays.
pub fn fleet_jsonl(fleet: &FleetLog) -> String {
    let t0 = fleet.t0();
    let mut tagged: Vec<(u64, &super::trace::Rec)> = Vec::new();
    for r in &fleet.router.recs {
        tagged.push((0, r));
    }
    for (i, log) in fleet.replicas.iter().enumerate() {
        for r in &log.recs {
            tagged.push((i as u64 + 1, r));
        }
    }
    tagged.sort_by_key(|(_, r)| r.ts_us); // stable: pid order on ties
    let mut out = String::new();
    for (pid, r) in tagged {
        let mut o = Json::obj();
        o.set("ts", Json::num(r.ts_us.saturating_sub(t0) as f64));
        o.set("pid", Json::num(pid as f64));
        o.set("ev", Json::str(r.ev.tag()));
        rec_fields(&mut o, &r.ev);
        out.push_str(&o.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;

    fn sample_log() -> TraceLog {
        let t = Tracer::virtual_ticks(256);
        t.record(Event::Submitted { id: 1, prompt: 6, max_new: 4 });
        t.set_virtual_tick(1);
        t.record(Event::Admitted { id: 1, lane: 0, hit: false, matched: 0 });
        t.record(Event::PrefillChunk { id: 1, lane: 0, tokens: 6 });
        t.record_at(1_000, Event::Step { step: 0, active: 1, queued: 0, dur_us: 0 });
        t.set_virtual_tick(2);
        t.record(Event::FirstToken { id: 1 });
        t.record(Event::Token { id: 1, tok: 11 });
        t.set_virtual_tick(4);
        t.record(Event::Finished { id: 1, reason: "eos", tokens: 4 });
        t.snapshot()
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line_and_deterministic() {
        let log = sample_log();
        let a = jsonl(&log);
        let b = jsonl(&sample_log());
        assert_eq!(a, b, "same events must serialize byte-identically");
        let lines: Vec<&str> = a.lines().collect();
        assert_eq!(lines.len(), log.recs.len());
        for l in &lines {
            let v = Json::parse(l).unwrap();
            assert!(v.get("ts").is_some() && v.get("ev").is_some());
        }
        assert!(lines[0].contains("\"ev\":\"submitted\""));
    }

    #[test]
    fn chrome_trace_nests_request_spans() {
        let doc = chrome_trace(&sample_log());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Every event carries the required keys.
        for e in evs {
            for k in ["name", "ph", "ts", "pid", "tid"] {
                assert!(e.get(k).is_some(), "missing {k}: {}", e.to_string());
            }
        }
        let span = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").unwrap().as_str() == Some(name))
                .unwrap_or_else(|| panic!("no {name} span"))
        };
        let req = span("request");
        let (rts, rdur) = (
            req.get("ts").unwrap().as_f64().unwrap(),
            req.get("dur").unwrap().as_f64().unwrap(),
        );
        for child in ["queued", "prefill", "decode"] {
            let c = span(child);
            let ts = c.get("ts").unwrap().as_f64().unwrap();
            let dur = c.get("dur").unwrap().as_f64().unwrap();
            assert!(ts >= rts && ts + dur <= rts + rdur, "{child} escapes request span");
            assert_eq!(c.get("tid"), req.get("tid"));
        }
        // queued + prefill + decode tile the request span end to end.
        let total: f64 = ["queued", "prefill", "decode"]
            .into_iter()
            .map(|n| span(n).get("dur").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(total, rdur);
    }

    /// A hand-built two-replica fleet over one shared clock: the router
    /// ring records the placement-side events, each replica ring the
    /// local lifecycle, and the merge must stitch them into pid-0 request
    /// tracks whose four children tile router-submit → finish exactly.
    fn sample_fleet() -> FleetLog {
        let router = Tracer::virtual_ticks(256);
        let clock = router.clock().unwrap();
        let replicas: Vec<Tracer> =
            (0..2).map(|_| Tracer::with_clock(clock.clone(), 256)).collect();
        let gid = |r: u64, local: u64| (r << 48) | local;

        // Request A → replica 0: routed at t0, submitted t1, admitted t2,
        // first token t3, finished t5.
        router.record(Event::ProbeRound { probed: 2, cached: 0 });
        router.record(Event::Routed {
            id: gid(0, 1),
            replica: 0,
            matched: 0,
            depth: 0,
            reason: "load",
            probes: vec![(0, 0), (0, 0)],
        });
        router.set_virtual_tick(1);
        replicas[0].record(Event::Submitted { id: gid(0, 1), prompt: 4, max_new: 4 });
        router.set_virtual_tick(2);
        replicas[0].record(Event::Admitted { id: gid(0, 1), lane: 0, hit: false, matched: 0 });
        router.set_virtual_tick(3);
        replicas[0].record(Event::FirstToken { id: gid(0, 1) });
        router.set_virtual_tick(5);
        replicas[0].record(Event::Finished { id: gid(0, 1), reason: "eos", tokens: 4 });

        // Request B → replica 1 behind a migration from 0 to 1.
        router.set_virtual_tick(6);
        router.record(Event::ProbeRound { probed: 2, cached: 0 });
        router.record(Event::MigrationBegin { mig: 1, src: 0, dst: 1 });
        router.set_virtual_tick(7);
        router.record(Event::MigrationEnd {
            mig: 1,
            src: 0,
            dst: 1,
            seg: 3,
            tokens: 4,
            adopted: true,
        });
        router.record(Event::Routed {
            id: gid(1, 1),
            replica: 1,
            matched: 4,
            depth: 0,
            reason: "spill",
            probes: vec![(4, 9), (0, 0)],
        });
        router.set_virtual_tick(8);
        replicas[1].record(Event::Submitted { id: gid(1, 1), prompt: 6, max_new: 2 });
        replicas[1].record(Event::Admitted { id: gid(1, 1), lane: 0, hit: true, matched: 4 });
        router.set_virtual_tick(9);
        replicas[1].record(Event::FirstToken { id: gid(1, 1) });
        router.set_virtual_tick(10);
        replicas[1].record(Event::Finished { id: gid(1, 1), reason: "length", tokens: 2 });

        FleetLog {
            router: router.snapshot(),
            replicas: replicas.iter().map(|t| t.snapshot()).collect(),
        }
    }

    #[test]
    fn merge_fleet_stitches_and_tiles_routed_lifecycles() {
        let fleet = sample_fleet();
        let doc = merge_fleet(&fleet);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Router pid 0 and both replica pids are named.
        let pnames: Vec<(f64, String)> = evs
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some("process_name"))
            .map(|e| {
                (
                    e.get("pid").unwrap().as_f64().unwrap(),
                    e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string(),
                )
            })
            .collect();
        assert!(pnames.contains(&(0.0, "puzzle-router".into())));
        assert!(pnames.contains(&(1.0, "puzzle-replica-0".into())));
        assert!(pnames.contains(&(2.0, "puzzle-replica-1".into())));
        // Every routed request gets a pid-0 track whose placement +
        // queued + prefill + decode children tile the request span.
        let pid0_reqs: Vec<&Json> = evs
            .iter()
            .filter(|e| {
                e.get("pid").unwrap().as_f64() == Some(0.0)
                    && e.get("name").unwrap().as_str() == Some("request")
            })
            .collect();
        assert_eq!(pid0_reqs.len(), 2, "both routed requests get fleet tracks");
        for req in pid0_reqs {
            let tid = req.get("tid").unwrap().as_f64().unwrap();
            let rdur = req.get("dur").unwrap().as_f64().unwrap();
            let child_total: f64 = evs
                .iter()
                .filter(|e| {
                    e.get("pid").unwrap().as_f64() == Some(0.0)
                        && e.get("tid").unwrap().as_f64() == Some(tid)
                        && matches!(
                            e.get("name").unwrap().as_str(),
                            Some("placement" | "queued" | "prefill" | "decode")
                        )
                })
                .map(|e| e.get("dur").unwrap().as_f64().unwrap())
                .sum();
            assert_eq!(child_total, rdur, "fleet children must tile e2e exactly");
        }
        // The migration pair became one complete span on the migration track.
        let mig: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some("migration"))
            .collect();
        assert_eq!(mig.len(), 1);
        assert_eq!(mig[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(mig[0].get("args").unwrap().get("tokens").unwrap().as_f64(), Some(4.0));
        // Replica lifecycles still appear under their own pids.
        assert!(evs.iter().any(|e| e.get("pid").unwrap().as_f64() == Some(2.0)
            && e.get("name").unwrap().as_str() == Some("request")));
    }

    #[test]
    fn fleet_jsonl_is_byte_stable_and_tags_pids() {
        let a = fleet_jsonl(&sample_fleet());
        let b = fleet_jsonl(&sample_fleet());
        assert_eq!(a, b, "virtual-clock fleet JSONL must be byte-identical across builds");
        let mut saw_routed = false;
        let mut last_ts = 0.0;
        for l in a.lines() {
            let v = Json::parse(l).unwrap();
            let ts = v.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "fleet JSONL must be time-ordered");
            last_ts = ts;
            let pid = v.get("pid").unwrap().as_f64().unwrap();
            if v.get("ev").unwrap().as_str() == Some("routed") {
                saw_routed = true;
                assert_eq!(pid, 0.0, "routed records belong to the router pid");
            }
        }
        assert!(saw_routed);
    }
}
