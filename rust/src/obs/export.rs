//! Trace exporters: JSONL event log and Chrome trace-event JSON.
//!
//! The JSONL form is one compact JSON object per line in recording order —
//! under the virtual clock it is byte-identical across replays of the same
//! seeded trace, which is what the determinism tests pin. The Chrome form
//! loads in Perfetto / `chrome://tracing`: one track for the engine step
//! timeline, one per decode lane (prefill chunks, spec rounds), one per
//! request (nested `queued` / `prefill` / `decode` spans inside a `request`
//! span), plus backend exec totals and prefix-cache evictions.

use crate::util::Json;

use super::trace::{request_spans, Event, TraceLog};

/// Serialize the log as one compact JSON object per line (`ts` first, then
/// the event tag, then its fields in a fixed order).
pub fn jsonl(log: &TraceLog) -> String {
    let mut out = String::new();
    for r in &log.recs {
        let mut o = Json::obj();
        o.set("ts", Json::num(r.ts_us as f64));
        o.set("ev", Json::str(r.ev.tag()));
        match &r.ev {
            Event::Submitted { id, prompt, max_new } => {
                o.set("id", Json::num(*id as f64));
                o.set("prompt", Json::num(*prompt as f64));
                o.set("max_new", Json::num(*max_new as f64));
            }
            Event::Rejected { id, cause } => {
                o.set("id", Json::num(*id as f64));
                o.set("cause", Json::str(cause));
            }
            Event::Admitted { id, lane, hit, matched } => {
                o.set("id", Json::num(*id as f64));
                o.set("lane", Json::num(*lane as f64));
                o.set("hit", Json::Bool(*hit));
                o.set("matched", Json::num(*matched as f64));
            }
            Event::PrefillChunk { id, lane, tokens } => {
                o.set("id", Json::num(*id as f64));
                o.set("lane", Json::num(*lane as f64));
                o.set("tokens", Json::num(*tokens as f64));
            }
            Event::FirstToken { id } => {
                o.set("id", Json::num(*id as f64));
            }
            Event::Token { id, tok } => {
                o.set("id", Json::num(*id as f64));
                o.set("tok", Json::num(*tok as f64));
            }
            Event::Finished { id, reason, tokens } => {
                o.set("id", Json::num(*id as f64));
                o.set("reason", Json::str(reason));
                o.set("tokens", Json::num(*tokens as f64));
            }
            Event::SpecRound { id, lane, drafted, accepted, rolled_back } => {
                o.set("id", Json::num(*id as f64));
                o.set("lane", Json::num(*lane as f64));
                o.set("drafted", Json::num(*drafted as f64));
                o.set("accepted", Json::num(*accepted as f64));
                o.set("rolled_back", Json::num(*rolled_back as f64));
            }
            Event::Step { step, active, queued, dur_us } => {
                o.set("step", Json::num(*step as f64));
                o.set("active", Json::num(*active as f64));
                o.set("queued", Json::num(*queued as f64));
                o.set("dur_us", Json::num(*dur_us as f64));
            }
            Event::PrefixEvict { seg, tokens } => {
                o.set("seg", Json::num(*seg as f64));
                o.set("tokens", Json::num(*tokens as f64));
            }
            Event::ExecTotal { name, calls, secs } => {
                o.set("name", Json::str(name));
                o.set("calls", Json::num(*calls as f64));
                o.set("secs", Json::num(*secs));
            }
        }
        out.push_str(&o.to_string());
        out.push('\n');
    }
    out
}

const TID_ENGINE: u64 = 0;
const TID_BACKEND: u64 = 1;
const TID_PREFIX: u64 = 2;
const TID_LANE_BASE: u64 = 100;
const TID_REQ_BASE: u64 = 1_000;

fn ev_base(name: &str, ph: &str, ts: u64, tid: u64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::str(name));
    o.set("ph", Json::str(ph));
    o.set("ts", Json::num(ts as f64));
    o.set("pid", Json::num(1.0));
    o.set("tid", Json::num(tid as f64));
    o
}

fn complete(name: &str, ts: u64, dur: u64, tid: u64, args: Json) -> Json {
    let mut o = ev_base(name, "X", ts, tid);
    o.set("dur", Json::num(dur as f64));
    o.set("args", args);
    o
}

fn instant(name: &str, ts: u64, tid: u64, args: Json) -> Json {
    let mut o = ev_base(name, "i", ts, tid);
    o.set("s", Json::str("t"));
    o.set("args", args);
    o
}

fn thread_name(tid: u64, name: &str) -> Json {
    let mut o = ev_base("thread_name", "M", 0, tid);
    o.set("args", Json::from_pairs(vec![("name", Json::str(name))]));
    o
}

/// Build a Chrome trace-event JSON document from the log.
///
/// Track layout: tid 0 = engine step timeline, tid 1 = backend exec totals,
/// tid 2 = prefix-cache evictions, tid 100+lane = per-lane chunk/spec-round
/// instants, tid 1000+id = per-request lifecycle spans.
pub fn chrome_trace(log: &TraceLog) -> Json {
    let last_ts = log.recs.iter().map(|r| r.ts_us).max().unwrap_or(0);
    let mut events: Vec<Json> = Vec::new();

    // Metadata first: process name, then one thread_name per used track.
    let mut proc = ev_base("process_name", "M", 0, TID_ENGINE);
    proc.set("args", Json::from_pairs(vec![("name", Json::str("puzzle-serve"))]));
    events.push(proc);

    let mut lanes: Vec<u64> = Vec::new();
    let mut have_backend = false;
    let mut have_prefix = false;
    for r in &log.recs {
        match &r.ev {
            Event::PrefillChunk { lane, .. } | Event::SpecRound { lane, .. } => {
                let l = *lane as u64;
                if !lanes.contains(&l) {
                    lanes.push(l);
                }
            }
            Event::ExecTotal { .. } => have_backend = true,
            Event::PrefixEvict { .. } => have_prefix = true,
            _ => {}
        }
    }
    lanes.sort_unstable();
    let spans = request_spans(log);

    events.push(thread_name(TID_ENGINE, "engine steps"));
    if have_backend {
        events.push(thread_name(TID_BACKEND, "backend execs"));
    }
    if have_prefix {
        events.push(thread_name(TID_PREFIX, "prefix cache"));
    }
    for &l in &lanes {
        events.push(thread_name(TID_LANE_BASE + l, &format!("lane{l}")));
    }
    for s in &spans {
        events.push(thread_name(TID_REQ_BASE + s.id, &format!("req{}", s.id)));
    }

    // Engine track: step spans plus door rejections, sorted by timestamp
    // with spans before instants at the same tick.
    let mut engine: Vec<(u64, u8, Json)> = Vec::new();
    if log.dropped > 0 {
        engine.push((
            0,
            1,
            instant(
                "ring_dropped",
                0,
                TID_ENGINE,
                Json::from_pairs(vec![("count", Json::num(log.dropped as f64))]),
            ),
        ));
    }
    for r in &log.recs {
        match &r.ev {
            Event::Step { step, active, queued, dur_us } => {
                engine.push((
                    r.ts_us,
                    0,
                    complete(
                        "step",
                        r.ts_us,
                        (*dur_us).max(1),
                        TID_ENGINE,
                        Json::from_pairs(vec![
                            ("step", Json::num(*step as f64)),
                            ("active", Json::num(*active as f64)),
                            ("queued", Json::num(*queued as f64)),
                        ]),
                    ),
                ));
            }
            Event::Rejected { id, cause } => {
                engine.push((
                    r.ts_us,
                    1,
                    instant(
                        "rejected",
                        r.ts_us,
                        TID_ENGINE,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("cause", Json::str(cause)),
                        ]),
                    ),
                ));
            }
            _ => {}
        }
    }
    engine.sort_by_key(|(ts, kind, _)| (*ts, *kind));
    events.extend(engine.into_iter().map(|(_, _, e)| e));

    if have_backend {
        for r in &log.recs {
            if let Event::ExecTotal { name, calls, secs } = &r.ev {
                events.push(instant(
                    name,
                    r.ts_us,
                    TID_BACKEND,
                    Json::from_pairs(vec![
                        ("calls", Json::num(*calls as f64)),
                        ("total_ms", Json::num(secs * 1e3)),
                    ]),
                ));
            }
        }
    }
    if have_prefix {
        for r in &log.recs {
            if let Event::PrefixEvict { seg, tokens } = &r.ev {
                events.push(instant(
                    "prefix_evict",
                    r.ts_us,
                    TID_PREFIX,
                    Json::from_pairs(vec![
                        ("seg", Json::num(*seg as f64)),
                        ("tokens", Json::num(*tokens as f64)),
                    ]),
                ));
            }
        }
    }
    for &l in &lanes {
        for r in &log.recs {
            match &r.ev {
                Event::PrefillChunk { id, lane, tokens } if *lane as u64 == l => {
                    events.push(instant(
                        "prefill_chunk",
                        r.ts_us,
                        TID_LANE_BASE + l,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("tokens", Json::num(*tokens as f64)),
                        ]),
                    ));
                }
                Event::SpecRound { id, lane, drafted, accepted, rolled_back }
                    if *lane as u64 == l =>
                {
                    events.push(instant(
                        "spec_round",
                        r.ts_us,
                        TID_LANE_BASE + l,
                        Json::from_pairs(vec![
                            ("id", Json::num(*id as f64)),
                            ("drafted", Json::num(*drafted as f64)),
                            ("accepted", Json::num(*accepted as f64)),
                            ("rolled_back", Json::num(*rolled_back as f64)),
                        ]),
                    ));
                }
                _ => {}
            }
        }
    }

    // Request tracks: an enclosing `request` span with the lifecycle
    // segments nested inside it (equal-boundary zero-width spans allowed).
    for s in &spans {
        let tid = TID_REQ_BASE + s.id;
        let end = s.finish_us.unwrap_or(last_ts).max(s.submit_us);
        let mut args = Json::obj();
        args.set("id", Json::num(s.id as f64));
        args.set("hit", Json::Bool(s.hit));
        args.set("matched", Json::num(s.matched as f64));
        args.set("tokens", Json::num(s.tokens as f64));
        if let Some(rs) = s.reason {
            args.set("reason", Json::str(rs));
        }
        events.push(complete("request", s.submit_us, end - s.submit_us, tid, args));
        if let Some(a) = s.admit_us {
            events.push(complete(
                "queued",
                s.submit_us,
                a - s.submit_us,
                tid,
                Json::obj(),
            ));
            if let Some(f) = s.first_us {
                events.push(complete("prefill", a, f - a, tid, Json::obj()));
                if let Some(e) = s.finish_us {
                    events.push(complete("decode", f, e - f, tid, Json::obj()));
                }
            }
        }
    }

    let mut doc = Json::obj();
    doc.set("displayTimeUnit", Json::str("ms"));
    doc.set("traceEvents", Json::Arr(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;

    fn sample_log() -> TraceLog {
        let t = Tracer::virtual_ticks(256);
        t.record(Event::Submitted { id: 1, prompt: 6, max_new: 4 });
        t.set_virtual_tick(1);
        t.record(Event::Admitted { id: 1, lane: 0, hit: false, matched: 0 });
        t.record(Event::PrefillChunk { id: 1, lane: 0, tokens: 6 });
        t.record_at(1_000, Event::Step { step: 0, active: 1, queued: 0, dur_us: 0 });
        t.set_virtual_tick(2);
        t.record(Event::FirstToken { id: 1 });
        t.record(Event::Token { id: 1, tok: 11 });
        t.set_virtual_tick(4);
        t.record(Event::Finished { id: 1, reason: "eos", tokens: 4 });
        t.snapshot()
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line_and_deterministic() {
        let log = sample_log();
        let a = jsonl(&log);
        let b = jsonl(&sample_log());
        assert_eq!(a, b, "same events must serialize byte-identically");
        let lines: Vec<&str> = a.lines().collect();
        assert_eq!(lines.len(), log.recs.len());
        for l in &lines {
            let v = Json::parse(l).unwrap();
            assert!(v.get("ts").is_some() && v.get("ev").is_some());
        }
        assert!(lines[0].contains("\"ev\":\"submitted\""));
    }

    #[test]
    fn chrome_trace_nests_request_spans() {
        let doc = chrome_trace(&sample_log());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Every event carries the required keys.
        for e in evs {
            for k in ["name", "ph", "ts", "pid", "tid"] {
                assert!(e.get(k).is_some(), "missing {k}: {}", e.to_string());
            }
        }
        let span = |name: &str| {
            evs.iter()
                .find(|e| e.get("name").unwrap().as_str() == Some(name))
                .unwrap_or_else(|| panic!("no {name} span"))
        };
        let req = span("request");
        let (rts, rdur) = (
            req.get("ts").unwrap().as_f64().unwrap(),
            req.get("dur").unwrap().as_f64().unwrap(),
        );
        for child in ["queued", "prefill", "decode"] {
            let c = span(child);
            let ts = c.get("ts").unwrap().as_f64().unwrap();
            let dur = c.get("dur").unwrap().as_f64().unwrap();
            assert!(ts >= rts && ts + dur <= rts + rdur, "{child} escapes request span");
            assert_eq!(c.get("tid"), req.get("tid"));
        }
        // queued + prefill + decode tile the request span end to end.
        let total: f64 = ["queued", "prefill", "decode"]
            .into_iter()
            .map(|n| span(n).get("dur").unwrap().as_f64().unwrap())
            .sum();
        assert_eq!(total, rdur);
    }
}
