//! Puzzle CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   pipeline    run the full Puzzle pipeline (parent -> BLD -> score ->
//!               MIP -> GKD -> eval) and print the summary
//!   exp <name>  regenerate a paper table/figure (table1..table17, fig4..fig8, all)
//!   serve       serving-engine demo over the chosen child
//!   measure     print measured per-block costs on this machine
//!   info        artifact/search-space summary
//!
//! Common flags: --config tiny|small|base  --run-dir DIR  --scale F
//!               --speedup X  --seed N

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use puzzle::arch::{Arch, SearchSpace};
use puzzle::data::corpus::sample_sequence;
use puzzle::experiments::{self, ExpCtx};
use puzzle::perf::{CostTable, Scenario};
use puzzle::pipeline::{Pipeline, StageCfg};
use puzzle::runtime::Registry;
use puzzle::scoring::Metric;
use puzzle::serving::Engine;
use puzzle::train::LossSpec;
use puzzle::util::{Args, Rng};
use puzzle::{eval::Evaluator, info};

fn open_registry(args: &Args) -> Result<Registry> {
    let config = args.str("config", "tiny");
    let dir = PathBuf::from(args.str("artifacts", "artifacts")).join(&config);
    Registry::open(&dir)
}

fn stage_cfg(args: &Args) -> StageCfg {
    let mut cfg = StageCfg::scaled(args.f64("scale", 1.0));
    cfg.seed = args.u64("seed", 42);
    if let Some(s) = args.get("parent-steps") {
        cfg.parent_steps = s.parse().unwrap_or(cfg.parent_steps);
    }
    if let Some(s) = args.get("bld-steps") {
        cfg.bld_steps = s.parse().unwrap_or(cfg.bld_steps);
    }
    if let Some(s) = args.get("gkd-steps") {
        cfg.gkd_steps = s.parse().unwrap_or(cfg.gkd_steps);
    }
    cfg
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", reg.man.cfg.name)));
    let pipe = Pipeline::new(&reg, &run_dir, stage_cfg(args))?;
    let space = SearchSpace::full(reg.man.cfg.n_heads as u32);
    info!(
        "search space: {} attn x {} ffn = {} per layer; |space| ~ 10^{:.1}",
        space.attn.len(),
        space.ffn.len(),
        space.per_layer_combinations(),
        space.log10_size(reg.man.cfg.n_layers)
    );
    let library = pipe.ensure_library(&space)?;
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let ct = pipe.default_cost_table();
    let speedup = args.f64("speedup", 1.8);
    let sol = pipe.search_speedup(&space, &scores, &ct, speedup)?;
    pipe.save_arch("cli", &sol)?;
    println!("chosen architecture: {}", sol.arch.signature());
    let mut child = library.clone();
    let rep = pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), pipe.cfg.gkd_steps)?;
    child.save(&run_dir.join("child_cli.pzw"))?;
    // final eval
    let parent_arch = Arch::parent(reg.man.cfg.n_layers);
    let pe = Evaluator::new(&reg, &library, &parent_arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    let ce = Evaluator::new(&reg, &child, &sol.arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    println!("parent: {}", pe.row());
    println!("child : {}", ce.row());
    println!(
        "accuracy preserved: {:.1}% | modeled H100 speedup: {:.2}x | val KLD {:.4}",
        100.0 * ce.accuracy() / pe.accuracy().max(1e-9),
        sol.throughput / ct.arch_throughput(&parent_arch),
        rep.val_kld
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: puzzle exp <table1..table17|fig4..fig8|all>"))?
        .clone();
    let reg = open_registry(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", reg.man.cfg.name)));
    let pipe = Pipeline::new(&reg, &run_dir, stage_cfg(args))?;
    let ctx = ExpCtx::new(pipe);
    experiments::run(&ctx, &name)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", reg.man.cfg.name)));
    let pipe = Pipeline::new(&reg, &run_dir, stage_cfg(args))?;
    let space = SearchSpace::full(reg.man.cfg.n_heads as u32);
    let library = pipe.ensure_library(&space)?;
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let ct = pipe.default_cost_table();
    let sol = pipe.search_speedup(&space, &scores, &ct, args.f64("speedup", 1.8))?;
    let mut eng = Engine::new(&reg, &library, &sol.arch, 64 << 20)?;
    let n_req = args.usize("requests", 16);
    let mut rng = Rng::new(1);
    let c = &reg.man.cfg;
    for _ in 0..n_req {
        let plen = rng.range(4, c.s_prefill.min(32));
        let prompt = sample_sequence(&pipe.world, &pipe.mix, plen, &mut rng);
        eng.submit(prompt, args.usize("max-new", 24));
    }
    let responses = eng.run_to_completion()?;
    println!("served {} requests | {}", responses.len(), eng.metrics.summary());
    Ok(())
}

fn cmd_measure(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let c = &reg.man.cfg;
    let sc = Scenario { prefill: c.s_prefill, decode: c.s_prefill, batch: c.b_decode };
    let ct = CostTable::measured(&reg, &sc, args.usize("reps", 5))?;
    println!("measured per-variant scenario costs on this machine ({}):", sc.name());
    println!("{:<12} {:>12} {:>12} {:>14}", "attn", "secs", "params", "kv bytes/seq");
    for (k, (s, p, kv)) in &ct.attn {
        println!("{:<12} {:>12.5} {:>12.0} {:>14.0}", k, s, p, kv);
    }
    println!("{:<12} {:>12} {:>12}", "ffn", "secs", "params");
    for (k, (s, p, _)) in &ct.ffn {
        println!("{:<12} {:>12.5} {:>12.0}", k, s, p);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let reg = open_registry(args)?;
    let c = &reg.man.cfg;
    let space = SearchSpace::full(c.n_heads as u32);
    println!("config {} | d {} L {} heads {} i {} v {}", c.name, c.d, c.n_layers, c.n_heads, c.i, c.v);
    println!("executables: {}", reg.man.execs.len());
    println!(
        "search space: {}x{}={} per layer; 10^{:.1} total",
        space.attn.len(),
        space.ffn.len(),
        space.per_layer_combinations(),
        space.log10_size(c.n_layers)
    );
    Ok(())
}

fn main() -> Result<()> {
    puzzle::util::log::init();
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("pipeline") => cmd_pipeline(&args),
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("measure") => cmd_measure(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: puzzle <pipeline|exp|serve|measure|info> [--config tiny|small|base] [--run-dir DIR] [--scale F] [--speedup X]"
            );
            Ok(())
        }
    }
}
