//! Puzzle CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   pipeline    run the full Puzzle pipeline (parent -> BLD -> score ->
//!               MIP -> GKD -> eval) and print the summary
//!   exp `<name>` regenerate a paper table/figure (table1..table17, fig4..fig8, all)
//!   serve       serving-engine demo over the chosen child; --speculate
//!               serves the parent with the child as speculative drafter;
//!               --async serves through the threaded front-end (many
//!               client threads, one engine worker), optionally with
//!               --prefill-budget N chunked prefill and --replicas N
//!               data-parallel engines behind the cache-aware router
//!   bench-workload  replay a seeded workload trace against plain,
//!               prefix-cache, and speculative configs; score goodput
//!               under (TTFT, ITL) SLOs -> BENCH_workloads.json
//!   bench-async replay one trace in wall-clock time through the async
//!               server, chunked vs unchunked prefill, checking byte
//!               identity against the sync replay ->
//!               BENCH_serving_async.json
//!   bench-router  replay one bursty shared-prefix trace open-loop
//!               through a bare server vs an N-replica router (cache-
//!               aware placement + prefix migration), checking byte
//!               identity against the sync replay -> BENCH_router.json
//!   measure     print measured per-block costs on this machine
//!   info        backend/search-space summary
//!
//! Common flags: --backend ref|pjrt  --config tiny|small  --run-dir DIR
//!               --scale F  --speedup X  --seed N
//!
//! The default `ref` backend is hermetic (in-memory synthetic manifest,
//! pure-Rust execution); `--backend pjrt` needs the `pjrt` cargo feature,
//! the external `xla` crate, and `make artifacts`.

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use puzzle::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use puzzle::config::TinyManifest;
use puzzle::data::corpus::sample_sequence;
use puzzle::experiments::{self, ExpCtx};
use puzzle::obs::{self, Tracer, DEFAULT_RING_CAP};
use puzzle::perf::{CostTable, HwProfile, Scenario};
use puzzle::pipeline::{Pipeline, StageCfg};
use puzzle::runtime::{share, RefBackend, SharedBackend};
use puzzle::scoring::Metric;
use puzzle::serving::{Engine, EngineConfig, GenRequest, SamplingParams, SchedulerKind, StreamEvent};
use puzzle::specdec::{SpecBatch, SpecConfig, SpecRequest};
use puzzle::train::LossSpec;
use puzzle::util::{Args, Rng};
use puzzle::weights::store::init_parent;
use puzzle::workload::{default_profiles, goodput, replay, report_json, MixKind, Server, TraceSpec};
use puzzle::{bld, eval::Evaluator, info};

fn open_backend(args: &Args) -> Result<SharedBackend> {
    let config = args.str("config", "tiny");
    let backend = args.str("backend", "ref");
    match backend.as_str() {
        "ref" => {
            let man = match config.as_str() {
                "tiny" => TinyManifest::synthetic(),
                "small" => TinyManifest::synthetic_small(),
                other => return Err(anyhow!("ref backend has no synthetic config '{other}' (tiny|small)")),
            };
            Ok(share(RefBackend::new(man)))
        }
        "pjrt" => open_pjrt(args, &config),
        other => Err(anyhow!("unknown backend '{other}' (ref|pjrt)")),
    }
}

#[cfg(feature = "pjrt")]
fn open_pjrt(args: &Args, config: &str) -> Result<SharedBackend> {
    let dir = PathBuf::from(args.str("artifacts", "artifacts")).join(config);
    Ok(share(puzzle::runtime::XlaBackend::open(&dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(_args: &Args, _config: &str) -> Result<SharedBackend> {
    Err(anyhow!("built without the `pjrt` feature; rebuild with --features pjrt"))
}

/// Resolve a trace-output flag to a path, failing at startup (not after
/// the run) when the path cannot be created.
fn trace_sink(args: &Args, key: &str) -> Result<Option<PathBuf>> {
    let Some(p) = args.get(key) else { return Ok(None) };
    let p = PathBuf::from(p);
    std::fs::File::create(&p)
        .map_err(|e| anyhow!("--{key} {} is not writable: {e}", p.display()))?;
    Ok(Some(p))
}

/// Export the tracer's log: Chrome trace-event JSON to `chrome`, JSONL to
/// `jsonl_path` (either may be absent). Backend exec totals are bridged
/// into the log here, once, at export time.
fn export_trace(
    tracer: &Tracer,
    be: &SharedBackend,
    chrome: &Option<PathBuf>,
    jsonl_path: &Option<PathBuf>,
) -> Result<()> {
    if !tracer.enabled() {
        return Ok(());
    }
    tracer.record_exec_totals(&be.stats_snapshot());
    let log = tracer.snapshot();
    if log.dropped > 0 {
        eprintln!(
            "warning: {} trace events dropped (ring full) — the exported timeline has holes; \
             raise the ring capacity",
            log.dropped
        );
    }
    if let Some(p) = chrome {
        std::fs::write(p, obs::chrome_trace(&log).to_pretty())?;
        println!("wrote {} ({} events, {} dropped)", p.display(), log.recs.len(), log.dropped);
    }
    if let Some(p) = jsonl_path {
        std::fs::write(p, obs::jsonl(&log))?;
        println!("wrote {} ({} events, {} dropped)", p.display(), log.recs.len(), log.dropped);
    }
    Ok(())
}

/// Export a merged fleet trace — the router ring plus every replica ring,
/// rebased onto one timeline (meaningful because all tracers shared one
/// clock): Chrome trace-event JSON to `chrome` (router = pid 0, replica r
/// = pid r+1), time-ordered JSONL to `jsonl_path`. Warns when any ring
/// overwrote records: a dropped event means the merged timeline has
/// holes and the ring capacity should grow.
fn export_fleet_trace(
    fleet: &obs::FleetLog,
    chrome: &Option<PathBuf>,
    jsonl_path: &Option<PathBuf>,
) -> Result<()> {
    if fleet.dropped() > 0 {
        eprintln!(
            "warning: {} trace events dropped fleet-wide (ring full) — the merged timeline has \
             holes; raise the ring capacity",
            fleet.dropped()
        );
    }
    let events =
        fleet.router.recs.len() + fleet.replicas.iter().map(|l| l.recs.len()).sum::<usize>();
    let rings = fleet.replicas.len() + 1;
    if let Some(p) = chrome {
        std::fs::write(p, obs::merge_fleet(fleet).to_pretty())?;
        println!(
            "wrote {} ({events} events across {rings} rings, {} dropped)",
            p.display(),
            fleet.dropped()
        );
    }
    if let Some(p) = jsonl_path {
        std::fs::write(p, obs::fleet_jsonl(fleet))?;
        println!(
            "wrote {} ({events} events across {rings} rings, {} dropped)",
            p.display(),
            fleet.dropped()
        );
    }
    Ok(())
}

fn stage_cfg(args: &Args) -> StageCfg {
    let mut cfg = StageCfg::scaled(args.f64("scale", 1.0));
    cfg.seed = args.u64("seed", 42);
    if let Some(s) = args.get("parent-steps") {
        cfg.parent_steps = s.parse().unwrap_or(cfg.parent_steps);
    }
    if let Some(s) = args.get("bld-steps") {
        cfg.bld_steps = s.parse().unwrap_or(cfg.bld_steps);
    }
    if let Some(s) = args.get("gkd-steps") {
        cfg.gkd_steps = s.parse().unwrap_or(cfg.gkd_steps);
    }
    cfg
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", be.man().cfg.name)));
    let pipe = Pipeline::new(be.clone(), &run_dir, stage_cfg(args))?;
    let space = SearchSpace::full(be.man().cfg.n_heads as u32);
    info!(
        "search space: {} attn x {} ffn = {} per layer; |space| ~ 10^{:.1}",
        space.attn.len(),
        space.ffn.len(),
        space.per_layer_combinations(),
        space.log10_size(be.man().cfg.n_layers)
    );
    let library = pipe.ensure_library(&space)?;
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let ct = pipe.default_cost_table();
    let speedup = args.f64("speedup", 1.8);
    let sol = pipe.search_speedup(&space, &scores, &ct, speedup)?;
    pipe.save_arch("cli", &sol)?;
    println!("chosen architecture: {}", sol.arch.signature());
    let mut child = library.clone();
    let rep = pipe.gkd_child(&mut child, &sol.arch, LossSpec::gkd_best(), pipe.cfg.gkd_steps)?;
    child.save(&run_dir.join("child_cli.pzw"))?;
    // final eval
    let parent_arch = Arch::parent(be.man().cfg.n_layers);
    let pe = Evaluator::new(&*be, &library, &parent_arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    let ce = Evaluator::new(&*be, &child, &sol.arch)?
        .run_suite(&pipe.world, pipe.cfg.eval_questions, 7)?;
    println!("parent: {}", pe.row());
    println!("child : {}", ce.row());
    println!(
        "accuracy preserved: {:.1}% | modeled H100 speedup: {:.2}x | val KLD {:.4}",
        100.0 * ce.accuracy() / pe.accuracy().max(1e-9),
        sol.throughput / ct.arch_throughput(&parent_arch),
        rep.val_kld
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: puzzle exp <table1..table17|fig4..fig8|all>"))?
        .clone();
    let be = open_backend(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", be.man().cfg.name)));
    let pipe = Pipeline::new(be.clone(), &run_dir, stage_cfg(args))?;
    let ctx = ExpCtx::new(pipe);
    experiments::run(&ctx, &name)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let run_dir = PathBuf::from(args.str("run-dir", &format!("runs/{}", be.man().cfg.name)));
    let pipe = Pipeline::new(be.clone(), &run_dir, stage_cfg(args))?;
    let space = SearchSpace::full(be.man().cfg.n_heads as u32);
    if args.flag("speculate") {
        return cmd_serve_speculative(args, &be, &pipe, &space);
    }
    let library = pipe.ensure_library(&space)?;
    let scores = pipe.ensure_scores(&space, Metric::Kl)?;
    let ct = pipe.default_cost_table();
    let sol = pipe.search_speedup(&space, &scores, &ct, args.f64("speedup", 1.8))?;
    let scheduler = args.str("scheduler", "fifo");
    let scheduler = SchedulerKind::parse(&scheduler)
        .ok_or_else(|| anyhow!("unknown scheduler '{scheduler}' (fifo|priority|spf|prefix)"))?;
    let chrome = trace_sink(args, "trace-out")?;
    let jsonl_p = trace_sink(args, "trace-jsonl")?;
    // --scrape wants the live SLO burn-rate gauges, which fold from the
    // trace rings — a scrape request enables tracing even without an
    // export sink
    let tracer = if chrome.is_some() || jsonl_p.is_some() || args.flag("scrape") {
        Tracer::wall(DEFAULT_RING_CAP)
    } else {
        Tracer::disabled()
    };
    let mut ecfg = EngineConfig::new()
        .kv_budget_bytes(64 << 20)
        .scheduler(scheduler)
        .prefix_cache(args.flag("prefix-cache"), args.usize("retain-budget", 8 << 20))
        .tracer(tracer.clone());
    if let Some(b) = args.get("prefill-budget") {
        let b: usize =
            b.parse().map_err(|_| anyhow!("--prefill-budget wants a token count, got '{b}'"))?;
        ecfg = ecfg.prefill_budget(b);
    }
    if args.flag("async") {
        // --replicas N: N identical engines behind the data-parallel
        // router; 1 (the default) serves through a bare AsyncServer.
        // With N > 1 and tracing on, each replica gets its OWN ring over
        // the router tracer's clock, so the per-process logs rebase onto
        // one fleet timeline at export (DESIGN.md §13).
        let replicas = args.usize("replicas", 1).max(1);
        let engines = (0..replicas)
            .map(|_| {
                let mut ec = ecfg.clone();
                if replicas > 1 {
                    ec = ec.tracer(match tracer.clock() {
                        Some(clock) => Tracer::with_clock(clock, DEFAULT_RING_CAP),
                        None => Tracer::disabled(),
                    });
                }
                ec.build(be.clone(), &library, &sol.arch)
            })
            .collect::<Result<Vec<_>>>()?;
        return cmd_serve_async(args, &be, &pipe, engines, &tracer);
    }
    let mut eng = ecfg.build(be.clone(), &library, &sol.arch)?;
    let n_req = args.usize("requests", 16);
    let temperature = args.f64("temperature", 0.0) as f32;
    let seed = args.u64("seed", 42);
    let mut rng = Rng::new(1);
    let c = &be.man().cfg;
    for i in 0..n_req {
        let plen = rng.range(4, c.s_prefill.min(32));
        let prompt = sample_sequence(&pipe.world, &pipe.mix, plen, &mut rng);
        let sampling = if temperature > 0.0 {
            SamplingParams::temperature(temperature).with_seed(seed ^ i as u64)
        } else {
            SamplingParams::greedy()
        };
        eng.submit(
            GenRequest::new(prompt, args.usize("max-new", 24))
                .with_priority((i % 3) as i32)
                .with_sampling(sampling),
        )?;
    }
    let responses = if args.flag("stream") {
        // step-driven event loop: print tokens as the engine produces them
        while !eng.is_idle() {
            for ev in eng.step()? {
                match ev {
                    StreamEvent::Token { id, tok } => println!("  req {id}: token {tok}"),
                    StreamEvent::Finished { id, reason } => {
                        println!("  req {id}: finished ({})", reason.as_str())
                    }
                    StreamEvent::Rejected { id, cause } => {
                        println!("  req {id}: rejected ({cause})")
                    }
                }
            }
        }
        eng.take_finished()
    } else {
        eng.run_to_completion()?
    };
    println!(
        "served {} requests ({} scheduler) | {}",
        responses.len(),
        eng.scheduler_name(),
        eng.metrics.summary()
    );
    if eng.prefix_enabled() {
        println!(
            "prefix cache: {} retained segments holding {} KiB ({} prompt tokens served from cache)",
            eng.prefix_segments(),
            eng.prefix_retained_bytes() / 1024,
            eng.metrics.prefix_tokens_saved
        );
    }
    export_trace(&tracer, &be, &chrome, &jsonl_p)?;
    Ok(())
}

/// `serve --async`: the same request mix as the synchronous path, but
/// submitted from `--clients` concurrent threads through the threaded
/// front-end — each client holds a cloned handle, streams its
/// completions token by token, and a worker thread owns each engine.
/// With one engine (the default) that front-end is a bare
/// `server::AsyncServer`; with `--replicas N` it is the data-parallel
/// `server::Router`, which places every request on the replica with the
/// longest retained prefix match and migrates hot segments when load
/// shifts. With `--prefill-budget N` the engines ingest prompts N tokens
/// per step interleaved with live decode. `tracer` is the front door's
/// own ring — the single engine's tracer in the 1-replica case, the
/// router's placement ring otherwise.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve_async(
    args: &Args,
    be: &SharedBackend,
    pipe: &Pipeline,
    mut engines: Vec<Engine>,
    tracer: &Tracer,
) -> Result<()> {
    use puzzle::server::{AsyncServer, Router, RouterConfig};
    let n_req = args.usize("requests", 16);
    let clients = args.usize("clients", 8).max(1);
    let temperature = args.f64("temperature", 0.0) as f32;
    let seed = args.u64("seed", 42);
    let max_new = args.usize("max-new", 24);
    let mut rng = Rng::new(1);
    let c = &be.man().cfg;
    // deterministic prompt set (same generator as the sync path), dealt
    // round-robin to the client threads
    let mut lots: Vec<Vec<(usize, GenRequest)>> = vec![Vec::new(); clients];
    for i in 0..n_req {
        let plen = rng.range(4, c.s_prefill.min(32));
        let prompt = sample_sequence(&pipe.world, &pipe.mix, plen, &mut rng);
        let sampling = if temperature > 0.0 {
            SamplingParams::temperature(temperature).with_seed(seed ^ i as u64)
        } else {
            SamplingParams::greedy()
        };
        lots[i % clients].push((i, GenRequest::new(prompt, max_new).with_sampling(sampling)));
    }
    if engines.len() == 1 {
        let metrics_interval =
            args.get("metrics-interval").and_then(|s| s.parse::<usize>().ok());
        let server = AsyncServer::spawn_with(engines.pop().expect("one engine"), metrics_interval);
        drive_clients(&server.handle(), lots);
        if args.flag("scrape") {
            // the live Prometheus snapshot clients would poll on a real deploy
            println!("{}", server.handle().metrics_text()?);
        }
        let eng = server.shutdown();
        println!(
            "async-served {n_req} requests over {clients} client threads | {}",
            eng.metrics.summary()
        );
        export_trace(
            eng.tracer(),
            be,
            &trace_sink(args, "trace-out")?,
            &trace_sink(args, "trace-jsonl")?,
        )?;
        return Ok(());
    }
    let rcfg = RouterConfig { tracer: tracer.clone(), ..RouterConfig::default() };
    let router = Router::spawn(engines, rcfg);
    let handle = router.handle();
    drive_clients(&handle, lots);
    if args.flag("scrape") {
        // the fleet rollup: router counters, per-replica sections, and —
        // with tracing on — the live SLO burn-rate gauges folded from
        // the merged rings
        println!("{}", handle.metrics_text()?);
    }
    let stats = handle.stats()?;
    let agg = handle.aggregate_metrics()?;
    drop(handle);
    let engines = router.shutdown();
    println!(
        "router-served {n_req} requests over {clients} client threads x {} replicas | routed {:?} (skew {}) | migrations {} ({} tok) | shed {} | probes {} rounds ({} paid, {} memo) | {}",
        engines.len(),
        stats.routed,
        stats.load_skew(),
        stats.migrations,
        stats.migrated_tokens,
        stats.shed,
        stats.probe_rounds,
        stats.digest_refreshes,
        stats.digest_hits,
        agg.summary()
    );
    if tracer.enabled() {
        // merged fleet export: the router's placement ring plus every
        // replica's engine ring, rebased onto the shared clock
        tracer.record_exec_totals(&be.stats_snapshot());
        let fleet = obs::FleetLog {
            router: tracer.snapshot(),
            replicas: engines.iter().map(|e| e.tracer().snapshot()).collect(),
        };
        export_fleet_trace(
            &fleet,
            &trace_sink(args, "trace-out")?,
            &trace_sink(args, "trace-jsonl")?,
        )?;
    }
    Ok(())
}

/// The `serve --async` client fan-out, front-end-agnostic: a
/// `ServerHandle` and a `RouterHandle` drive it identically (the point
/// of the `Frontend` trait). One scoped thread per client lot.
#[cfg(not(feature = "pjrt"))]
fn drive_clients<F: puzzle::server::Frontend>(handle: &F, lots: Vec<Vec<(usize, GenRequest)>>) {
    std::thread::scope(|s| {
        for (ci, lot) in lots.into_iter().enumerate() {
            let h = handle.clone();
            s.spawn(move || {
                for (i, req) in lot {
                    match h.submit(req) {
                        Ok(stream) => {
                            let (tokens, finish) = stream.collect();
                            println!(
                                "  client {ci} req {i}: {} tokens ({})",
                                tokens.len(),
                                finish.map(|f| f.as_str()).unwrap_or("server gone")
                            );
                        }
                        Err(e) => println!("  client {ci} req {i}: shed ({e})"),
                    }
                }
            });
        }
    });
}

#[cfg(feature = "pjrt")]
fn cmd_serve_async(
    _args: &Args,
    _be: &SharedBackend,
    _pipe: &Pipeline,
    _engines: Vec<Engine>,
    _tracer: &Tracer,
) -> Result<()> {
    Err(anyhow!(
        "serve --async needs the threaded front-end, which the pjrt build cannot provide \
         (the PJRT engine is not Send); rebuild without --features pjrt"
    ))
}

/// `serve --speculate`: the GKD-uptrained Puzzle child drafts for the
/// parent, which verifies each batch of drafts in one fused teacher-
/// forced pass; all requests share the engines' decode lanes
/// (`SpecBatch`). `--draft-k N` pins the draft length; without it the
/// length is tuned online from the running acceptance rate
/// (`SpecModel::best_k`). `--draft-arch <arch_tag.json>` pins the
/// drafter architecture instead of searching.
fn cmd_serve_speculative(
    args: &Args,
    be: &SharedBackend,
    pipe: &Pipeline,
    space: &SearchSpace,
) -> Result<()> {
    let pinned_k = args.get("draft-k").and_then(|v| v.parse::<usize>().ok());
    let draft_arch = args.get("draft-arch").map(PathBuf::from);
    let pair = pipe.ensure_spec_pair(space, Metric::Kl, args.f64("speedup", 1.8), draft_arch.as_deref())?;
    info!("speculative serve: drafter {}", pair.child_arch.signature());
    let chrome = trace_sink(args, "trace-out")?;
    let jsonl_p = trace_sink(args, "trace-jsonl")?;
    let tracer = if chrome.is_some() || jsonl_p.is_some() {
        Tracer::wall(DEFAULT_RING_CAP)
    } else {
        Tracer::disabled()
    };
    let cfg = SpecConfig {
        draft_k: pinned_k.unwrap_or(4),
        // no pin: tune k online from the measured acceptance rate
        adapt_k_max: if pinned_k.is_some() { None } else { Some(8) },
        // --prefix-cache: BOTH engines reuse retained prompt prefixes, so
        // a fleet of requests sharing a system prompt prefills it once
        engine: EngineConfig::new()
            .kv_budget_bytes(64 << 20)
            .prefix_cache(args.flag("prefix-cache"), args.usize("retain-budget", 8 << 20))
            .tracer(tracer.clone()),
    };
    let mut batch = SpecBatch::new(
        be.clone(),
        &pair.parent_store,
        &pair.parent_arch,
        &pair.child_store,
        &pair.child_arch,
        cfg,
    )?;
    let temperature = args.f64("temperature", 0.0) as f32;
    let seed = args.u64("seed", 42);
    let n_req = args.usize("requests", 8);
    let max_new = args.usize("max-new", 24);
    let mut rng = Rng::new(1);
    let c = &be.man().cfg;
    let mut reqs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let plen = rng.range(4, c.s_prefill.min(32));
        let prompt = sample_sequence(&pipe.world, &pipe.mix, plen, &mut rng);
        let sampling = if temperature > 0.0 {
            SamplingParams::temperature(temperature).with_seed(seed ^ i as u64)
        } else {
            SamplingParams::greedy()
        };
        reqs.push(SpecRequest { prompt, max_new, sampling });
    }
    // one batched call: every sequence shares the engines' decode lanes
    let responses = batch.generate_many(&reqs)?;
    let mut total_tokens = 0usize;
    let mut total_passes = 0usize;
    for (i, r) in responses.iter().enumerate() {
        total_tokens += r.tokens.len();
        total_passes += r.parent_passes;
        println!(
            "  req {i}: {} tokens in {} parent passes ({:.2} tok/pass) | accepted/proposed {}/{} (α {:.0}%) | finish {}",
            r.tokens.len(),
            r.parent_passes,
            r.tokens_per_pass(),
            r.accepted,
            r.proposed,
            r.acceptance_rate() * 100.0,
            r.finish.as_str()
        );
    }
    println!(
        "speculative: {} tokens / {} parent forwards = {:.2} amortized tok/pass ({} lanes, draft_k {}{}, α̂ {:.0}%)",
        total_tokens,
        total_passes,
        total_tokens as f64 / total_passes.max(1) as f64,
        batch.lane_capacity(),
        batch.current_draft_k(),
        if pinned_k.is_some() { " pinned" } else { " auto" },
        batch.observed_alpha() * 100.0
    );
    println!("{}", batch.parent_metrics().summary());
    if args.flag("prefix-cache") {
        let (p, c) = batch.prefix_tokens_saved();
        println!("prefix cache: parent saved {p} prompt tokens, drafter saved {c}");
    }
    export_trace(&tracer, be, &chrome, &jsonl_p)?;
    Ok(())
}

/// `bench-workload`: replay one seeded trace against three serving
/// configurations — plain engine, prefix-cache engine, and speculative
/// drafter/verifier (prefix cache on both) — scoring per-request TTFT /
/// inter-token latency / goodput on the deterministic virtual tick
/// clock, and write `BENCH_workloads.json` for the CI gate. Wall tok/s
/// is printed but deliberately kept out of the json.
fn cmd_bench_workload(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let cfg = be.man().cfg.clone();
    let seed = args.u64("seed", 7);
    let mix_s = args.str("trace", "multiturn");
    let mix = MixKind::parse(&mix_s).ok_or_else(|| {
        anyhow!("unknown trace mix '{mix_s}' (chat|longcontext|shared|spec|multiturn|mixed)")
    })?;
    let mut spec = TraceSpec::small(mix, seed);
    spec.conversations = args.usize("conversations", 6);
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    println!(
        "trace '{}' seed {}: {} conversations, {} requests",
        trace.name,
        trace.seed,
        trace.convs.len(),
        trace.requests()
    );

    // parent weights plus a variable-arch drafter (per-layer KV-head
    // counts differ — the serving case the paper's §6 contributes)
    let mut rng = Rng::new(0);
    let mut store = init_parent(be.man(), &mut rng);
    let parent_arch = Arch::parent(cfg.n_layers);
    let mut child_arch = Arch::parent(cfg.n_layers);
    child_arch.layers[0].0 = AttnChoice::Gqa { divisor: 2 };
    if cfg.n_layers > 1 {
        child_arch.layers[1].0 = AttnChoice::Gqa { divisor: 4 };
    }
    if cfg.n_layers > 2 {
        child_arch.layers[2] = (AttnChoice::Linear, FfnChoice::Ratio(3));
    }
    for l in 0..cfg.n_layers {
        for (kind, variant) in
            [("attn", child_arch.layers[l].0.name()), ("ffn", child_arch.layers[l].1.name())]
        {
            if variant != "noop" && variant != "gqa_r1" && variant != "r100" {
                let job = bld::Job { layer: l, kind, variant };
                bld::init_job_weights(be.man(), &mut store, &job, None)?;
            }
        }
    }

    let page_len = args.usize("page-len", 4);
    let retain = args.usize("retain-budget", 8 << 20);
    let engine_cfg = |prefix: bool| {
        EngineConfig::new()
            .kv_budget_bytes(16 << 20)
            .page_len(page_len)
            .prefix_cache(prefix, retain)
    };
    let slos = default_profiles();
    let mut runs = Vec::new();
    {
        let mut eng = engine_cfg(false).build(be.clone(), &store, &parent_arch)?;
        runs.push(replay(&trace, &mut Server::Engine(&mut eng), "plain")?);
    }
    {
        let mut eng = engine_cfg(true).build(be.clone(), &store, &parent_arch)?;
        runs.push(replay(&trace, &mut Server::Engine(&mut eng), "prefix_cache")?);
    }
    {
        // `--trace-out` / `--trace-jsonl` trace the speculative config: it
        // has the prefix cache on both engines, so one trace carries every
        // event kind (admitted hits, prefill chunks, spec rounds). The
        // virtual-tick clock keeps the JSONL byte-deterministic per seed.
        let chrome = trace_sink(args, "trace-out")?;
        let jsonl_p = trace_sink(args, "trace-jsonl")?;
        let traced = chrome.is_some() || jsonl_p.is_some();
        let tracer =
            if traced { Tracer::virtual_ticks(DEFAULT_RING_CAP) } else { Tracer::disabled() };
        let scfg = SpecConfig {
            draft_k: args.usize("draft-k", 3),
            adapt_k_max: None,
            engine: engine_cfg(true).tracer(tracer.clone()),
        };
        let mut batch =
            SpecBatch::new(be.clone(), &store, &parent_arch, &store, &child_arch, scfg)?;
        runs.push(replay(&trace, &mut Server::Spec(&mut batch), "speculative")?);
        export_trace(&tracer, &be, &chrome, &jsonl_p)?;
    }
    for run in &runs {
        println!("[{}] {}", run.config, run.metrics.summary());
        let wall_tok_s = if run.wall_secs > 0.0 {
            run.metrics.generated_tokens as f64 / run.wall_secs
        } else {
            0.0
        };
        let slo_line = slos
            .iter()
            .map(|s| {
                let (met, frac) = goodput(run, s);
                format!("{} {:.0}% ({met}/{})", s.name, frac * 100.0, run.intended)
            })
            .collect::<Vec<_>>()
            .join(", ");
        println!(
            "  {} ticks | {:.2} tok/forward | goodput: {slo_line} | wall {wall_tok_s:.1} tok/s",
            run.ticks,
            run.tok_per_forward()
        );
    }
    let j = report_json(&trace, &runs, &slos);
    std::fs::write("BENCH_workloads.json", j.to_pretty())?;
    println!("wrote BENCH_workloads.json");
    Ok(())
}

/// `bench-async`: replay one seeded trace in *wall-clock* time through
/// the threaded async server, twice — unchunked (inline prefills) and
/// chunked (`--prefill-budget` tokens per step) — plus once through the
/// synchronous virtual-tick driver as the byte-identity oracle. Emits
/// `BENCH_serving_async.json`; the CI gate requires `byte_identical` and
/// a chunked p95 TTFT below the unchunked one.
#[cfg(not(feature = "pjrt"))]
fn cmd_bench_async(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::time::Duration;

    use puzzle::server::AsyncServer;
    use puzzle::serving::EngineMetrics;
    use puzzle::util::percentile;
    use puzzle::workload::{replay_wall, wall_report_json, WallRun};

    let be = open_backend(args)?;
    let cfg = be.man().cfg.clone();
    let seed = args.u64("seed", 7);
    let mix_s = args.str("trace", "mixed");
    let mix = MixKind::parse(&mix_s).ok_or_else(|| {
        anyhow!("unknown trace mix '{mix_s}' (chat|longcontext|shared|spec|multiturn|mixed)")
    })?;
    let mut spec = TraceSpec::small(mix, seed);
    spec.conversations = args.usize("conversations", 10);
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let tick = Duration::from_secs_f64(args.f64("tick-ms", 5.0) / 1e3);
    let budget = args.usize("prefill-budget", 16);
    println!(
        "trace '{}' seed {}: {} conversations, {} requests | tick {:.1} ms | prefill budget {budget}",
        trace.name,
        trace.seed,
        trace.convs.len(),
        trace.requests(),
        tick.as_secs_f64() * 1e3
    );

    let mut rng = Rng::new(0);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    // a queue deep enough that shedding never depends on wall timing —
    // shed-vs-served divergence would fail the byte-identity check
    let engine_cfg = || {
        EngineConfig::new()
            .kv_budget_bytes(16 << 20)
            .page_len(args.usize("page-len", 4))
            .max_queue(1024)
    };

    // oracle: the deterministic virtual-tick replay, no budget
    let oracle = {
        let mut eng = engine_cfg().build(be.clone(), &store, &arch)?;
        replay(&trace, &mut Server::Engine(&mut eng), "sync_oracle")?
    };

    let run_wall = |label: &str, budget: Option<usize>, tracer: Tracer| -> Result<(WallRun, EngineMetrics)> {
        let mut ec = engine_cfg().tracer(tracer);
        if let Some(b) = budget {
            ec = ec.prefill_budget(b);
        }
        let eng = ec.build(be.clone(), &store, &arch)?;
        let server = AsyncServer::spawn(eng);
        let handle = server.handle();
        let run = replay_wall(&trace, &handle, tick, label);
        drop(handle);
        let eng = server.shutdown();
        Ok((run, eng.metrics.clone()))
    };
    // `--trace-out` traces the chunked run — the one whose step timeline
    // (budgeted prefill chunks interleaved with live decode) is the point
    // of this bench — on the wall clock.
    let chrome = trace_sink(args, "trace-out")?;
    let jsonl_p = trace_sink(args, "trace-jsonl")?;
    let tracer = if chrome.is_some() || jsonl_p.is_some() {
        Tracer::wall(DEFAULT_RING_CAP)
    } else {
        Tracer::disabled()
    };
    let (unchunked, m_un) = run_wall("unchunked", None, Tracer::disabled())?;
    let (chunked, m_ch) = run_wall("chunked", Some(budget), tracer.clone())?;
    export_trace(&tracer, &be, &chrome, &jsonl_p)?;

    // byte identity: every (conv, turn)'s generated stream must match the
    // sync oracle in BOTH wall runs, chunked and not
    let oracle_map: BTreeMap<(usize, usize), Vec<u32>> =
        oracle.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect();
    let wall_map = |run: &WallRun| -> BTreeMap<(usize, usize), Vec<u32>> {
        run.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect()
    };
    let byte_identical = wall_map(&unchunked) == oracle_map && wall_map(&chunked) == oracle_map;

    for (run, m) in [(&unchunked, &m_un), (&chunked, &m_ch)] {
        let done = run.records.iter().filter(|r| r.finish.is_some()).count();
        let ttfts: Vec<f64> =
            run.records.iter().filter_map(|r| r.ttft_secs).map(|t| t * 1e3).collect();
        println!(
            "[{}] completed {done}/{} | ttft p50 {:.1} ms p95 {:.1} ms | wall {:.2} s | chunk passes {} ({} tok)",
            run.config,
            run.intended,
            percentile(&ttfts, 50.0),
            percentile(&ttfts, 95.0),
            run.wall_secs,
            m.prefill_chunk_passes,
            m.prefill_chunk_tokens
        );
    }
    println!("byte identical to sync oracle: {byte_identical}");
    let j =
        wall_report_json(&trace, tick, byte_identical, &[(&unchunked, &m_un), (&chunked, &m_ch)]);
    std::fs::write("BENCH_serving_async.json", j.to_pretty())?;
    println!("wrote BENCH_serving_async.json");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_bench_async(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "bench-async needs the threaded front-end, which the pjrt build cannot provide \
         (the PJRT engine is not Send); rebuild without --features pjrt"
    ))
}

/// `bench-router`: replay one seeded *bursty* shared-prefix trace in
/// wall-clock time with **open-loop** pacing (latency billed from the
/// scheduled arrival — no coordinated omission), twice: once through a
/// bare single-engine `AsyncServer`, once through an N-replica `Router`
/// with cache-aware placement and prefix migration. A synchronous
/// virtual-tick replay is the byte-identity oracle for both. Emits
/// `BENCH_router.json`; the CI gate requires `byte_identical`, an
/// aggregate prefix hit rate > 0, and routed goodput no worse than the
/// single replica's under the lenient wall SLO.
#[cfg(not(feature = "pjrt"))]
fn cmd_bench_router(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::time::Duration;

    use puzzle::server::{AsyncServer, Router, RouterConfig};
    use puzzle::util::{percentile, Json};
    use puzzle::workload::{replay_wall_paced, wall_run_json, Pacing, WallRun};

    let be = open_backend(args)?;
    let cfg = be.man().cfg.clone();
    let seed = args.u64("seed", 7);
    let mix_s = args.str("trace", "shared");
    let mix = MixKind::parse(&mix_s).ok_or_else(|| {
        anyhow!("unknown trace mix '{mix_s}' (chat|longcontext|shared|spec|multiturn|mixed)")
    })?;
    let mut spec = TraceSpec::bursty(mix, seed);
    spec.conversations = args.usize("conversations", 12);
    let trace = spec.generate(cfg.v as u32, cfg.s_prefill, cfg.s_max);
    let replicas = args.usize("replicas", 4).max(1);
    let tick = Duration::from_secs_f64(args.f64("tick-ms", 5.0) / 1e3);
    println!(
        "trace '{}' seed {}: {} conversations, {} requests | {} replicas | tick {:.1} ms | open-loop",
        trace.name,
        trace.seed,
        trace.convs.len(),
        trace.requests(),
        replicas,
        tick.as_secs_f64() * 1e3
    );

    let mut rng = Rng::new(0);
    let store = init_parent(be.man(), &mut rng);
    let arch = Arch::parent(cfg.n_layers);
    // prefix cache on (the router's placement signal) and a queue deep
    // enough that shedding never depends on wall timing — shed-vs-served
    // divergence would fail the byte-identity check
    let engine_cfg = || {
        EngineConfig::new()
            .kv_budget_bytes(16 << 20)
            .page_len(args.usize("page-len", 4))
            .max_queue(1024)
            .prefix_cache(true, args.usize("retain-budget", 8 << 20))
    };

    // oracle: the deterministic virtual-tick replay on one engine
    let oracle = {
        let mut eng = engine_cfg().build(be.clone(), &store, &arch)?;
        replay(&trace, &mut Server::Engine(&mut eng), "sync_oracle")?
    };

    // baseline: one engine behind a bare AsyncServer, same open pacing
    let (single, m_single) = {
        let eng = engine_cfg().build(be.clone(), &store, &arch)?;
        let server = AsyncServer::spawn(eng);
        let handle = server.handle();
        let run = replay_wall_paced(&trace, &handle, tick, "single", Pacing::Open);
        drop(handle);
        let eng = server.shutdown();
        (run, eng.metrics.clone())
    };

    // `--trace-out` / `--trace-jsonl` trace the routed run *fleet-wide*:
    // the router's placement ring plus one ring per replica, all over one
    // shared wall clock, merged at export. Tracing observes, never
    // steers — byte identity and the scored goodput are unchanged, which
    // the CI gate re-asserts against an untraced baseline run.
    let chrome = trace_sink(args, "trace-out")?;
    let jsonl_p = trace_sink(args, "trace-jsonl")?;
    let traced = chrome.is_some() || jsonl_p.is_some();
    let fleet_clock = std::sync::Arc::new(obs::Clock::wall());
    let fleet_tracer = |on: bool| {
        if on { Tracer::with_clock(fleet_clock.clone(), DEFAULT_RING_CAP) } else { Tracer::disabled() }
    };

    // routed: N identical replicas, overload low enough that a burst
    // spills past the hot replica and drags its prefix segment along.
    // Each replica runs on its OWN backend instance so its exec wall is
    // separable for the predicted-vs-measured drift block below.
    let rcfg = RouterConfig {
        overload: args.usize("overload", 2).max(1),
        min_migrate: 1,
        tracer: fleet_tracer(traced),
        ..RouterConfig::default()
    };
    let router_tracer = rcfg.tracer.clone();
    let r_backends: Vec<SharedBackend> =
        (0..replicas).map(|_| share(RefBackend::new(be.man().clone()))).collect();
    let engines = r_backends
        .iter()
        .map(|rb| engine_cfg().tracer(fleet_tracer(traced)).build(rb.clone(), &store, &arch))
        .collect::<Result<Vec<_>>>()?;
    let router = Router::spawn(engines, rcfg);
    let handle = router.handle();
    let routed = replay_wall_paced(&trace, &handle, tick, "routed", Pacing::Open);
    let stats = handle.stats()?;
    let agg = handle.aggregate_metrics()?;
    drop(handle);
    let engines = router.shutdown();

    // byte identity: every (conv, turn)'s generated stream must match the
    // sync oracle through BOTH front-ends — placement must not steer
    // sampling (DESIGN.md §12)
    let oracle_map: BTreeMap<(usize, usize), Vec<u32>> =
        oracle.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect();
    let wall_map = |run: &WallRun| -> BTreeMap<(usize, usize), Vec<u32>> {
        run.records.iter().map(|r| ((r.conv, r.turn), r.gen.clone())).collect()
    };
    let byte_identical = wall_map(&single) == oracle_map && wall_map(&routed) == oracle_map;

    for (run, m) in [(&single, &m_single), (&routed, &agg)] {
        let done = run.records.iter().filter(|r| r.finish.is_some()).count();
        let ttfts: Vec<f64> =
            run.records.iter().filter_map(|r| r.ttft_secs).map(|t| t * 1e3).collect();
        println!(
            "[{}] completed {done}/{} | ttft p50 {:.1} ms p95 {:.1} ms | wall {:.2} s | prefix hits {} ({} tok saved)",
            run.config,
            run.intended,
            percentile(&ttfts, 50.0),
            percentile(&ttfts, 95.0),
            run.wall_secs,
            m.prefix_hits,
            m.prefix_tokens_saved
        );
    }
    println!(
        "routed {:?} (skew {}) | migrations {} ({} tok) | shed {} | aggregate hit rate {:.2} | byte identical: {byte_identical}",
        stats.routed,
        stats.load_skew(),
        stats.migrations,
        stats.migrated_tokens,
        stats.shed,
        agg.prefix_hit_rate()
    );
    println!(
        "probes: {} rounds, {} paid over the channel, {} served from the digest memo",
        stats.probe_rounds, stats.digest_refreshes, stats.digest_hits
    );

    // predicted vs measured: each replica ran on its own backend, so its
    // exec wall is separable; the cost model predicts seconds for the
    // tokens that replica actually generated. The ratio is machine- and
    // load-dependent — reported for observability, never gated.
    let sc = Scenario { prefill: cfg.s_prefill, decode: cfg.s_prefill, batch: cfg.b_decode };
    let ct = CostTable::modeled(be.man(), &HwProfile::cpu(), &sc);
    let modeled_tput = ct.arch_throughput(&arch);
    let drift: Vec<Json> = engines
        .iter()
        .zip(&r_backends)
        .enumerate()
        .map(|(i, (e, rb))| {
            let measured: f64 = rb.stats_snapshot().iter().map(|(_, s)| s.total_secs).sum();
            let modeled = e.metrics.generated_tokens as f64 / modeled_tput;
            let ratio = if modeled > 0.0 { measured / modeled } else { 0.0 };
            println!(
                "  replica {i}: exec wall {measured:.3} s vs modeled {modeled:.3} s for {} tokens (x{ratio:.2})",
                e.metrics.generated_tokens
            );
            Json::from_pairs(vec![
                ("replica", Json::num(i as f64)),
                ("exec_wall_secs", Json::num(measured)),
                ("generated_tokens", Json::num(e.metrics.generated_tokens as f64)),
                ("modeled_secs", Json::num(modeled)),
                ("measured_over_modeled", Json::num(ratio)),
            ])
        })
        .collect();

    if traced {
        for (e, rb) in engines.iter().zip(&r_backends) {
            e.tracer().record_exec_totals(&rb.stats_snapshot());
        }
        let fleet = obs::FleetLog {
            router: router_tracer.snapshot(),
            replicas: engines.iter().map(|e| e.tracer().snapshot()).collect(),
        };
        export_fleet_trace(&fleet, &chrome, &jsonl_p)?;
    }

    let mut root = Json::obj();
    root.set("bench", Json::str("router"));
    root.set("trace", Json::str(&trace.name));
    root.set("seed", Json::num(trace.seed as f64));
    root.set("conversations", Json::num(trace.convs.len() as f64));
    root.set("requests", Json::num(trace.requests() as f64));
    root.set("replicas", Json::num(replicas as f64));
    root.set("tick_ms", Json::num(tick.as_secs_f64() * 1e3));
    root.set("pacing", Json::str("open"));
    root.set("byte_identical", Json::Bool(byte_identical));
    root.set(
        "configs",
        Json::Arr(vec![wall_run_json(&single, &m_single), wall_run_json(&routed, &agg)]),
    );
    root.set(
        "router",
        Json::from_pairs(vec![
            ("migrations", Json::num(stats.migrations as f64)),
            ("migrated_tokens", Json::num(stats.migrated_tokens as f64)),
            ("shed", Json::num(stats.shed as f64)),
            (
                "routed_per_replica",
                Json::Arr(stats.routed.iter().map(|&n| Json::num(n as f64)).collect()),
            ),
            ("load_skew", Json::num(stats.load_skew() as f64)),
            ("aggregate_prefix_hit_rate", Json::num(agg.prefix_hit_rate())),
            ("prefix_hits", Json::num(agg.prefix_hits as f64)),
            ("prefix_misses", Json::num(agg.prefix_misses as f64)),
            ("probe_rounds", Json::num(stats.probe_rounds as f64)),
            ("digest_refreshes", Json::num(stats.digest_refreshes as f64)),
            ("digest_hits", Json::num(stats.digest_hits as f64)),
        ]),
    );
    root.set("traced", Json::Bool(traced));
    root.set(
        "cost_model",
        Json::from_pairs(vec![
            ("hw", Json::str("cpu")),
            ("modeled_tok_per_sec", Json::num(modeled_tput)),
            ("per_replica", Json::Arr(drift)),
        ]),
    );
    std::fs::write("BENCH_router.json", root.to_pretty())?;
    println!("wrote BENCH_router.json");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_bench_router(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "bench-router needs the threaded front-end, which the pjrt build cannot provide \
         (the PJRT engine is not Send); rebuild without --features pjrt"
    ))
}

fn cmd_measure(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let c = &be.man().cfg;
    let sc = Scenario { prefill: c.s_prefill, decode: c.s_prefill, batch: c.b_decode };
    let ct = CostTable::measured(&*be, &sc, args.usize("reps", 5))?;
    println!(
        "measured per-variant scenario costs on this machine ({} backend, {}):",
        be.name(),
        sc.name()
    );
    println!("{:<12} {:>12} {:>12} {:>14}", "attn", "secs", "params", "kv bytes/seq");
    for (k, (s, p, kv)) in &ct.attn {
        println!("{:<12} {:>12.5} {:>12.0} {:>14.0}", k, s, p, kv);
    }
    println!("{:<12} {:>12} {:>12}", "ffn", "secs", "params");
    for (k, (s, p, _)) in &ct.ffn {
        println!("{:<12} {:>12.5} {:>12.0}", k, s, p);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let be = open_backend(args)?;
    let c = &be.man().cfg;
    let space = SearchSpace::full(c.n_heads as u32);
    println!("backend {} | config {} | d {} L {} heads {} i {} v {}", be.name(), c.name, c.d, c.n_layers, c.n_heads, c.i, c.v);
    println!("executables: {}", be.man().execs.len());
    println!(
        "search space: {}x{}={} per layer; 10^{:.1} total",
        space.attn.len(),
        space.ffn.len(),
        space.per_layer_combinations(),
        space.log10_size(c.n_layers)
    );
    Ok(())
}

fn main() -> Result<()> {
    puzzle::util::log::init();
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("pipeline") => cmd_pipeline(&args),
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-workload") => cmd_bench_workload(&args),
        Some("bench-async") => cmd_bench_async(&args),
        Some("bench-router") => cmd_bench_router(&args),
        Some("measure") => cmd_measure(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: puzzle <pipeline|exp|serve|bench-workload|bench-async|bench-router|measure|info> [--backend ref|pjrt] [--config tiny|small] [--run-dir DIR] [--scale F] [--speedup X]\n       serve also takes: [--scheduler fifo|priority|spf|prefix] [--temperature T] [--stream] [--requests N] [--max-new N]\n                         [--prefix-cache] [--retain-budget BYTES] [--prefill-budget TOKENS]\n                         [--async] [--replicas N] [--clients N] [--metrics-interval STEPS] [--scrape]\n                         [--speculate] [--draft-k N (pins; omit to auto-tune)] [--draft-arch arch_tag.json]\n       bench-workload takes: [--trace chat|longcontext|shared|spec|multiturn|mixed] [--seed N] [--conversations N]\n                             [--page-len N] [--draft-k N] [--retain-budget BYTES]\n       bench-async takes: [--trace ...] [--seed N] [--conversations N] [--tick-ms MS] [--prefill-budget TOKENS] [--page-len N]\n       bench-router takes: [--trace ...] [--seed N] [--conversations N] [--replicas N] [--overload DEPTH] [--tick-ms MS] [--page-len N] [--retain-budget BYTES]\n       serve / bench-workload / bench-async / bench-router also take: [--trace-out chrome_trace.json] [--trace-jsonl events.jsonl]\n       (bench-router and serve --async --replicas N export a MERGED fleet trace: router ring = pid 0, replica r = pid r+1)"
            );
            Ok(())
        }
    }
}
