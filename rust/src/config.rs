//! Model configuration + artifact manifest, parsed from
//! `artifacts/<cfg>/manifest.json` (written by `python -m compile.aot`).
//! The manifest is the contract between the python compile path and the
//! rust runtime: weight names/shapes per variant and executable signatures.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::arch::{ffn_ratio_value, AttnChoice, FfnChoice, FFN_RATIO_NAMES};
use crate::util::Json;

#[derive(Debug, Clone)]
/// Model hyperparameters shared by every executable in a manifest.
pub struct ModelCfg {
    /// Config name (e.g. "tiny", "small").
    pub name: String,
    /// Model (residual stream) dimension.
    pub d: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Query head count.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Parent FFN intermediate dimension.
    pub i: usize,
    /// Vocabulary size.
    pub v: usize,
    /// Training sequence length.
    pub s_train: usize,
    /// Training batch size.
    pub b_train: usize,
    /// Compiled prefill window length.
    pub s_prefill: usize,
    /// Compiled decode batch (the engine's lane count).
    pub b_decode: usize,
    /// Compiled KV-cache horizon (max sequence length at decode).
    pub s_max: usize,
    /// Long-context evaluation sequence length.
    pub s_long: usize,
    /// Rotary embedding base.
    pub rope_theta: f64,
    /// RMSNorm epsilon.
    pub eps: f64,
}

impl ModelCfg {
    /// Query projection width (`n_heads * head_dim`).
    pub fn qdim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// KV head count for a GQA divisor.
    pub fn kv_heads(&self, divisor: u32) -> usize {
        self.n_heads / divisor as usize
    }

    fn from_json(j: &Json) -> Result<ModelCfg> {
        let gu = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest config missing {k}"))
        };
        Ok(ModelCfg {
            name: j.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            d: gu("d")?,
            n_layers: gu("n_layers")?,
            n_heads: gu("n_heads")?,
            head_dim: gu("head_dim")?,
            i: gu("i")?,
            v: gu("v")?,
            s_train: gu("s_train")?,
            b_train: gu("b_train")?,
            s_prefill: gu("s_prefill")?,
            b_decode: gu("b_decode")?,
            s_max: gu("s_max")?,
            s_long: gu("s_long")?,
            rope_theta: j.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0),
            eps: j.get("eps").and_then(Json::as_f64).unwrap_or(1e-5),
        })
    }
}

/// Weight layout of one variant: ordered (name, shape) pairs.
#[derive(Debug, Clone)]
pub struct VariantLayout {
    /// Ordered (name, shape) weight pairs, as the executables expect them.
    pub weights: Vec<(String, Vec<usize>)>,
    /// kv heads (gqa attn variants), 0 otherwise
    pub kv_heads: usize,
    /// intermediate dim (ffn ratio variants), 0 otherwise
    pub i_dim: usize,
}

impl VariantLayout {
    /// Total parameters across the variant's weights.
    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// Executable signature from the manifest.
#[derive(Debug, Clone)]
pub struct ExecSig {
    /// HLO text file relative to the manifest directory.
    pub file: String,
    /// Ordered (dtype, shape) input signature.
    pub in_shapes: Vec<(String, Vec<usize>)>,
    /// Ordered (dtype, shape) output signature.
    pub out_shapes: Vec<(String, Vec<usize>)>,
}

#[derive(Debug, Clone)]
/// The artifact manifest: model config, per-variant weight layouts, and
/// executable signatures — the contract between the compile path (or the
/// synthetic in-memory builder) and every `Backend`.
pub struct Manifest {
    /// Artifact directory (empty for in-memory synthetic manifests).
    pub dir: PathBuf,
    /// Model hyperparameters.
    pub cfg: ModelCfg,
    /// Attention variant name -> weight layout.
    pub attn_variants: BTreeMap<String, VariantLayout>,
    /// FFN variant name -> weight layout.
    pub ffn_variants: BTreeMap<String, VariantLayout>,
    /// Executable name -> signature.
    pub execs: BTreeMap<String, ExecSig>,
}

fn parse_variants(j: &Json, extra_key: &str) -> Result<BTreeMap<String, VariantLayout>> {
    let mut out = BTreeMap::new();
    for (name, v) in j.as_obj().ok_or_else(|| anyhow!("variants not an object"))? {
        let weights = v
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("variant {name} missing weights"))?
            .iter()
            .map(|w| {
                let n = w.idx(0).and_then(Json::as_str).unwrap_or("?").to_string();
                let s = w
                    .idx(1)
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                (n, s)
            })
            .collect();
        let extra = v.get(extra_key).and_then(Json::as_usize).unwrap_or(0);
        let layout = if extra_key == "kv_heads" {
            VariantLayout { weights, kv_heads: extra, i_dim: 0 }
        } else {
            VariantLayout { weights, kv_heads: 0, i_dim: extra }
        };
        out.insert(name.clone(), layout);
    }
    Ok(out)
}

impl Manifest {
    /// Parse `<dir>/manifest.json` (the `python -m compile.aot` output).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let cfg = ModelCfg::from_json(j.get("config").ok_or_else(|| anyhow!("no config"))?)?;
        let attn_variants =
            parse_variants(j.get("attn_variants").ok_or_else(|| anyhow!("no attn_variants"))?, "kv_heads")?;
        let ffn_variants =
            parse_variants(j.get("ffn_variants").ok_or_else(|| anyhow!("no ffn_variants"))?, "i_dim")?;
        let mut execs = BTreeMap::new();
        for (name, e) in j
            .get("execs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("no execs"))?
        {
            let shapes = |key: &str| -> Vec<(String, Vec<usize>)> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .map(|s| {
                                (
                                    s.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
                                    s.get("shape")
                                        .and_then(Json::as_arr)
                                        .map(|d| d.iter().filter_map(Json::as_usize).collect())
                                        .unwrap_or_default(),
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            execs.insert(
                name.clone(),
                ExecSig {
                    file: e.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                    in_shapes: shapes("in"),
                    out_shapes: shapes("out"),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), cfg, attn_variants, ffn_variants, execs })
    }

    /// Build a fully in-memory manifest for `cfg` — same variant layouts
    /// and executable signatures that `python -m compile.aot` writes, but
    /// with no artifact files behind the signatures. This is what lets the
    /// `RefBackend` run the whole pipeline with no `artifacts/` directory.
    pub fn synthetic(cfg: ModelCfg) -> Manifest {
        let (d, dh, qd) = (cfg.d, cfg.head_dim, cfg.qdim());

        let mut attn_variants = BTreeMap::new();
        for divisor in [1usize, 2, 4, 8] {
            if cfg.n_heads % divisor != 0 {
                continue;
            }
            let kv = cfg.n_heads / divisor;
            let weights = vec![
                ("norm".to_string(), vec![d]),
                ("wq".to_string(), vec![d, qd]),
                ("wk".to_string(), vec![d, kv * dh]),
                ("wv".to_string(), vec![d, kv * dh]),
                ("wo".to_string(), vec![qd, d]),
            ];
            attn_variants
                .insert(format!("gqa_r{divisor}"), VariantLayout { weights, kv_heads: kv, i_dim: 0 });
        }
        attn_variants.insert(
            "linear".to_string(),
            VariantLayout {
                weights: vec![("norm".to_string(), vec![d]), ("wl".to_string(), vec![d, d])],
                kv_heads: 0,
                i_dim: 0,
            },
        );

        let mut ffn_variants = BTreeMap::new();
        for name in FFN_RATIO_NAMES {
            let i_dim = round_dim(cfg.i as f64 * ffn_ratio_value(name));
            let weights = vec![
                ("norm".to_string(), vec![d]),
                ("wg".to_string(), vec![d, i_dim]),
                ("wu".to_string(), vec![d, i_dim]),
                ("wd".to_string(), vec![i_dim, d]),
            ];
            ffn_variants.insert(name.to_string(), VariantLayout { weights, kv_heads: 0, i_dim });
        }
        ffn_variants.insert(
            "linear".to_string(),
            VariantLayout {
                weights: vec![("norm".to_string(), vec![d]), ("wl".to_string(), vec![d, d])],
                kv_heads: 0,
                i_dim: 0,
            },
        );

        let execs = synthetic_execs(&cfg, &attn_variants, &ffn_variants);
        Manifest { dir: PathBuf::new(), cfg, attn_variants, ffn_variants, execs }
    }

    /// Absolute path of an executable's HLO text file.
    pub fn exec_path(&self, name: &str) -> Result<PathBuf> {
        let sig = self.execs.get(name).ok_or_else(|| anyhow!("unknown exec {name}"))?;
        Ok(self.dir.join(&sig.file))
    }

    /// Layout for an architecture choice (None for NoOp).
    pub fn attn_layout(&self, c: &AttnChoice) -> Option<&VariantLayout> {
        match c {
            AttnChoice::NoOp => None,
            _ => self.attn_variants.get(&c.name()),
        }
    }

    /// Layout for an FFN choice (None for NoOp).
    pub fn ffn_layout(&self, c: &FfnChoice) -> Option<&VariantLayout> {
        match c {
            FfnChoice::NoOp => None,
            _ => self.ffn_variants.get(&c.name()),
        }
    }
}

/// Round a pruned dimension to a hardware-friendly multiple of 16
/// (mirrors `compile.configs.round_dim`).
fn round_dim(x: f64) -> usize {
    (((x / 16.0).round() as usize) * 16).max(16)
}

type Sig = Vec<(String, Vec<usize>)>;

fn f32s(shape: &[usize]) -> (String, Vec<usize>) {
    ("float32".to_string(), shape.to_vec())
}

fn i32s(shape: &[usize]) -> (String, Vec<usize>) {
    ("int32".to_string(), shape.to_vec())
}

/// Executable signatures for every (variant, mode), mirroring the export
/// loop in `python/compile/aot.py`.
fn synthetic_execs(
    cfg: &ModelCfg,
    attn_variants: &BTreeMap<String, VariantLayout>,
    ffn_variants: &BTreeMap<String, VariantLayout>,
) -> BTreeMap<String, ExecSig> {
    let (d, dh, v) = (cfg.d, cfg.head_dim, cfg.v);
    let (bt, st) = (cfg.b_train, cfg.s_train);
    let (bd, sp, sl, smax) = (cfg.b_decode, cfg.s_prefill, cfg.s_long, cfg.s_max);
    let mut execs = BTreeMap::new();
    let mut add = |name: String, ins: Sig, outs: Sig| {
        execs.insert(name, ExecSig { file: String::new(), in_shapes: ins, out_shapes: outs });
    };
    let wsig = |layout: &VariantLayout| -> Sig {
        layout.weights.iter().map(|(_, s)| f32s(s)).collect()
    };
    let cat = |head: Sig, tail: Sig| -> Sig { head.into_iter().chain(tail).collect() };

    for (variant, layout) in attn_variants {
        let n = format!("attn_{variant}");
        let ws = wsig(layout);
        let x_t = f32s(&[bt, st, d]);
        add(format!("{n}_train_fwd"), cat(vec![x_t.clone()], ws.clone()), vec![x_t.clone()]);
        add(
            format!("{n}_train_vjp"),
            cat(cat(vec![x_t.clone()], ws.clone()), vec![x_t.clone()]),
            cat(vec![x_t.clone()], ws.clone()),
        );
        if variant == "linear" {
            for (mode, b, s) in [("prefill", 1, sp), ("decode", bd, 1), ("long", 1, sl)] {
                let x = f32s(&[b, s, d]);
                add(format!("{n}_{mode}"), cat(vec![x.clone()], ws.clone()), vec![x]);
            }
        } else {
            let kv = layout.kv_heads;
            let x_p = f32s(&[1, sp, d]);
            let kv_p = f32s(&[1, sp, kv, dh]);
            add(
                format!("{n}_prefill"),
                cat(vec![x_p.clone()], ws.clone()),
                vec![x_p, kv_p.clone(), kv_p],
            );
            let x_d = f32s(&[bd, 1, d]);
            let cache = f32s(&[bd, smax, kv, dh]);
            add(
                format!("{n}_decode"),
                cat(
                    vec![x_d.clone(), cache.clone(), cache.clone(), i32s(&[bd])],
                    ws.clone(),
                ),
                vec![x_d, cache.clone(), cache],
            );
            let x_l = f32s(&[1, sl, d]);
            add(format!("{n}_long"), cat(vec![x_l.clone()], ws.clone()), vec![x_l]);
        }
    }

    for (variant, layout) in ffn_variants {
        let n = format!("ffn_{variant}");
        let ws = wsig(layout);
        let x_t = f32s(&[bt, st, d]);
        add(format!("{n}_train_fwd"), cat(vec![x_t.clone()], ws.clone()), vec![x_t.clone()]);
        add(
            format!("{n}_train_vjp"),
            cat(cat(vec![x_t.clone()], ws.clone()), vec![x_t.clone()]),
            cat(vec![x_t.clone()], ws.clone()),
        );
        for (mode, b, s) in [("prefill", 1, sp), ("decode", bd, 1), ("long", 1, sl)] {
            let x = f32s(&[b, s, d]);
            add(format!("{n}_{mode}"), cat(vec![x.clone()], ws.clone()), vec![x]);
        }
    }

    let e = f32s(&[v, d]);
    let nw = f32s(&[d]);
    for (mode, b, s) in [("train", bt, st), ("prefill", 1, sp), ("decode", bd, 1), ("long", 1, sl)] {
        add(
            format!("embed_{mode}"),
            vec![i32s(&[b, s]), e.clone()],
            vec![f32s(&[b, s, d])],
        );
        add(
            format!("head_{mode}"),
            vec![f32s(&[b, s, d]), nw.clone(), e.clone()],
            vec![f32s(&[b, s, v])],
        );
    }
    add(
        "embed_train_vjp".to_string(),
        vec![i32s(&[bt, st]), e.clone(), f32s(&[bt, st, d])],
        vec![e.clone()],
    );
    add(
        "head_train_vjp".to_string(),
        vec![f32s(&[bt, st, d]), nw.clone(), e.clone(), f32s(&[bt, st, v])],
        vec![f32s(&[bt, st, d]), nw, e],
    );
    execs
}

/// Ready-made synthetic configurations for the hermetic reference backend:
/// `TinyManifest::synthetic()` is the standard in-memory test model (no
/// `artifacts/` directory, no python step).
pub struct TinyManifest;

impl TinyManifest {
    /// A deliberately small config so the naive reference interpreter keeps
    /// the whole test suite fast: 3 layers, d=32, 4 heads, vocab 128.
    pub fn synthetic() -> Manifest {
        Manifest::synthetic(ModelCfg {
            name: "ref-tiny".to_string(),
            d: 32,
            n_layers: 3,
            n_heads: 4,
            head_dim: 8,
            i: 64,
            v: 128,
            s_train: 32,
            b_train: 4,
            s_prefill: 32,
            b_decode: 2,
            s_max: 48,
            s_long: 64,
            rope_theta: 10000.0,
            eps: 1e-5,
        })
    }

    /// A larger synthetic config for demos and perf experiments.
    pub fn synthetic_small() -> Manifest {
        Manifest::synthetic(ModelCfg {
            name: "ref-small".to_string(),
            d: 64,
            n_layers: 6,
            n_heads: 8,
            head_dim: 8,
            i: 192,
            v: 256,
            s_train: 64,
            b_train: 8,
            s_prefill: 64,
            b_decode: 4,
            s_max: 96,
            s_long: 128,
            rope_theta: 10000.0,
            eps: 1e-5,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name":"t","d":8,"n_layers":2,"n_heads":2,"head_dim":4,"i":16,
                 "v":32,"s_train":8,"b_train":2,"s_prefill":8,"b_decode":2,
                 "s_max":12,"s_long":16,"rope_theta":10000.0,"eps":1e-5},
      "attn_variants": {"gqa_r1": {"weights": [["norm",[8]],["wq",[8,8]],["wk",[8,8]],["wv",[8,8]],["wo",[8,8]]], "kv_heads": 2},
                         "linear": {"weights": [["norm",[8]],["wl",[8,8]]], "kv_heads": 0}},
      "ffn_variants": {"r100": {"weights": [["norm",[8]],["wg",[8,16]],["wu",[8,16]],["wd",[16,8]]], "i_dim": 16}},
      "execs": {"attn_gqa_r1_train_fwd": {"file":"a.hlo.txt","in":[{"dtype":"float32","shape":[2,8,8]}],"out":[{"dtype":"float32","shape":[2,8,8]}]}}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("puzzle_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.cfg.d, 8);
        assert_eq!(m.cfg.qdim(), 8);
        assert_eq!(m.attn_variants["gqa_r1"].kv_heads, 2);
        assert_eq!(m.attn_variants["gqa_r1"].param_count(), 8 + 4 * 64);
        assert_eq!(m.ffn_variants["r100"].i_dim, 16);
        assert_eq!(m.execs["attn_gqa_r1_train_fwd"].in_shapes[0].1, vec![2, 8, 8]);
        assert!(m.attn_layout(&AttnChoice::NoOp).is_none());
        assert!(m.attn_layout(&AttnChoice::Linear).is_some());
    }

    #[test]
    fn synthetic_manifest_mirrors_aot_contract() {
        let m = TinyManifest::synthetic();
        let c = &m.cfg;
        // 4 heads -> divisors 1/2/4 valid, plus linear
        assert_eq!(m.attn_variants.len(), 4);
        assert_eq!(m.attn_variants["gqa_r1"].kv_heads, 4);
        assert_eq!(m.attn_variants["gqa_r4"].kv_heads, 1);
        assert_eq!(m.ffn_variants.len(), 8); // 7 ratios + linear
        assert_eq!(m.ffn_variants["r100"].i_dim, c.i);
        assert!(m.ffn_variants["r10"].i_dim >= 16);
        // exec signatures present for every variant x mode + embed/head
        for variant in m.attn_variants.keys() {
            for mode in ["train_fwd", "train_vjp", "prefill", "decode", "long"] {
                assert!(m.execs.contains_key(&format!("attn_{variant}_{mode}")), "{variant}/{mode}");
            }
        }
        for variant in m.ffn_variants.keys() {
            for mode in ["train_fwd", "train_vjp", "prefill", "decode", "long"] {
                assert!(m.execs.contains_key(&format!("ffn_{variant}_{mode}")), "{variant}/{mode}");
            }
        }
        for mode in ["train", "prefill", "decode", "long"] {
            assert!(m.execs.contains_key(&format!("embed_{mode}")));
            assert!(m.execs.contains_key(&format!("head_{mode}")));
        }
        // gqa prefill returns (y, k, v); decode takes caches + positions
        let pre = &m.execs["attn_gqa_r2_prefill"];
        assert_eq!(pre.out_shapes.len(), 3);
        assert_eq!(pre.out_shapes[1].1, vec![1, c.s_prefill, 2, c.head_dim]);
        let dec = &m.execs["attn_gqa_r2_decode"];
        assert_eq!(dec.in_shapes[3].0, "int32");
        assert_eq!(dec.in_shapes[1].1, vec![c.b_decode, c.s_max, 2, c.head_dim]);
        // vjp returns (dx, *dweights) in manifest weight order
        let vjp = &m.execs["ffn_r50_train_vjp"];
        assert_eq!(vjp.out_shapes.len(), 1 + m.ffn_variants["r50"].weights.len());
    }
}
