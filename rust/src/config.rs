//! Model configuration + artifact manifest, parsed from
//! `artifacts/<cfg>/manifest.json` (written by `python -m compile.aot`).
//! The manifest is the contract between the python compile path and the
//! rust runtime: weight names/shapes per variant and executable signatures.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::arch::{AttnChoice, FfnChoice};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub i: usize,
    pub v: usize,
    pub s_train: usize,
    pub b_train: usize,
    pub s_prefill: usize,
    pub b_decode: usize,
    pub s_max: usize,
    pub s_long: usize,
    pub rope_theta: f64,
    pub eps: f64,
}

impl ModelCfg {
    pub fn qdim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_heads(&self, divisor: u32) -> usize {
        self.n_heads / divisor as usize
    }

    fn from_json(j: &Json) -> Result<ModelCfg> {
        let gu = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest config missing {k}"))
        };
        Ok(ModelCfg {
            name: j.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            d: gu("d")?,
            n_layers: gu("n_layers")?,
            n_heads: gu("n_heads")?,
            head_dim: gu("head_dim")?,
            i: gu("i")?,
            v: gu("v")?,
            s_train: gu("s_train")?,
            b_train: gu("b_train")?,
            s_prefill: gu("s_prefill")?,
            b_decode: gu("b_decode")?,
            s_max: gu("s_max")?,
            s_long: gu("s_long")?,
            rope_theta: j.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0),
            eps: j.get("eps").and_then(Json::as_f64).unwrap_or(1e-5),
        })
    }
}

/// Weight layout of one variant: ordered (name, shape) pairs.
#[derive(Debug, Clone)]
pub struct VariantLayout {
    pub weights: Vec<(String, Vec<usize>)>,
    /// kv heads (gqa attn variants), 0 otherwise
    pub kv_heads: usize,
    /// intermediate dim (ffn ratio variants), 0 otherwise
    pub i_dim: usize,
}

impl VariantLayout {
    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// Executable signature from the manifest.
#[derive(Debug, Clone)]
pub struct ExecSig {
    pub file: String,
    pub in_shapes: Vec<(String, Vec<usize>)>,
    pub out_shapes: Vec<(String, Vec<usize>)>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub cfg: ModelCfg,
    pub attn_variants: BTreeMap<String, VariantLayout>,
    pub ffn_variants: BTreeMap<String, VariantLayout>,
    pub execs: BTreeMap<String, ExecSig>,
}

fn parse_variants(j: &Json, extra_key: &str) -> Result<BTreeMap<String, VariantLayout>> {
    let mut out = BTreeMap::new();
    for (name, v) in j.as_obj().ok_or_else(|| anyhow!("variants not an object"))? {
        let weights = v
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("variant {name} missing weights"))?
            .iter()
            .map(|w| {
                let n = w.idx(0).and_then(Json::as_str).unwrap_or("?").to_string();
                let s = w
                    .idx(1)
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                (n, s)
            })
            .collect();
        let extra = v.get(extra_key).and_then(Json::as_usize).unwrap_or(0);
        let layout = if extra_key == "kv_heads" {
            VariantLayout { weights, kv_heads: extra, i_dim: 0 }
        } else {
            VariantLayout { weights, kv_heads: 0, i_dim: extra }
        };
        out.insert(name.clone(), layout);
    }
    Ok(out)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let cfg = ModelCfg::from_json(j.get("config").ok_or_else(|| anyhow!("no config"))?)?;
        let attn_variants =
            parse_variants(j.get("attn_variants").ok_or_else(|| anyhow!("no attn_variants"))?, "kv_heads")?;
        let ffn_variants =
            parse_variants(j.get("ffn_variants").ok_or_else(|| anyhow!("no ffn_variants"))?, "i_dim")?;
        let mut execs = BTreeMap::new();
        for (name, e) in j
            .get("execs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("no execs"))?
        {
            let shapes = |key: &str| -> Vec<(String, Vec<usize>)> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .map(|s| {
                                (
                                    s.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
                                    s.get("shape")
                                        .and_then(Json::as_arr)
                                        .map(|d| d.iter().filter_map(Json::as_usize).collect())
                                        .unwrap_or_default(),
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            execs.insert(
                name.clone(),
                ExecSig {
                    file: e.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                    in_shapes: shapes("in"),
                    out_shapes: shapes("out"),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), cfg, attn_variants, ffn_variants, execs })
    }

    pub fn exec_path(&self, name: &str) -> Result<PathBuf> {
        let sig = self.execs.get(name).ok_or_else(|| anyhow!("unknown exec {name}"))?;
        Ok(self.dir.join(&sig.file))
    }

    /// Layout for an architecture choice (None for NoOp).
    pub fn attn_layout(&self, c: &AttnChoice) -> Option<&VariantLayout> {
        match c {
            AttnChoice::NoOp => None,
            _ => self.attn_variants.get(&c.name()),
        }
    }

    pub fn ffn_layout(&self, c: &FfnChoice) -> Option<&VariantLayout> {
        match c {
            FfnChoice::NoOp => None,
            _ => self.ffn_variants.get(&c.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name":"t","d":8,"n_layers":2,"n_heads":2,"head_dim":4,"i":16,
                 "v":32,"s_train":8,"b_train":2,"s_prefill":8,"b_decode":2,
                 "s_max":12,"s_long":16,"rope_theta":10000.0,"eps":1e-5},
      "attn_variants": {"gqa_r1": {"weights": [["norm",[8]],["wq",[8,8]],["wk",[8,8]],["wv",[8,8]],["wo",[8,8]]], "kv_heads": 2},
                         "linear": {"weights": [["norm",[8]],["wl",[8,8]]], "kv_heads": 0}},
      "ffn_variants": {"r100": {"weights": [["norm",[8]],["wg",[8,16]],["wu",[8,16]],["wd",[16,8]]], "i_dim": 16}},
      "execs": {"attn_gqa_r1_train_fwd": {"file":"a.hlo.txt","in":[{"dtype":"float32","shape":[2,8,8]}],"out":[{"dtype":"float32","shape":[2,8,8]}]}}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("puzzle_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.cfg.d, 8);
        assert_eq!(m.cfg.qdim(), 8);
        assert_eq!(m.attn_variants["gqa_r1"].kv_heads, 2);
        assert_eq!(m.attn_variants["gqa_r1"].param_count(), 8 + 4 * 64);
        assert_eq!(m.ffn_variants["r100"].i_dim, 16);
        assert_eq!(m.execs["attn_gqa_r1_train_fwd"].in_shapes[0].1, vec![2, 8, 8]);
        assert!(m.attn_layout(&AttnChoice::NoOp).is_none());
        assert!(m.attn_layout(&AttnChoice::Linear).is_some());
    }
}
