//! One-sided Jacobi SVD — substrate for the low-rank comparison method
//! (paper §8.4, Table 17: factorized layers à la Khodak et al.).
//!
//! Good enough numerically for the weight matrices we factor (hundreds of
//! rows/cols); O(mn²) per sweep with a handful of sweeps to converge.

use super::Tensor;

/// Singular value decomposition A = U diag(s) Vᵀ.
pub struct Svd {
    /// Left singular vectors [m, r].
    pub u: Tensor,      // [m, r]
    /// Singular values, descending.
    pub s: Vec<f32>,    // [r], descending
    /// Right singular vectors, transposed [r, n].
    pub vt: Tensor,     // [r, n]
}

/// Full SVD of a [m, n] matrix via one-sided Jacobi on A (operating on
/// columns of A, accumulating V).
pub fn svd(a: &Tensor) -> Svd {
    let (m, n) = (a.shape[0], a.shape[1]);
    // work on columns: store A column-major for cache-friendly rotations
    let mut cols: Vec<Vec<f32>> = (0..n)
        .map(|j| (0..m).map(|i| a.data[i * n + j]).collect())
        .collect();
    let mut v: Vec<Vec<f32>> = (0..n)
        .map(|j| {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            e
        })
        .collect();

    let eps = 1e-9f64;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    app += (cols[p][i] as f64) * (cols[p][i] as f64);
                    aqq += (cols[q][i] as f64) * (cols[q][i] as f64);
                    apq += (cols[p][i] as f64) * (cols[q][i] as f64);
                }
                off += apq.abs();
                if apq.abs() < eps * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let xp = cols[p][i];
                    let xq = cols[q][i];
                    cols[p][i] = cf * xp - sf * xq;
                    cols[q][i] = sf * xp + cf * xq;
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = cf * vp - sf * vq;
                    v[q][i] = sf * vp + cf * vq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // singular values = column norms; U = normalized columns
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f32> = cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f32>().sqrt()).collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());

    let r = n.min(m);
    let mut u = Tensor::zeros(&[m, r]);
    let mut s = vec![0.0f32; r];
    let mut vt = Tensor::zeros(&[r, n]);
    for (k, &j) in order.iter().take(r).enumerate() {
        s[k] = norms[j];
        let inv = if norms[j] > 1e-20 { 1.0 / norms[j] } else { 0.0 };
        for i in 0..m {
            u.data[i * r + k] = cols[j][i] * inv;
        }
        for i in 0..n {
            vt.data[k * n + i] = v[j][i];
        }
    }
    Svd { u, s, vt }
}

/// Rank-k approximation of `a`: U_k diag(s_k) V_kᵀ, returned at full shape.
pub fn low_rank_approx(a: &Tensor, k: usize) -> Tensor {
    let dec = svd(a);
    let (m, n) = (a.shape[0], a.shape[1]);
    let r = dec.s.len().min(k);
    let mut out = Tensor::zeros(&[m, n]);
    for kk in 0..r {
        let sk = dec.s[kk];
        for i in 0..m {
            let uik = dec.u.data[i * dec.s.len() + kk] * sk;
            if uik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.data[i * n + j] += uik * dec.vt.data[kk * n + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reconstructs_full_rank() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[8, 6], 1.0, &mut rng);
        let d = svd(&a);
        // U diag(s) Vt == A
        let mut us = d.u.clone();
        for i in 0..8 {
            for k in 0..6 {
                us.data[i * 6 + k] *= d.s[k];
            }
        }
        let rec = us.matmul(&d.vt);
        assert!(rec.sub(&a).frob_norm() < 1e-3 * a.frob_norm().max(1.0));
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[10, 5], 1.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn exact_on_rank_one() {
        // A = u v^T has exactly one nonzero singular value
        let u = vec![1.0f32, 2.0, -1.0];
        let v = vec![0.5f32, -0.5, 1.0, 2.0];
        let mut a = Tensor::zeros(&[3, 4]);
        for i in 0..3 {
            for j in 0..4 {
                a.data[i * 4 + j] = u[i] * v[j];
            }
        }
        let d = svd(&a);
        assert!(d.s[0] > 1.0);
        for &s in &d.s[1..] {
            assert!(s < 1e-4, "trailing singular value {s}");
        }
        let rec = low_rank_approx(&a, 1);
        assert!(rec.sub(&a).frob_norm() < 1e-4);
    }

    #[test]
    fn low_rank_is_best_approx_improves_with_k() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[12, 12], 1.0, &mut rng);
        let e1 = low_rank_approx(&a, 2).sub(&a).frob_norm();
        let e2 = low_rank_approx(&a, 6).sub(&a).frob_norm();
        let e3 = low_rank_approx(&a, 12).sub(&a).frob_norm();
        assert!(e1 > e2 && e2 > e3);
        assert!(e3 < 1e-3);
    }
}
