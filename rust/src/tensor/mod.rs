//! Dense f32 tensor math used host-side: weight-init transforms (§3.2),
//! Wanda / low-rank comparison methods (§8.4), loss gradients, Adam, and
//! eval logprob arithmetic. All heavy model compute runs through the AOT
//! executables — this module is for coordinator-side linear algebra.

pub mod svd;

use crate::util::Rng;

#[derive(Debug, Clone, PartialEq)]
/// Dense row-major f32 tensor.
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major element storage.
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    /// Wrap `data` (length must equal the shape's product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Gaussian init with the given std (parent weight initialization).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal() * std).collect() }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Leading dimension (2-D).
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Trailing dimension (2-D).
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    /// Element (i, j) of a 2-D tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    /// Set element (i, j) of a 2-D tensor.
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    /// Matrix multiply: [m,k] @ [k,n] -> [m,n]. Blocked i-k-j loop order
    /// (row-major friendly, vectorizes well).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(other.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|x| x * s).collect() }
    }

    /// Elementwise sum (shapes must match).
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Elementwise difference (shapes must match).
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// L2 norm of row i (2-D only).
    pub fn row_norm(&self, i: usize) -> f32 {
        let n = self.cols();
        self.data[i * n..(i + 1) * n].iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// L2 norm of column j (2-D only).
    pub fn col_norm(&self, j: usize) -> f32 {
        let (m, n) = (self.shape[0], self.shape[1]);
        (0..m).map(|i| self.data[i * n + j].powi(2)).sum::<f32>().sqrt()
    }

    /// Keep only rows listed in `idx` (2-D): used by Channel-Contribution
    /// pruning of the FFN down-projection [I, D] -> [I', D].
    pub fn select_rows(&self, idx: &[usize]) -> Tensor {
        let n = self.cols();
        let mut data = Vec::with_capacity(idx.len() * n);
        for &i in idx {
            data.extend_from_slice(&self.data[i * n..(i + 1) * n]);
        }
        Tensor { shape: vec![idx.len(), n], data }
    }

    /// Keep only columns listed in `idx` (2-D): prunes the up/gate
    /// projections [D, I] -> [D, I'].
    pub fn select_cols(&self, idx: &[usize]) -> Tensor {
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut data = Vec::with_capacity(idx.len() * m);
        for i in 0..m {
            for &j in idx {
                data.push(self.data[i * n + j]);
            }
        }
        Tensor { shape: vec![m, idx.len()], data }
    }
}

/// Numerically-stable softmax over the last axis of a flat [rows, v] slice,
/// in place.
pub fn softmax_rows(data: &mut [f32], v: usize) {
    for row in data.chunks_mut(v) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            z += *x;
        }
        for x in row.iter_mut() {
            *x /= z;
        }
    }
}

/// log-softmax over rows, in place.
pub fn log_softmax_rows(data: &mut [f32], v: usize) {
    for row in data.chunks_mut(v) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|x| (x - m).exp()).sum();
        let lz = z.ln() + m;
        for x in row.iter_mut() {
            *x -= lz;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut eye = Tensor::zeros(&[3, 3]);
        for i in 0..3 {
            eye.set2(i, i, 1.0);
        }
        assert_eq!(a.matmul(&eye).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn select_rows_cols() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.select_rows(&[2, 0]).data, vec![5., 6., 1., 2.]);
        assert_eq!(a.select_cols(&[1]).data, vec![2., 4., 6.]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut d = vec![1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0];
        softmax_rows(&mut d, 3);
        assert!((d[0..3].iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((d[3..6].iter().sum::<f32>() - 1.0).abs() < 1e-6); // stable at large values
        assert!(d[2] > d[1] && d[1] > d[0]);
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let mut a = vec![0.5, -1.0, 2.0];
        let mut b = a.clone();
        softmax_rows(&mut a, 3);
        log_softmax_rows(&mut b, 3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x.ln() - y).abs() < 1e-5);
        }
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[2, 2], vec![3., 0., 4., 0.]);
        assert!((a.col_norm(0) - 5.0).abs() < 1e-6);
        assert!((a.row_norm(0) - 3.0).abs() < 1e-6);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
    }
}
