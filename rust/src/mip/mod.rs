//! Architecture search (paper §4.3): the grouped-knapsack MIP plus the
//! ablation searchers (greedy §8.2.2, parameter-max §8.2.3, random
//! §8.2.4). Variables are per-(layer, attention x FFN combo); exactly one
//! combo per layer; memory / throughput / latency constraints from the
//! cost table; scores from the replace-1-block table. The diversity
//! constraint bounds overlap with previous solutions.

pub mod bnb;
pub mod lp;

use anyhow::{anyhow, Result};

use crate::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use crate::perf::CostTable;
use crate::scoring::ScoreTable;
use crate::util::Rng;

pub use bnb::MipResult;
pub use lp::{Lp, LpResult};

/// Deployment constraints (paper's Memory_max / Throughput_min /
/// Latency_max; any may be disabled with None).
#[derive(Debug, Clone, Copy, Default)]
pub struct Constraints {
    /// Max KV + weight bytes (None = unconstrained).
    pub memory_max_bytes: Option<f64>,
    /// Min tokens/s under the cost table's scenario.
    pub throughput_min: Option<f64>,
    /// Max per-request latency in seconds.
    pub latency_max_secs: Option<f64>,
}

#[derive(Debug, Clone)]
/// One architecture chosen by the search, with its modeled stats.
pub struct Solution {
    /// The chosen architecture.
    pub arch: Arch,
    /// sum of replace-1-block costs (lower = closer to parent)
    pub cost: f64,
    /// Modeled scenario runtime in seconds.
    pub secs: f64,
    /// Modeled throughput (tokens/s).
    pub throughput: f64,
    /// Modeled memory footprint in bytes.
    pub memory: f64,
    /// Parameter count.
    pub params: f64,
}

struct Combos {
    list: Vec<(AttnChoice, FfnChoice)>,
}

impl Combos {
    fn new(space: &SearchSpace) -> Combos {
        let mut list = Vec::new();
        for a in &space.attn {
            for f in &space.ffn {
                list.push((*a, *f));
            }
        }
        Combos { list }
    }

    fn k(&self) -> usize {
        self.list.len()
    }
}

fn combo_cost(scores: &ScoreTable, layer: usize, c: &(AttnChoice, FfnChoice)) -> f64 {
    scores.get(layer, "attn", &c.0.name()) + scores.get(layer, "ffn", &c.1.name())
}

fn combo_secs(ct: &CostTable, c: &(AttnChoice, FfnChoice)) -> f64 {
    ct.attn[&c.0.name()].0 + ct.ffn[&c.1.name()].0
}

fn combo_mem(ct: &CostTable, c: &(AttnChoice, FfnChoice)) -> f64 {
    let (_, p_a, kv) = ct.attn[&c.0.name()];
    let (_, p_f, _) = ct.ffn[&c.1.name()];
    (p_a + p_f) * ct.bytes_per_param + ct.scenario.batch as f64 * kv
}

fn solution_from_arch(arch: Arch, scores: &ScoreTable, ct: &CostTable) -> Solution {
    let cost = scores.arch_cost(&arch);
    let secs = ct.arch_secs(&arch);
    let throughput = ct.arch_throughput(&arch);
    let memory = ct.arch_memory(&arch);
    let params = ct.arch_params(&arch);
    Solution { arch, cost, secs, throughput, memory, params }
}

/// The Puzzle MIP search. `previous` solutions + `alpha` add the §4.3
/// diversity constraint (each new solution differs in >= (1-alpha)·L
/// layer choices).
pub fn search_mip(
    space: &SearchSpace,
    scores: &ScoreTable,
    ct: &CostTable,
    cons: &Constraints,
    n_layers: usize,
    previous: &[Arch],
    alpha: f64,
) -> Result<Solution> {
    let combos = Combos::new(space);
    let k = combos.k();
    let n = n_layers * k;
    let mut lp = Lp::new(n);
    let var = |l: usize, j: usize| l * k + j;

    // maximize -(sum of costs): scores are KL-style costs (lower better)
    for l in 0..n_layers {
        for (j, c) in combos.list.iter().enumerate() {
            lp.obj[var(l, j)] = -combo_cost(scores, l, c);
        }
    }
    // one combo per layer
    for l in 0..n_layers {
        lp.add_eq((0..k).map(|j| (var(l, j), 1.0)).collect(), 1.0);
    }
    // memory
    if let Some(mem) = cons.memory_max_bytes {
        let mut terms = Vec::with_capacity(n);
        for l in 0..n_layers {
            for (j, c) in combos.list.iter().enumerate() {
                terms.push((var(l, j), combo_mem(ct, c)));
            }
        }
        lp.add_le(terms, mem - ct.fixed_params * ct.bytes_per_param);
    }
    // throughput: total seconds <= tokens / throughput_min
    let sc = &ct.scenario;
    let total_out_tokens = (sc.batch * sc.decode) as f64;
    let mut time_budgets = Vec::new();
    if let Some(tp) = cons.throughput_min {
        time_budgets.push(total_out_tokens / tp - ct.fixed_secs);
    }
    if let Some(lat) = cons.latency_max_secs {
        time_budgets.push(lat - ct.fixed_secs);
    }
    for budget in time_budgets {
        let mut terms = Vec::with_capacity(n);
        for l in 0..n_layers {
            for (j, c) in combos.list.iter().enumerate() {
                terms.push((var(l, j), combo_secs(ct, c)));
            }
        }
        lp.add_le(terms, budget);
    }
    // diversity vs previous solutions
    for prev in previous {
        let mut terms = Vec::new();
        for (l, choice) in prev.layers.iter().enumerate() {
            if let Some(j) = combos.list.iter().position(|c| c == choice) {
                terms.push((var(l, j), 1.0));
            }
        }
        lp.add_le(terms, alpha * n_layers as f64);
    }

    match bnb::solve_binary(&lp, 20_000) {
        MipResult::Infeasible => Err(anyhow!("MIP infeasible under constraints {cons:?}")),
        MipResult::Optimal { x, .. } => {
            let mut layers = vec![(AttnChoice::NoOp, FfnChoice::NoOp); n_layers];
            for j in x {
                layers[j / k] = combos.list[j % k];
            }
            Ok(solution_from_arch(Arch { layers }, scores, ct))
        }
    }
}

/// Budget-constrained greedy baseline (paper §8.2.2): split the time/memory
/// budgets equally across layers, process layers from most- to
/// least-replaceable (mean replace-1-block score), pick the best-scoring
/// combo within the layer's budget, and roll unused budget forward.
pub fn search_greedy(
    space: &SearchSpace,
    scores: &ScoreTable,
    ct: &CostTable,
    cons: &Constraints,
    n_layers: usize,
) -> Result<Solution> {
    let combos = Combos::new(space);
    let sc = &ct.scenario;
    let total_secs_budget = match (cons.throughput_min, cons.latency_max_secs) {
        (Some(tp), lat) => {
            let t = (sc.batch * sc.decode) as f64 / tp - ct.fixed_secs;
            lat.map(|l| t.min(l - ct.fixed_secs)).unwrap_or(t)
        }
        (None, Some(l)) => l - ct.fixed_secs,
        (None, None) => f64::INFINITY,
    };
    let total_mem_budget = cons
        .memory_max_bytes
        .map(|m| m - ct.fixed_params * ct.bytes_per_param)
        .unwrap_or(f64::INFINITY);

    // layer order: ascending mean score = easiest to replace first
    let mut order: Vec<usize> = (0..n_layers).collect();
    order.sort_by(|&a, &b| scores.layer_mean(a).partial_cmp(&scores.layer_mean(b)).unwrap());

    let mut layers = vec![(AttnChoice::Gqa { divisor: 1 }, FfnChoice::Ratio(0)); n_layers];
    let mut secs_left = total_secs_budget;
    let mut mem_left = total_mem_budget;
    for (rank, &l) in order.iter().enumerate() {
        let remaining = (n_layers - rank) as f64;
        let secs_budget = secs_left / remaining;
        let mem_budget = mem_left / remaining;
        // best-scoring combo within this layer's budget
        let mut best: Option<(f64, usize)> = None;
        for (j, c) in combos.list.iter().enumerate() {
            if combo_secs(ct, c) <= secs_budget && combo_mem(ct, c) <= mem_budget {
                let cost = combo_cost(scores, l, c);
                if best.map(|(b, _)| cost < b).unwrap_or(true) {
                    best = Some((cost, j));
                }
            }
        }
        let (_, j) = best.ok_or_else(|| anyhow!("greedy: no combo fits layer {l} budget"))?;
        layers[l] = combos.list[j];
        secs_left -= combo_secs(ct, &combos.list[j]);
        mem_left -= combo_mem(ct, &combos.list[j]);
    }
    Ok(solution_from_arch(Arch { layers }, scores, ct))
}

/// Parameter-maximizing baseline (paper §8.2.3): per layer, the combo with
/// the most parameters that fits the equally-split budget. Data-free.
pub fn search_param_max(
    space: &SearchSpace,
    scores: &ScoreTable,
    ct: &CostTable,
    cons: &Constraints,
    n_layers: usize,
) -> Result<Solution> {
    let combos = Combos::new(space);
    let sc = &ct.scenario;
    let secs_budget = match cons.throughput_min {
        Some(tp) => ((sc.batch * sc.decode) as f64 / tp - ct.fixed_secs) / n_layers as f64,
        None => f64::INFINITY,
    };
    let mem_budget = cons
        .memory_max_bytes
        .map(|m| (m - ct.fixed_params * ct.bytes_per_param) / n_layers as f64)
        .unwrap_or(f64::INFINITY);
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let mut best: Option<(f64, usize)> = None;
        for (j, c) in combos.list.iter().enumerate() {
            if combo_secs(ct, c) <= secs_budget && combo_mem(ct, c) <= mem_budget {
                let params = ct.attn[&c.0.name()].1 + ct.ffn[&c.1.name()].1;
                if best.map(|(b, _)| params > b).unwrap_or(true) {
                    best = Some((params, j));
                }
            }
        }
        let (_, j) = best.ok_or_else(|| anyhow!("param-max: nothing fits"))?;
        layers.push(combos.list[j]);
    }
    Ok(solution_from_arch(Arch { layers }, scores, ct))
}

/// Random-from-library baseline (paper §8.2.4): uniform random combos,
/// resampled layer-wise until the time constraint holds (simple repair).
pub fn search_random(
    space: &SearchSpace,
    scores: &ScoreTable,
    ct: &CostTable,
    cons: &Constraints,
    n_layers: usize,
    rng: &mut Rng,
) -> Result<Solution> {
    let combos = Combos::new(space);
    let sc = &ct.scenario;
    let secs_budget = match cons.throughput_min {
        Some(tp) => (sc.batch * sc.decode) as f64 / tp - ct.fixed_secs,
        None => f64::INFINITY,
    };
    for _attempt in 0..5000 {
        let layers: Vec<(AttnChoice, FfnChoice)> =
            (0..n_layers).map(|_| *rng.choice(&combos.list)).collect();
        let arch = Arch { layers };
        if ct.arch_secs(&arch) - ct.fixed_secs <= secs_budget {
            if let Some(m) = cons.memory_max_bytes {
                if ct.arch_memory(&arch) > m {
                    continue;
                }
            }
            return Ok(solution_from_arch(arch, scores, ct));
        }
    }
    Err(anyhow!("random search found no feasible arch in 5000 samples"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::{HwProfile, Scenario};

    fn setup() -> (SearchSpace, ScoreTable, CostTable, usize) {
        let man = crate::config::TinyManifest::synthetic();
        let space = SearchSpace::full(man.cfg.n_heads as u32);
        let n_layers = man.cfg.n_layers;
        // synthetic scores: cheaper variants "hurt more", deeper layers hurt more
        let mut scores = ScoreTable { metric_name: "synthetic".into(), ..Default::default() };
        for l in 0..n_layers {
            let depth = 1.0 + l as f64 * 0.3;
            for a in &space.attn {
                let pain = match a {
                    AttnChoice::Gqa { divisor } => 0.01 * (*divisor as f64 - 1.0),
                    AttnChoice::Linear => 0.3,
                    AttnChoice::NoOp => 0.6,
                };
                scores.set(l, "attn", &a.name(), pain * depth);
            }
            for f in &space.ffn {
                let pain = match f {
                    FfnChoice::Ratio(i) => 0.05 * *i as f64,
                    FfnChoice::Linear => 0.5,
                    FfnChoice::NoOp => 0.8,
                };
                scores.set(l, "ffn", &f.name(), pain * depth);
            }
        }
        let hw = HwProfile::h100_fp8();
        let sc = Scenario { prefill: 128, decode: 128, batch: 8 };
        let ct = CostTable::modeled(&man, &hw, &sc);
        (space, scores, ct, n_layers)
    }

    #[test]
    fn mip_meets_constraints_and_beats_greedy() {
        let (space, scores, ct, n_layers) = setup();
        let parent = Arch::parent(n_layers);
        let parent_tp = ct.arch_throughput(&parent);
        let cons = Constraints {
            throughput_min: Some(parent_tp * 1.8),
            memory_max_bytes: None,
            latency_max_secs: None,
        };
        let mip = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
        assert!(mip.throughput >= parent_tp * 1.8 * 0.999, "tp {}", mip.throughput);
        let greedy = search_greedy(&space, &scores, &ct, &cons, n_layers).unwrap();
        assert!(greedy.throughput >= parent_tp * 1.8 * 0.98);
        assert!(
            mip.cost <= greedy.cost + 1e-9,
            "MIP ({:.4}) must beat greedy ({:.4})",
            mip.cost,
            greedy.cost
        );
        // unconstrained: MIP picks the parent (zero cost)
        let free = search_mip(&space, &scores, &ct, &Constraints::default(), n_layers, &[], 1.0).unwrap();
        assert!(free.cost < 1e-9, "unconstrained cost {}", free.cost);
        assert_eq!(free.arch, parent);
    }

    #[test]
    fn diversity_constraint_produces_different_archs() {
        let (space, scores, ct, n_layers) = setup();
        let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
        let cons = Constraints { throughput_min: Some(parent_tp * 1.5), ..Default::default() };
        let s1 = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
        let s2 =
            search_mip(&space, &scores, &ct, &cons, n_layers, &[s1.arch.clone()], 0.5).unwrap();
        let sim = s1.arch.similarity(&s2.arch);
        assert!(sim <= 0.5 + 1e-9, "similarity {sim}");
        assert!(s2.cost >= s1.cost - 1e-9); // diversity can only cost quality
    }

    #[test]
    fn memory_constraint_prefers_fewer_kv_heads() {
        let (space, scores, ct, n_layers) = setup();
        // memory cap at ~40% of parent's footprint
        let parent_mem = ct.arch_memory(&Arch::parent(n_layers));
        let cons = Constraints { memory_max_bytes: Some(parent_mem * 0.4), ..Default::default() };
        let sol = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
        assert!(sol.memory <= parent_mem * 0.4 * 1.001);
        // at least one layer must shed kv heads or attention entirely
        assert!(sol
            .arch
            .layers
            .iter()
            .any(|(a, _)| !matches!(a, AttnChoice::Gqa { divisor: 1 })));
    }

    #[test]
    fn random_baseline_feasible_but_worse() {
        let (space, scores, ct, n_layers) = setup();
        let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
        let cons = Constraints { throughput_min: Some(parent_tp * 1.5), ..Default::default() };
        let mip = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
        let mut rng = Rng::new(0);
        let rnd = search_random(&space, &scores, &ct, &cons, n_layers, &mut rng).unwrap();
        assert!(rnd.throughput >= parent_tp * 1.5 * 0.98);
        assert!(rnd.cost >= mip.cost);
    }

    #[test]
    fn param_max_ignores_scores() {
        let (space, scores, ct, n_layers) = setup();
        let parent_tp = ct.arch_throughput(&Arch::parent(n_layers));
        let cons = Constraints { throughput_min: Some(parent_tp * 1.8), ..Default::default() };
        let pm = search_param_max(&space, &scores, &ct, &cons, n_layers).unwrap();
        let mip = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
        assert!(pm.cost >= mip.cost);
        // uniform: all layers pick the same combo
        assert!(pm.arch.layers.windows(2).all(|w| w[0] == w[1]));
    }

    /// Exhaustively enumerate every architecture of a 3-layer x 3-variant
    /// space and check branch-and-bound returns exactly the brute-force
    /// optimum under a memory constraint.
    #[test]
    fn bnb_equals_brute_force_on_small_space() {
        let (_, scores, ct, _) = setup();
        let n_layers = 3;
        // 3 combos per layer: parent, linear-attention, and all-noop
        let space = SearchSpace::reduced(
            vec![AttnChoice::Gqa { divisor: 1 }, AttnChoice::Linear, AttnChoice::NoOp],
            vec![FfnChoice::Ratio(0)],
        );
        let combos: Vec<(AttnChoice, FfnChoice)> = space
            .attn
            .iter()
            .flat_map(|a| space.ffn.iter().map(move |f| (*a, *f)))
            .collect();
        assert_eq!(combos.len(), 3);

        // memory cap: forces at least one non-parent layer but keeps the
        // problem feasible (all-noop always fits)
        let parent_mem = ct.arch_memory(&Arch::parent(n_layers));
        for frac in [0.5, 0.75, 0.95] {
            let cons = Constraints {
                memory_max_bytes: Some(parent_mem * frac),
                ..Default::default()
            };
            // brute force over all 3^3 = 27 architectures
            let mut best: Option<(f64, Arch)> = None;
            for i in 0..combos.len().pow(n_layers as u32) {
                let mut idx = i;
                let mut layers = Vec::with_capacity(n_layers);
                for _ in 0..n_layers {
                    layers.push(combos[idx % combos.len()]);
                    idx /= combos.len();
                }
                let arch = Arch { layers };
                if ct.arch_memory(&arch) > parent_mem * frac {
                    continue;
                }
                let cost = scores.arch_cost(&arch);
                if best.as_ref().map(|(b, _)| cost < *b).unwrap_or(true) {
                    best = Some((cost, arch));
                }
            }
            let (bf_cost, _) = best.expect("brute force must find a feasible arch");
            let mip = search_mip(&space, &scores, &ct, &cons, n_layers, &[], 1.0).unwrap();
            assert!(mip.memory <= parent_mem * frac * 1.001, "mip violates memory cap");
            assert!(
                (mip.cost - bf_cost).abs() < 1e-6,
                "frac {frac}: bnb cost {} != brute-force optimum {bf_cost}",
                mip.cost
            );
        }
    }
}
