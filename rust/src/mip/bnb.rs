//! Branch & bound on the LP relaxation: the MIP layer on top of `lp`.
//!
//! Grouped one-hot structure makes the relaxations nearly integral, so a
//! best-first DFS with fractional-variable branching converges in a few
//! dozen nodes on Puzzle instances.

use super::lp::{Lp, LpResult};

#[derive(Debug, Clone, PartialEq)]
/// Outcome of a branch-and-bound solve.
pub enum MipResult {
    /// Integral optimum: chosen index per group and the objective.
    Optimal { x: Vec<usize>, obj: f64 },
    /// No integral feasible point.
    Infeasible,
}

const INT_EPS: f64 = 1e-6;

fn most_fractional(x: &[f64]) -> Option<usize> {
    let mut best = None;
    let mut best_dist = INT_EPS;
    for (j, &v) in x.iter().enumerate() {
        let frac = (v - v.round()).abs();
        if frac > best_dist {
            best_dist = frac;
            best = Some(j);
        }
    }
    best
}

/// Solve a 0/1 MIP (all structural vars binary). Returns the set of
/// variables at 1.
pub fn solve_binary(lp: &Lp, node_limit: usize) -> MipResult {
    let mut best_obj = f64::NEG_INFINITY;
    let mut best_x: Option<Vec<usize>> = None;
    // DFS stack of (lower, upper) bound vectors
    let mut stack = vec![(lp.lower.clone(), lp.upper.clone())];
    let mut nodes = 0;

    while let Some((lo, hi)) = stack.pop() {
        nodes += 1;
        if nodes > node_limit {
            break;
        }
        let mut sub = lp.clone();
        sub.lower = lo.clone();
        sub.upper = hi.clone();
        match sub.solve() {
            LpResult::Infeasible => continue,
            LpResult::Unbounded => continue,
            LpResult::Optimal { x, obj } => {
                if obj <= best_obj + 1e-9 {
                    continue; // pruned by bound
                }
                match most_fractional(&x) {
                    None => {
                        // integral
                        best_obj = obj;
                        best_x = Some(
                            x.iter()
                                .enumerate()
                                .filter(|(_, &v)| v > 0.5)
                                .map(|(j, _)| j)
                                .collect(),
                        );
                    }
                    Some(j) => {
                        // branch: x_j = 1 first (greedy toward good scores)
                        let mut lo1 = lo.clone();
                        let mut hi0 = hi.clone();
                        lo1[j] = 1.0;
                        hi0[j] = 0.0;
                        stack.push((lo, hi0));
                        stack.push((lo1, hi));
                    }
                }
            }
        }
    }
    match best_x {
        Some(x) => MipResult::Optimal { x, obj: best_obj },
        None => MipResult::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// brute-force reference over all binary assignments
    fn brute(lp: &Lp) -> Option<(Vec<usize>, f64)> {
        let n = lp.n;
        let mut best: Option<(Vec<usize>, f64)> = None;
        for mask in 0..(1u32 << n) {
            let x: Vec<f64> = (0..n)
                .map(|j| if mask >> j & 1 == 1 { 1.0 } else { 0.0 })
                .collect();
            // bounds
            if (0..n).any(|j| x[j] < lp.lower[j] - 1e-9 || x[j] > lp.upper[j] + 1e-9) {
                continue;
            }
            let feasible = lp.cons.iter().all(|c| {
                let lhs: f64 = c.terms.iter().map(|&(j, v)| v * x[j]).sum();
                match c.sense {
                    super::super::lp::Sense::Le => lhs <= c.rhs + 1e-9,
                    super::super::lp::Sense::Eq => (lhs - c.rhs).abs() < 1e-9,
                }
            });
            if !feasible {
                continue;
            }
            let obj: f64 = (0..n).map(|j| lp.obj[j] * x[j]).sum();
            if best.as_ref().map(|(_, b)| obj > *b).unwrap_or(true) {
                best = Some(((0..n).filter(|&j| x[j] > 0.5).collect(), obj));
            }
        }
        best
    }

    #[test]
    fn matches_brute_force_grouped_knapsack() {
        // 3 groups x 3 choices, one resource constraint
        let vals = [9.0, 5.0, 1.0, 8.0, 6.0, 2.0, 7.0, 4.0, 1.0];
        let costs = [5.0, 3.0, 1.0, 5.0, 3.0, 1.0, 5.0, 3.0, 1.0];
        for budget in [3.0, 5.0, 7.0, 9.0, 11.0, 15.0] {
            let mut lp = Lp::new(9);
            lp.obj = vals.to_vec();
            for g in 0..3 {
                lp.add_eq((0..3).map(|k| (g * 3 + k, 1.0)).collect(), 1.0);
            }
            lp.add_le((0..9).map(|j| (j, costs[j])).collect(), budget);
            let got = solve_binary(&lp, 10_000);
            let want = brute(&lp).expect("brute found feasible");
            match got {
                MipResult::Optimal { obj, .. } => {
                    assert!(
                        (obj - want.1).abs() < 1e-6,
                        "budget {budget}: got {obj} want {}",
                        want.1
                    );
                }
                r => panic!("budget {budget}: {r:?}"),
            }
        }
    }

    #[test]
    fn infeasible_budget() {
        let mut lp = Lp::new(2);
        lp.obj = vec![1.0, 1.0];
        lp.add_eq(vec![(0, 1.0), (1, 1.0)], 1.0);
        lp.add_le(vec![(0, 5.0), (1, 5.0)], 1.0); // every choice too expensive
        assert_eq!(solve_binary(&lp, 1000), MipResult::Infeasible);
    }

    #[test]
    fn multi_constraint_matches_brute() {
        // 2 groups x 2, two resources
        let mut lp = Lp::new(4);
        lp.obj = vec![10.0, 6.0, 9.0, 5.0];
        lp.add_eq(vec![(0, 1.0), (1, 1.0)], 1.0);
        lp.add_eq(vec![(2, 1.0), (3, 1.0)], 1.0);
        lp.add_le(vec![(0, 4.0), (1, 1.0), (2, 4.0), (3, 1.0)], 5.0);
        lp.add_le(vec![(0, 1.0), (1, 3.0), (2, 1.0), (3, 3.0)], 4.5);
        let want = brute(&lp).unwrap();
        match solve_binary(&lp, 1000) {
            MipResult::Optimal { obj, x } => {
                assert!((obj - want.1).abs() < 1e-6, "got {obj} ({x:?}) want {want:?}");
            }
            r => panic!("{r:?}"),
        }
    }
}
