//! Dense two-phase primal simplex with bounded variables (l <= x <= u).
//!
//! Built from scratch (python-mip/CBC are unavailable offline). Sized for
//! Puzzle's grouped-knapsack instances: ~L·54 structural variables but only
//! ~L + a few constraint rows, so a dense row tableau with *implicit*
//! variable bounds (no per-variable rows) stays small and each pivot is
//! O(rows · cols).
//!
//! Upper bounds use the classic complementing trick: a nonbasic variable
//! that moves to its upper bound is substituted x -> u - x (column sign
//! flip + rhs shift), so every nonbasic variable always sits at zero and
//! the core iteration is the plain simplex with an extended ratio test.
//! Lower bounds are shifted out at build time. Equalities get phase-1
//! artificials.

const EPS: f64 = 1e-9;

#[derive(Debug, Clone, Copy, PartialEq)]
/// Constraint sense.
pub enum Sense {
    /// Less-than-or-equal row.
    Le,
    /// Equality row.
    Eq,
}

#[derive(Debug, Clone)]
/// One sparse constraint row.
pub struct Constraint {
    /// sparse row: (var index, coefficient)
    pub terms: Vec<(usize, f64)>,
    /// Row sense.
    pub sense: Sense,
    /// Right-hand side.
    pub rhs: f64,
}

#[derive(Debug, Clone)]
/// A bounded-variable LP, maximized by `solve`.
pub struct Lp {
    /// Structural variable count.
    pub n: usize,
    /// objective to MAXIMIZE
    pub obj: Vec<f64>,
    /// Constraint rows.
    pub cons: Vec<Constraint>,
    /// Per-variable lower bounds.
    pub lower: Vec<f64>,
    /// Per-variable upper bounds.
    pub upper: Vec<f64>,
}

#[derive(Debug, Clone, PartialEq)]
/// Outcome of an LP solve.
pub enum LpResult {
    /// Optimal solution vector and objective.
    Optimal { x: Vec<f64>, obj: f64 },
    /// No feasible point.
    Infeasible,
    /// Objective unbounded above.
    Unbounded,
}

impl Lp {
    /// An LP over `n` variables bounded to [0, 1] by default.
    pub fn new(n: usize) -> Lp {
        Lp { n, obj: vec![0.0; n], cons: vec![], lower: vec![0.0; n], upper: vec![1.0; n] }
    }

    /// Add a `terms . x <= rhs` row.
    pub fn add_le(&mut self, terms: Vec<(usize, f64)>, rhs: f64) {
        self.cons.push(Constraint { terms, sense: Sense::Le, rhs });
    }

    /// Add a `terms . x == rhs` row.
    pub fn add_eq(&mut self, terms: Vec<(usize, f64)>, rhs: f64) {
        self.cons.push(Constraint { terms, sense: Sense::Eq, rhs });
    }

    /// Two-phase primal simplex; maximizes the objective.
    pub fn solve(&self) -> LpResult {
        Simplex::build(self).solve(self)
    }
}

struct Simplex {
    m: usize,
    ncols: usize,
    n_struct: usize,
    art0: usize,
    /// row-major tableau [m x ncols], maintained as B^-1 A (complemented)
    t: Vec<f64>,
    /// rhs = current basic values
    beta: Vec<f64>,
    /// span (upper - lower) per column; infinity for slacks/artificials-pre-fix
    u: Vec<f64>,
    /// working objective (complement flips sign)
    c: Vec<f64>,
    flipped: Vec<bool>,
    basis: Vec<usize>,
    in_basis: Vec<bool>,
}

impl Simplex {
    fn build(lp: &Lp) -> Simplex {
        let m = lp.cons.len();
        let n_slack = lp.cons.iter().filter(|c| c.sense == Sense::Le).count();
        let n_struct = lp.n;
        let art0 = n_struct + n_slack;
        let ncols = art0 + m;
        let mut t = vec![0.0; m * ncols];
        let mut beta = vec![0.0; m];
        let mut u = vec![f64::INFINITY; ncols];
        for j in 0..n_struct {
            u[j] = lp.upper[j] - lp.lower[j];
        }
        let mut c = vec![0.0; ncols];
        c[..n_struct].copy_from_slice(&lp.obj);

        let mut slack = 0;
        for (row, con) in lp.cons.iter().enumerate() {
            // shift lower bounds: rhs -= a_j * l_j
            let mut rhs = con.rhs;
            for &(j, v) in &con.terms {
                t[row * ncols + j] += v;
                rhs -= v * lp.lower[j];
            }
            if con.sense == Sense::Le {
                t[row * ncols + n_struct + slack] = 1.0;
                slack += 1;
            }
            // normalize rhs >= 0 so artificial start is feasible
            if rhs < 0.0 {
                rhs = -rhs;
                for j in 0..art0 {
                    t[row * ncols + j] = -t[row * ncols + j];
                }
            }
            t[row * ncols + art0 + row] = 1.0;
            beta[row] = rhs;
        }

        let basis: Vec<usize> = (0..m).map(|r| art0 + r).collect();
        let mut in_basis = vec![false; ncols];
        for &b in &basis {
            in_basis[b] = true;
        }
        Simplex {
            m,
            ncols,
            n_struct,
            art0,
            t,
            beta,
            u,
            c,
            flipped: vec![false; ncols],
            basis,
            in_basis,
        }
    }

    fn solve(mut self, lp: &Lp) -> LpResult {
        // phase 1: maximize -sum(artificials)
        let mut c1 = vec![0.0; self.ncols];
        for j in self.art0..self.ncols {
            c1[j] = -1.0;
        }
        std::mem::swap(&mut self.c, &mut c1);
        if !self.iterate() {
            return LpResult::Unbounded;
        }
        let art_val: f64 = (0..self.m)
            .filter(|&r| self.basis[r] >= self.art0)
            .map(|r| self.beta[r])
            .sum();
        if art_val > 1e-6 {
            return LpResult::Infeasible;
        }
        // fix artificials at zero and restore the real objective
        for j in self.art0..self.ncols {
            self.u[j] = 0.0;
        }
        std::mem::swap(&mut self.c, &mut c1); // c1 now holds phase-2 obj (flips preserved below)
        // re-apply complement flips to the restored objective
        for j in 0..self.ncols {
            if self.flipped[j] {
                self.c[j] = -self.c[j];
            }
        }
        if !self.iterate() {
            return LpResult::Unbounded;
        }

        // extract solution in original coordinates
        let mut x = vec![0.0; self.n_struct];
        for j in 0..self.n_struct {
            if self.flipped[j] && !self.in_basis[j] {
                x[j] = self.u[j]; // complemented nonbasic sits at upper
            }
        }
        for r in 0..self.m {
            let j = self.basis[r];
            if j < self.n_struct {
                x[j] = if self.flipped[j] { self.u[j] - self.beta[r] } else { self.beta[r] };
            }
        }
        let mut obj = 0.0;
        for j in 0..self.n_struct {
            x[j] += lp.lower[j];
            // clamp tiny numerical dust
            if x[j] < lp.lower[j] {
                x[j] = lp.lower[j];
            }
            if x[j] > lp.upper[j] {
                x[j] = lp.upper[j];
            }
            obj += lp.obj[j] * x[j];
        }
        LpResult::Optimal { x, obj }
    }

    /// Core primal loop; returns false on unbounded.
    fn iterate(&mut self) -> bool {
        let max_iter = 50 * (self.m + self.ncols) + 200;
        for _ in 0..max_iter {
            // reduced costs via c_B . T
            let cb: Vec<f64> = self.basis.iter().map(|&j| self.c[j]).collect();
            let mut enter = None;
            let mut best = 1e-7;
            for j in 0..self.ncols {
                if self.in_basis[j] || self.u[j] <= EPS {
                    continue;
                }
                let mut d = self.c[j];
                if cb.iter().any(|&x| x != 0.0) {
                    for r in 0..self.m {
                        let crr = cb[r];
                        if crr != 0.0 {
                            d -= crr * self.t[r * self.ncols + j];
                        }
                    }
                }
                if d > best {
                    best = d;
                    enter = Some(j);
                }
            }
            let Some(jin) = enter else { return true };

            // ratio test
            let mut theta = self.u[jin];
            let mut leave: Option<(usize, bool)> = None; // (row, hits_upper)
            for r in 0..self.m {
                let trj = self.t[r * self.ncols + jin];
                if trj > EPS {
                    let lim = self.beta[r] / trj;
                    if lim < theta - EPS {
                        theta = lim;
                        leave = Some((r, false));
                    }
                } else if trj < -EPS {
                    let ub = self.u[self.basis[r]];
                    if ub.is_finite() {
                        let lim = (ub - self.beta[r]) / (-trj);
                        if lim < theta - EPS {
                            theta = lim;
                            leave = Some((r, true));
                        }
                    }
                }
            }
            if theta.is_infinite() {
                return false;
            }
            match leave {
                None => {
                    // bound flip of the entering variable
                    self.complement(jin);
                }
                Some((r_star, hits_upper)) => {
                    if hits_upper {
                        // complement the leaving basic so it exits at zero
                        let jout = self.basis[r_star];
                        self.complement_basic(jout, r_star);
                    }
                    self.pivot(r_star, jin);
                }
            }
        }
        true
    }

    /// Complement a nonbasic column: x -> u - x.
    fn complement(&mut self, j: usize) {
        let uj = self.u[j];
        for r in 0..self.m {
            self.beta[r] -= self.t[r * self.ncols + j] * uj;
            self.t[r * self.ncols + j] = -self.t[r * self.ncols + j];
            if self.beta[r].abs() < EPS {
                self.beta[r] = 0.0;
            }
        }
        self.c[j] = -self.c[j];
        self.flipped[j] = !self.flipped[j];
    }

    /// Complement a *basic* variable (its tableau column is e_r).
    fn complement_basic(&mut self, j: usize, row: usize) {
        let uj = self.u[j];
        self.beta[row] -= uj; // becomes <= 0; the subsequent pivot restores >= 0
        self.t[row * self.ncols + j] = -1.0;
        self.c[j] = -self.c[j];
        self.flipped[j] = !self.flipped[j];
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let nc = self.ncols;
        let p = self.t[row * nc + col];
        debug_assert!(p.abs() > EPS, "pivot on ~0");
        let inv = 1.0 / p;
        for j in 0..nc {
            self.t[row * nc + j] *= inv;
        }
        self.beta[row] *= inv;
        for r in 0..self.m {
            if r == row {
                continue;
            }
            let f = self.t[r * nc + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..nc {
                self.t[r * nc + j] -= f * self.t[row * nc + j];
            }
            self.beta[r] -= f * self.beta[row];
            if self.beta[r].abs() < EPS {
                self.beta[r] = 0.0;
            }
        }
        let jout = self.basis[row];
        self.in_basis[jout] = false;
        self.in_basis[col] = true;
        self.basis[row] = col;
        if self.beta[row] < 0.0 && self.beta[row] > -1e-7 {
            self.beta[row] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(lp: &Lp, want_obj: f64, want_x: Option<&[f64]>) {
        match lp.solve() {
            LpResult::Optimal { x, obj } => {
                assert!((obj - want_obj).abs() < 1e-6, "obj {obj} want {want_obj} (x={x:?})");
                if let Some(w) = want_x {
                    for (a, b) in x.iter().zip(w) {
                        assert!((a - b).abs() < 1e-6, "x {x:?} want {w:?}");
                    }
                }
            }
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn simple_le_max() {
        // max x0 + 2 x1, x0 + x1 <= 1.5, x in [0,1]^2 -> (0.5, 1), obj 2.5
        let mut lp = Lp::new(2);
        lp.obj = vec![1.0, 2.0];
        lp.add_le(vec![(0, 1.0), (1, 1.0)], 1.5);
        assert_opt(&lp, 2.5, Some(&[0.5, 1.0]));
    }

    #[test]
    fn upper_bounds_bind_without_constraints() {
        let mut lp = Lp::new(3);
        lp.obj = vec![1.0, 2.0, 3.0];
        assert_opt(&lp, 6.0, Some(&[1.0, 1.0, 1.0]));
    }

    #[test]
    fn group_equality() {
        let mut lp = Lp::new(2);
        lp.obj = vec![3.0, 1.0];
        lp.add_eq(vec![(0, 1.0), (1, 1.0)], 1.0);
        assert_opt(&lp, 3.0, Some(&[1.0, 0.0]));
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(2);
        lp.add_eq(vec![(0, 1.0), (1, 1.0)], 3.0);
        assert_eq!(lp.solve(), LpResult::Infeasible);
    }

    #[test]
    fn grouped_knapsack_relaxation() {
        // 2 groups x 2 choices; budget forces a fractional mix.
        let mut lp = Lp::new(4);
        lp.obj = vec![10.0, 4.0, 10.0, 3.0];
        lp.add_eq(vec![(0, 1.0), (1, 1.0)], 1.0);
        lp.add_eq(vec![(2, 1.0), (3, 1.0)], 1.0);
        lp.add_le(vec![(0, 4.0), (1, 1.0), (2, 4.0), (3, 1.0)], 6.0);
        // optimum: x2=1 (w 4); group0 fractional x0=1/3, x1=2/3 (w 2)
        // obj = 10 + 10/3 + 8/3 = 16
        match lp.solve() {
            LpResult::Optimal { x, obj } => {
                assert!((obj - 16.0).abs() < 1e-6, "obj {obj} x {x:?}");
                assert!((x[0] + x[1] - 1.0).abs() < 1e-6);
                assert!((x[2] + x[3] - 1.0).abs() < 1e-6);
                let w: f64 = 4.0 * x[0] + x[1] + 4.0 * x[2] + x[3];
                assert!(w <= 6.0 + 1e-6);
            }
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn respects_fixed_bounds() {
        let mut lp = Lp::new(2);
        lp.obj = vec![1.0, 2.0];
        lp.lower[0] = 1.0; // x0 fixed to [1,1]
        lp.add_le(vec![(0, 1.0), (1, 1.0)], 1.2);
        assert_opt(&lp, 1.4, Some(&[1.0, 0.2]));
    }

    #[test]
    fn negative_rhs_and_coefficients() {
        // max -x0 s.t. -x0 <= -0.3 (i.e. x0 >= 0.3)
        let mut lp = Lp::new(1);
        lp.obj = vec![-1.0];
        lp.add_le(vec![(0, -1.0)], -0.3);
        assert_opt(&lp, -0.3, Some(&[0.3]));
    }

    #[test]
    fn random_lps_match_enumeration() {
        // vertices of box-constrained LPs with one <= row: optimum is at a
        // vertex of {0,1}^n intersected with the halfspace — check against
        // a fine grid search.
        use crate::util::Rng;
        let mut rng = Rng::new(123);
        for case in 0..30 {
            let n = 3;
            let obj: Vec<f64> = (0..n).map(|_| (rng.f64() - 0.3) * 4.0).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 + 0.1).collect();
            let budget = rng.f64() * 3.0 + 0.2;
            let mut lp = Lp::new(n);
            lp.obj = obj.clone();
            lp.add_le((0..n).map(|j| (j, w[j])).collect(), budget);
            let LpResult::Optimal { obj: got, .. } = lp.solve() else {
                panic!("case {case} not optimal")
            };
            // grid reference
            let steps = 40;
            let mut best = f64::NEG_INFINITY;
            let mut idx = vec![0usize; n];
            loop {
                let x: Vec<f64> = idx.iter().map(|&i| i as f64 / steps as f64).collect();
                let wt: f64 = (0..n).map(|j| w[j] * x[j]).sum();
                if wt <= budget + 1e-12 {
                    let o: f64 = (0..n).map(|j| obj[j] * x[j]).sum();
                    if o > best {
                        best = o;
                    }
                }
                let mut k = 0;
                loop {
                    idx[k] += 1;
                    if idx[k] <= steps {
                        break;
                    }
                    idx[k] = 0;
                    k += 1;
                    if k == n {
                        break;
                    }
                }
                if k == n {
                    break;
                }
            }
            assert!(
                got >= best - 0.02 && got <= best + 0.26,
                "case {case}: simplex {got} vs grid {best}"
            );
            assert!(got >= best - 0.02, "simplex must not be below grid optimum");
        }
    }
}
