//! Puzzle: distillation-based NAS for inference-optimized LLMs (ICML 2025)
//! — full-system reproduction. See DESIGN.md for the architecture and the
//! substitution ledger, EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): pipeline coordinator, BLD/GKD training drivers, MIP
//!   architecture search, hardware cost models, serving engine, eval suite.
//! * L2/L1 (python/compile): JAX block-variant graphs + Pallas kernels,
//!   AOT-lowered once to `artifacts/<cfg>/*.hlo.txt` (HLO text), executed
//!   here through the PJRT CPU client (`runtime`).

pub mod arch;
pub mod bld;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod gkd;
pub mod mip;
pub mod model;
pub mod serving;
pub mod perf;
pub mod pipeline;
pub mod runtime;
pub mod scoring;
pub mod tensor;
pub mod train;
pub mod util;
pub mod weights;

pub use config::{Manifest, ModelCfg};
