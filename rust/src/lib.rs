//! Puzzle: distillation-based NAS for inference-optimized LLMs (ICML 2025)
//! — full-system reproduction. See DESIGN.md for the architecture, the
//! `Backend` contract and the substitution ledger, EXPERIMENTS.md for
//! paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): pipeline coordinator, BLD/GKD training drivers, MIP
//!   architecture search, hardware cost models, serving engine, eval suite.
//!   All drivers are generic over the `runtime::Backend` trait.
//! * Execution backends (`runtime`):
//!   - `RefBackend` (default): hermetic pure-Rust interpreter of the block
//!     executables over an in-memory synthetic manifest — the whole
//!     pipeline runs in CI with no artifacts, no `xla` crate, no python.
//!   - `XlaBackend` (`pjrt` feature): JAX block-variant graphs + Pallas
//!     kernels (python/compile), AOT-lowered once to
//!     `artifacts/<cfg>/*.hlo.txt` and executed via the PJRT CPU client.

// This crate leans on explicit index arithmetic for tensor layouts and on
// wide driver signatures; keep clippy's style lints out of `-D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::new_without_default
)]
// Every public item carries rustdoc; CI builds `cargo doc --no-deps` with
// `-D warnings`, so a missing doc is a build failure, not a nit.
#![warn(missing_docs)]

pub mod arch;
pub mod bld;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod gkd;
pub mod mip;
pub mod model;
pub mod obs;
pub mod serving;
pub mod perf;
pub mod pipeline;
pub mod runtime;
pub mod scoring;
// The async front-end needs `Engine: Send`, which only the default
// (owned-`Arc`) backend build provides — the PJRT handle is `Rc`.
#[cfg(not(feature = "pjrt"))]
pub mod server;
pub mod specdec;
pub mod tensor;
pub mod train;
pub mod util;
pub mod weights;
pub mod workload;

pub use config::{Manifest, ModelCfg, TinyManifest};
pub use runtime::{share, Backend, RefBackend, SharedBackend};
