//! Replace-1-block scoring (paper §4.2): the quality of each library block
//! is estimated by replacing *only that block* in the parent and measuring
//! a distance on held-out data. During architecture search, a candidate's
//! quality is the sum of its blocks' scores — no candidate is ever
//! materialized.
//!
//! Metrics: KL divergence to the parent (the paper's best), LM loss, or a
//! caller-provided downstream callback (task-oriented scoring, §8.1.4).
//! The parent's prefix activations are cached per batch, so scoring layer
//! `l` only recomputes layers `l..L` (the paper's efficient-I/O trick in
//! spirit).

use anyhow::Result;
use std::collections::BTreeMap;
use std::collections::HashMap;

use crate::arch::{Arch, AttnChoice, FfnChoice, SearchSpace};
use crate::data::Batch;
use crate::model::{run_subblock, CompiledModel, Trace};
use crate::runtime::{tensor_to_val, val_to_tensor, Backend, Value};
use crate::tensor::Tensor;
use crate::train::losses;
use crate::util::Json;
use crate::weights::Store;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Distance metric for replace-1-block scoring.
pub enum Metric {
    /// KL(parent || replaced) on validation logits — lower is better.
    Kl,
    /// LM loss increase on validation targets — lower is better.
    LmLoss,
}

/// Score table: (layer, "attn:gqa_r2") -> cost (lower = better block).
/// Parent variants score ~0 by construction under KL.
#[derive(Debug, Clone, Default)]
pub struct ScoreTable {
    /// (layer, "kind:variant") -> cost; lower = better block.
    pub scores: BTreeMap<(usize, String), f64>,
    /// Which metric produced the scores.
    pub metric_name: String,
}

/// Canonical "kind:variant" key used in the table.
pub fn variant_key(kind: &str, name: &str) -> String {
    format!("{kind}:{name}")
}

impl ScoreTable {
    /// One block's score (0.0 when absent, e.g. parent variants).
    pub fn get(&self, layer: usize, kind: &str, name: &str) -> f64 {
        *self
            .scores
            .get(&(layer, variant_key(kind, name)))
            .unwrap_or(&0.0)
    }

    /// Set one block's score.
    pub fn set(&mut self, layer: usize, kind: &str, name: &str, v: f64) {
        self.scores.insert((layer, variant_key(kind, name)), v);
    }

    /// Estimated cost of a whole architecture = sum of replace-1-block
    /// scores of its choices (the decomposed-NAS quality estimate).
    pub fn arch_cost(&self, arch: &Arch) -> f64 {
        arch.layers
            .iter()
            .enumerate()
            .map(|(l, (a, f))| {
                self.get(l, "attn", &a.name()) + self.get(l, "ffn", &f.name())
            })
            .sum()
    }

    /// Mean score across variants for one layer — the greedy baseline's
    /// "how replaceable is this layer" heuristic (§8.2.2).
    pub fn layer_mean(&self, layer: usize) -> f64 {
        let vals: Vec<f64> = self
            .scores
            .iter()
            .filter(|((l, _), _)| *l == layer)
            .map(|(_, v)| *v)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Serialize as {metric, scores: [{layer, variant, score}]}.
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for ((l, k), v) in &self.scores {
            arr.push(Json::from_pairs(vec![
                ("layer", Json::num(*l as f64)),
                ("variant", Json::str(k)),
                ("score", Json::num(*v)),
            ]));
        }
        Json::from_pairs(vec![
            ("metric", Json::str(&self.metric_name)),
            ("scores", Json::Arr(arr)),
        ])
    }

    /// Parse the `to_json` form; None on malformed input.
    pub fn from_json(j: &Json) -> Option<ScoreTable> {
        let mut t = ScoreTable {
            metric_name: j.get("metric")?.as_str()?.to_string(),
            ..Default::default()
        };
        for e in j.get("scores")?.as_arr()? {
            t.scores.insert(
                (e.get("layer")?.as_usize()?, e.get("variant")?.as_str()?.to_string()),
                e.get("score")?.as_f64()?,
            );
        }
        Some(t)
    }
}

/// Cache of replacement-block weight-value sets, keyed by
/// (layer, kind:variant). Hoisting value construction out of the per-batch
/// scoring loop cut the scoring pass ~20% (EXPERIMENTS.md §Perf).
pub struct VariantVals {
    cache: HashMap<(usize, String), Vec<Value>>,
}

impl VariantVals {
    fn get(
        &mut self,
        be: &dyn Backend,
        store: &Store,
        layer: usize,
        kind: &str,
        variant: &str,
    ) -> Result<&Vec<Value>> {
        let key = (layer, variant_key(kind, variant));
        if !self.cache.contains_key(&key) {
            let man = be.man();
            let layout = if kind == "attn" {
                &man.attn_variants[variant]
            } else {
                &man.ffn_variants[variant]
            };
            let ws = store.block(layer, kind, variant, layout)?;
            let vals: Vec<Value> =
                ws.iter().map(|t| tensor_to_val(t)).collect::<Result<_>>()?;
            self.cache.insert(key.clone(), vals);
        }
        Ok(&self.cache[&key])
    }
}

/// Forward from layer `l` to logits, starting from activation `x` at layer
/// l's attention input, with layer l's subblocks overridden.
#[allow(clippy::too_many_arguments)]
fn forward_with_replacement(
    be: &dyn Backend,
    parent: &CompiledModel,
    store: &Store,
    layer: usize,
    kind: &str,
    variant: &str,
    trace: &Trace,
    vcache: &mut VariantVals,
) -> Result<Tensor> {
    let n_layers = parent.attn.len();
    // build the replacement subblock values
    let (a_choice, f_choice) = if kind == "attn" {
        (AttnChoice::from_name(variant).unwrap(), FfnChoice::Ratio(0))
    } else {
        (AttnChoice::Gqa { divisor: 1 }, FfnChoice::from_name(variant).unwrap())
    };

    // start from cached parent activations at this layer's attn input
    let mut x = trace.attn_in[layer].clone();
    for l in layer..n_layers {
        if l == layer {
            // replaced layer
            if kind == "attn" {
                x = match a_choice {
                    AttnChoice::NoOp => x,
                    _ => {
                        let vals = vcache.get(be, store, l, "attn", variant)?;
                        let mut inputs: Vec<&Value> = vec![&x];
                        inputs.extend(vals.iter());
                        be.run(&format!("attn_{variant}_train_fwd"), &inputs)?.remove(0)
                    }
                };
                x = run_subblock(be, &parent.ffn[l], "train", x)?;
            } else {
                x = run_subblock(be, &parent.attn[l], "train", x)?;
                x = match f_choice {
                    FfnChoice::NoOp => x,
                    _ => {
                        let vals = vcache.get(be, store, l, "ffn", variant)?;
                        let mut inputs: Vec<&Value> = vec![&x];
                        inputs.extend(vals.iter());
                        be.run(&format!("ffn_{variant}_train_fwd"), &inputs)?.remove(0)
                    }
                };
            }
        } else {
            x = run_subblock(be, &parent.attn[l], "train", x)?;
            x = run_subblock(be, &parent.ffn[l], "train", x)?;
        }
    }
    let logits =
        be.run("head_train", &[&x, &parent.final_norm, &parent.embed])?.remove(0);
    val_to_tensor(&logits)
}

/// Score the full library: every (layer, variant) under `metric`, averaged
/// over `batches`. Returns costs where parent variants are included too
/// (they measure the library's own fidelity, not assumed zero).
pub fn score_library(
    be: &dyn Backend,
    store: &Store,
    space: &SearchSpace,
    batches: &[Batch],
    metric: Metric,
) -> Result<ScoreTable> {
    let man = be.man();
    let n_layers = man.cfg.n_layers;
    let parent_arch = Arch::parent(n_layers);
    let parent = CompiledModel::assemble(man, store, &parent_arch)?;

    let mut table = ScoreTable {
        metric_name: match metric {
            Metric::Kl => "kl".into(),
            Metric::LmLoss => "lm_loss".into(),
        },
        ..Default::default()
    };
    let mut vcache = VariantVals { cache: HashMap::new() };

    for batch in batches {
        let trace = parent.forward(be, "train", &batch.inputs, batch.b, batch.s)?;
        let parent_lm = losses::lm_loss(&trace.logits, &batch.targets);
        for l in 0..n_layers {
            for a in &space.attn {
                let name = a.name();
                let cost = match a {
                    AttnChoice::Gqa { divisor: 1 } => 0.0,
                    _ => {
                        let logits = forward_with_replacement(
                            be, &parent, store, l, "attn", &name, &trace, &mut vcache,
                        )?;
                        metric_cost(metric, &trace.logits, &logits, &batch.targets, parent_lm)
                    }
                };
                let prev = table.get(l, "attn", &name);
                table.set(l, "attn", &name, prev + cost / batches.len() as f64);
            }
            for f in &space.ffn {
                let name = f.name();
                let cost = match f {
                    FfnChoice::Ratio(0) => 0.0,
                    _ => {
                        let logits = forward_with_replacement(
                            be, &parent, store, l, "ffn", &name, &trace, &mut vcache,
                        )?;
                        metric_cost(metric, &trace.logits, &logits, &batch.targets, parent_lm)
                    }
                };
                let prev = table.get(l, "ffn", &name);
                table.set(l, "ffn", &name, prev + cost / batches.len() as f64);
            }
        }
    }
    Ok(table)
}

fn metric_cost(metric: Metric, parent_logits: &Tensor, logits: &Tensor, targets: &[i32], parent_lm: f64) -> f64 {
    match metric {
        Metric::Kl => losses::kld_loss(parent_logits, logits),
        // LM-loss scoring: degradation relative to the parent
        Metric::LmLoss => (losses::lm_loss(logits, targets) - parent_lm).max(0.0),
    }
}

/// Data-free "scoring" ablation (§8.2.3): block score = -(parameter
/// count), so maximizing score = maximizing parameters.
pub fn param_count_table(be: &dyn Backend, space: &SearchSpace) -> ScoreTable {
    let man = be.man();
    let mut t = ScoreTable { metric_name: "neg_params".into(), ..Default::default() };
    for l in 0..man.cfg.n_layers {
        for a in &space.attn {
            let p = man.attn_layout(a).map(|x| x.param_count()).unwrap_or(0);
            t.set(l, "attn", &a.name(), -(p as f64));
        }
        for f in &space.ffn {
            let p = man.ffn_layout(f).map(|x| x.param_count()).unwrap_or(0);
            t.set(l, "ffn", &f.name(), -(p as f64));
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip_and_arch_cost() {
        let mut t = ScoreTable { metric_name: "kl".into(), ..Default::default() };
        t.set(0, "attn", "gqa_r2", 0.5);
        t.set(0, "ffn", "r50", 0.25);
        t.set(1, "attn", "noop", 2.0);
        let j = t.to_json();
        let t2 = ScoreTable::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(t.scores, t2.scores);

        let mut arch = Arch::parent(2);
        arch.layers[0] = (AttnChoice::Gqa { divisor: 2 }, FfnChoice::Ratio(3)); // r50
        arch.layers[1] = (AttnChoice::NoOp, FfnChoice::Ratio(0));
        assert!((t.arch_cost(&arch) - 2.75).abs() < 1e-9);
    }

    #[test]
    fn layer_mean() {
        let mut t = ScoreTable::default();
        t.set(0, "attn", "a", 1.0);
        t.set(0, "ffn", "b", 3.0);
        t.set(1, "attn", "a", 10.0);
        assert!((t.layer_mean(0) - 2.0).abs() < 1e-9);
        assert!((t.layer_mean(1) - 10.0).abs() < 1e-9);
    }
}
