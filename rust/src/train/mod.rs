//! Training driver: forward/backward chaining over block executables.
//!
//! Used for (a) pretraining the parent ("LM" loss), (b) GKD uptraining of
//! reassembled children (paper §5, any combination of LM / cosine / KLD
//! losses), and (c) the lightweight-alignment finetune (Table 5).
//!
//! The backward pass chains per-variant `*_train_vjp` executables (which
//! recompute their primal internally — deliberate rematerialization) and
//! applies Adam host-side. Runs on any `Backend`.

pub mod adam;
pub mod losses;

use anyhow::Result;
use std::collections::HashMap;

use crate::arch::Arch;
use crate::config::Manifest;
use crate::data::Batch;
use crate::model::{vjp_subblock, CompiledModel, Trace};
use crate::runtime::{tensor_to_val, val_i32, val_to_tensor, Backend, Value};
use crate::tensor::Tensor;
use crate::weights::{store::block_key, Store};

pub use adam::{lr_schedule, Adam, AdamCfg};

/// Which loss components drive the step (paper Table 1 combinations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossSpec {
    /// Next-token cross-entropy against the data.
    pub lm: bool,
    /// Per-layer hidden-state cosine distance to the parent.
    pub cosine: bool,
    /// KL divergence of logits to the parent.
    pub kld: bool,
}

impl LossSpec {
    /// Plain language-model pretraining (no parent).
    pub fn lm_only() -> LossSpec {
        LossSpec { lm: true, cosine: false, kld: false }
    }

    /// The paper's final GKD recipe (Eq. 4): cosine + KLD, no LM.
    pub fn gkd_best() -> LossSpec {
        LossSpec { lm: false, cosine: true, kld: true }
    }

    /// Short label, e.g. "cos+KLD".
    pub fn name(&self) -> String {
        let mut parts = vec![];
        if self.lm {
            parts.push("LM");
        }
        if self.cosine {
            parts.push("cos");
        }
        if self.kld {
            parts.push("KLD");
        }
        if parts.is_empty() {
            "none".into()
        } else {
            parts.join("+")
        }
    }
}

#[derive(Debug, Clone, Default)]
/// Loss values of one training step.
pub struct StepMetrics {
    /// Total weighted loss.
    pub loss: f64,
    /// LM component (0 when disabled).
    pub lm: f64,
    /// Cosine component (0 when disabled).
    pub cosine: f64,
    /// KLD component (0 when disabled).
    pub kld: f64,
}

/// Per-layer hidden states (outputs of each layer's FFN subblock) from a
/// trace: what the cosine loss compares between parent and child.
pub fn layer_hiddens(trace: &Trace) -> Vec<&Value> {
    let l = trace.attn_in.len();
    let mut out: Vec<&Value> = Vec::with_capacity(l);
    for i in 1..l {
        out.push(&trace.attn_in[i]);
    }
    out.push(&trace.hidden);
    out
}

/// One optimizer step of the child described by `arch` on `batch`.
/// `parent` (with its trace on the same batch) is required when the spec
/// uses cosine or KLD. Returns metrics; mutates `store` in place.
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    be: &dyn Backend,
    store: &mut Store,
    arch: &Arch,
    adam: &mut Adam,
    batch: &Batch,
    spec: LossSpec,
    parent_trace: Option<&Trace>,
    lr: f32,
) -> Result<StepMetrics> {
    let man = be.man();
    let child = CompiledModel::assemble(man, store, arch)?;
    let trace = child.forward(be, "train", &batch.inputs, batch.b, batch.s)?;

    // ---- loss heads -> dlogits ----
    let mut metrics = StepMetrics::default();
    let mut dlogits = Tensor::zeros(&trace.logits.shape);
    if spec.lm {
        let (l, g) = losses::ce_loss_and_grad(&trace.logits, &batch.targets);
        metrics.lm = l;
        dlogits = dlogits.add(&g);
    }
    if spec.kld {
        let p = parent_trace.expect("kld loss requires parent trace");
        let (l, g) = losses::kld_loss_and_grad(&p.logits, &trace.logits);
        metrics.kld = l;
        dlogits = dlogits.add(&g);
    }

    // per-layer cosine grads, indexed by layer (applied during backward)
    let n_layers = arch.n_layers();
    let mut dcos: Vec<Option<Tensor>> = vec![None; n_layers];
    if spec.cosine {
        let p = parent_trace.expect("cosine loss requires parent trace");
        let ph = layer_hiddens(p);
        let ch = layer_hiddens(&trace);
        for l in 0..n_layers {
            let hp = val_to_tensor(ph[l])?;
            let hc = val_to_tensor(ch[l])?;
            let (cl, g) = losses::cosine_loss_and_grad(&hc, &hp);
            metrics.cosine += cl / n_layers as f64;
            dcos[l] = Some(g);
        }
    }
    metrics.loss = metrics.lm + metrics.cosine + metrics.kld;

    // ---- backward chain ----
    let mut grads: HashMap<String, Tensor> = HashMap::new();
    let dlogits_val = tensor_to_val(&dlogits)?;
    let mut out = be.run(
        "head_train_vjp",
        &[&trace.hidden, &child.final_norm, &child.embed, &dlogits_val],
    )?;
    let mut dx = out.remove(0);
    grads.insert("final_norm".into(), val_to_tensor(&out[0])?);
    grads.insert("embed".into(), val_to_tensor(&out[1])?);

    for l in (0..n_layers).rev() {
        if let Some(g) = &dcos[l] {
            // cosine grad attaches to this layer's hidden state
            dx = tensor_to_val(&val_to_tensor(&dx)?.add(g))?;
        }
        let (a, f) = &arch.layers[l];
        let (dx2, dwf) = vjp_subblock(be, &child.ffn[l], &trace.ffn_in[l], dx)?;
        accumulate_block_grads(&mut grads, man, l, "ffn", &f.name(), dwf)?;
        let (dx3, dwa) = vjp_subblock(be, &child.attn[l], &trace.attn_in[l], dx2)?;
        accumulate_block_grads(&mut grads, man, l, "attn", &a.name(), dwa)?;
        dx = dx3;
    }

    let tok = val_i32(&[batch.b, batch.s], &batch.inputs)?;
    let de = be.run("embed_train_vjp", &[&tok, &child.embed, &dx])?.remove(0);
    let de = val_to_tensor(&de)?;
    let e = grads.get_mut("embed").unwrap();
    *e = e.add(&de); // tied embedding: head grad + input grad

    // ---- optimizer ----
    adam.cfg.lr = lr;
    adam.begin_step();
    let grad_refs: Vec<(&str, &Tensor)> = grads.iter().map(|(k, g)| (k.as_str(), g)).collect();
    let scale = adam.clip_scale(&grad_refs);
    for (key, g) in &grads {
        let w = store.map.get_mut(key).expect("grad for unknown weight");
        adam.update(key, w, g, scale);
    }
    Ok(metrics)
}

fn accumulate_block_grads(
    grads: &mut HashMap<String, Tensor>,
    man: &Manifest,
    layer: usize,
    kind: &str,
    variant: &str,
    dws: Vec<Value>,
) -> Result<()> {
    if dws.is_empty() {
        return Ok(()); // NoOp
    }
    let layout = if kind == "attn" {
        &man.attn_variants[variant]
    } else {
        &man.ffn_variants[variant]
    };
    for ((name, _), val) in layout.weights.iter().zip(dws) {
        grads.insert(block_key(layer, kind, variant, name), val_to_tensor(&val)?);
    }
    Ok(())
}

/// Evaluation-only forward: mean LM loss and KLD vs an optional parent
/// trace over one batch.
pub fn eval_batch(
    be: &dyn Backend,
    store: &Store,
    arch: &Arch,
    batch: &Batch,
    parent_trace: Option<&Trace>,
) -> Result<(f64, f64)> {
    let child = CompiledModel::assemble(be.man(), store, arch)?;
    let trace = child.forward(be, "train", &batch.inputs, batch.b, batch.s)?;
    let lm = losses::lm_loss(&trace.logits, &batch.targets);
    let kld = parent_trace
        .map(|p| losses::kld_loss(&p.logits, &trace.logits))
        .unwrap_or(0.0);
    Ok((lm, kld))
}
