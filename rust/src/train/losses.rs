//! Loss functions + analytic gradients, computed host-side over logits /
//! hidden states (V <= 512 keeps this cheap). Formulas mirror
//! python/compile/model.py, which is verified against jax autodiff in
//! pytest; the rust unit tests below pin the same values.

use crate::tensor::{log_softmax_rows, softmax_rows, Tensor};

/// Mean-token cross entropy + d/dlogits. logits [b*s, v] flattened.
pub fn ce_loss_and_grad(logits: &Tensor, targets: &[i32]) -> (f64, Tensor) {
    let v = *logits.shape.last().unwrap();
    let n = logits.numel() / v;
    assert_eq!(targets.len(), n);
    let mut lsm = logits.data.clone();
    log_softmax_rows(&mut lsm, v);
    let mut loss = 0.0f64;
    for (row, &t) in targets.iter().enumerate() {
        loss -= lsm[row * v + t as usize] as f64;
    }
    loss /= n as f64;
    // grad = (softmax - onehot) / n
    let mut g = logits.data.clone();
    softmax_rows(&mut g, v);
    let inv_n = 1.0 / n as f32;
    for (row, &t) in targets.iter().enumerate() {
        g[row * v + t as usize] -= 1.0;
    }
    for x in g.iter_mut() {
        *x *= inv_n;
    }
    (loss, Tensor::from_vec(&logits.shape, g))
}

/// Mean-token KL(parent || child) + d/dchild_logits.
pub fn kld_loss_and_grad(parent: &Tensor, child: &Tensor) -> (f64, Tensor) {
    assert_eq!(parent.shape, child.shape);
    let v = *parent.shape.last().unwrap();
    let n = parent.numel() / v;
    let mut lp = parent.data.clone();
    let mut lc = child.data.clone();
    log_softmax_rows(&mut lp, v);
    log_softmax_rows(&mut lc, v);
    let mut loss = 0.0f64;
    for i in 0..parent.numel() {
        let p = lp[i].exp();
        loss += (p * (lp[i] - lc[i])) as f64;
    }
    loss /= n as f64;
    // grad = (softmax(c) - softmax(p)) / n
    let inv_n = 1.0 / n as f32;
    let g: Vec<f32> = lc
        .iter()
        .zip(lp.iter())
        .map(|(c, p)| (c.exp() - p.exp()) * inv_n)
        .collect();
    (loss, Tensor::from_vec(&parent.shape, g))
}

/// KL eval only (validation KLD in Table 1).
pub fn kld_loss(parent: &Tensor, child: &Tensor) -> f64 {
    kld_loss_and_grad(parent, child).0
}

/// Mean (1 - cosine) between per-token hidden states + d/dh_child.
/// hc, hp: [n_tokens, d] flattened.
pub fn cosine_loss_and_grad(hc: &Tensor, hp: &Tensor) -> (f64, Tensor) {
    assert_eq!(hc.shape, hp.shape);
    let d = *hc.shape.last().unwrap();
    let n = hc.numel() / d;
    let mut loss = 0.0f64;
    let mut grad = vec![0.0f32; hc.numel()];
    let eps = 1e-8f32;
    for t in 0..n {
        let a = &hc.data[t * d..(t + 1) * d];
        let b = &hp.data[t * d..(t + 1) * d];
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        let den = na * nb + eps;
        let cos = dot / den;
        loss += 1.0 - cos as f64;
        // d(1-cos)/da = -(b/den - cos * a / (na^2))
        let inv_n = 1.0 / n as f32;
        for j in 0..d {
            let da = -(b[j] / den - cos * a[j] / (na * na + eps));
            grad[t * d + j] = da * inv_n;
        }
    }
    (loss / n as f64, Tensor::from_vec(&hc.shape, grad))
}

/// BLD objective (§3): normalized MSE = ||oc-op||² / ||op||², + d/doc.
pub fn nmse_loss_and_grad(oc: &Tensor, op: &Tensor) -> (f64, Tensor) {
    assert_eq!(oc.shape, op.shape);
    let denom: f32 = op.data.iter().map(|x| x * x).sum::<f32>() + 1e-8;
    let mut num = 0.0f64;
    let mut g = vec![0.0f32; oc.numel()];
    for i in 0..oc.numel() {
        let diff = oc.data[i] - op.data[i];
        num += (diff * diff) as f64;
        g[i] = 2.0 * diff / denom;
    }
    (num / denom as f64, Tensor::from_vec(&oc.shape, g))
}

/// Per-token LM loss of `logits` against targets, no grad (replace-1-block
/// LM-loss scoring, §4.2).
pub fn lm_loss(logits: &Tensor, targets: &[i32]) -> f64 {
    ce_loss_and_grad(logits, targets).0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check<F>(f: F, x0: &Tensor, analytic: &Tensor, tol: f32)
    where
        F: Fn(&Tensor) -> f64,
    {
        let h = 1e-3f32;
        for i in (0..x0.numel()).step_by((x0.numel() / 7).max(1)) {
            let mut xp = x0.clone();
            xp.data[i] += h;
            let mut xm = x0.clone();
            xm.data[i] -= h;
            let fd = ((f(&xp) - f(&xm)) / (2.0 * h as f64)) as f32;
            assert!(
                (fd - analytic.data[i]).abs() < tol,
                "idx {i}: fd {fd} vs analytic {}",
                analytic.data[i]
            );
        }
    }

    #[test]
    fn ce_grad_matches_finite_diff() {
        let logits = Tensor::from_vec(&[3, 4], vec![0.1, -0.5, 0.3, 1.0, 0.0, 0.2, -1.0, 0.4, 2.0, 0.1, 0.0, -0.3]);
        let targets = vec![2, 0, 1];
        let (_, g) = ce_loss_and_grad(&logits, &targets);
        finite_diff_check(|l| ce_loss_and_grad(l, &targets).0, &logits, &g, 1e-3);
    }

    #[test]
    fn ce_perfect_prediction_low_loss() {
        let mut logits = Tensor::zeros(&[2, 4]);
        logits.data[1] = 20.0; // row 0 predicts class 1
        logits.data[4 + 3] = 20.0; // row 1 predicts class 3
        let (loss, _) = ce_loss_and_grad(&logits, &[1, 3]);
        assert!(loss < 1e-3, "loss {loss}");
    }

    #[test]
    fn kld_zero_at_equal_and_grad_fd() {
        let p = Tensor::from_vec(&[2, 3], vec![0.5, -0.2, 1.0, 0.0, 0.3, -0.7]);
        assert!(kld_loss(&p, &p).abs() < 1e-9);
        let c = Tensor::from_vec(&[2, 3], vec![0.1, 0.4, -0.5, 0.9, -0.2, 0.0]);
        let (loss, g) = kld_loss_and_grad(&p, &c);
        assert!(loss > 0.0);
        finite_diff_check(|x| kld_loss_and_grad(&p, x).0, &c, &g, 1e-3);
    }

    #[test]
    fn cosine_grad_fd() {
        let hp = Tensor::from_vec(&[2, 4], vec![1.0, 0.5, -0.3, 0.8, -1.0, 0.2, 0.4, 0.1]);
        let hc = Tensor::from_vec(&[2, 4], vec![0.9, 0.1, 0.3, -0.2, 0.5, 0.5, -0.4, 1.0]);
        let (loss, g) = cosine_loss_and_grad(&hc, &hp);
        assert!(loss > 0.0 && loss < 2.0);
        finite_diff_check(|x| cosine_loss_and_grad(x, &hp).0, &hc, &g, 2e-3);
    }

    #[test]
    fn nmse_grad_fd_and_normalization() {
        let op = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.5, 0.3, 1.5, -0.7]);
        let zero = Tensor::zeros(&[2, 3]);
        assert!((nmse_loss_and_grad(&zero, &op).0 - 1.0).abs() < 1e-5);
        let oc = Tensor::from_vec(&[2, 3], vec![0.8, -1.5, 0.7, 0.0, 1.2, -0.2]);
        let (_, g) = nmse_loss_and_grad(&oc, &op);
        finite_diff_check(|x| nmse_loss_and_grad(x, &op).0, &oc, &g, 1e-3);
    }
}
