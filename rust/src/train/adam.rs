//! Adam optimizer, applied host-side to the weight store after the
//! backward chain returns gradients (elementwise; tiny fraction of step
//! cost).

use std::collections::HashMap;

use crate::tensor::Tensor;

#[derive(Debug, Clone)]
/// Adam hyperparameters.
pub struct AdamCfg {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator epsilon.
    pub eps: f32,
    /// Decoupled weight decay (0 = off).
    pub weight_decay: f32,
    /// Global gradient-norm clip (<= 0 disables).
    pub grad_clip: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { lr: 3e-3, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.0, grad_clip: 1.0 }
    }
}

#[derive(Default)]
struct Slot {
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Adam state over named parameters.
pub struct Adam {
    /// Hyperparameters.
    pub cfg: AdamCfg,
    /// Step counter (bias correction).
    pub t: u64,
    slots: HashMap<String, Slot>,
}

impl Adam {
    /// Fresh optimizer state under `cfg`.
    pub fn new(cfg: AdamCfg) -> Adam {
        Adam { cfg, t: 0, slots: HashMap::new() }
    }

    /// Begin a step (increments the bias-correction counter once per
    /// optimizer step regardless of parameter count).
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Global gradient-norm clipping across a set of grads; returns scale.
    pub fn clip_scale(&self, grads: &[(&str, &Tensor)]) -> f32 {
        if self.cfg.grad_clip <= 0.0 {
            return 1.0;
        }
        let total: f32 = grads
            .iter()
            .map(|(_, g)| g.data.iter().map(|x| x * x).sum::<f32>())
            .sum();
        let norm = total.sqrt();
        if norm > self.cfg.grad_clip {
            self.cfg.grad_clip / norm
        } else {
            1.0
        }
    }

    /// Update one parameter in place. `scale` multiplies the grad (clip).
    pub fn update(&mut self, key: &str, w: &mut Tensor, g: &Tensor, scale: f32) {
        assert_eq!(w.shape, g.shape, "adam shape mismatch for {key}");
        let n = w.numel();
        let slot = self.slots.entry(key.to_string()).or_insert_with(|| Slot {
            m: vec![0.0; n],
            v: vec![0.0; n],
        });
        let c = &self.cfg;
        let t = self.t.max(1) as i32;
        let bc1 = 1.0 - c.beta1.powi(t);
        let bc2 = 1.0 - c.beta2.powi(t);
        for i in 0..n {
            let gi = g.data[i] * scale + c.weight_decay * w.data[i];
            slot.m[i] = c.beta1 * slot.m[i] + (1.0 - c.beta1) * gi;
            slot.v[i] = c.beta2 * slot.v[i] + (1.0 - c.beta2) * gi * gi;
            let mhat = slot.m[i] / bc1;
            let vhat = slot.v[i] / bc2;
            w.data[i] -= c.lr * mhat / (vhat.sqrt() + c.eps);
        }
    }
}

/// Linear-warmup cosine-decay LR schedule.
pub fn lr_schedule(base: f32, step: u64, warmup: u64, total: u64) -> f32 {
    if step < warmup {
        return base * (step + 1) as f32 / warmup as f32;
    }
    let p = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    let p = p.min(1.0);
    0.1 * base + 0.9 * base * 0.5 * (1.0 + (std::f32::consts::PI * p).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_descends_quadratic() {
        // minimize f(w) = sum w^2 from w=1; grad = 2w
        let mut adam = Adam::new(AdamCfg { lr: 0.05, ..Default::default() });
        let mut w = Tensor::ones(&[4]);
        for _ in 0..200 {
            adam.begin_step();
            let g = Tensor::from_vec(&[4], w.data.iter().map(|x| 2.0 * x).collect());
            adam.update("w", &mut w, &g, 1.0);
        }
        assert!(w.data.iter().all(|x| x.abs() < 0.05), "{:?}", w.data);
    }

    #[test]
    fn clip_caps_norm() {
        let adam = Adam::new(AdamCfg { grad_clip: 1.0, ..Default::default() });
        let g = Tensor::from_vec(&[2], vec![30.0, 40.0]); // norm 50
        let s = adam.clip_scale(&[("g", &g)]);
        assert!((s - 0.02).abs() < 1e-6);
    }

    #[test]
    fn schedule_shape() {
        let base = 1.0;
        assert!(lr_schedule(base, 0, 10, 100) < 0.2);
        assert!((lr_schedule(base, 9, 10, 100) - 1.0).abs() < 0.01);
        assert!(lr_schedule(base, 99, 10, 100) < 0.2);
        assert!(lr_schedule(base, 50, 10, 100) > lr_schedule(base, 90, 10, 100));
    }
}
