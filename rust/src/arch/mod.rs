//! Architecture descriptors and the Puzzle search space (paper §2).
//!
//! A child architecture assigns every layer one attention choice and one
//! FFN choice. `NoOp` (skip the subblock) lives only here — it needs no
//! compiled executable or weights.

use crate::util::Json;

/// FFN intermediate-size ratios in the search space, largest first.
pub const FFN_RATIO_NAMES: [&str; 7] = ["r100", "r87", "r75", "r50", "r25", "r20", "r10"];

/// Numeric value of an FFN ratio name (e.g. "r50" -> 0.50).
pub fn ffn_ratio_value(name: &str) -> f64 {
    match name {
        "r100" => 1.00,
        "r87" => 0.87,
        "r75" => 0.75,
        "r50" => 0.50,
        "r25" => 0.25,
        "r20" => 0.20,
        "r10" => 0.10,
        _ => panic!("unknown ffn ratio {name}"),
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
/// Per-layer attention replacement choices (paper §2).
pub enum AttnChoice {
    /// GQA with kv_heads = n_heads / divisor. divisor 1 = the parent MHA.
    Gqa { divisor: u32 },
    /// Attention replaced by one linear layer.
    Linear,
    /// Subblock skipped entirely.
    NoOp,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
/// Per-layer FFN replacement choices (paper §2).
pub enum FfnChoice {
    /// SwiGLU with intermediate dim = ratio * parent I (by ratio name idx).
    Ratio(u8),
    /// FFN replaced by one linear layer.
    Linear,
    /// Subblock skipped entirely.
    NoOp,
}

impl AttnChoice {
    /// Variant name as used in manifests and score tables (e.g. "gqa_r2").
    pub fn name(&self) -> String {
        match self {
            AttnChoice::Gqa { divisor } => format!("gqa_r{divisor}"),
            AttnChoice::Linear => "linear".into(),
            AttnChoice::NoOp => "noop".into(),
        }
    }

    /// Parse a variant name back into a choice.
    pub fn from_name(s: &str) -> Option<AttnChoice> {
        if s == "linear" {
            return Some(AttnChoice::Linear);
        }
        if s == "noop" {
            return Some(AttnChoice::NoOp);
        }
        s.strip_prefix("gqa_r")?.parse().ok().map(|divisor| AttnChoice::Gqa { divisor })
    }

    /// Executable name prefix in the artifact manifest (None for NoOp).
    pub fn exec_prefix(&self) -> Option<String> {
        match self {
            AttnChoice::NoOp => None,
            _ => Some(format!("attn_{}", self.name())),
        }
    }
}

impl FfnChoice {
    /// Variant name as used in manifests and score tables (e.g. "r50").
    pub fn name(&self) -> String {
        match self {
            FfnChoice::Ratio(i) => FFN_RATIO_NAMES[*i as usize].to_string(),
            FfnChoice::Linear => "linear".into(),
            FfnChoice::NoOp => "noop".into(),
        }
    }

    /// Parse a variant name back into a choice.
    pub fn from_name(s: &str) -> Option<FfnChoice> {
        if s == "linear" {
            return Some(FfnChoice::Linear);
        }
        if s == "noop" {
            return Some(FfnChoice::NoOp);
        }
        FFN_RATIO_NAMES.iter().position(|&n| n == s).map(|i| FfnChoice::Ratio(i as u8))
    }

    /// Executable name prefix in the artifact manifest (None for NoOp).
    pub fn exec_prefix(&self) -> Option<String> {
        match self {
            FfnChoice::NoOp => None,
            _ => Some(format!("ffn_{}", self.name())),
        }
    }
}

/// The per-layer choice sets (paper's §2 instantiation: 6 x 9 = 54).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Attention choices available at every layer.
    pub attn: Vec<AttnChoice>,
    /// FFN choices available at every layer.
    pub ffn: Vec<FfnChoice>,
}

impl SearchSpace {
    /// Full space for a parent with `n_heads` query heads.
    pub fn full(n_heads: u32) -> SearchSpace {
        let mut attn = vec![];
        for divisor in [1u32, 2, 4, 8] {
            if n_heads % divisor == 0 && n_heads / divisor >= 1 {
                attn.push(AttnChoice::Gqa { divisor });
            }
        }
        attn.push(AttnChoice::Linear);
        attn.push(AttnChoice::NoOp);
        let mut ffn: Vec<FfnChoice> =
            (0..FFN_RATIO_NAMES.len()).map(|i| FfnChoice::Ratio(i as u8)).collect();
        ffn.push(FfnChoice::Linear);
        ffn.push(FfnChoice::NoOp);
        SearchSpace { attn, ffn }
    }

    /// "No-op only" ablation space (paper §8.1.5): parent block or skip.
    pub fn noop_only(n_heads: u32) -> SearchSpace {
        let _ = n_heads;
        SearchSpace {
            attn: vec![AttnChoice::Gqa { divisor: 1 }, AttnChoice::NoOp],
            ffn: vec![FfnChoice::Ratio(0), FfnChoice::NoOp],
        }
    }

    /// Reduced space for coupled-BLD refinement (paper §8.1.1).
    pub fn reduced(attn: Vec<AttnChoice>, ffn: Vec<FfnChoice>) -> SearchSpace {
        SearchSpace { attn, ffn }
    }

    /// Number of (attention, FFN) combinations per layer.
    pub fn per_layer_combinations(&self) -> usize {
        self.attn.len() * self.ffn.len()
    }

    /// log10 of the total architecture count for `layers` layers — the
    /// paper's 10^138 headline for Llama-70B.
    pub fn log10_size(&self, layers: usize) -> f64 {
        (self.per_layer_combinations() as f64).log10() * layers as f64
    }
}

/// One layer's assembled block: (attention choice, FFN choice).
pub type BlockChoice = (AttnChoice, FfnChoice);

/// A full child architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    /// One (attention, FFN) choice per layer, input to output.
    pub layers: Vec<BlockChoice>,
}

impl Arch {
    /// The parent: full MHA + full FFN everywhere.
    pub fn parent(n_layers: usize) -> Arch {
        Arch {
            layers: vec![(AttnChoice::Gqa { divisor: 1 }, FfnChoice::Ratio(0)); n_layers],
        }
    }

    /// Depth of the architecture.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Fraction of layer choices identical to `other` (diversity metric for
    /// the MIP's solution-diversity constraint, paper §4.3).
    pub fn similarity(&self, other: &Arch) -> f64 {
        assert_eq!(self.layers.len(), other.layers.len());
        let same = self
            .layers
            .iter()
            .zip(&other.layers)
            .filter(|(a, b)| a == b)
            .count();
        same as f64 / self.layers.len() as f64
    }

    /// Serialize as a per-layer array of {attn, ffn} variant names.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.layers
                .iter()
                .map(|(a, f)| {
                    Json::from_pairs(vec![
                        ("attn", Json::str(&a.name())),
                        ("ffn", Json::str(&f.name())),
                    ])
                })
                .collect(),
        )
    }

    /// Parse the `to_json` form; None on malformed input.
    pub fn from_json(j: &Json) -> Option<Arch> {
        let arr = j.as_arr()?;
        let mut layers = Vec::with_capacity(arr.len());
        for l in arr {
            let a = AttnChoice::from_name(l.get("attn")?.as_str()?)?;
            let f = FfnChoice::from_name(l.get("ffn")?.as_str()?)?;
            layers.push((a, f));
        }
        Some(Arch { layers })
    }

    /// Short human-readable signature, e.g. "L0:gqa_r4+r50 L1:noop+r100 ..."
    pub fn signature(&self) -> String {
        self.layers
            .iter()
            .enumerate()
            .map(|(i, (a, f))| format!("L{i}:{}+{}", a.name(), f.name()))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_space_matches_paper_counts() {
        // paper: 8 query heads -> gqa{8,4,2,1 kv} + linear + noop = 6 attn;
        // 7 ratios + linear + noop = 9 ffn; 54 per layer; 54^80 ~ 1e138.
        let s = SearchSpace::full(8);
        assert_eq!(s.attn.len(), 6);
        assert_eq!(s.ffn.len(), 9);
        assert_eq!(s.per_layer_combinations(), 54);
        let log10 = s.log10_size(80);
        assert!(log10 > 138.0 && log10 < 139.0, "log10 size {log10}");
    }

    #[test]
    fn name_roundtrip() {
        for a in SearchSpace::full(8).attn {
            assert_eq!(AttnChoice::from_name(&a.name()), Some(a));
        }
        for f in SearchSpace::full(8).ffn {
            assert_eq!(FfnChoice::from_name(&f.name()), Some(f));
        }
    }

    #[test]
    fn arch_json_roundtrip() {
        let mut arch = Arch::parent(4);
        arch.layers[1] = (AttnChoice::Linear, FfnChoice::Ratio(3));
        arch.layers[2] = (AttnChoice::NoOp, FfnChoice::NoOp);
        let j = arch.to_json();
        assert_eq!(Arch::from_json(&Json::parse(&j.to_string()).unwrap()), Some(arch));
    }

    #[test]
    fn similarity_metric() {
        let a = Arch::parent(4);
        let mut b = a.clone();
        assert_eq!(a.similarity(&b), 1.0);
        b.layers[0] = (AttnChoice::NoOp, FfnChoice::NoOp);
        assert_eq!(a.similarity(&b), 0.75);
    }

    #[test]
    fn small_head_counts_shrink_attn_space() {
        let s = SearchSpace::full(4);
        assert_eq!(s.attn.len(), 5); // divisor 8 invalid for 4 heads
    }
}
