//! Training-free initialization of alternative subblocks (paper §3.2).
//!
//! * GQA with fewer KV heads: mean-pool the parent's K/V head projections
//!   into the grouped heads (following Ainslie et al., GQA).
//! * Attention -> linear: W_l = W_v @ W_o, simulating "each token attends
//!   only to itself".
//! * FFN -> linear: W_l = W_up @ W_down, ignoring the gate.
//! * FFN intermediate-dim pruning via **Channel Contribution**: rank
//!   channels by mean |X_i| * ||W_down[i,:]||_2 over a calibration set and
//!   keep the top ones.

use crate::config::ModelCfg;
use crate::tensor::Tensor;

/// Mean-pool parent K or V projection [D, H*Dh] down to [D, KV*Dh].
/// Parent heads g*group..(g+1)*group are averaged into child head g.
pub fn pool_kv_heads(w: &Tensor, n_heads: usize, kv_heads: usize, head_dim: usize) -> Tensor {
    assert_eq!(w.shape[1], n_heads * head_dim);
    assert_eq!(n_heads % kv_heads, 0);
    let group = n_heads / kv_heads;
    let d = w.shape[0];
    let mut out = Tensor::zeros(&[d, kv_heads * head_dim]);
    let scale = 1.0 / group as f32;
    for row in 0..d {
        for g in 0..kv_heads {
            for j in 0..head_dim {
                let mut acc = 0.0;
                for m in 0..group {
                    let src_head = g * group + m;
                    acc += w.data[row * n_heads * head_dim + src_head * head_dim + j];
                }
                out.data[row * kv_heads * head_dim + g * head_dim + j] = acc * scale;
            }
        }
    }
    out
}

/// Attention-as-linear init: W_v [D, H*Dh] @ W_o [H*Dh, D] -> [D, D].
pub fn attn_linear_init(wv: &Tensor, wo: &Tensor) -> Tensor {
    wv.matmul(wo)
}

/// FFN-as-linear init: W_up [D, I] @ W_down [I, D] -> [D, D] (gate ignored).
pub fn ffn_linear_init(wu: &Tensor, wd: &Tensor) -> Tensor {
    wu.matmul(wd)
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Channel Contribution scores (paper §3.2): C_i = mean_t |X_{t,i}| *
/// ||W_down[i,:]||_2, where X = silu(h Wg) ⊙ (h Wu) are the FFN's
/// intermediate activations over a calibration batch `h` [T, D] of
/// post-norm block inputs.
pub fn channel_contribution(h: &Tensor, wg: &Tensor, wu: &Tensor, wd: &Tensor) -> Vec<f32> {
    let t = h.shape[0];
    let i = wg.shape[1];
    let g = h.matmul(wg);
    let u = h.matmul(wu);
    let mut mean_abs = vec![0.0f32; i];
    for row in 0..t {
        for j in 0..i {
            let x = silu(g.data[row * i + j]) * u.data[row * i + j];
            mean_abs[j] += x.abs();
        }
    }
    let inv_t = 1.0 / t.max(1) as f32;
    (0..i).map(|j| mean_abs[j] * inv_t * wd.row_norm(j)).collect()
}

/// Fallback data-free contribution when no calibration activations are
/// available: ||Wg[:,i]|| * ||Wd[i,:]|| (magnitude product).
pub fn datafree_contribution(wg: &Tensor, wd: &Tensor) -> Vec<f32> {
    (0..wg.shape[1]).map(|j| wg.col_norm(j) * wd.row_norm(j)).collect()
}

/// Keep the `keep` highest-scoring channels (original order preserved) and
/// prune Wg/Wu columns and Wd rows accordingly.
pub fn prune_ffn_channels(
    wg: &Tensor,
    wu: &Tensor,
    wd: &Tensor,
    scores: &[f32],
    keep: usize,
) -> (Tensor, Tensor, Tensor) {
    let i = wg.shape[1];
    assert_eq!(scores.len(), i);
    assert!(keep <= i);
    let mut idx: Vec<usize> = (0..i).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut kept: Vec<usize> = idx[..keep].to_vec();
    kept.sort_unstable();
    (wg.select_cols(&kept), wu.select_cols(&kept), wd.select_rows(&kept))
}

/// Derive a GQA variant's weights from the parent attention block.
/// parent ws = [norm, wq, wk, wv, wo] at kv_heads == n_heads.
pub fn derive_gqa(cfg: &ModelCfg, parent: &[&Tensor], divisor: u32) -> Vec<Tensor> {
    let kv = cfg.kv_heads(divisor);
    vec![
        parent[0].clone(),
        parent[1].clone(),
        pool_kv_heads(parent[2], cfg.n_heads, kv, cfg.head_dim),
        pool_kv_heads(parent[3], cfg.n_heads, kv, cfg.head_dim),
        parent[4].clone(),
    ]
}

/// Derive the attention-linear variant: [norm, wl].
pub fn derive_attn_linear(parent: &[&Tensor]) -> Vec<Tensor> {
    vec![parent[0].clone(), attn_linear_init(parent[3], parent[4])]
}

/// Derive an FFN ratio variant: [norm, wg', wu', wd'] with `i_dim` channels.
/// `calib_h`: post-norm block inputs for channel contribution; falls back
/// to the data-free metric when absent.
pub fn derive_ffn_ratio(parent: &[&Tensor], i_dim: usize, calib_h: Option<&Tensor>) -> Vec<Tensor> {
    let (norm, wg, wu, wd) = (parent[0], parent[1], parent[2], parent[3]);
    let scores = match calib_h {
        Some(h) => channel_contribution(h, wg, wu, wd),
        None => datafree_contribution(wg, wd),
    };
    let (wg2, wu2, wd2) = prune_ffn_channels(wg, wu, wd, &scores, i_dim);
    vec![norm.clone(), wg2, wu2, wd2]
}

/// Derive the FFN-linear variant: [norm, wl].
pub fn derive_ffn_linear(parent: &[&Tensor]) -> Vec<Tensor> {
    vec![parent[0].clone(), ffn_linear_init(parent[2], parent[3])]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pool_to_one_head_is_mean_of_all() {
        let (d, h, dh) = (3, 4, 2);
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[d, h * dh], 1.0, &mut rng);
        let pooled = pool_kv_heads(&w, h, 1, dh);
        assert_eq!(pooled.shape, vec![d, dh]);
        for row in 0..d {
            for j in 0..dh {
                let mean: f32 =
                    (0..h).map(|hh| w.data[row * h * dh + hh * dh + j]).sum::<f32>() / h as f32;
                assert!((pooled.data[row * dh + j] - mean).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn pool_identity_when_same_heads() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[4, 8], 1.0, &mut rng);
        assert_eq!(pool_kv_heads(&w, 4, 4, 2).data, w.data);
    }

    #[test]
    fn channel_contribution_finds_dominant_channel() {
        // craft an FFN where channel 2 carries all the signal
        let (d, i) = (4, 6);
        let mut wg = Tensor::zeros(&[d, i]);
        let mut wu = Tensor::zeros(&[d, i]);
        let mut wd = Tensor::zeros(&[i, d]);
        for row in 0..d {
            wg.set2(row, 2, 3.0);
            wu.set2(row, 2, 3.0);
        }
        for col in 0..d {
            wd.set2(2, col, 2.0);
        }
        // small noise on other channels
        let mut rng = Rng::new(3);
        for row in 0..d {
            for j in 0..i {
                if j != 2 {
                    wg.set2(row, j, rng.normal() * 0.01);
                    wu.set2(row, j, rng.normal() * 0.01);
                }
            }
        }
        let h = Tensor::randn(&[16, d], 1.0, &mut rng);
        let c = channel_contribution(&h, &wg, &wu, &wd);
        let best = c
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 2);
        let (wg2, wu2, wd2) = prune_ffn_channels(&wg, &wu, &wd, &c, 1);
        assert_eq!(wg2.shape, vec![d, 1]);
        assert_eq!(wu2.shape, vec![d, 1]);
        assert_eq!(wd2.shape, vec![1, d]);
        assert!((wd2.data[0] - 2.0).abs() < 1e-6); // kept channel 2's row
    }

    #[test]
    fn prune_keeps_original_channel_order() {
        let wg = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let wu = wg.clone();
        let wd = Tensor::from_vec(&[4, 1], vec![1.0, 1.0, 1.0, 1.0]);
        // scores favor channels 3 and 1 (descending)
        let scores = vec![0.1, 5.0, 0.2, 9.0];
        let (wg2, _, _) = prune_ffn_channels(&wg, &wu, &wd, &scores, 2);
        assert_eq!(wg2.data, vec![2.0, 4.0]); // order 1, 3 — not 3, 1
    }

    #[test]
    fn linear_inits_have_right_shapes() {
        let mut rng = Rng::new(4);
        let wv = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let wo = Tensor::randn(&[8, 6], 1.0, &mut rng);
        assert_eq!(attn_linear_init(&wv, &wo).shape, vec![6, 6]);
        let wu = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let wd = Tensor::randn(&[12, 6], 1.0, &mut rng);
        assert_eq!(ffn_linear_init(&wu, &wd).shape, vec![6, 6]);
    }
}
