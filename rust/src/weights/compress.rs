//! Comparison compression methods (paper §8.4, Table 17): Wanda 2:4
//! structured sparsity and low-rank factorization. Both operate on the
//! parent weights in place of (not inside) the Puzzle search — the paper's
//! point is that they are subsets of Puzzle's search space.

use crate::tensor::{svd::low_rank_approx, Tensor};

/// Wanda pruning metric: |W_ij| * ||X_i||_2 where i is the input channel.
/// Our weights are stored as [in, out] (x @ W), so the activation norm
/// indexes rows. `x_norms[i]` = L2 norm of input feature i over a
/// calibration batch.
pub fn wanda_metric(w: &Tensor, x_norms: &[f32]) -> Tensor {
    let (m, n) = (w.shape[0], w.shape[1]);
    assert_eq!(x_norms.len(), m);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            out.data[i * n + j] = w.data[i * n + j].abs() * x_norms[i];
        }
    }
    out
}

/// 2:4 structured sparsity along the input dimension: within every group
/// of 4 consecutive input channels (per output column), zero the 2 entries
/// with the smallest Wanda metric. Matches NVIDIA sparse-tensor-core
/// semantics (the pattern the paper's Wanda baseline targets).
pub fn wanda_2_4(w: &Tensor, x_norms: &[f32]) -> Tensor {
    let metric = wanda_metric(w, x_norms);
    let (m, n) = (w.shape[0], w.shape[1]);
    let mut out = w.clone();
    for j in 0..n {
        let mut i = 0;
        while i + 4 <= m {
            // indices of the 2 smallest metrics in the group
            let mut group: Vec<(usize, f32)> =
                (i..i + 4).map(|r| (r, metric.data[r * n + j])).collect();
            group.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            out.data[group[0].0 * n + j] = 0.0;
            out.data[group[1].0 * n + j] = 0.0;
            i += 4;
        }
        // ragged tail (input dim not divisible by 4): prune half, rounded down
        if i < m {
            let tail = m - i;
            let mut group: Vec<(usize, f32)> =
                (i..m).map(|r| (r, metric.data[r * n + j])).collect();
            group.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            for k in 0..tail / 2 {
                out.data[group[k].0 * n + j] = 0.0;
            }
        }
    }
    out
}

/// Fraction of zero entries.
pub fn sparsity(w: &Tensor) -> f64 {
    let zeros = w.data.iter().filter(|&&x| x == 0.0).count();
    zeros as f64 / w.numel() as f64
}

/// Low-rank factorization baseline: replace W by its best rank-k
/// approximation (returned at full shape; the perf model accounts the
/// factored FLOPs analytically).
pub fn low_rank(w: &Tensor, rank: usize) -> Tensor {
    low_rank_approx(w, rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn wanda_2_4_pattern() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[16, 6], 1.0, &mut rng);
        let xn = vec![1.0f32; 16];
        let pruned = wanda_2_4(&w, &xn);
        // each column, each group of 4 rows: exactly 2 zeros
        for j in 0..6 {
            for g in 0..4 {
                let zeros = (0..4)
                    .filter(|&r| pruned.data[(g * 4 + r) * 6 + j] == 0.0)
                    .count();
                assert_eq!(zeros, 2, "col {j} group {g}");
            }
        }
        assert!((sparsity(&pruned) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn wanda_keeps_high_activation_channels() {
        // weights equal; activations make rows 0..2 matter
        let w = Tensor::ones(&[4, 1]);
        let xn = vec![10.0, 9.0, 0.1, 0.2];
        let pruned = wanda_2_4(&w, &xn);
        assert_eq!(pruned.data, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn low_rank_reduces_error_with_rank() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[10, 10], 1.0, &mut rng);
        let e2 = low_rank(&w, 2).sub(&w).frob_norm();
        let e8 = low_rank(&w, 8).sub(&w).frob_norm();
        assert!(e8 < e2);
    }
}
