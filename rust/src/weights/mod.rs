//! Weight storage + the paper's training-free initializations (§3.2) and
//! comparison compression methods (§8.4).

pub mod compress;
pub mod init;
pub mod store;

pub use store::Store;
