//! Named-tensor store with a simple binary on-disk format (`.pzw`).
//!
//! Key convention:
//!
//! ```text
//! embed                     [V, D]
//! final_norm                [D]
//! L{i}.attn@{variant}.{w}   block-library entry for layer i
//! L{i}.ffn@{variant}.{w}
//! ```
//!
//! The parent model is simply the library entries at `gqa_r1` / `r100`.
//! Format: magic "PZW1", u32 count, then per entry:
//! u32 key_len, key bytes, u32 ndim, u64 dims, f32 data
//! (little-endian throughout).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::{Manifest, VariantLayout};
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Debug, Clone, Default)]
/// Named weight tensors (parent + block library + children).
pub struct Store {
    /// Key -> tensor, ordered for stable serialization.
    pub map: BTreeMap<String, Tensor>,
}

/// Canonical key of one block weight: `L{layer}.{kind}@{variant}.{w}`.
pub fn block_key(layer: usize, kind: &str, variant: &str, w: &str) -> String {
    format!("L{layer}.{kind}@{variant}.{w}")
}

impl Store {
    /// An empty store.
    pub fn new() -> Store {
        Store::default()
    }

    /// Insert or replace a tensor.
    pub fn put(&mut self, key: &str, t: Tensor) {
        self.map.insert(key.to_string(), t);
    }

    /// Borrow a tensor; errors with the missing key's name.
    pub fn get(&self, key: &str) -> Result<&Tensor> {
        self.map.get(key).ok_or_else(|| anyhow!("missing weight {key}"))
    }

    /// Whether `key` exists.
    pub fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// Ordered weight list for a block-library entry per its layout.
    pub fn block(&self, layer: usize, kind: &str, variant: &str, layout: &VariantLayout) -> Result<Vec<&Tensor>> {
        layout
            .weights
            .iter()
            .map(|(w, shape)| {
                let t = self.get(&block_key(layer, kind, variant, w))?;
                if &t.shape != shape {
                    return Err(anyhow!(
                        "shape mismatch for {}: store {:?} vs layout {:?}",
                        block_key(layer, kind, variant, w), t.shape, shape
                    ));
                }
                Ok(t)
            })
            .collect()
    }

    /// Insert a whole block's weights in layout order.
    pub fn put_block(&mut self, layer: usize, kind: &str, variant: &str, layout: &VariantLayout, ws: Vec<Tensor>) {
        assert_eq!(ws.len(), layout.weights.len());
        for ((name, _), t) in layout.weights.iter().zip(ws) {
            self.put(&block_key(layer, kind, variant, name), t);
        }
    }

    /// Total parameters across all tensors.
    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    // ---------------- binary serialization ----------------

    /// Serialize to a `.pzw` file (bincode-free custom format).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
        );
        f.write_all(b"PZW1")?;
        f.write_all(&(self.map.len() as u32).to_le_bytes())?;
        for (k, t) in &self.map {
            f.write_all(&(k.len() as u32).to_le_bytes())?;
            f.write_all(k.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            // bulk f32 write
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Load a `.pzw` file written by `save`.
    pub fn load(path: &Path) -> Result<Store> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"PZW1" {
            return Err(anyhow!("bad magic in {}", path.display()));
        }
        let mut u32b = [0u8; 4];
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u32b)?;
        let count = u32::from_le_bytes(u32b);
        let mut map = BTreeMap::new();
        for _ in 0..count {
            f.read_exact(&mut u32b)?;
            let klen = u32::from_le_bytes(u32b) as usize;
            let mut kb = vec![0u8; klen];
            f.read_exact(&mut kb)?;
            let key = String::from_utf8(kb)?;
            f.read_exact(&mut u32b)?;
            let ndim = u32::from_le_bytes(u32b) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut u64b)?;
                shape.push(u64::from_le_bytes(u64b) as usize);
            }
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
            };
            f.read_exact(bytes)?;
            map.insert(key, Tensor { shape, data });
        }
        Ok(Store { map })
    }
}

/// Initialize a parent model: library entries at gqa_r1 / r100 plus
/// embedding and final norm. Gaussian 0.02 projections, residual-scaled
/// output projections, unit norms.
pub fn init_parent(man: &Manifest, rng: &mut Rng) -> Store {
    let cfg = &man.cfg;
    let mut s = Store::new();
    let std = 0.02f32;
    let out_std = std / (2.0 * cfg.n_layers as f32).sqrt();
    s.put("embed", Tensor::randn(&[cfg.v, cfg.d], std, rng));
    s.put("final_norm", Tensor::ones(&[cfg.d]));
    let attn = &man.attn_variants["gqa_r1"];
    let ffn = &man.ffn_variants["r100"];
    for l in 0..cfg.n_layers {
        for (name, shape) in &attn.weights {
            let t = match name.as_str() {
                "norm" => Tensor::ones(shape),
                "wo" => Tensor::randn(shape, out_std, rng),
                _ => Tensor::randn(shape, std, rng),
            };
            s.put(&block_key(l, "attn", "gqa_r1", name), t);
        }
        for (name, shape) in &ffn.weights {
            let t = match name.as_str() {
                "norm" => Tensor::ones(shape),
                "wd" => Tensor::randn(shape, out_std, rng),
                _ => Tensor::randn(shape, std, rng),
            };
            s.put(&block_key(l, "ffn", "r100", name), t);
        }
    }
    s
}

/// Randomize all non-norm weights in place (the Parent-Randomized baseline
/// of Table 15).
pub fn randomize_weights(store: &mut Store, rng: &mut Rng) {
    for (k, t) in store.map.iter_mut() {
        if !k.ends_with("norm") {
            *t = Tensor::randn(&t.shape, 0.02, rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let mut s = Store::new();
        let mut rng = Rng::new(1);
        s.put("a.b", Tensor::randn(&[3, 4], 1.0, &mut rng));
        s.put("c", Tensor::ones(&[7]));
        let path = std::env::temp_dir().join("puzzle_store_test.pzw");
        s.save(&path).unwrap();
        let s2 = Store::load(&path).unwrap();
        assert_eq!(s.map, s2.map);
    }

    #[test]
    fn block_key_format() {
        assert_eq!(block_key(3, "attn", "gqa_r2", "wk"), "L3.attn@gqa_r2.wk");
    }

    #[test]
    fn missing_weight_is_error() {
        let s = Store::new();
        assert!(s.get("nope").is_err());
    }
}
