//! Synthetic benchmark suite — the substitution for the paper's eval grid
//! (MMLU, MT-Bench, GSM8K, HellaSwag, RULER; see DESIGN.md §2).
//!
//! * SynthQA (MMLU proxy): 4-way multiple choice over world facts, scored
//!   by next-token logit ranking — knowledge stored in weights.
//! * GenScore (MT-Bench proxy): greedy generation of the answer to
//!   question-form prompts, scored 0–10.
//! * SynthMath (GSM8K proxy): single-digit addition.
//! * ContScore (HellaSwag proxy): rank the true Markov continuation
//!   against distractors.
//! * RULER proxy (long context): needle retrieval, 1-hop variable
//!   tracking, frequent-token extraction at context lengths beyond the
//!   training horizon.
//!
//! The paper's combined metric Accuracy = (MT-Bench x 10 + MMLU) / 2 maps
//! to (GenScore x 10 + SynthQA) / 2.

pub mod tasks;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::arch::Arch;
use crate::model::CompiledModel;
use crate::runtime::{val_i32, val_to_tensor, Backend, Value};
use crate::tensor::Tensor;
use crate::weights::Store;

pub use tasks::{LongTask, McQuestion};

/// Runs the synthetic benchmark suite over one assembled model.
pub struct Evaluator<'a> {
    /// Backend executing the model's block chain.
    pub be: &'a dyn Backend,
    /// The assembled (Arch, Store) model under evaluation.
    pub model: CompiledModel,
}

#[derive(Debug, Clone, Default)]
/// Benchmark name -> score for one evaluated model.
pub struct EvalReport {
    /// Per-benchmark scores (e.g. "synthqa", "genscore").
    pub scores: BTreeMap<String, f64>,
}

impl EvalReport {
    /// (GenScore x 10 + SynthQA) / 2, mirroring the paper's accuracy axis.
    pub fn accuracy(&self) -> f64 {
        let gen = self.scores.get("genscore").copied().unwrap_or(0.0);
        let qa = self.scores.get("synthqa").copied().unwrap_or(0.0);
        (gen * 10.0 + qa) / 2.0
    }

    /// One benchmark's score (0.0 when absent).
    pub fn get(&self, k: &str) -> f64 {
        self.scores.get(k).copied().unwrap_or(0.0)
    }

    /// One-line report row of every score plus the accuracy axis.
    pub fn row(&self) -> String {
        let mut parts: Vec<String> =
            self.scores.iter().map(|(k, v)| format!("{k} {v:.2}")).collect();
        parts.push(format!("accuracy {:.2}", self.accuracy()));
        parts.join(" | ")
    }
}

impl<'a> Evaluator<'a> {
    /// Assemble `arch` over `store` for evaluation on `be`.
    pub fn new(be: &'a dyn Backend, store: &Store, arch: &Arch) -> Result<Evaluator<'a>> {
        Ok(Evaluator { be, model: CompiledModel::assemble(be.man(), store, arch)? })
    }

    /// Train-shaped forward over packed question rows -> logits tensor.
    fn logits(&self, tokens: &[i32], b: usize, s: usize) -> Result<Tensor> {
        let trace = self.model.forward(self.be, "train", tokens, b, s)?;
        Ok(trace.logits)
    }

    /// Long-context forward (1, s_long).
    fn logits_long(&self, tokens: &[i32]) -> Result<Tensor> {
        let cfg = &self.be.man().cfg;
        let tok = val_i32(&[1, cfg.s_long], tokens)?;
        let mut x = self.be.run("embed_long", &[&tok, &self.model.embed])?.remove(0);
        for l in 0..self.model.attn.len() {
            for blk in [&self.model.attn[l], &self.model.ffn[l]] {
                if let Some(prefix) = &blk.prefix {
                    let mut inputs: Vec<&Value> = vec![&x];
                    inputs.extend(blk.vals.iter());
                    x = self.be.run(&format!("{prefix}_long"), &inputs)?.remove(0);
                }
            }
        }
        let logits = self
            .be
            .run("head_long", &[&x, &self.model.final_norm, &self.model.embed])?
            .remove(0);
        val_to_tensor(&logits)
    }

    /// Score a set of multiple-choice questions by next-token logit
    /// ranking, packing `b_train` questions per forward. Returns accuracy
    /// in percent.
    pub fn mc_accuracy(&self, questions: &[McQuestion]) -> Result<f64> {
        let cfg = &self.be.man().cfg;
        let (b, s, v) = (cfg.b_train, cfg.s_train, cfg.v);
        let mut correct = 0usize;
        for chunk in questions.chunks(b) {
            let mut tokens = vec![0i32; b * s];
            for (row, q) in chunk.iter().enumerate() {
                for (i, &t) in q.prompt.iter().take(s).enumerate() {
                    tokens[row * s + i] = t as i32;
                }
            }
            let logits = self.logits(&tokens, b, s)?;
            for (row, q) in chunk.iter().enumerate() {
                let pos = (q.answer_pos).min(s - 1);
                let base = (row * s + pos) * v;
                let lg = &logits.data[base..base + v];
                let pick = q
                    .candidates
                    .iter()
                    .enumerate()
                    .max_by(|a, b| {
                        lg[*a.1 as usize].partial_cmp(&lg[*b.1 as usize]).unwrap()
                    })
                    .unwrap()
                    .0;
                if pick == q.correct {
                    correct += 1;
                }
            }
        }
        Ok(100.0 * correct as f64 / questions.len().max(1) as f64)
    }

    /// Greedy full-vocab generation accuracy (GenScore / SynthMath): the
    /// argmax token at answer_pos must equal the gold candidate.
    pub fn greedy_accuracy(&self, questions: &[McQuestion]) -> Result<f64> {
        let cfg = &self.be.man().cfg;
        let (b, s, v) = (cfg.b_train, cfg.s_train, cfg.v);
        let mut correct = 0usize;
        for chunk in questions.chunks(b) {
            let mut tokens = vec![0i32; b * s];
            for (row, q) in chunk.iter().enumerate() {
                for (i, &t) in q.prompt.iter().take(s).enumerate() {
                    tokens[row * s + i] = t as i32;
                }
            }
            let logits = self.logits(&tokens, b, s)?;
            for (row, q) in chunk.iter().enumerate() {
                let pos = (q.answer_pos).min(s - 1);
                let base = (row * s + pos) * v;
                let lg = &logits.data[base..base + v];
                let mut best = 0usize;
                for (i, &x) in lg.iter().enumerate() {
                    if x > lg[best] {
                        best = i;
                    }
                }
                if best as u32 == q.candidates[q.correct] {
                    correct += 1;
                }
            }
        }
        Ok(100.0 * correct as f64 / questions.len().max(1) as f64)
    }

    /// Long-context MC accuracy: one question per forward at s_long.
    pub fn long_mc_accuracy(&self, questions: &[McQuestion]) -> Result<f64> {
        let cfg = &self.be.man().cfg;
        let (sl, v) = (cfg.s_long, cfg.v);
        let mut correct = 0usize;
        for q in questions {
            let mut tokens = vec![0i32; sl];
            for (i, &t) in q.prompt.iter().take(sl).enumerate() {
                tokens[i] = t as i32;
            }
            let logits = self.logits_long(&tokens)?;
            let base = q.answer_pos.min(sl - 1) * v;
            let lg = &logits.data[base..base + v];
            let pick = q
                .candidates
                .iter()
                .enumerate()
                .max_by(|a, b| lg[*a.1 as usize].partial_cmp(&lg[*b.1 as usize]).unwrap())
                .unwrap()
                .0;
            if pick == q.correct {
                correct += 1;
            }
        }
        Ok(100.0 * correct as f64 / questions.len().max(1) as f64)
    }

    /// Run the standard benchmark suite (Table 2's rows, scaled).
    pub fn run_suite(&self, world: &crate::data::World, n_per_task: usize, seed: u64) -> Result<EvalReport> {
        let mut rng = crate::util::Rng::new(seed);
        let mut report = EvalReport::default();
        let qa = tasks::synth_qa(world, n_per_task, &mut rng, None);
        report.scores.insert("synthqa".into(), self.mc_accuracy(&qa)?);
        let gs = tasks::gen_questions(world, n_per_task, &mut rng);
        report
            .scores
            .insert("genscore".into(), self.greedy_accuracy(&gs)? / 10.0);
        let math = tasks::math_questions(world, n_per_task, &mut rng);
        report.scores.insert("synthmath".into(), self.greedy_accuracy(&math)?);
        let cont = tasks::cont_questions(world, n_per_task, &mut rng);
        report.scores.insert("contscore".into(), self.mc_accuracy(&cont)?);
        Ok(report)
    }

    /// RULER-proxy sweep over context lengths (Table 4 / 18 / 19 analog).
    pub fn run_ruler(
        &self,
        world: &crate::data::World,
        ctxs: &[usize],
        n_per_task: usize,
        seed: u64,
    ) -> Result<Vec<(usize, f64)>> {
        let mut out = Vec::new();
        for &ctx in ctxs {
            let mut rng = crate::util::Rng::new(seed ^ ctx as u64);
            let mut accs = Vec::new();
            for task in [LongTask::Needle, LongTask::VarTrack, LongTask::FreqWords] {
                let qs = tasks::long_questions(world, task, ctx, n_per_task, &mut rng);
                accs.push(self.long_mc_accuracy(&qs)?);
            }
            out.push((ctx, accs.iter().sum::<f64>() / accs.len() as f64));
        }
        Ok(out)
    }
}
