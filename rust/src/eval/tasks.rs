//! Benchmark question generators over a FactWorld.

use crate::data::world::{World, BOS, EQ, FRQ, PLUS, QRY, SEP};
use crate::util::Rng;

/// A multiple-choice question: read logits at `answer_pos` (the position
/// whose *next* token is the answer) and rank `candidates`.
#[derive(Debug, Clone)]
pub struct McQuestion {
    /// Question tokens.
    pub prompt: Vec<u32>,
    /// index into prompt whose next-token distribution is scored
    pub answer_pos: usize,
    /// Candidate answer tokens, one correct.
    pub candidates: Vec<u32>,
    /// Index of the correct candidate.
    pub correct: usize,
}

fn distractors(world: &World, truth: u32, n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let v = world.vocab.value(rng.below(world.vocab.n_values as usize) as u32);
        if v != truth && !out.contains(&v) {
            out.push(v);
        }
    }
    out
}

fn shuffle_in(truth: u32, mut ds: Vec<u32>, rng: &mut Rng) -> (Vec<u32>, usize) {
    let idx = rng.below(ds.len() + 1);
    ds.insert(idx, truth);
    (ds, idx)
}

/// SynthQA (MMLU proxy): [BOS, e, r, SEP] -> value. `relation_filter`
/// restricts to a relation subset (Half-MMLU splits, §8.1.4 / Table 11).
pub fn synth_qa(
    world: &World,
    n: usize,
    rng: &mut Rng,
    relation_filter: Option<&dyn Fn(u32) -> bool>,
) -> Vec<McQuestion> {
    let v = &world.vocab;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let e = rng.below(v.n_entities as usize) as u32;
        let r = rng.below(v.n_relations as usize) as u32;
        if let Some(f) = relation_filter {
            if !f(r) {
                continue;
            }
        }
        let truth = world.fact_value(e, r);
        let (candidates, correct) = shuffle_in(truth, distractors(world, truth, 3, rng), rng);
        out.push(McQuestion {
            prompt: vec![BOS, v.entity(e), v.relation(r), SEP],
            answer_pos: 3,
            candidates,
            correct,
        });
    }
    out
}

/// GenScore (MT-Bench proxy): question-form prompts, answered by greedy
/// full-vocab generation. `candidates[correct]` = gold token.
pub fn gen_questions(world: &World, n: usize, rng: &mut Rng) -> Vec<McQuestion> {
    let v = &world.vocab;
    (0..n)
        .map(|_| {
            let e = rng.below(v.n_entities as usize) as u32;
            let r = rng.below(v.n_relations as usize) as u32;
            McQuestion {
                prompt: vec![BOS, QRY, v.entity(e), v.relation(r), SEP],
                answer_pos: 4,
                candidates: vec![world.fact_value(e, r)],
                correct: 0,
            }
        })
        .collect()
}

/// SynthMath (GSM8K proxy): a + b = c with c < 10 (single-token answer).
pub fn math_questions(world: &World, n: usize, rng: &mut Rng) -> Vec<McQuestion> {
    let v = &world.vocab;
    (0..n)
        .map(|_| {
            let a = rng.below(10) as u32;
            let b = rng.below(10 - a as usize) as u32;
            McQuestion {
                prompt: vec![BOS, v.digit(a), PLUS, v.digit(b), EQ],
                answer_pos: 4,
                candidates: vec![v.digit(a + b)],
                correct: 0,
            }
        })
        .collect()
}

/// ContScore (HellaSwag proxy): rank the Markov-mode continuation of a
/// narrative prefix against random fillers.
pub fn cont_questions(world: &World, n: usize, rng: &mut Rng) -> Vec<McQuestion> {
    let v = &world.vocab;
    (0..n)
        .map(|_| {
            let mut prompt = vec![BOS];
            let mut cur = v.filler(rng.below(v.n_filler() as usize) as u32);
            prompt.push(cur);
            for _ in 0..10 {
                cur = world.narrative_mode_successor(cur);
                prompt.push(cur);
            }
            let truth = world.narrative_mode_successor(cur);
            let mut ds = Vec::new();
            while ds.len() < 3 {
                let f = v.filler(rng.below(v.n_filler() as usize) as u32);
                if f != truth && !ds.contains(&f) {
                    ds.push(f);
                }
            }
            let (candidates, correct) = shuffle_in(truth, ds, rng);
            let answer_pos = prompt.len() - 1;
            McQuestion { prompt, answer_pos, candidates, correct }
        })
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Long-context task families (the RULER-proxy suite, Table 4).
pub enum LongTask {
    /// a fact sentence hidden in filler; query it at the end
    Needle,
    /// e1 -> e2 alias plus e2's fact; query e1 (1 hop)
    VarTrack,
    /// one value token repeated among filler; query the most frequent
    FreqWords,
}

/// Build long-context questions of exactly `ctx` tokens (query included),
/// padded later by the evaluator.
pub fn long_questions(
    world: &World,
    task: LongTask,
    ctx: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<McQuestion> {
    let v = &world.vocab;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let e = rng.below(v.n_entities as usize) as u32;
        let r = rng.below(v.n_relations as usize) as u32;
        let truth = world.fact_value(e, r);
        // filler body
        let mut body: Vec<u32> = Vec::with_capacity(ctx);
        let mut cur = v.filler(rng.below(v.n_filler() as usize) as u32);
        for _ in 0..ctx {
            cur = world.narrative_successor(cur, rng, 3);
            body.push(cur);
        }
        let (needle, query, truth, extra): (Vec<u32>, Vec<u32>, u32, Option<Vec<u32>>) = match task {
            LongTask::Needle => (
                vec![v.entity(e), v.relation(r), SEP, truth],
                vec![QRY, v.entity(e), v.relation(r), SEP],
                truth,
                None,
            ),
            LongTask::VarTrack => {
                let e1 = world.alias_of(e, 1) - v.ent0;
                // context: "e1 r0 SEP e" (alias stored as relation 0 linking to e)
                // plus the needle fact for e; query e1.
                (
                    vec![v.entity(e), v.relation(r), SEP, truth],
                    vec![QRY, v.entity(e1), v.relation(r), SEP],
                    truth,
                    Some(vec![v.entity(e1), v.relation(0), SEP, v.entity(e)]),
                )
            }
            LongTask::FreqWords => {
                // repeat `truth` k times through the body
                (vec![], vec![FRQ, SEP], truth, None)
            }
        };
        // insert needle (and alias link) at random interior offsets
        let mut seq = vec![BOS];
        seq.extend_from_slice(&body);
        let tail = query.len() + needle.len() + extra.as_ref().map(|e| e.len()).unwrap_or(0) + 8;
        let limit = ctx.saturating_sub(tail).max(2);
        if !needle.is_empty() {
            let at = 1 + rng.below(limit);
            for (i, &t) in needle.iter().enumerate() {
                seq[at + i] = t;
            }
        }
        if let Some(extra) = extra {
            let at = 1 + rng.below(limit);
            for (i, &t) in extra.iter().enumerate() {
                seq[at + i] = t;
            }
        }
        if task == LongTask::FreqWords {
            // sprinkle the target token so it is the clear mode
            let k = (ctx / 16).max(4);
            for _ in 0..k {
                let at = 1 + rng.below(limit);
                seq[at] = truth;
            }
        }
        // append query, trim to ctx
        seq.truncate(ctx.saturating_sub(query.len()));
        seq.extend_from_slice(&query);
        let answer_pos = seq.len() - 1;
        let (candidates, correct) = shuffle_in(truth, distractors(world, truth, 3, rng), rng);
        out.push(McQuestion { prompt: seq, answer_pos, candidates, correct });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(5, 256)
    }

    #[test]
    fn synth_qa_has_valid_candidates() {
        let w = world();
        let mut rng = Rng::new(1);
        for q in synth_qa(&w, 20, &mut rng, None) {
            assert_eq!(q.candidates.len(), 4);
            assert!(q.correct < 4);
            let truth = q.candidates[q.correct];
            assert!(w.vocab.is_value(truth));
            // truth matches the world's fact table
            let e = q.prompt[1] - w.vocab.ent0;
            let r = q.prompt[2] - w.vocab.rel0;
            assert_eq!(w.fact_value(e, r), truth);
            // distractors unique
            let mut c = q.candidates.clone();
            c.sort();
            c.dedup();
            assert_eq!(c.len(), 4);
        }
    }

    #[test]
    fn relation_filter_splits() {
        let w = world();
        let mut rng = Rng::new(2);
        let even = synth_qa(&w, 10, &mut rng, Some(&|r| r % 2 == 0));
        for q in even {
            let r = q.prompt[2] - w.vocab.rel0;
            assert_eq!(r % 2, 0);
        }
    }

    #[test]
    fn long_questions_have_exact_ctx() {
        let w = world();
        let mut rng = Rng::new(3);
        for task in [LongTask::Needle, LongTask::VarTrack, LongTask::FreqWords] {
            for q in long_questions(&w, task, 128, 5, &mut rng) {
                assert_eq!(q.prompt.len(), 128, "{task:?}");
                assert_eq!(q.answer_pos, 127);
            }
        }
    }

    #[test]
    fn needle_is_present_in_context() {
        let w = world();
        let mut rng = Rng::new(4);
        for q in long_questions(&w, LongTask::Needle, 64, 10, &mut rng) {
            let truth = q.candidates[q.correct];
            assert!(
                q.prompt[..60].contains(&truth),
                "needle value must appear in the context"
            );
        }
    }

    #[test]
    fn freqwords_target_is_mode() {
        let w = world();
        let mut rng = Rng::new(5);
        for q in long_questions(&w, LongTask::FreqWords, 128, 5, &mut rng) {
            let truth = q.candidates[q.correct];
            let count = q.prompt.iter().filter(|&&t| t == truth).count();
            assert!(count >= 4, "target should repeat, got {count}");
        }
    }

    #[test]
    fn math_questions_single_token_answers() {
        let w = world();
        let mut rng = Rng::new(6);
        for q in math_questions(&w, 30, &mut rng) {
            let a = q.prompt[1] - w.vocab.dig0;
            let b = q.prompt[3] - w.vocab.dig0;
            assert!(a + b < 10);
            assert_eq!(q.candidates[0], w.vocab.digit(a + b));
        }
    }
}
