//! Data-parallel multi-engine router: N `AsyncServer` replicas behind
//! one cloneable handle, with cache-aware placement and prefix
//! migration (DESIGN.md §12).
//!
//! A [`Router`] owns N worker threads (one [`super::AsyncServer`] each)
//! and hands out [`RouterHandle`] clones with the same
//! `submit -> TokenStream` / `cancel` surface as a single-engine
//! [`super::ServerHandle`] — client code cannot tell one replica from
//! eight. Per submit the handle probes every replica over its control
//! channel (`Ctl::Probe`: longest retained prefix match + load counters,
//! snapshotted between engine steps), places the request with
//! [`super::placement::choose`], and — when the best-matching replica is
//! overloaded — first *migrates* the retained segment to the chosen
//! replica (`Ctl::ExportPrefix` → `Ctl::ImportPrefix`, cloned host rows,
//! refcount-correct on both ends), so hot system prompts follow load.
//!
//! Placement never steers sampling: every request's RNG stream is seeded
//! per-request, and a prefix hit is byte-identical to a cold prefill by
//! the cache's core invariant — so routed outputs equal a single-engine
//! run token-for-token, which `tests/router_integration.rs` and the
//! `bench-router` CI gate both assert.
//!
//! Request ids are globally unique across replicas: replica `i`'s engine
//! starts its id counter at `i << 48` (`Engine::set_request_id_base`), so
//! `RouterHandle::cancel` recovers the owning replica from the id alone.
//!
//! Two fleet-scope layers ride on top (DESIGN.md §13). **Digest-cached
//! probing**: each worker publishes its load and prefix-cache digest
//! lock-free ([`super::ReplicaLoad`]); with [`RouterConfig::probe_cache`]
//! on, a replica that is alive, not full, under the overload threshold,
//! and whose digest matches the memoized probe answer is served from the
//! memo with zero channel round-trips — placement-equivalent to
//! always-probe (the digest moves on every retained-set mutation) but
//! without N round-trips per submit. **Router tracing**: an optional
//! [`Tracer`] records `probe_round` / `routed` / migration spans /
//! `router_shed` onto the router's own ring; built over the same shared
//! clock as the replica tracers, the rings merge into one fleet timeline
//! ([`crate::obs::merge_fleet`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::obs::{Event, FleetLog, MetricsRegistry, Tracer};
use crate::serving::{Engine, EngineMetrics, GenRequest};
use crate::workload::report::load_skew;

use super::handle::Frontend;
use super::placement::{choose, ReplicaProbe};
use super::{AsyncServer, ReplicaLoad, ServerHandle, ServerStats, TokenStream};

/// Bits reserved for the per-replica request-id base: replica `i` issues
/// ids in `[i << REPLICA_SHIFT, (i + 1) << REPLICA_SHIFT)`.
pub const REPLICA_SHIFT: u32 = 48;

/// Router tuning knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// In-flight depth (active + queued) at which a replica's prefix
    /// match no longer pins placement: the request goes to the best
    /// non-overloaded replica instead, and the segment migrates along.
    pub overload: usize,
    /// Minimum match length (tokens) worth migrating; shorter matches
    /// just re-prefill at the destination.
    pub min_migrate: usize,
    /// Serve placement probes from the per-replica digest memo when the
    /// retained set is provably unchanged, paying the control-channel
    /// round-trip only on digest staleness, overload, or a full/dead
    /// replica. Placement-equivalent to always-probe; off recovers the
    /// PR 9 probe-everything behavior (and the equivalence test's
    /// baseline).
    pub probe_cache: bool,
    /// The router's placement-side tracer (disabled by default). For a
    /// mergeable fleet timeline, build it and every replica engine's
    /// tracer over ONE shared clock (`Tracer::with_clock`).
    pub tracer: Tracer,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            overload: 4,
            min_migrate: 1,
            probe_cache: true,
            tracer: Tracer::disabled(),
        }
    }
}

/// One memoized probe answer: the digest it was taken under and the
/// match length it reported. Keyed by `(replica, prompt fnv, prompt
/// len)`; valid while the replica's published digest equals `gen`.
type ProbeMemo = HashMap<(usize, u64, usize), (u64, usize)>;

/// The memo is cleared rather than evicted when it grows past this —
/// probe caching is an optimization, forgetting is always safe.
const PROBE_MEMO_CAP: usize = 1 << 14;

/// FNV-1a 64 over a token slice (the probe memo's prompt key).
fn fnv_tokens(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Router-level counters shared by every handle clone (atomics: handles
/// bump them from many client threads).
#[derive(Debug, Default)]
struct RouterShared {
    /// Requests accepted per replica, indexed by replica id.
    routed: Vec<AtomicU64>,
    /// Cross-replica prefix migrations performed.
    migrations: AtomicU64,
    /// Tokens of retained prefix moved by those migrations.
    migrated_tokens: AtomicU64,
    /// Requests shed at the router's door (every replica full).
    shed: AtomicU64,
    /// Placement probe rounds performed (one per submit attempt).
    probe_rounds: AtomicU64,
    /// Per-replica control-channel probes paid (memo miss, stale digest,
    /// overload, or full/dead replica — and every probe with the cache
    /// off).
    digest_refreshes: AtomicU64,
    /// Per-replica probes served from the digest memo with no
    /// round-trip.
    digest_hits: AtomicU64,
    /// Migration ordinal source (pairs `migration_begin`/`_end` spans).
    mig_seq: AtomicU64,
    /// Each worker's lock-free load/digest publication, by replica id.
    loads: Vec<Arc<ReplicaLoad>>,
    /// Memoized probe answers (see [`ProbeMemo`]).
    memo: Mutex<ProbeMemo>,
}

/// Point-in-time router counters plus each replica's [`ServerStats`]
/// (`RouterHandle::stats`).
#[derive(Debug, Clone)]
pub struct RouterStats {
    /// Per-replica occupancy, indexed by replica id.
    pub replicas: Vec<ServerStats>,
    /// Requests accepted per replica, indexed by replica id.
    pub routed: Vec<u64>,
    /// Cross-replica prefix migrations performed.
    pub migrations: u64,
    /// Tokens of retained prefix moved by those migrations.
    pub migrated_tokens: u64,
    /// Requests shed at the router's door (every replica full).
    pub shed: u64,
    /// Placement probe rounds performed (one per submit attempt).
    pub probe_rounds: u64,
    /// Per-replica control-channel probes paid across all rounds.
    pub digest_refreshes: u64,
    /// Per-replica probes served from the digest memo (no round-trip).
    pub digest_hits: u64,
}

impl RouterStats {
    /// Requests accepted across all replicas.
    pub fn total_routed(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Routing imbalance: max − min of the per-replica routed counts (0
    /// for a perfectly balanced fleet — the `bench-router` skew gauge).
    pub fn load_skew(&self) -> u64 {
        load_skew(&self.routed)
    }
}

/// N engine replicas behind one routing front door. Spawn with
/// [`Router::spawn`], hand out [`RouterHandle`]s via
/// [`Router::handle`], and call [`Router::shutdown`] to get the engines
/// (and their metrics) back.
pub struct Router {
    replicas: Vec<AsyncServer>,
    shared: Arc<RouterShared>,
    cfg: RouterConfig,
}

impl Router {
    /// Move each engine onto its own worker thread and start routing.
    /// Each engine's request-id counter is rebased to `i << 48` first so
    /// ids are globally unique (see the module docs).
    ///
    /// # Panics
    /// With an empty engine list — a router needs at least one replica.
    pub fn spawn(engines: Vec<Engine>, cfg: RouterConfig) -> Router {
        assert!(!engines.is_empty(), "Router::spawn needs at least one engine");
        let replicas: Vec<AsyncServer> = engines
            .into_iter()
            .enumerate()
            .map(|(i, mut eng)| {
                eng.set_request_id_base((i as u64) << REPLICA_SHIFT);
                AsyncServer::spawn(eng)
            })
            .collect();
        let shared = Arc::new(RouterShared {
            routed: (0..replicas.len()).map(|_| AtomicU64::new(0)).collect(),
            loads: replicas.iter().map(|r| r.load()).collect(),
            ..Default::default()
        });
        Router { replicas, shared, cfg }
    }

    /// Number of replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// A new routing handle (cheap to clone, safe to move across
    /// threads; all clones share the router counters).
    pub fn handle(&self) -> RouterHandle {
        RouterHandle {
            replicas: self.replicas.iter().map(|r| r.handle()).collect(),
            shared: self.shared.clone(),
            cfg: self.cfg.clone(),
        }
    }

    /// Stop every worker and return the engines in replica order (with
    /// their accumulated metrics). In-flight requests are torn down.
    pub fn shutdown(self) -> Vec<Engine> {
        self.replicas.into_iter().map(|r| r.shutdown()).collect()
    }
}

/// A client's connection to the router — same surface as
/// [`ServerHandle`], with placement in between. Clone one per client
/// thread.
#[derive(Clone)]
pub struct RouterHandle {
    replicas: Vec<ServerHandle>,
    shared: Arc<RouterShared>,
    cfg: RouterConfig,
}

impl RouterHandle {
    /// Probe every replica for this prompt, returning the probes plus
    /// how many were paid over the control channel vs served from the
    /// digest memo. A dead replica reports as full so placement routes
    /// around it. With [`RouterConfig::probe_cache`] on, a replica whose
    /// published digest matches the memoized answer — and which is
    /// alive, not full, and under the overload threshold — is answered
    /// from the memo + its published load counters; everything else pays
    /// the round-trip and refreshes the memo.
    fn probe_all(&self, prompt: &[u32]) -> (Vec<ReplicaProbe>, usize, usize) {
        let key_hash = fnv_tokens(prompt);
        let mut probes = Vec::with_capacity(self.replicas.len());
        let (mut probed, mut cached) = (0usize, 0usize);
        for (r, h) in self.replicas.iter().enumerate() {
            let key = (r, key_hash, prompt.len());
            if self.cfg.probe_cache {
                if let Some(load) = self.shared.loads.get(r) {
                    let depth = load.active() + load.queued();
                    if load.alive() && !load.full() && depth < self.cfg.overload {
                        let memo = self.shared.memo.lock().unwrap();
                        if let Some(&(gen, match_len)) = memo.get(&key) {
                            if gen == load.digest() {
                                cached += 1;
                                probes.push(ReplicaProbe {
                                    match_len,
                                    active: load.active(),
                                    queued: load.queued(),
                                    full: false,
                                });
                                continue;
                            }
                        }
                    }
                }
            }
            probed += 1;
            match h.probe_with_digest(prompt) {
                Ok((p, gen)) => {
                    if self.cfg.probe_cache {
                        let mut memo = self.shared.memo.lock().unwrap();
                        if memo.len() >= PROBE_MEMO_CAP {
                            memo.clear();
                        }
                        memo.insert(key, (gen, p.match_len));
                    }
                    probes.push(p);
                }
                Err(_) => {
                    probes.push(ReplicaProbe { match_len: 0, active: 0, queued: 0, full: true });
                }
            }
        }
        self.shared.probe_rounds.fetch_add(1, Ordering::Relaxed);
        self.shared.digest_refreshes.fetch_add(probed as u64, Ordering::Relaxed);
        self.shared.digest_hits.fetch_add(cached as u64, Ordering::Relaxed);
        (probes, probed, cached)
    }

    /// Route a request: probe, place, migrate if the placement asks for
    /// it, then submit — falling back through the remaining candidates
    /// if a submit races to full. `Err` only when every replica refuses
    /// (router-level shed) or the fleet is shut down.
    ///
    /// With tracing on, the router ring gets one `probe_round` per call,
    /// a `routed` record stamped at the submit's *entry* time (so its gap
    /// to the replica's own `submitted` is the placement + channel-hop
    /// cost — the merged timeline's `placement` span), and a
    /// `router_shed` when every replica refuses. Tracing observes, never
    /// steers: the records are written after the decisions they describe.
    pub fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        let t0 = self.cfg.tracer.now_us();
        let (probes, probed, cached) = self.probe_all(&req.prompt);
        self.cfg.tracer.record_at(t0, Event::ProbeRound { probed, cached });
        let Some(placement) = choose(&probes, self.cfg.overload) else {
            self.shared.shed.fetch_add(1, Ordering::Relaxed);
            self.cfg.tracer.record(Event::RouterShed { replicas: self.replicas.len() });
            return Err(anyhow!(
                "router: all {} replicas are full, request shed",
                self.replicas.len()
            ));
        };
        let target = placement.target();
        if let Some(src) = placement.migrate_from {
            if probes[src].match_len >= self.cfg.min_migrate {
                self.migrate(src, target, &req.prompt);
            }
        }
        let mut last_err = anyhow!("router has no replicas");
        for &r in &placement.order {
            match self.replicas[r].submit(req.clone()) {
                Ok(stream) => {
                    self.shared.routed[r].fetch_add(1, Ordering::Relaxed);
                    // the id exists only now, but the span starts at the
                    // submit's entry: record_at back-stamps it so the
                    // placement gap is visible on the merged timeline
                    self.cfg.tracer.record_at(
                        t0,
                        Event::Routed {
                            id: stream.id(),
                            replica: r,
                            matched: probes[r].match_len,
                            depth: probes[r].depth(),
                            reason: placement.reason(&probes, r),
                            probes: probes.iter().map(|p| (p.match_len, p.depth())).collect(),
                        },
                    );
                    return Ok(stream);
                }
                // raced to full (or this replica just shut down): try the
                // next-best candidate before giving up
                Err(e) => last_err = e,
            }
        }
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
        self.cfg.tracer.record(Event::RouterShed { replicas: self.replicas.len() });
        Err(last_err)
    }

    /// Move the retained prefix matching `prompt` from replica `src` to
    /// replica `dst`, best-effort: the source clones the rows out
    /// (keeping its own copy and refcounts untouched), the destination
    /// re-retains them under its own budgets and segment ids. Counted
    /// only when the destination actually adopts. With tracing on, the
    /// attempt is a `migration_begin`/`migration_end` span pair (shared
    /// ordinal), the end carrying the source segment id, token count,
    /// and whether adoption happened — adopted ends match
    /// `RouterStats::migrations` exactly.
    fn migrate(&self, src: usize, dst: usize, prompt: &[u32]) {
        let mig = self.shared.mig_seq.fetch_add(1, Ordering::Relaxed) + 1;
        self.cfg.tracer.record(Event::MigrationBegin { mig, src, dst });
        let (seg, tokens, adopted) = match self.replicas[src].export_prefix(prompt) {
            Ok(Some(prefix)) => {
                let (seg, tokens) = (prefix.src_seg, prefix.seg.len);
                let adopted = self.replicas[dst].import_prefix(prefix).unwrap_or(false);
                (seg, tokens, adopted)
            }
            // cache off, no match, or the source died: nothing moved
            _ => (0, 0, false),
        };
        if adopted {
            self.shared.migrations.fetch_add(1, Ordering::Relaxed);
            self.shared.migrated_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        }
        self.cfg.tracer.record(Event::MigrationEnd { mig, src, dst, seg, tokens, adopted });
    }

    /// Cancel a request by id, routed to the owning replica via the id's
    /// replica bits (fire-and-forget; unknown ids are ignored).
    pub fn cancel(&self, id: u64) {
        if let Some(h) = self.replicas.get((id >> REPLICA_SHIFT) as usize) {
            h.cancel(id);
        }
    }

    /// Number of replicas behind this handle.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Router counters plus every replica's occupancy snapshot.
    pub fn stats(&self) -> Result<RouterStats> {
        let replicas =
            self.replicas.iter().map(|h| h.stats()).collect::<Result<Vec<_>>>()?;
        Ok(RouterStats {
            replicas,
            routed: self.shared.routed.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            migrations: self.shared.migrations.load(Ordering::Relaxed),
            migrated_tokens: self.shared.migrated_tokens.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
            probe_rounds: self.shared.probe_rounds.load(Ordering::Relaxed),
            digest_refreshes: self.shared.digest_refreshes.load(Ordering::Relaxed),
            digest_hits: self.shared.digest_hits.load(Ordering::Relaxed),
        })
    }

    /// The router's placement-side tracer (disabled unless
    /// [`RouterConfig::tracer`] was built enabled).
    pub fn tracer(&self) -> &Tracer {
        &self.cfg.tracer
    }

    /// Snapshot the whole fleet's trace rings: the router's own ring plus
    /// every replica's (fetched over the control channels, each consistent
    /// between engine steps). Feed the result to
    /// [`crate::obs::merge_fleet`] / [`crate::obs::fleet_jsonl`] for one
    /// merged timeline — meaningful when every tracer shares one clock.
    pub fn trace_fleet(&self) -> Result<FleetLog> {
        Ok(FleetLog {
            router: self.cfg.tracer.snapshot(),
            replicas: self
                .replicas
                .iter()
                .map(|h| h.trace_snapshot())
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Every replica's metrics snapshot, in replica order.
    pub fn metrics(&self) -> Result<Vec<EngineMetrics>> {
        self.replicas.iter().map(|h| h.metrics()).collect()
    }

    /// Fleet-wide counter rollup: every replica's counters folded into
    /// one snapshot via [`EngineMetrics::absorb`] (latency series stay
    /// per-replica — reservoirs do not compose).
    pub fn aggregate_metrics(&self) -> Result<EngineMetrics> {
        let mut agg = EngineMetrics::default();
        for m in self.metrics()? {
            agg.absorb(&m);
        }
        Ok(agg)
    }

    /// The router's scrape payload: fleet-level counters and gauges
    /// (routed/migrated/shed totals, aggregate prefix hit rate, load
    /// skew), then a namespaced `puzzle_router_replica_<i>_*` section
    /// per replica — all merged into one Prometheus text exposition.
    /// For a single replica's full engine registry (histograms
    /// included), scrape that replica's own `metrics_text` instead.
    pub fn metrics_text(&self) -> Result<String> {
        let stats = self.stats()?;
        let metrics = self.metrics()?;
        let agg = {
            let mut agg = EngineMetrics::default();
            for m in &metrics {
                agg.absorb(m);
            }
            agg
        };
        let mut reg = MetricsRegistry::new();
        reg.gauge("puzzle_router_replicas", "Engine replicas behind the router.", self.replicas.len() as f64);
        reg.counter("puzzle_router_routed_total", "Requests accepted across all replicas.", stats.total_routed() as f64);
        reg.counter("puzzle_router_migrations_total", "Cross-replica prefix migrations performed.", stats.migrations as f64);
        reg.counter("puzzle_router_migrated_tokens_total", "Tokens of retained prefix moved by migrations.", stats.migrated_tokens as f64);
        reg.counter("puzzle_router_shed_total", "Requests shed with every replica full.", stats.shed as f64);
        reg.gauge("puzzle_router_prefix_hit_rate", "Aggregate prefix hit rate across replicas.", agg.prefix_hit_rate());
        reg.gauge("puzzle_router_load_skew", "Max minus min of per-replica routed counts.", stats.load_skew() as f64);
        reg.counter("puzzle_router_generated_tokens_total", "Tokens generated across all replicas.", agg.generated_tokens as f64);
        reg.counter("puzzle_router_prefix_hits_total", "Prefix-cache hits across all replicas.", agg.prefix_hits as f64);
        reg.counter("puzzle_router_prefix_misses_total", "Prefix-cache misses across all replicas.", agg.prefix_misses as f64);
        reg.counter("puzzle_router_probe_rounds_total", "Placement probe rounds (one per submit attempt).", stats.probe_rounds as f64);
        reg.counter("puzzle_router_digest_refreshes_total", "Per-replica control-channel probes paid.", stats.digest_refreshes as f64);
        reg.counter("puzzle_router_digest_hits_total", "Per-replica probes served from the digest memo.", stats.digest_hits as f64);
        if self.cfg.tracer.enabled() {
            // fleet SLO monitor: fold every ring's finished requests into
            // rolling goodput / burn-rate gauges at scrape time
            let fleet = self.trace_fleet()?;
            reg.counter(
                "puzzle_trace_dropped_events",
                "Trace events dropped fleet-wide (ring capacity exceeded).",
                fleet.dropped() as f64,
            );
            let logs: Vec<_> = std::iter::once(&fleet.router).chain(fleet.replicas.iter()).collect();
            let records = crate::obs::slo::fold_requests(&logs);
            let profiles = crate::obs::slo::burn_profiles(self.cfg.tracer.is_virtual());
            let rates =
                crate::obs::slo::burn_rates(&records, &profiles, self.cfg.tracer.now_us());
            crate::obs::slo::register_gauges(&mut reg, &rates);
        }
        for (i, (s, m)) in stats.replicas.iter().zip(&metrics).enumerate() {
            let mut section = MetricsRegistry::new();
            let name = |field: &str| format!("puzzle_router_replica_{i}_{field}");
            section.counter(&name("routed_total"), "Requests accepted by this replica.", stats.routed[i] as f64);
            section.gauge(&name("depth"), "In-flight requests (active + queued).", (s.active + s.queued) as f64);
            section.gauge(&name("kv_allocated_bytes"), "Paged KV bytes currently allocated.", s.kv_allocated_bytes as f64);
            section.gauge(&name("prefix_segments"), "Retained prefix segments held.", s.prefix_segments as f64);
            section.counter(&name("prefix_hits_total"), "Prefix-cache hits on this replica.", m.prefix_hits as f64);
            section.counter(&name("generated_tokens_total"), "Tokens generated by this replica.", m.generated_tokens as f64);
            reg.merge(section);
        }
        Ok(reg.render())
    }
}

impl Frontend for RouterHandle {
    fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        RouterHandle::submit(self, req)
    }

    fn cancel(&self, id: u64) {
        RouterHandle::cancel(self, id)
    }
}
