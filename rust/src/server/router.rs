//! Data-parallel multi-engine router: N `AsyncServer` replicas behind
//! one cloneable handle, with cache-aware placement and prefix
//! migration (DESIGN.md §12).
//!
//! A [`Router`] owns N worker threads (one [`super::AsyncServer`] each)
//! and hands out [`RouterHandle`] clones with the same
//! `submit -> TokenStream` / `cancel` surface as a single-engine
//! [`super::ServerHandle`] — client code cannot tell one replica from
//! eight. Per submit the handle probes every replica over its control
//! channel (`Ctl::Probe`: longest retained prefix match + load counters,
//! snapshotted between engine steps), places the request with
//! [`super::placement::choose`], and — when the best-matching replica is
//! overloaded — first *migrates* the retained segment to the chosen
//! replica (`Ctl::ExportPrefix` → `Ctl::ImportPrefix`, cloned host rows,
//! refcount-correct on both ends), so hot system prompts follow load.
//!
//! Placement never steers sampling: every request's RNG stream is seeded
//! per-request, and a prefix hit is byte-identical to a cold prefill by
//! the cache's core invariant — so routed outputs equal a single-engine
//! run token-for-token, which `tests/router_integration.rs` and the
//! `bench-router` CI gate both assert.
//!
//! Request ids are globally unique across replicas: replica `i`'s engine
//! starts its id counter at `i << 48` (`Engine::set_request_id_base`), so
//! `RouterHandle::cancel` recovers the owning replica from the id alone.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::obs::MetricsRegistry;
use crate::serving::{Engine, EngineMetrics, GenRequest};
use crate::workload::report::load_skew;

use super::handle::Frontend;
use super::placement::{choose, ReplicaProbe};
use super::{AsyncServer, ServerHandle, ServerStats, TokenStream};

/// Bits reserved for the per-replica request-id base: replica `i` issues
/// ids in `[i << REPLICA_SHIFT, (i + 1) << REPLICA_SHIFT)`.
pub const REPLICA_SHIFT: u32 = 48;

/// Router tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// In-flight depth (active + queued) at which a replica's prefix
    /// match no longer pins placement: the request goes to the best
    /// non-overloaded replica instead, and the segment migrates along.
    pub overload: usize,
    /// Minimum match length (tokens) worth migrating; shorter matches
    /// just re-prefill at the destination.
    pub min_migrate: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig { overload: 4, min_migrate: 1 }
    }
}

/// Router-level counters shared by every handle clone (atomics: handles
/// bump them from many client threads).
#[derive(Debug, Default)]
struct RouterShared {
    /// Requests accepted per replica, indexed by replica id.
    routed: Vec<AtomicU64>,
    /// Cross-replica prefix migrations performed.
    migrations: AtomicU64,
    /// Tokens of retained prefix moved by those migrations.
    migrated_tokens: AtomicU64,
    /// Requests shed at the router's door (every replica full).
    shed: AtomicU64,
}

/// Point-in-time router counters plus each replica's [`ServerStats`]
/// (`RouterHandle::stats`).
#[derive(Debug, Clone)]
pub struct RouterStats {
    /// Per-replica occupancy, indexed by replica id.
    pub replicas: Vec<ServerStats>,
    /// Requests accepted per replica, indexed by replica id.
    pub routed: Vec<u64>,
    /// Cross-replica prefix migrations performed.
    pub migrations: u64,
    /// Tokens of retained prefix moved by those migrations.
    pub migrated_tokens: u64,
    /// Requests shed at the router's door (every replica full).
    pub shed: u64,
}

impl RouterStats {
    /// Requests accepted across all replicas.
    pub fn total_routed(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Routing imbalance: max − min of the per-replica routed counts (0
    /// for a perfectly balanced fleet — the `bench-router` skew gauge).
    pub fn load_skew(&self) -> u64 {
        load_skew(&self.routed)
    }
}

/// N engine replicas behind one routing front door. Spawn with
/// [`Router::spawn`], hand out [`RouterHandle`]s via
/// [`Router::handle`], and call [`Router::shutdown`] to get the engines
/// (and their metrics) back.
pub struct Router {
    replicas: Vec<AsyncServer>,
    shared: Arc<RouterShared>,
    cfg: RouterConfig,
}

impl Router {
    /// Move each engine onto its own worker thread and start routing.
    /// Each engine's request-id counter is rebased to `i << 48` first so
    /// ids are globally unique (see the module docs).
    ///
    /// # Panics
    /// With an empty engine list — a router needs at least one replica.
    pub fn spawn(engines: Vec<Engine>, cfg: RouterConfig) -> Router {
        assert!(!engines.is_empty(), "Router::spawn needs at least one engine");
        let replicas: Vec<AsyncServer> = engines
            .into_iter()
            .enumerate()
            .map(|(i, mut eng)| {
                eng.set_request_id_base((i as u64) << REPLICA_SHIFT);
                AsyncServer::spawn(eng)
            })
            .collect();
        let shared = Arc::new(RouterShared {
            routed: (0..replicas.len()).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        });
        Router { replicas, shared, cfg }
    }

    /// Number of replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// A new routing handle (cheap to clone, safe to move across
    /// threads; all clones share the router counters).
    pub fn handle(&self) -> RouterHandle {
        RouterHandle {
            replicas: self.replicas.iter().map(|r| r.handle()).collect(),
            shared: self.shared.clone(),
            cfg: self.cfg,
        }
    }

    /// Stop every worker and return the engines in replica order (with
    /// their accumulated metrics). In-flight requests are torn down.
    pub fn shutdown(self) -> Vec<Engine> {
        self.replicas.into_iter().map(|r| r.shutdown()).collect()
    }
}

/// A client's connection to the router — same surface as
/// [`ServerHandle`], with placement in between. Clone one per client
/// thread.
#[derive(Clone)]
pub struct RouterHandle {
    replicas: Vec<ServerHandle>,
    shared: Arc<RouterShared>,
    cfg: RouterConfig,
}

impl RouterHandle {
    /// Probe every replica for this prompt (a dead replica reports as
    /// full so placement routes around it).
    fn probe_all(&self, prompt: &[u32]) -> Vec<ReplicaProbe> {
        self.replicas
            .iter()
            .map(|h| {
                h.probe(prompt).unwrap_or(ReplicaProbe {
                    match_len: 0,
                    active: 0,
                    queued: 0,
                    full: true,
                })
            })
            .collect()
    }

    /// Route a request: probe, place, migrate if the placement asks for
    /// it, then submit — falling back through the remaining candidates
    /// if a submit races to full. `Err` only when every replica refuses
    /// (router-level shed) or the fleet is shut down.
    pub fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        let probes = self.probe_all(&req.prompt);
        let Some(placement) = choose(&probes, self.cfg.overload) else {
            self.shared.shed.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(
                "router: all {} replicas are full, request shed",
                self.replicas.len()
            ));
        };
        let target = placement.target();
        if let Some(src) = placement.migrate_from {
            if probes[src].match_len >= self.cfg.min_migrate {
                self.migrate(src, target, &req.prompt);
            }
        }
        let mut last_err = anyhow!("router has no replicas");
        for &r in &placement.order {
            match self.replicas[r].submit(req.clone()) {
                Ok(stream) => {
                    self.shared.routed[r].fetch_add(1, Ordering::Relaxed);
                    return Ok(stream);
                }
                // raced to full (or this replica just shut down): try the
                // next-best candidate before giving up
                Err(e) => last_err = e,
            }
        }
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
        Err(last_err)
    }

    /// Move the retained prefix matching `prompt` from replica `src` to
    /// replica `dst`, best-effort: the source clones the rows out
    /// (keeping its own copy and refcounts untouched), the destination
    /// re-retains them under its own budgets and segment ids. Counted
    /// only when the destination actually adopts.
    fn migrate(&self, src: usize, dst: usize, prompt: &[u32]) {
        let Ok(Some(prefix)) = self.replicas[src].export_prefix(prompt) else { return };
        let tokens = prefix.seg.len as u64;
        if self.replicas[dst].import_prefix(prefix).unwrap_or(false) {
            self.shared.migrations.fetch_add(1, Ordering::Relaxed);
            self.shared.migrated_tokens.fetch_add(tokens, Ordering::Relaxed);
        }
    }

    /// Cancel a request by id, routed to the owning replica via the id's
    /// replica bits (fire-and-forget; unknown ids are ignored).
    pub fn cancel(&self, id: u64) {
        if let Some(h) = self.replicas.get((id >> REPLICA_SHIFT) as usize) {
            h.cancel(id);
        }
    }

    /// Number of replicas behind this handle.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Router counters plus every replica's occupancy snapshot.
    pub fn stats(&self) -> Result<RouterStats> {
        let replicas =
            self.replicas.iter().map(|h| h.stats()).collect::<Result<Vec<_>>>()?;
        Ok(RouterStats {
            replicas,
            routed: self.shared.routed.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            migrations: self.shared.migrations.load(Ordering::Relaxed),
            migrated_tokens: self.shared.migrated_tokens.load(Ordering::Relaxed),
            shed: self.shared.shed.load(Ordering::Relaxed),
        })
    }

    /// Every replica's metrics snapshot, in replica order.
    pub fn metrics(&self) -> Result<Vec<EngineMetrics>> {
        self.replicas.iter().map(|h| h.metrics()).collect()
    }

    /// Fleet-wide counter rollup: every replica's counters folded into
    /// one snapshot via [`EngineMetrics::absorb`] (latency series stay
    /// per-replica — reservoirs do not compose).
    pub fn aggregate_metrics(&self) -> Result<EngineMetrics> {
        let mut agg = EngineMetrics::default();
        for m in self.metrics()? {
            agg.absorb(&m);
        }
        Ok(agg)
    }

    /// The router's scrape payload: fleet-level counters and gauges
    /// (routed/migrated/shed totals, aggregate prefix hit rate, load
    /// skew), then a namespaced `puzzle_router_replica_<i>_*` section
    /// per replica — all merged into one Prometheus text exposition.
    /// For a single replica's full engine registry (histograms
    /// included), scrape that replica's own `metrics_text` instead.
    pub fn metrics_text(&self) -> Result<String> {
        let stats = self.stats()?;
        let metrics = self.metrics()?;
        let agg = {
            let mut agg = EngineMetrics::default();
            for m in &metrics {
                agg.absorb(m);
            }
            agg
        };
        let mut reg = MetricsRegistry::new();
        reg.gauge("puzzle_router_replicas", "Engine replicas behind the router.", self.replicas.len() as f64);
        reg.counter("puzzle_router_routed_total", "Requests accepted across all replicas.", stats.total_routed() as f64);
        reg.counter("puzzle_router_migrations_total", "Cross-replica prefix migrations performed.", stats.migrations as f64);
        reg.counter("puzzle_router_migrated_tokens_total", "Tokens of retained prefix moved by migrations.", stats.migrated_tokens as f64);
        reg.counter("puzzle_router_shed_total", "Requests shed with every replica full.", stats.shed as f64);
        reg.gauge("puzzle_router_prefix_hit_rate", "Aggregate prefix hit rate across replicas.", agg.prefix_hit_rate());
        reg.gauge("puzzle_router_load_skew", "Max minus min of per-replica routed counts.", stats.load_skew() as f64);
        reg.counter("puzzle_router_generated_tokens_total", "Tokens generated across all replicas.", agg.generated_tokens as f64);
        reg.counter("puzzle_router_prefix_hits_total", "Prefix-cache hits across all replicas.", agg.prefix_hits as f64);
        reg.counter("puzzle_router_prefix_misses_total", "Prefix-cache misses across all replicas.", agg.prefix_misses as f64);
        for (i, (s, m)) in stats.replicas.iter().zip(&metrics).enumerate() {
            let mut section = MetricsRegistry::new();
            let name = |field: &str| format!("puzzle_router_replica_{i}_{field}");
            section.counter(&name("routed_total"), "Requests accepted by this replica.", stats.routed[i] as f64);
            section.gauge(&name("depth"), "In-flight requests (active + queued).", (s.active + s.queued) as f64);
            section.gauge(&name("kv_allocated_bytes"), "Paged KV bytes currently allocated.", s.kv_allocated_bytes as f64);
            section.gauge(&name("prefix_segments"), "Retained prefix segments held.", s.prefix_segments as f64);
            section.counter(&name("prefix_hits_total"), "Prefix-cache hits on this replica.", m.prefix_hits as f64);
            section.counter(&name("generated_tokens_total"), "Tokens generated by this replica.", m.generated_tokens as f64);
            reg.merge(section);
        }
        Ok(reg.render())
    }
}

impl Frontend for RouterHandle {
    fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        RouterHandle::submit(self, req)
    }

    fn cancel(&self, id: u64) {
        RouterHandle::cancel(self, id)
    }
}
