//! Cache-aware placement for the data-parallel router (DESIGN.md §12).
//!
//! Pure decision logic, separated from the channel plumbing so it can be
//! fuzzed against a naive model without threads: given one
//! [`ReplicaProbe`] per replica, [`choose`] picks where a request goes,
//! which replicas to fall back to if the pick sheds in a race, and
//! whether a retained prefix should migrate first.
//!
//! The rule extends the `PrefixAffinity` scheduler's ranking across
//! engines: prefer the replica with the **longest retained prefix match**
//! for the prompt, break ties by the **shallowest queue** (active +
//! queued), then by the lowest replica index so equal states place
//! deterministically. A replica whose match would win but whose depth has
//! reached the overload threshold loses the pick to the best
//! non-overloaded replica — and because that replica has a shorter (often
//! zero) match, the router *migrates* the hot segment to it
//! (`migrate_from`), so cache affinity follows load instead of pinning
//! it. Shedding happens at the router's door only when **every** replica
//! reports a full admission queue.

/// One replica's answer to a placement probe, snapshotted between engine
/// steps (so the counters are mutually consistent).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaProbe {
    /// Longest retained prefix match for the probed prompt, tokens
    /// (page-aligned; 0 with the cache off or no match).
    pub match_len: usize,
    /// Sequences currently holding a decode slot.
    pub active: usize,
    /// Requests waiting in the admission queue.
    pub queued: usize,
    /// Would a submit be shed at the door right now?
    pub full: bool,
}

impl ReplicaProbe {
    /// In-flight requests: active + queued — the placement tie-breaker
    /// and the overload measure.
    pub fn depth(&self) -> usize {
        self.active + self.queued
    }
}

/// A placement decision from [`choose`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Every non-full replica in submission order: the chosen target
    /// first, then the remaining candidates by rank — the router walks
    /// this list if a submit races to full.
    pub order: Vec<usize>,
    /// Migrate the retained prefix from this replica to the target before
    /// submitting (`None`: the target already holds the best match, or no
    /// replica has one worth moving).
    pub migrate_from: Option<usize>,
}

impl Placement {
    /// The chosen replica.
    pub fn target(&self) -> usize {
        self.order[0]
    }

    /// Classify why the request landed on `landed` — the `reason` field
    /// of the router's `routed` trace record: `fallback` (a submit race
    /// pushed it past the target down the order walk), `spill` (the best
    /// match was overloaded, so the segment migrates to the pick),
    /// `affinity` (the pick holds a prefix match), or `load` (cold pick
    /// by queue depth alone).
    pub fn reason(&self, probes: &[ReplicaProbe], landed: usize) -> &'static str {
        if landed != self.target() {
            "fallback"
        } else if self.migrate_from.is_some() {
            "spill"
        } else if probes.get(landed).is_some_and(|p| p.match_len > 0) {
            "affinity"
        } else {
            "load"
        }
    }
}

/// Pick a replica for a request probed as `probes` (one entry per
/// replica, indexed by replica id).
///
/// * `None` iff every replica is full — the shed-at-the-door rule.
/// * Otherwise candidates are ranked by `(match_len, -depth, -index)`
///   descending; the target is the best-ranked candidate whose depth is
///   below `overload`, falling back to the overall best-ranked candidate
///   when everyone is at or past it (equal misery: affinity wins again).
/// * `migrate_from` points at the replica with the longest match overall
///   (lowest index on ties) whenever that beats the target's own match —
///   full replicas included, since exporting reads the source without
///   touching its queue.
pub fn choose(probes: &[ReplicaProbe], overload: usize) -> Option<Placement> {
    let mut order: Vec<usize> = (0..probes.len()).filter(|&i| !probes[i].full).collect();
    if order.is_empty() {
        return None;
    }
    // descending by (match_len, Reverse(depth), Reverse(index)): longest
    // match first, then shallowest queue, then lowest index — the
    // PrefixAffinity ranking, extended across replicas
    order.sort_by(|&a, &b| {
        let key = |i: usize| {
            (probes[i].match_len, std::cmp::Reverse(probes[i].depth()), std::cmp::Reverse(i))
        };
        key(b).cmp(&key(a))
    });
    if let Some(pos) = order.iter().position(|&i| probes[i].depth() < overload) {
        // hoist the best non-overloaded candidate to the front; the ranks
        // behind it keep their relative order as the fallback chain
        let target = order.remove(pos);
        order.insert(0, target);
    }
    let target = order[0];
    let best = (0..probes.len())
        .max_by_key(|&i| (probes[i].match_len, std::cmp::Reverse(i)))
        .expect("order is non-empty, so probes is too");
    let migrate_from = (probes[best].match_len > probes[target].match_len).then_some(best);
    Some(Placement { order, migrate_from })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(match_len: usize, depth: usize, full: bool) -> ReplicaProbe {
        ReplicaProbe { match_len, active: depth, queued: 0, full }
    }

    #[test]
    fn longest_match_wins_then_depth_then_index() {
        let probes = vec![probe(0, 0, false), probe(8, 2, false), probe(8, 1, false)];
        let p = choose(&probes, usize::MAX).unwrap();
        assert_eq!(p.target(), 2, "equal match: shallower queue wins");
        assert_eq!(p.migrate_from, None, "target already holds the best match");
        let probes = vec![probe(4, 3, false), probe(0, 0, false)];
        assert_eq!(choose(&probes, usize::MAX).unwrap().target(), 0, "match beats depth");
        let probes = vec![probe(0, 1, false), probe(0, 1, false)];
        assert_eq!(choose(&probes, usize::MAX).unwrap().target(), 0, "ties break low-index");
    }

    #[test]
    fn sheds_iff_all_full() {
        assert!(choose(&[probe(9, 0, true), probe(0, 0, true)], usize::MAX).is_none());
        let p = choose(&[probe(9, 0, true), probe(0, 5, false)], usize::MAX).unwrap();
        assert_eq!(p.order, vec![1], "full replicas never appear in the order");
        assert_eq!(p.migrate_from, Some(0), "a full replica can still be a migration source");
        assert!(choose(&[], usize::MAX).is_none(), "no replicas means nowhere to place");
    }

    #[test]
    fn overloaded_best_match_loses_pick_and_becomes_migration_source() {
        // replica 0 holds the hot prefix but is at the overload threshold;
        // replica 1 is idle and cold
        let probes = vec![probe(8, 2, false), probe(0, 0, false)];
        let p = choose(&probes, 2).unwrap();
        assert_eq!(p.target(), 1);
        assert_eq!(p.migrate_from, Some(0), "the hot segment follows the request");
        assert_eq!(p.order, vec![1, 0], "the loser stays in the fallback chain");
        // below the threshold, affinity holds the pick
        let p = choose(&probes, 3).unwrap();
        assert_eq!((p.target(), p.migrate_from), (0, None));
        // everyone overloaded: affinity wins again (equal misery)
        let probes = vec![probe(8, 4, false), probe(0, 4, false)];
        let p = choose(&probes, 2).unwrap();
        assert_eq!((p.target(), p.migrate_from), (0, None));
    }

    #[test]
    fn reason_classification_covers_the_four_outcomes() {
        // affinity: the pick holds the best match
        let probes = vec![probe(8, 0, false), probe(0, 0, false)];
        let p = choose(&probes, usize::MAX).unwrap();
        assert_eq!(p.reason(&probes, p.target()), "affinity");
        // load: everyone cold, pick by depth
        let probes = vec![probe(0, 2, false), probe(0, 0, false)];
        let p = choose(&probes, usize::MAX).unwrap();
        assert_eq!(p.reason(&probes, p.target()), "load");
        // spill: best match overloaded, segment follows the request
        let probes = vec![probe(8, 2, false), probe(0, 0, false)];
        let p = choose(&probes, 2).unwrap();
        assert_eq!(p.migrate_from, Some(0));
        assert_eq!(p.reason(&probes, p.target()), "spill");
        // fallback: landed past the target in the order walk
        assert_eq!(p.reason(&probes, 0), "fallback");
    }

    #[test]
    fn order_is_a_permutation_of_the_non_full_replicas() {
        let probes =
            vec![probe(2, 1, false), probe(0, 0, true), probe(6, 3, false), probe(0, 0, false)];
        // overload 1: replicas 0 (depth 1) and 2 (depth 3) are at or past
        // it, so the only idle replica is hoisted from the back of the
        // rank order (2, 0, 3)
        let p = choose(&probes, 1).unwrap();
        let mut sorted = p.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 2, 3]);
        assert_eq!(p.target(), 3, "best non-overloaded candidate (rank: 2, 0, 3 → 3 hoisted)");
        assert_eq!(p.order, vec![3, 2, 0], "the overloaded ranks keep their order behind it");
        // overload 2 lets replica 0's depth-1 queue back in: it outranks
        // the idle replica 3 on match length
        assert_eq!(choose(&probes, 2).unwrap().target(), 0);
    }

    /// The PR 6-style property fuzz: drive 5 seeds × 300 random
    /// submit/finish/cancel ops through a naive model router (a `Vec` of
    /// replica states with explicit depth counters and retained paths)
    /// and assert, on every submit, that [`choose`] picks exactly the
    /// replica maximizing `(match_len, -queue_depth)` (lowest index on
    /// ties) and sheds iff every replica is full.
    #[test]
    fn placement_matches_naive_model_under_fuzz() {
        const REPLICAS: usize = 4;
        const CAP: usize = 3; // model max_queue: full iff depth >= CAP
        const PAGE: usize = 2;
        for fuzz_seed in 0..5u64 {
            let mut rng = crate::util::Rng::new(0x907e_12 ^ fuzz_seed);
            // naive model: per replica, (retained paths, depth)
            let mut retained: Vec<Vec<Vec<u32>>> = vec![Vec::new(); REPLICAS];
            let mut depth = [0usize; REPLICAS];
            // in-flight (replica, prompt) pairs for finish/cancel ops
            let mut inflight: Vec<(usize, Vec<u32>)> = Vec::new();
            let mut placed = 0usize;
            for _ in 0..300 {
                let op = rng.below(10);
                if op < 5 {
                    // submit: half the time extend a retained path so
                    // non-trivial matches actually occur
                    let mut prompt: Vec<u32> = Vec::new();
                    if rng.below(2) == 0 {
                        let r = rng.below(REPLICAS);
                        if !retained[r].is_empty() {
                            prompt = retained[r][rng.below(retained[r].len())].clone();
                        }
                    }
                    while prompt.len() < 2 || rng.below(3) > 0 {
                        prompt.push(rng.below(3) as u32);
                        if prompt.len() >= 8 {
                            break;
                        }
                    }
                    // the model's probes: longest retained path that
                    // prefixes the prompt (page-aligned paths, capped at
                    // prompt.len() - 1 like the radix cache)
                    let probes: Vec<ReplicaProbe> = (0..REPLICAS)
                        .map(|r| {
                            let match_len = retained[r]
                                .iter()
                                .filter(|q| q.len() < prompt.len() && prompt.starts_with(q))
                                .map(|q| q.len())
                                .max()
                                .unwrap_or(0);
                            ReplicaProbe {
                                match_len,
                                active: depth[r].min(2),
                                queued: depth[r].saturating_sub(2),
                                full: depth[r] >= CAP,
                            }
                        })
                        .collect();
                    let decision = choose(&probes, usize::MAX);
                    // naive argmax over non-full replicas
                    let naive = (0..REPLICAS)
                        .filter(|&r| depth[r] < CAP)
                        .max_by_key(|&r| {
                            (probes[r].match_len, std::cmp::Reverse(depth[r]), std::cmp::Reverse(r))
                        });
                    match (decision, naive) {
                        (None, None) => {} // shed iff all full
                        (Some(p), Some(n)) => {
                            assert_eq!(
                                p.target(),
                                n,
                                "seed {fuzz_seed}: choose disagrees with the naive argmax \
                                 for probes {probes:?}"
                            );
                            depth[n] += 1;
                            inflight.push((n, prompt));
                            placed += 1;
                        }
                        (got, want) => panic!(
                            "seed {fuzz_seed}: shed disagreement (choose: {}, naive: {})",
                            got.is_some(),
                            want.is_some()
                        ),
                    }
                } else if !inflight.is_empty() {
                    // finish (retaining the path, like finish-time
                    // retention) or cancel (retaining nothing)
                    let i = rng.below(inflight.len());
                    let (r, prompt) = inflight.swap_remove(i);
                    depth[r] -= 1;
                    let aligned = (prompt.len() / PAGE) * PAGE;
                    if op < 8 && aligned > 0 && !retained[r].iter().any(|q| q.len() == aligned && prompt.starts_with(&q[..])) {
                        retained[r].push(prompt[..aligned].to_vec());
                    }
                }
            }
            assert!(placed > 50, "seed {fuzz_seed}: fuzz must actually place requests ({placed})");
        }
    }
}
