//! Client-side types of the async front-end: the cloneable
//! [`ServerHandle`], the per-request [`TokenStream`], and the control
//! messages they exchange with the worker thread.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use crate::obs::TraceLog;
use crate::serving::{EngineMetrics, FinishReason, GenRequest, MigratedPrefix};

use super::placement::ReplicaProbe;

/// One item of a request's token stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamItem {
    /// One generated token (teacher-forced prompt tokens are not echoed).
    Token(u32),
    /// Terminal state — sent exactly once, then the stream ends.
    Finished(FinishReason),
}

/// Point-in-time occupancy counters of the serving engine, fetched over
/// the control channel (`ServerHandle::stats`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    /// Sequences currently holding a decode slot.
    pub active: usize,
    /// Requests waiting in the admission queue.
    pub queued: usize,
    /// Bytes of the paged KV pool currently allocated.
    pub kv_allocated_bytes: usize,
    /// The share of allocated bytes held by retained prefix segments.
    pub prefix_retained_bytes: usize,
    /// Retained prefix segments currently held by the cache.
    pub prefix_segments: usize,
}

/// Control messages from handles to the worker (crate-internal).
pub(super) enum Ctl {
    /// Submit a request; the reply carries the id and the stream
    /// receiver, or the engine's rejection message.
    Submit {
        /// The request to enqueue.
        req: GenRequest,
        /// One-shot reply channel for this submission.
        reply: Sender<SubmitReply>,
    },
    /// Cancel a queued or running request (fire-and-forget).
    Cancel(u64),
    /// Fetch point-in-time occupancy counters.
    Stats(Sender<ServerStats>),
    /// Fetch a snapshot of the engine's accumulated metrics.
    Metrics(Sender<EngineMetrics>),
    /// Fetch a Prometheus text-format rendering of the metrics registry
    /// plus live occupancy gauges — the scrape endpoint's payload.
    MetricsText(Sender<String>),
    /// Placement probe: longest retained prefix match for a prompt plus
    /// load counters, answered between engine steps (router plumbing).
    /// The reply pairs the probe with the engine's prefix-cache digest
    /// (`Engine::prefix_generation`), so the router can cache the answer
    /// until the retained set changes.
    Probe {
        /// The prompt to probe the prefix cache with.
        prompt: Vec<u32>,
        /// One-shot reply channel for the probe result + digest.
        reply: Sender<(ReplicaProbe, u64)>,
    },
    /// Copy out the engine tracer's ring (empty when tracing is off) —
    /// fleet trace merging and the SLO monitor read replica rings this
    /// way, consistently between engine steps.
    TraceSnapshot(Sender<TraceLog>),
    /// Clone this engine's best retained match for a prompt out as a
    /// migration payload (`None`: cache off or no match).
    ExportPrefix {
        /// The prompt whose matched prefix should be exported.
        prompt: Vec<u32>,
        /// One-shot reply channel carrying the payload.
        reply: Sender<Option<MigratedPrefix>>,
    },
    /// Adopt a prefix exported from another engine (boxed: the rows are
    /// large and `Ctl` travels by value through the channel).
    ImportPrefix {
        /// The migration payload to adopt.
        prefix: Box<MigratedPrefix>,
        /// One-shot reply: was the segment retained locally?
        reply: Sender<bool>,
    },
    /// Stop the worker and hand the engine back to `shutdown`.
    Shutdown,
}

pub(super) type SubmitReply = std::result::Result<(u64, Receiver<StreamItem>), String>;

/// A client's connection to the [`super::AsyncServer`] worker. Clone one
/// per client thread; all clones feed the same engine.
#[derive(Clone)]
pub struct ServerHandle {
    ctl: Sender<Ctl>,
}

impl ServerHandle {
    pub(super) fn new(ctl: Sender<Ctl>) -> ServerHandle {
        ServerHandle { ctl }
    }

    /// Submit a request and get its token stream. Blocks only for the
    /// round-trip to the worker (one queue insertion), never for
    /// generation. Engine-side rejections — queue full (shedding),
    /// over-horizon prompts, zero budgets — come back as `Err` with the
    /// engine's message; the request then holds no server state.
    pub fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        let (reply, rx) = channel();
        self.ctl
            .send(Ctl::Submit { req, reply })
            .map_err(|_| anyhow!("server is shut down"))?;
        match rx.recv().map_err(|_| anyhow!("server dropped the submit reply"))? {
            Ok((id, stream)) => Ok(TokenStream { id, rx: stream, ctl: self.ctl.clone() }),
            Err(cause) => Err(anyhow!(cause)),
        }
    }

    /// Cancel a request by id (fire-and-forget; unknown ids are ignored).
    /// Its stream still receives `Finished(Cancelled)`.
    pub fn cancel(&self, id: u64) {
        let _ = self.ctl.send(Ctl::Cancel(id));
    }

    /// Point-in-time occupancy counters (blocks for one round-trip).
    pub fn stats(&self) -> Result<ServerStats> {
        let (reply, rx) = channel();
        self.ctl.send(Ctl::Stats(reply)).map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the stats reply"))
    }

    /// Snapshot of the engine's accumulated metrics (blocks for one
    /// round-trip).
    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (reply, rx) = channel();
        self.ctl.send(Ctl::Metrics(reply)).map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the metrics reply"))
    }

    /// Live scrape: the engine's metrics registry rendered in the
    /// Prometheus text exposition format, with point-in-time occupancy
    /// gauges (active lanes, queue depth, KV bytes) appended. Blocks for
    /// one round-trip; the snapshot is consistent — the worker renders it
    /// between engine steps.
    pub fn metrics_text(&self) -> Result<String> {
        let (reply, rx) = channel();
        self.ctl.send(Ctl::MetricsText(reply)).map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the metrics-text reply"))
    }

    /// Placement probe: the engine's longest retained prefix match for
    /// `prompt` (no LRU bump) plus its live load counters, in one
    /// consistent snapshot taken between engine steps. The router calls
    /// this on every replica per submit; also useful for tests.
    pub fn probe(&self, prompt: &[u32]) -> Result<ReplicaProbe> {
        self.probe_with_digest(prompt).map(|(p, _)| p)
    }

    /// [`ServerHandle::probe`] plus the engine's prefix-cache digest
    /// (`Engine::prefix_generation` at answer time). While two answers
    /// carry the same digest, the retained set did not change between
    /// them — the router's probe memo keys on exactly this.
    pub fn probe_with_digest(&self, prompt: &[u32]) -> Result<(ReplicaProbe, u64)> {
        let (reply, rx) = channel();
        self.ctl
            .send(Ctl::Probe { prompt: prompt.to_vec(), reply })
            .map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the probe reply"))
    }

    /// Copy out the engine tracer's ring (empty when tracing is off),
    /// consistently between engine steps. Fleet trace export and the SLO
    /// monitor read every replica through this.
    pub fn trace_snapshot(&self) -> Result<TraceLog> {
        let (reply, rx) = channel();
        self.ctl.send(Ctl::TraceSnapshot(reply)).map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the trace reply"))
    }

    /// Export this engine's best retained match for `prompt` as a
    /// migration payload (the engine keeps its own copy — see
    /// `Engine::export_prefix`). `Ok(None)`: cache off or no match.
    pub fn export_prefix(&self, prompt: &[u32]) -> Result<Option<MigratedPrefix>> {
        let (reply, rx) = channel();
        self.ctl
            .send(Ctl::ExportPrefix { prompt: prompt.to_vec(), reply })
            .map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the export reply"))
    }

    /// Hand a migration payload to this engine for adoption (see
    /// `Engine::adopt_prefix`). `Ok(false)`: declined — incompatible
    /// geometry, already covered, or no budget room; never an error.
    pub fn import_prefix(&self, prefix: MigratedPrefix) -> Result<bool> {
        let (reply, rx) = channel();
        self.ctl
            .send(Ctl::ImportPrefix { prefix: Box::new(prefix), reply })
            .map_err(|_| anyhow!("server is shut down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the import reply"))
    }
}

/// The client surface a wall-clock replay drives: anything that can
/// accept a request and hand back its [`TokenStream`]. Implemented by
/// [`ServerHandle`] (one engine) and `RouterHandle` (N replicas behind
/// cache-aware placement), so `workload::wallclock::replay_wall` replays
/// the same trace against either without caring which.
pub trait Frontend: Clone + Send {
    /// Submit a request, returning its stream; `Err` means shed (or shut
    /// down) with no server state held.
    fn submit(&self, req: GenRequest) -> Result<TokenStream>;

    /// Cancel a request by id (fire-and-forget; unknown ids ignored).
    fn cancel(&self, id: u64);
}

impl Frontend for ServerHandle {
    fn submit(&self, req: GenRequest) -> Result<TokenStream> {
        ServerHandle::submit(self, req)
    }

    fn cancel(&self, id: u64) {
        ServerHandle::cancel(self, id)
    }
}

/// The receiving end of one request's generation: tokens as they are
/// sampled, then exactly one [`StreamItem::Finished`]. Dropping the
/// stream mid-generation auto-cancels the request on the worker's next
/// token send.
pub struct TokenStream {
    id: u64,
    rx: Receiver<StreamItem>,
    ctl: Sender<Ctl>,
}

impl TokenStream {
    /// The engine-assigned request id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next item; `None` once the stream is finished (or
    /// the server died mid-request).
    pub fn recv(&self) -> Option<StreamItem> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll for the next item.
    pub fn try_recv(&self) -> Option<StreamItem> {
        self.rx.try_recv().ok()
    }

    /// Cancel this request. The stream still receives its
    /// `Finished(Cancelled)` terminal item (any tokens generated before
    /// the cancel lands are delivered first).
    pub fn cancel(&self) {
        let _ = self.ctl.send(Ctl::Cancel(self.id));
    }

    /// Drain the stream to completion: all generated tokens plus the
    /// finish reason (`None` if the server died before finishing).
    pub fn collect(self) -> (Vec<u32>, Option<FinishReason>) {
        let mut tokens = Vec::new();
        while let Some(item) = self.recv() {
            match item {
                StreamItem::Token(t) => tokens.push(t),
                StreamItem::Finished(reason) => return (tokens, Some(reason)),
            }
        }
        (tokens, None)
    }
}
